//! `cargo bench` target regenerating Fig 5 (adaptive polling microbench) on the simulated fabric.
//! harness = false (criterion is unavailable offline); prints the paper-
//! style table plus wall-clock regeneration time.

use rdmabox::experiments::{run_by_id, ExpCtx};

fn main() {
    let ctx = ExpCtx::quick();
    let t0 = std::time::Instant::now();
    let out = run_by_id("5", &ctx).expect("registered experiment");
    let dt = t0.elapsed();
    print!("{out}");
    println!("bench(fig05_adaptive_micro): regenerated in {:.2}s", dt.as_secs_f64());
}
