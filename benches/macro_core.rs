//! Macro-workload benchmarks: the paper's application workloads (KV
//! store, RFS/IOzone file streaming, ML training traces) run end-to-end
//! on the simulated RDMAbox stack (harness = false; criterion is
//! unavailable offline).
//!
//! Unlike `micro_core` — which measures wall-clock ns/iter of hot paths
//! — every number here is **virtual time** from the DES: throughput and
//! p99 latency are deterministic for a given code version, so the CI
//! gate catches any change to the modeled pipeline (batching, admission,
//! paging, striping), not machine noise.
//!
//! CI runs this in **smoke mode** on every push and uploads the JSON as
//! the macro perf trajectory:
//!
//! * `BENCH_SMOKE=1` — shrunk workloads (seconds, not minutes);
//! * `BENCH_JSON=path` — write machine-readable results (name, mean
//!   ns/op, per-op virtual-time p99, ops/s) to `path`.
//!
//! `tools/check_bench.py` gates the JSON against
//! `ci/bench_macro_baseline.json` (ops/s floors and `p99_ns` ceilings;
//! >25% regression fails the job).

use rdmabox::config::FabricConfig;
use rdmabox::coordinator::StackConfig;
use rdmabox::rfs::run_iozone_with_stats;
use rdmabox::workloads::kv::{mongodb, run_kv, voltdb, KvConfig, Mix};
use rdmabox::workloads::mltrace::{logreg, run_ml};

/// One measured workload, as written to `BENCH_JSON`.
struct BenchResult {
    name: &'static str,
    /// Operations the workload completed (KV ops, FUSE requests,
    /// records streamed, pages moved — per the bench's unit).
    iters: u64,
    /// Mean virtual ns per operation (`1e9 / ops_per_sec`).
    mean_ns: f64,
    /// p99 of the per-op virtual-time latency histogram. `None` for
    /// bandwidth-only entries; the JSON omits the field and the gate
    /// skips it.
    p99_ns: Option<f64>,
    /// Operations per virtual second (bytes/s for bandwidth entries).
    ops_per_sec: f64,
}

fn push_result(
    results: &mut Vec<BenchResult>,
    name: &'static str,
    iters: u64,
    ops_per_sec: f64,
    p99_ns: Option<u64>,
) {
    let mean_ns = if ops_per_sec > 0.0 {
        1e9 / ops_per_sec
    } else {
        0.0
    };
    let p99 = p99_ns.map(|p| p as f64);
    match p99 {
        Some(p) => println!(
            "{name:26} {iters:>9} ops  {mean_ns:>10.1} ns/op  ({ops_per_sec:>14.0} ops/s)  \
             p99 {p:>10.0} ns"
        ),
        None => println!(
            "{name:26} {iters:>9} ops  {mean_ns:>10.1} ns/op  ({ops_per_sec:>14.0} ops/s)"
        ),
    }
    results.push(BenchResult {
        name,
        iters,
        mean_ns,
        p99_ns: p99,
        ops_per_sec,
    });
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn write_json(path: &str, smoke: bool, results: &[BenchResult]) {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"benches\": [\n");
    for (i, r) in results.iter().enumerate() {
        let p99 = match r.p99_ns {
            Some(p) => format!("\"p99_ns\": {p:.1}, "),
            None => String::new(),
        };
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
             {}\"ops_per_sec\": {:.0}}}{}\n",
            r.name,
            r.iters,
            r.mean_ns,
            p99,
            r.ops_per_sec,
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}

fn main() {
    let smoke = env_flag("BENCH_SMOKE");
    println!(
        "== macro_core: paper workloads end-to-end (virtual time){} ==",
        if smoke { " (smoke mode)" } else { "" }
    );
    let cfg = FabricConfig::default();
    let stack = StackConfig::rdmabox(&cfg);
    let mut results: Vec<BenchResult> = Vec::new();

    // KV store (Fig 12 shape): Facebook ETC mix on the VoltDB profile
    // and the write-heavier SYS mix on MongoDB. Throughput is the
    // post-warmup application ops/s; p99 is per-op latency including
    // paging and remote I/O.
    for (name, profile, mix) in [
        ("kv_voltdb_etc", voltdb(), Mix::Etc),
        ("kv_mongodb_sys", mongodb(), Mix::Sys),
    ] {
        let mut kv = KvConfig::small(profile, mix);
        if smoke {
            kv.records = 50_000;
            kv.ops = 12_000;
        }
        let (_, stats) = run_kv(&cfg, &stack, kv);
        push_result(
            &mut results,
            name,
            stats.ops_done,
            stats.throughput(),
            Some(stats.op_lat.p99()),
        );
    }

    // RFS (Fig 14 shape): IOzone sequential write then read of one big
    // file through the FUSE pipeline, 4 nodes, 128 KB records. The two
    // bandwidth entries gate GB/s as bytes per virtual second; the
    // request entry gates the FUSE request rate and its p99.
    {
        let record_bytes: u64 = 128 << 10;
        let file_bytes: u64 = if smoke { 16 << 20 } else { 64 << 20 };
        let (w_gbs, r_gbs, stats) =
            run_iozone_with_stats(&cfg, &stack, 4, record_bytes, file_bytes);
        let records = file_bytes / record_bytes;
        push_result(&mut results, "rfs_iozone_write_bw", records, w_gbs * 1e9, None);
        push_result(&mut results, "rfs_iozone_read_bw", records, r_gbs * 1e9, None);
        push_result(
            &mut results,
            "rfs_fuse_requests",
            stats.ops_done,
            stats.throughput(),
            Some(stats.op_lat.p99()),
        );
    }

    // ML training (Fig 13 shape): logistic regression epochs with 25%
    // of the working set resident, paging the rest over the fabric.
    // Throughput is pages moved per virtual second; p99 is the page-in
    // read latency tail.
    {
        let mut profile = logreg();
        if smoke {
            profile.dataset_pages = 4_000;
            profile.state_pages = profile.state_pages.min(128);
            profile.epochs = 2;
        }
        let (t_ns, report) = run_ml(&cfg, &stack, profile, 0.25, 3);
        let pages = report.completed_reads + report.completed_writes;
        let pages_per_sec = if t_ns == 0 {
            0.0
        } else {
            pages as f64 * 1e9 / t_ns as f64
        };
        push_result(
            &mut results,
            "ml_logreg_pages",
            pages,
            pages_per_sec,
            Some(report.read_lat.p99()),
        );
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        if !path.is_empty() {
            write_json(&path, smoke, &results);
        }
    }
}
