//! Microbenchmarks of the coordinator hot paths (harness = false; criterion
//! is unavailable offline). These are the numbers the §Perf pass tracks:
//! merge-queue ops, batch planning, the full engine pipeline
//! (merge → batch → admit → poll-retire), the poller FSM, Zipfian
//! sampling, histogram recording, the CLOCK page cache, and raw DES event
//! throughput.
//!
//! CI runs this in **smoke mode** on every push and uploads the JSON as
//! the perf trajectory:
//!
//! * `BENCH_SMOKE=1` — ~20× fewer iterations (seconds, not minutes);
//! * `BENCH_JSON=path` — write machine-readable results (name, mean
//!   ns/iter, ops/s, p99 of per-block means) to `path`.
//!
//! `tools/check_bench.py` gates the JSON against `ci/bench_baseline.json`
//! (>25% regression fails the job).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use rdmabox::config::FabricConfig;
use rdmabox::coordinator::batching::{plan_into, BatchLimits, BatchMode, ChainSpan, PlanArena};
use rdmabox::coordinator::engine::{DrainOut, IoEngine, WcOut};
use rdmabox::coordinator::gossip::GossipDelta;
use rdmabox::coordinator::merge_queue::{MergeCheck, MergeQueue};
use rdmabox::coordinator::polling::{PollStep, PollerFsm, PollingMode};
use rdmabox::coordinator::{EngineSpec, StackConfig};
use rdmabox::fabric::sim::{run_pipeline, Driver, Sim};
use rdmabox::fabric::{AppIo, Dir, TenantId, Wc, WcStatus, WorkRequest};
use rdmabox::paging::cache::ClockCache;
use rdmabox::util::fxhash::FxHashMap;
use rdmabox::util::hist::Hist;
use rdmabox::util::rng::Pcg32;
use rdmabox::util::slab::Slab;
use rdmabox::util::zipf::ScrambledZipfian;

/// Counting allocator: every bench reports **allocations per op** in the
/// measured (post-warmup) phase, and `tools/check_bench.py` gates the
/// zero-allocation property of the engine's steady-state hot path
/// (`engine_pipeline_64ios_steady` must report `allocs_per_op == 0`).
/// Only allocation *events* are counted (alloc/realloc/alloc_zeroed);
/// frees are not, since the gated property is "touches the allocator",
/// not live-byte accounting.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One measured hot path, as written to `BENCH_JSON`.
struct BenchResult {
    name: &'static str,
    iters: u64,
    mean_ns: f64,
    /// p99 over per-block mean iteration times (64 blocks per bench) —
    /// the tail the trajectory watches, robust to scheduler noise.
    /// `None` for single-shot benches (DES end-to-end) that have no
    /// block samples; the JSON omits the field and the gate skips it.
    p99_block_ns: Option<f64>,
    ops_per_sec: f64,
    /// Allocator events per iteration in the measured phase (after
    /// warm-up). `None` for single-shot benches.
    allocs_per_op: Option<f64>,
    /// QoS fairness benches only: p99 *virtual-time* latency of the
    /// victim tenant's I/Os (deterministic — the drain loop advances
    /// virtual time by a fixed step per admission round), so the gate on
    /// it is machine-independent.
    victim_p99_ns: Option<f64>,
}

/// Blocks per bench for the p99-of-block-means tail estimate.
const BLOCKS: u64 = 64;

fn bench<F: FnMut() -> u64>(
    results: &mut Vec<BenchResult>,
    name: &'static str,
    iters: u64,
    mut f: F,
) {
    // warmup
    let mut sink = 0u64;
    for _ in 0..iters / 10 + 1 {
        sink = sink.wrapping_add(f());
    }
    let per_block = (iters / BLOCKS).max(1);
    let mut samples = Vec::with_capacity(BLOCKS as usize);
    let allocs_before = ALLOC_EVENTS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..BLOCKS {
        let b0 = Instant::now();
        for _ in 0..per_block {
            sink = sink.wrapping_add(f());
        }
        samples.push(b0.elapsed().as_nanos() as f64 / per_block as f64);
    }
    let done = BLOCKS * per_block;
    // the measurement loop itself is allocation-free (samples are
    // preallocated), so this diff is exactly f()'s allocator traffic
    let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - allocs_before;
    let allocs_per_op = allocs as f64 / done as f64;
    let mean = t0.elapsed().as_nanos() as f64 / done as f64;
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite sample"));
    let idx = ((samples.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
    let p99 = samples[idx.min(samples.len() - 1)];
    let ops = 1e9 / mean;
    println!(
        "{name:34} {done:>9} iters  {mean:>9.1} ns/iter  ({ops:>12.0} ops/s)  \
         p99/blk {p99:>9.1} ns  {allocs_per_op:>7.3} allocs/op  [sink {sink}]"
    );
    results.push(BenchResult {
        name,
        iters: done,
        mean_ns: mean,
        p99_block_ns: Some(p99),
        ops_per_sec: ops,
        allocs_per_op: Some(allocs_per_op),
        victim_p99_ns: None,
    });
}

/// Hog-vs-victim fairness probe: one iteration submits a 48-page hog
/// burst ahead of an 8-page victim burst (disjoint address regions, so
/// the comparison is pure drain policy), then drains to completion in
/// admission-window rounds of fixed virtual duration. Each victim I/O
/// records the virtual time of its retirement round; the caller-visible
/// `victim_p99_ns` is deterministic (no wall clock involved), so
/// `tools/check_bench.py` can gate DRR-vs-FIFO victim latency exactly.
fn qos_fairness(
    results: &mut Vec<BenchResult>,
    name: &'static str,
    iters: u64,
    spec: &EngineSpec,
    hog_tenant: TenantId,
) {
    const HOG_IOS: u64 = 48;
    const VICTIM_IOS: u64 = 8;
    const ROUND_NS: u64 = 1_000;
    let mut e = IoEngine::build(spec);
    let mut out = DrainOut::default();
    let mut wout = WcOut::default();
    let mut id = 0u64;
    let mut victim_hist = Hist::new();
    bench(results, name, iters, || {
        for i in 0..HOG_IOS {
            e.submit(io_t(id, (1u64 << 32) + i * 4096, hog_tenant));
            id += 1;
        }
        let victim_base = id;
        for i in 0..VICTIM_IOS {
            e.submit(io_t(id, i * 4096, 0));
            id += 1;
        }
        let mut now = 0u64;
        let mut retired = 0u64;
        loop {
            e.drain_all_into(now, &mut out);
            if out.wrs.is_empty() {
                break;
            }
            let chains = std::mem::take(&mut out.chains);
            for c in &chains {
                for wr in &mut out.wrs[c.start..c.end] {
                    let wc = Wc {
                        wr_id: wr.wr_id,
                        qp: c.qp,
                        op: wr.op,
                        len: wr.len,
                        app_ios: std::mem::take(&mut wr.app_ios),
                        status: WcStatus::Success,
                        tenant: wr.tenant,
                    };
                    e.on_wc_into(&wc, now, &mut wout);
                    for r in &wout.retired {
                        retired += 1;
                        if r.id >= victim_base {
                            victim_hist.record(now + ROUND_NS);
                        }
                    }
                }
            }
            out.chains = chains;
            now += ROUND_NS;
        }
        assert_eq!(retired, HOG_IOS + VICTIM_IOS, "exactly-once retirement");
        retired
    });
    let p99 = victim_hist.p99();
    let last = results.last_mut().expect("bench just pushed a result");
    last.victim_p99_ns = Some(p99 as f64);
    println!("{name:34} victim p99 {p99} ns (virtual rounds)");
}

fn io(id: u64, addr: u64) -> AppIo {
    io_t(id, addr, 0)
}

fn io_r(id: u64, addr: u64) -> AppIo {
    AppIo {
        dir: Dir::Read,
        ..io(id, addr)
    }
}

fn io_t(id: u64, addr: u64, tenant: TenantId) -> AppIo {
    AppIo {
        id,
        dir: Dir::Write,
        node: 0,
        addr,
        len: 4096,
        thread: 0,
        t_submit: 0,
        tenant,
    }
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn write_json(path: &str, smoke: bool, results: &[BenchResult]) {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"benches\": [\n");
    for (i, r) in results.iter().enumerate() {
        let p99 = match r.p99_block_ns {
            Some(p) => format!("\"p99_block_ns\": {p:.1}, "),
            None => String::new(),
        };
        let allocs = match r.allocs_per_op {
            Some(a) => format!("\"allocs_per_op\": {a:.4}, "),
            None => String::new(),
        };
        let victim = match r.victim_p99_ns {
            Some(v) => format!("\"victim_p99_ns\": {v:.1}, "),
            None => String::new(),
        };
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
             {}{}{}\"ops_per_sec\": {:.0}}}{}\n",
            r.name,
            r.iters,
            r.mean_ns,
            p99,
            allocs,
            victim,
            r.ops_per_sec,
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}

fn main() {
    let smoke = env_flag("BENCH_SMOKE");
    let scale = if smoke { 20 } else { 1 };
    let iters = |n: u64| (n / scale).max(BLOCKS);
    println!(
        "== micro_core: coordinator hot paths{} ==",
        if smoke { " (smoke mode)" } else { "" }
    );
    let mut results: Vec<BenchResult> = Vec::new();

    // merge queue push + drain in batches of 16
    {
        let mut q = MergeQueue::new();
        let mut next = 0u64;
        bench(&mut results, "merge_queue_push_drain16", iters(200_000), || {
            for _ in 0..16 {
                q.push(io(next, next * 4096));
                next += 1;
            }
            match q.merge_check(u64::MAX) {
                MergeCheck::Drained(v) => v.len() as u64,
                _ => 0,
            }
        });
    }

    // batch planning: 16 adjacent + 16 scattered, through the
    // zero-allocation `plan_into` path with reused buffers (the form
    // every production drain calls)
    {
        let lim = BatchLimits::default();
        let mut wr_id = 0u64;
        let mut ios: Vec<AppIo> = Vec::new();
        let mut wrs: Vec<WorkRequest> = Vec::new();
        let mut chains: Vec<ChainSpan> = Vec::new();
        let mut arena = PlanArena::default();
        bench(&mut results, "plan_hybrid_32ios", iters(100_000), || {
            ios.clear();
            ios.extend((0..16u64).map(|i| io(i, i * 4096)));
            ios.extend((0..16u64).map(|i| io(16 + i, (1000 + i * 7) << 20)));
            wrs.clear();
            chains.clear();
            let st = plan_into(
                BatchMode::Hybrid,
                &lim,
                &mut ios,
                &mut wr_id,
                &mut wrs,
                &mut chains,
                &mut arena,
            );
            chains.len() as u64 + st.wqes
        });
    }

    // the full engine pipeline: submit → merge → batch → admit → retire.
    // This is the merge/batch/poll hot path the CI perf trajectory gates.
    {
        let mut e = IoEngine::build(&EngineSpec::new(1).qps(4).window(Some(7 << 20)));
        let mut out = DrainOut::default();
        let mut id = 0u64;
        bench(&mut results, "engine_pipeline_16ios", iters(50_000), || {
            for _ in 0..16 {
                e.submit(io(id, (id % 4096) * 4096));
                id += 1;
            }
            e.drain_all_into(0, &mut out);
            let mut retired = 0u64;
            let chains = std::mem::take(&mut out.chains);
            for c in &chains {
                for wr in &out.wrs[c.start..c.end] {
                    let wc = Wc {
                        wr_id: wr.wr_id,
                        qp: c.qp,
                        op: wr.op,
                        len: wr.len,
                        app_ios: wr.app_ios.clone(),
                        status: WcStatus::Success,
                        tenant: wr.tenant,
                    };
                    retired += e.on_wc(&wc, 0).retired.len() as u64;
                }
            }
            out.chains = chains;
            retired
        });
    }

    // the allocation-gated steady-state pipeline (the tentpole number of
    // the zero-allocation hot path): 64 placed writes per iteration
    // through submit -> merge -> plan -> admit -> retire, with the
    // engine's slab ledgers, the merge queues' swap-buffer drain, the
    // planner arena, and caller-owned DrainOut/WcOut scratch. The
    // pinning-free MR cache is ON (cap = the 16 MiB working set), and
    // the gossip plane is ON (member 0 of 2, exchanging one full
    // anti-entropy round with a peer engine every iteration through a
    // reused delta), so both ride the gated cycle. Completion deadlines
    // are armed too (the per-WR enrollment/unlink on the intrusive
    // deadline list is part of every production cycle; at virtual time 0
    // they never expire). After warm-up this cycle must not touch the
    // allocator at all — `allocs_per_op == 0` is enforced by
    // ci/bench_baseline.json.
    {
        let mut e = IoEngine::build(
            &EngineSpec::new(1)
                .qps(4)
                .window(Some(7 << 20))
                .replicated(1)
                .stripe(1 << 20)
                .mr_cache(16 << 20)
                .deadlines(1_000_000, 2)
                .gossip(0, 2),
        );
        let mut peer = IoEngine::build(&EngineSpec::new(1).replicated(1).gossip(1, 2));
        let mut delta = GossipDelta::default();
        let mut out = DrainOut::default();
        let mut wout = WcOut::default();
        let mut id = 0u64;
        bench(&mut results, "engine_pipeline_64ios_steady", iters(20_000), || {
            for _ in 0..64 {
                e.submit(io(id, (id % 4096) * 4096));
                id += 1;
            }
            e.drain_all_into(0, &mut out);
            let mut retired = 0u64;
            // detach the chain list so the WR arena can be borrowed
            // mutably below (mem::take of a Vec allocates nothing)
            let chains = std::mem::take(&mut out.chains);
            for c in &chains {
                for wr in &mut out.wrs[c.start..c.end] {
                    let wc = Wc {
                        wr_id: wr.wr_id,
                        qp: c.qp,
                        op: wr.op,
                        len: wr.len,
                        // move the inline id list out of the arena
                        // (leaves an empty inline list behind): the
                        // whole WC round trip is allocation-free
                        app_ios: std::mem::take(&mut wr.app_ios),
                        status: WcStatus::Success,
                        tenant: wr.tenant,
                    };
                    e.on_wc_into(&wc, 0, &mut wout);
                    retired += wout.retired.len() as u64;
                }
            }
            out.chains = chains;
            // one anti-entropy round each way: the export refills the
            // reused delta in place, the absorb is pure ledger merging
            e.export_gossip_into(&mut delta);
            peer.absorb_gossip(&delta);
            peer.export_gossip_into(&mut delta);
            e.absorb_gossip(&delta);
            retired
        });
    }

    // the same steady-state cycle with two weighted tenants: the DRR
    // drain (per-round entitlements + per-lane deficit accounting) and
    // the per-tenant ledgers must not cost the zero-allocation property
    // — with the gossip plane ON here too, same shape as above.
    // ci/bench_baseline.json gates allocs_per_op == 0 here exactly like
    // the single-tenant pipeline above.
    {
        let mut e = IoEngine::build(
            &EngineSpec::new(1)
                .qps(4)
                .window(Some(7 << 20))
                .replicated(1)
                .stripe(1 << 20)
                .tenants(&[3, 1])
                // two disjoint 16 MiB tenant regions: cap covers both
                .mr_cache(32 << 20)
                .gossip(0, 2),
        );
        let mut peer = IoEngine::build(&EngineSpec::new(1).replicated(1).gossip(1, 2));
        let mut delta = GossipDelta::default();
        let mut out = DrainOut::default();
        let mut wout = WcOut::default();
        let mut id = 0u64;
        bench(
            &mut results,
            "engine_pipeline_64ios_2tenants_steady",
            iters(20_000),
            || {
                for _ in 0..64 {
                    // even ids: tenant 0, low region; odd ids: tenant 1,
                    // high region (disjoint, so lanes never contend for
                    // the same mergeable run)
                    let t = (id % 2) as usize;
                    let addr = ((t as u64) << 32) + (id % 4096) * 4096;
                    e.submit(io_t(id, addr, t));
                    id += 1;
                }
                e.drain_all_into(0, &mut out);
                let mut retired = 0u64;
                let chains = std::mem::take(&mut out.chains);
                for c in &chains {
                    for wr in &mut out.wrs[c.start..c.end] {
                        let wc = Wc {
                            wr_id: wr.wr_id,
                            qp: c.qp,
                            op: wr.op,
                            len: wr.len,
                            app_ios: std::mem::take(&mut wr.app_ios),
                            status: WcStatus::Success,
                            tenant: wr.tenant,
                        };
                        e.on_wc_into(&wc, 0, &mut wout);
                        retired += wout.retired.len() as u64;
                    }
                }
                out.chains = chains;
                e.export_gossip_into(&mut delta);
                peer.absorb_gossip(&delta);
                peer.export_gossip_into(&mut delta);
                e.absorb_gossip(&delta);
                retired
            },
        );
    }

    // the deadline-expiry hot path (the recovery layer's steady-state
    // number): one iteration submits 8 adjacent page reads — merged by
    // the planner into a single WR (max_sge 16) — whose completion is
    // never delivered. The deadline lapses, `service_timers` synthesizes
    // the timeout-WC through the same idempotent retirement path (window
    // released, all 8 subs failed over in place to the peer replica),
    // and the failover WR retires successfully. Stripe parity alternates
    // the primary node each iteration, so the timed-out QP always takes
    // a success before a third consecutive timeout could trip it into
    // `Error`, and `max_retries = 0` means no backoff-release timers are
    // ever armed: the whole expire → failover → retire cycle lives on
    // the intrusive deadline list and the slab ledgers.
    // ci/bench_baseline.json gates allocs_per_op == 0 here too.
    {
        const TIMEOUT: u64 = 10_000;
        const STRIPE: u64 = 1 << 20;
        let mut e = IoEngine::build(
            &EngineSpec::new(2)
                .window(Some(7 << 20))
                .replicated(2)
                .stripe(STRIPE)
                .deadlines(TIMEOUT, 0),
        );
        let mut out = DrainOut::default();
        let mut wout = WcOut::default();
        let mut id = 0u64;
        let mut it = 0u64;
        let mut now = 0u64;
        bench(&mut results, "recovery_timeout_retire", iters(20_000), || {
            let base = (it % 2) * STRIPE;
            it += 1;
            for i in 0..8u64 {
                e.submit(io_r(id, base + i * 4096));
                id += 1;
            }
            now += 1;
            e.drain_all_into(now, &mut out);
            // the primary leg is never delivered: lapse its deadline
            now += TIMEOUT + 1;
            e.service_timers(now, &mut wout);
            // the expiry re-queued every sub onto the peer replica;
            // drain the failover WR and deliver it successfully
            e.drain_all_into(now, &mut out);
            let mut retired = 0u64;
            let chains = std::mem::take(&mut out.chains);
            for c in &chains {
                for wr in &mut out.wrs[c.start..c.end] {
                    let wc = Wc {
                        wr_id: wr.wr_id,
                        qp: c.qp,
                        op: wr.op,
                        len: wr.len,
                        app_ios: std::mem::take(&mut wr.app_ios),
                        status: WcStatus::Success,
                        tenant: wr.tenant,
                    };
                    e.on_wc_into(&wc, now, &mut wout);
                    retired += wout.retired.len() as u64;
                }
            }
            out.chains = chains;
            assert_eq!(retired, 8, "every timed-out read failed over and retired");
            assert_eq!(e.qps_not_ok(), 0, "alternating parity keeps every QP Ok");
            retired
        });
        assert_eq!(e.stats.window_leaks, 0, "expiry path leaked admission bytes");
    }

    // the ledger ablation (kept in-tree so the slab's win stays
    // measured, not asserted): one op = retire + re-register one
    // in-flight record at steady depth 64 — the exact access pattern of
    // the engine's submit/retire ledgers. `submit_retire_slab` is the
    // generational-slab path (id encodes the slot: index + generation
    // check); `submit_retire_hashmap` is the FxHashMap path it replaced
    // (hash probe per lookup). ci/bench_baseline.json gates the slab at
    // >= 2x the hashmap's throughput.
    {
        const DEPTH: usize = 64;
        type Rec = [u64; 8]; // SubIo-sized payload
        let mut slab: Slab<Rec> = Slab::new();
        let mut ring = [0u64; DEPTH];
        for (i, slot) in ring.iter_mut().enumerate() {
            *slot = slab.insert([i as u64; 8]);
        }
        let mut pos = 0usize;
        bench(&mut results, "submit_retire_slab", iters(2_000_000), || {
            let v = slab.remove(ring[pos]).expect("live key");
            let k = slab.insert(v);
            ring[pos] = k;
            pos = (pos + 1) % DEPTH;
            k
        });

        let mut map: FxHashMap<u64, Rec> = FxHashMap::default();
        let mut ring = [0u64; DEPTH];
        let mut next_id = 0u64;
        for slot in ring.iter_mut() {
            map.insert(next_id, [next_id; 8]);
            *slot = next_id;
            next_id += 1;
        }
        let mut pos = 0usize;
        bench(&mut results, "submit_retire_hashmap", iters(2_000_000), || {
            let v = map.remove(&ring[pos]).expect("live key");
            next_id += 1;
            map.insert(next_id, v);
            ring[pos] = next_id;
            pos = (pos + 1) % DEPTH;
            next_id
        });
    }

    // resync repair-copy throughput (the ROADMAP's "resync copy
    // throughput" trajectory candidate): one iteration = a replica dies,
    // misses an 8-page write burst, revives, and the epoch-resync
    // protocol (with donor election enabled) drains its repair copies
    // through the pipeline back to Alive.
    {
        let mut e = IoEngine::build(
            &EngineSpec::new(2)
                .replicated(2)
                .stripe(1 << 20)
                .resync(4 * 4096)
                .election(),
        );
        let mut out = DrainOut::default();
        let mut id = 0u64;
        fn drain_complete(e: &mut IoEngine, out: &mut DrainOut) {
            loop {
                e.drain_all_into(0, out);
                if out.wrs.is_empty() {
                    break;
                }
                let chains = std::mem::take(&mut out.chains);
                for c in &chains {
                    for wr in &mut out.wrs[c.start..c.end] {
                        let wc = Wc {
                            wr_id: wr.wr_id,
                            qp: c.qp,
                            op: wr.op,
                            len: wr.len,
                            app_ios: std::mem::take(&mut wr.app_ios),
                            status: WcStatus::Success,
                            tenant: wr.tenant,
                        };
                        e.on_wc(&wc, 0);
                    }
                }
                out.chains = chains;
            }
        }
        bench(&mut results, "resync_repair_8pages", iters(20_000), || {
            let before = e.stats.resync_copies;
            e.on_node_down(0);
            for p in 0..8u64 {
                e.submit(io(id, p * 4096));
                id += 1;
                drain_complete(&mut e, &mut out);
            }
            e.on_node_up(0);
            drain_complete(&mut e, &mut out);
            debug_assert_eq!(e.resync_backlog(0), 0);
            e.stats.resync_copies - before
        });
    }

    // multi-tenant QoS fairness pair: the same hog-vs-victim workload
    // drained FIFO (single tenant — the pre-QoS behavior) and DRR
    // (victim weight 3, hog weight 1) through a tight admission window.
    // ci/bench_baseline.json gates (a) DRR aggregate throughput at
    // >= 0.9x FIFO from the same run, and (b) the DRR victim's virtual
    // p99 at a fraction of FIFO's — the isolation claim, measured.
    {
        let w = Some(8 * 4096u64);
        qos_fairness(
            &mut results,
            "qos_fairness_fifo",
            iters(20_000),
            &EngineSpec::new(1).window(w),
            0,
        );
        qos_fairness(
            &mut results,
            "qos_fairness_drr",
            iters(20_000),
            &EngineSpec::new(1).window(w).tenants(&[3, 1]),
            1,
        );
    }

    // poller FSM: one adaptive wake → burst-poll → retry → re-arm cycle
    {
        bench(&mut results, "poller_fsm_adaptive_cycle", iters(500_000), || {
            let mut fsm = PollerFsm::new(PollingMode::Adaptive {
                batch: 16,
                max_retry: 4,
            });
            let mut got = 0u64;
            let mut step = fsm.on_wake(0);
            loop {
                match step {
                    PollStep::Poll { max } => {
                        // first poll returns a burst, then the CQ is empty
                        let n = if got == 0 { max.min(16) } else { 0 };
                        got += n as u64;
                        step = fsm.after_poll(n, 0);
                    }
                    PollStep::Rearm => break,
                }
            }
            got
        });
    }

    // zipfian sampling
    {
        let z = ScrambledZipfian::new(10_000_000, 0.99);
        let mut rng = Pcg32::new(1);
        bench(&mut results, "zipf_sample_10m", iters(2_000_000), || {
            z.sample(&mut rng)
        });
    }

    // histogram record
    {
        let mut h = Hist::new();
        let mut rng = Pcg32::new(2);
        bench(&mut results, "hist_record", iters(2_000_000), || {
            let v = rng.gen_range(100, 10_000_000);
            h.record(v);
            h.count()
        });
    }

    // CLOCK cache access (hit-heavy)
    {
        let mut c = ClockCache::new(65_536);
        let mut rng = Pcg32::new(3);
        for p in 0..65_536u64 {
            c.access(p, false);
        }
        bench(&mut results, "clock_cache_access", iters(1_000_000), || {
            let p = rng.gen_below(72_000);
            match c.access(p, false) {
                rdmabox::paging::cache::Access::Hit => 1,
                _ => 0,
            }
        });
    }

    // dynamic MR cache (the pinning-free memory path) probe pair: one op
    // = one span touch. `mr_cache_hit` runs steady-state over a working
    // set inside the cap (every touch is a resident-span lkey lookup);
    // `mr_cache_miss` sweeps far past the cap (every touch lazily
    // registers, clock-evicts a victim, and churns the deferred-dereg
    // queue through its self-flush). ci/bench_baseline.json gates the
    // hit path at allocs_per_op == 0 and — same-run — at >= the miss
    // path's throughput: a cache whose hit is no cheaper than its miss
    // would be pure overhead.
    {
        use rdmabox::coordinator::mr_cache::{MrCache, MR_SPAN_BYTES};
        let mut hot = MrCache::new(16 << 20);
        let ws = 8u64 << 20;
        for addr in (0..ws).step_by(MR_SPAN_BYTES as usize) {
            hot.touch(addr, 4096);
        }
        let mut addr = 0u64;
        bench(&mut results, "mr_cache_hit", iters(2_000_000), || {
            let t = hot.touch(addr, 4096);
            addr = (addr + 4096) % ws;
            u64::from(t.hit_spans)
        });

        let mut cold = MrCache::new(16 * MR_SPAN_BYTES);
        let sweep_spans = 1024u64; // 64 MiB swept span-by-span: never resident
        let mut i = 0u64;
        bench(&mut results, "mr_cache_miss", iters(1_000_000), || {
            let t = cold.touch((i % sweep_spans) * MR_SPAN_BYTES, 4096);
            i += 1;
            u64::from(t.miss_spans)
        });
    }

    // end-to-end DES throughput: simulated IOs per wall second
    {
        struct Loop {
            left: u64,
            addr: u64,
        }
        impl Driver for Loop {
            fn on_start(&mut self, sim: &mut Sim) {
                for t in 0..8 {
                    sim.submit_at(Dir::Write, 0, (t as u64) << 24, 4096, t, 0);
                }
            }
            fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, _l: u64, at: u64) {
                if self.left == 0 {
                    sim.request_stop();
                    return;
                }
                self.left -= 1;
                self.addr += 4096;
                sim.submit_at(Dir::Write, 0, self.addr, 4096, io.thread, at);
            }
            fn on_timer(&mut self, _s: &mut Sim, _t: usize, _g: u64) {}
        }
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let n = if smoke { 30_000u64 } else { 300_000u64 };
        let t0 = Instant::now();
        let r = run_pipeline(&cfg, &stack, 1, Box::new(Loop { left: n, addr: 0 }));
        let dt = t0.elapsed().as_secs_f64();
        let ios_per_sec = r.completed_writes as f64 / dt;
        println!(
            "DES end-to-end: {} IOs in {:.2}s = {:.0} sim-IOs/s wall ({} WQEs)",
            r.completed_writes,
            dt,
            ios_per_sec,
            r.trace.wqes_total()
        );
        results.push(BenchResult {
            name: "des_end_to_end",
            iters: r.completed_writes,
            mean_ns: 1e9 / ios_per_sec,
            p99_block_ns: None, // single shot: no tail estimate
            ops_per_sec: ios_per_sec,
            allocs_per_op: None,
            victim_p99_ns: None,
        });
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        if !path.is_empty() {
            write_json(&path, smoke, &results);
        }
    }
}
