//! Microbenchmarks of the coordinator hot paths (harness = false; criterion
//! is unavailable offline). These are the numbers the §Perf pass tracks:
//! merge-queue ops, batch planning, Zipfian sampling, histogram recording,
//! the CLOCK page cache, and raw DES event throughput.

use std::time::Instant;

use rdmabox::config::FabricConfig;
use rdmabox::coordinator::batching::{plan, BatchLimits, BatchMode};
use rdmabox::coordinator::merge_queue::{MergeCheck, MergeQueue};
use rdmabox::coordinator::StackConfig;
use rdmabox::fabric::sim::{run_pipeline, Driver, Sim};
use rdmabox::fabric::{AppIo, Dir};
use rdmabox::paging::cache::ClockCache;
use rdmabox::util::hist::Hist;
use rdmabox::util::rng::Pcg32;
use rdmabox::util::zipf::ScrambledZipfian;

fn bench<F: FnMut() -> u64>(name: &str, iters: u64, mut f: F) {
    // warmup
    let mut sink = 0u64;
    for _ in 0..iters / 10 + 1 {
        sink = sink.wrapping_add(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let dt = t0.elapsed();
    let per = dt.as_nanos() as f64 / iters as f64;
    println!(
        "{name:38} {iters:>10} iters  {per:>9.1} ns/iter  ({:>12.0} ops/s)  [sink {sink}]",
        1e9 / per
    );
}

fn io(id: u64, addr: u64) -> AppIo {
    AppIo {
        id,
        dir: Dir::Write,
        node: 0,
        addr,
        len: 4096,
        thread: 0,
        t_submit: 0,
    }
}

fn main() {
    println!("== micro_core: coordinator hot paths ==");

    // merge queue push + drain in batches of 16
    {
        let mut q = MergeQueue::new();
        let mut next = 0u64;
        bench("merge_queue push+drain(16)", 200_000, || {
            for _ in 0..16 {
                q.push(io(next, next * 4096));
                next += 1;
            }
            match q.merge_check(u64::MAX) {
                MergeCheck::Drained(v) => v.len() as u64,
                _ => 0,
            }
        });
    }

    // batch planning: 16 adjacent + 16 scattered
    {
        let lim = BatchLimits::default();
        let mut wr_id = 0u64;
        bench("plan(hybrid, 32 ios)", 100_000, || {
            let mut ios: Vec<AppIo> = (0..16u64).map(|i| io(i, i * 4096)).collect();
            ios.extend((0..16u64).map(|i| io(16 + i, (1000 + i * 7) << 20)));
            let (chains, st) = plan(BatchMode::Hybrid, &lim, ios, &mut wr_id);
            chains.len() as u64 + st.wqes
        });
    }

    // zipfian sampling
    {
        let z = ScrambledZipfian::new(10_000_000, 0.99);
        let mut rng = Pcg32::new(1);
        bench("scrambled_zipf sample (10M keys)", 2_000_000, || {
            z.sample(&mut rng)
        });
    }

    // histogram record
    {
        let mut h = Hist::new();
        let mut rng = Pcg32::new(2);
        bench("hist record", 2_000_000, || {
            let v = rng.gen_range(100, 10_000_000);
            h.record(v);
            h.count()
        });
    }

    // CLOCK cache access (hit-heavy)
    {
        let mut c = ClockCache::new(65_536);
        let mut rng = Pcg32::new(3);
        for p in 0..65_536u64 {
            c.access(p, false);
        }
        bench("clock_cache access (90% hit)", 1_000_000, || {
            let p = rng.gen_below(72_000);
            match c.access(p, false) {
                rdmabox::paging::cache::Access::Hit => 1,
                _ => 0,
            }
        });
    }

    // end-to-end DES throughput: simulated IOs per wall second
    {
        struct Loop {
            left: u64,
            addr: u64,
        }
        impl Driver for Loop {
            fn on_start(&mut self, sim: &mut Sim) {
                for t in 0..8 {
                    sim.submit_at(Dir::Write, 0, (t as u64) << 24, 4096, t, 0);
                }
            }
            fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, _l: u64, at: u64) {
                if self.left == 0 {
                    sim.request_stop();
                    return;
                }
                self.left -= 1;
                self.addr += 4096;
                sim.submit_at(Dir::Write, 0, self.addr, 4096, io.thread, at);
            }
            fn on_timer(&mut self, _s: &mut Sim, _t: usize, _g: u64) {}
        }
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let n = 300_000u64;
        let t0 = Instant::now();
        let r = run_pipeline(&cfg, &stack, 1, Box::new(Loop { left: n, addr: 0 }));
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "DES end-to-end: {} IOs in {:.2}s = {:.0} sim-IOs/s wall ({} WQEs)",
            r.completed_writes,
            dt,
            r.completed_writes as f64 / dt,
            r.trace.wqes_total()
        );
    }
}
