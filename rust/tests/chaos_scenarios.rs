//! Chaos scenario suite: named regression seeds for each fault class plus
//! a randomized multi-seed sweep, all on the deterministic chaos fabric.
//!
//! Every scenario asserts the engine invariants (exactly-once retirement,
//! admission window never exceeded, no lost I/O, quiescence with a fully
//! released window) inside `run_scenario`; the tests here additionally
//! assert that the *intended* fault actually fired, so a refactor cannot
//! quietly neuter the harness.
//!
//! On failure the panic message contains a one-command reproducer (the
//! seed pinned), and the same command is written to
//! `target/chaos-repro.txt` for CI to upload:
//!
//! ```text
//! CHAOS_SEED=0x... cargo test --release --test chaos_scenarios replay_env_seed -- --nocapture
//! ```

use rdmabox::coordinator::node::NodeState;
use rdmabox::coordinator::EngineSpec;
use rdmabox::fabric::chaos::{
    rack_members, replay_command, run_scenario, ChaosFabric, ChaosProfile, FaultPlan, MultiChaos,
    MultiPlan, Scenario, ScenarioReport, PAGE_BYTES, RESYNC_CHUNK_BYTES, STRIPE_BYTES,
};
use rdmabox::fabric::Dir;

/// The 2-node × 1-QP × 2-replica spec the direct-fabric regressions
/// drive, with the resync pipeline (and optionally the donor election)
/// enabled on top of the plain replicated baseline.
fn paired_spec(resync: bool, election: bool) -> EngineSpec {
    let mut spec = EngineSpec::new(2).replicated(2);
    if resync || election {
        spec = spec.resync(RESYNC_CHUNK_BYTES);
    }
    if election {
        spec = spec.election();
    }
    spec
}

/// Default base of the randomized sweep when CI does not pin one.
const DEFAULT_SWEEP_BASE: u64 = 0x52D3_A201;
/// Default sweep width (the acceptance floor is 20 seeds; raised to 36
/// once the donor election + splitter + overlapping-divergence mixes
/// joined the sweep — CI runs 64, the nightly extended sweep 200).
const DEFAULT_SWEEP_N: u64 = 36;
/// Livelock guard for directly driven fabrics.
const STEPS: u64 = 4_000_000;

fn env_u64(name: &str) -> Option<u64> {
    let v = std::env::var(name).ok()?;
    let v = v.trim().to_string();
    let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => v.parse(),
    };
    match parsed {
        Ok(x) => Some(x),
        Err(_) => panic!("{name} must be a u64 (decimal or 0x-hex), got `{v}`"),
    }
}

/// Which randomized mix the sweep draws (`CHAOS_PROFILE=election`,
/// `CHAOS_PROFILE=qos`, `CHAOS_PROFILE=scale`, `CHAOS_PROFILE=multi`
/// and `CHAOS_PROFILE=recovery` are what the nightly `chaos-extended`
/// workflow sets; replay commands carry it).
fn env_profile() -> ChaosProfile {
    match std::env::var("CHAOS_PROFILE").ok().as_deref() {
        Some("election") => ChaosProfile::ElectionHeavy,
        Some("qos") => ChaosProfile::Qos,
        Some("scale") => ChaosProfile::Scale,
        Some("multi") => ChaosProfile::Multi,
        Some("recovery") => ChaosProfile::Recovery,
        Some("") | None => ChaosProfile::Standard,
        Some(other) => panic!(
            "CHAOS_PROFILE must be `election`, `qos`, `scale`, `multi`, `recovery`, \
             or unset, got `{other}`"
        ),
    }
}

fn write_repro(sc: &Scenario) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../target/chaos-repro.txt");
    if let Some(dir) = std::path::Path::new(path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let _ = std::fs::write(path, format!("{}\n", replay_command(sc)));
}

/// Run a scenario; on an invariant violation, persist the reproducer
/// command for CI and panic with it.
fn check(sc: &Scenario) -> ScenarioReport {
    match run_scenario(sc) {
        Ok(r) => r,
        Err(e) => {
            write_repro(sc);
            panic!("{e}");
        }
    }
}

// ---------------- named regression seeds ----------------

/// WCs overtake each other within a CQ; retirement order must not matter.
#[test]
fn wc_reordering() {
    let plan = FaultPlan::none().with_reordering(0.6, 40_000);
    let r = check(&Scenario::named("wc_reordering", 0x2E02DE2, plan));
    assert!(r.reordered_wcs > 0, "reordering never fired: {r:?}");
    assert_eq!(r.failovers, 0, "reordering alone must not fail over");
    assert_eq!(r.disk_fallbacks, 0);
}

/// The CQ replays completions; the wr_id ledger must absorb every replay.
#[test]
fn duplicate_completions() {
    let plan = FaultPlan::none().with_duplicates(0.8, 15_000);
    let r = check(&Scenario::named("duplicate_completions", 0xD0B1E, plan));
    assert!(r.duplicate_wcs > 0, "duplicates never fired: {r:?}");
    assert_eq!(r.failovers, 0);
    assert_eq!(r.disk_fallbacks, 0);
}

/// Completion errors on a replicated topology: reads must fail over to
/// the next alive replica instead of surfacing the error.
#[test]
fn completion_errors_with_read_failover() {
    let plan = FaultPlan::none().with_errors(0.3);
    let sc = Scenario::named("completion_errors_with_read_failover", 0xE2202, plan);
    let r = check(&sc);
    assert!(r.injected_errors > 0, "errors never fired: {r:?}");
    assert!(r.failovers > 0, "errors must drive failover: {r:?}");
}

/// A node dies mid-run while its QPs are stalled: everything posted to it
/// before the death is still in flight when it lands, so those WCs come
/// back as errors and reads *must* fail over; with two replicas and one
/// death no I/O may degrade to the disk path.
#[test]
fn node_death_mid_run() {
    // QPs 0 and 1 belong to node 0 on the named 3-node × 2-QP topology
    let plan = FaultPlan::none()
        .stall(0, 0, 60_000)
        .stall(1, 0, 60_000)
        .node_down(0, 30_000);
    let r = check(&Scenario::named("node_death_mid_run", 0xDEAD0, plan));
    assert_eq!(r.node_transitions, 1);
    assert!(r.failovers > 0, "no failover from the death: {r:?}");
    assert_eq!(r.disk_fallbacks, 0, "a replica survived: {r:?}");
    assert_eq!(r.disk_at_submit, 0);
}

/// Two QPs stall ("NIC cache thrash"): completions are delayed, never
/// lost, and the admission window stays bounded throughout the stall.
#[test]
fn per_qp_stall() {
    let plan = FaultPlan::none()
        .stall(0, 10_000, 150_000)
        .stall(3, 20_000, 120_000);
    let r = check(&Scenario::named("per_qp_stall", 0x57A11, plan));
    assert!(r.stalled_wcs > 0, "the stall never fired: {r:?}");
    assert_eq!(r.failovers, 0);
    assert_eq!(r.disk_fallbacks, 0);
}

/// Lazy-registration stalls (the pinning-free MR path's miss cost landing
/// on the critical path): first touches of unregistered spans delay their
/// WRs synchronously. Stalled requests are slow, never lost — the
/// admission window is checked continuously by the runner through every
/// stall, and the engine's own MR cache (attached on every named
/// scenario) counts the same first touches as misses.
#[test]
fn registration_stalls_never_leak_the_window() {
    let plan = FaultPlan::none().with_reg_stalls(0.8, 120_000);
    let r = check(&Scenario::named(
        "registration_stalls_never_leak_the_window",
        0x2E957A,
        plan,
    ));
    assert!(r.reg_stalled_wcs > 0, "the reg stall never fired: {r:?}");
    assert!(r.mr_misses > 0, "the engine cache saw the first touches: {r:?}");
    assert_eq!(r.failovers, 0, "a stall is slow, not broken: {r:?}");
    assert_eq!(r.disk_fallbacks, 0, "{r:?}");
    assert_eq!(r.stale_reads, 0);
    assert!(
        r.elapsed_virtual_ns >= 120_000,
        "stalled WRs must actually be delayed: {r:?}"
    );
}

/// Everything at once: errors, reordering, duplicates, a stall, and a
/// death+revival — the invariants hold under the full fault mix.
#[test]
fn combined_fault_mix() {
    let plan = FaultPlan::none()
        .with_errors(0.15)
        .with_reordering(0.4, 30_000)
        .with_duplicates(0.3, 10_000)
        .stall(2, 5_000, 90_000)
        .node_down(1, 40_000)
        .node_up(1, 140_000);
    let r = check(&Scenario::named("combined_fault_mix", 0xC0B0, plan));
    assert!(r.injected_errors > 0 && r.duplicate_wcs > 0, "{r:?}");
    assert_eq!(r.node_transitions, 2, "{r:?}");
}

/// A partial partition silently diverges one replica (its write legs
/// error while it stays nominally up): the engine must demote it,
/// repair it through the pipeline, and never let a read observe the
/// divergence.
#[test]
fn partial_partition() {
    let plan = FaultPlan::none().partition(1, 2_000, 60_000);
    let r = check(&Scenario::named("partial_partition", 0x9A27, plan));
    assert!(r.partitioned_wcs > 0, "partition never fired: {r:?}");
    assert_eq!(r.stale_reads, 0, "divergence leaked to a read: {r:?}");
    assert!(r.resync_demotions >= 1, "diverged replica not demoted: {r:?}");
    assert_eq!(r.disk_fallbacks, 0, "a healthy replica always remained: {r:?}");
}

/// A replica dies mid-run and comes back after the writes stop: the
/// revival must be gated by resync (rounds run, the node completes) and
/// no read may ever see pre-death data.
#[test]
fn revival_under_load_resyncs_cleanly() {
    let plan = FaultPlan::none().node_down(0, 10_000).node_up(0, 200_000);
    let sc = Scenario::named("revival_under_load_resyncs_cleanly", 0x2E71F, plan);
    let r = check(&sc);
    assert_eq!(r.node_transitions, 2, "{r:?}");
    assert_eq!(r.stale_reads, 0, "resync must gate the revival: {r:?}");
    assert!(r.resync_rounds >= 1, "the revival had missed writes: {r:?}");
    assert!(r.resyncs_completed >= 1, "the node must finish resync: {r:?}");
}

/// Acceptance scenario for the payload model: kill a replica, write to
/// its range, revive it, and immediately read from it. Without resync
/// the revived primary serves the pre-death version — now *caught* by
/// the data model as a stale read. With resync the same schedule routes
/// around the node until the missed write has been replayed, then
/// serves fresh data even after the peer dies.
#[test]
fn kill_write_revive_read_needs_resync() {
    let drive = |resync: bool| {
        // 2 nodes × 2 replicas: stripe 0 lives on both, primary node 0
        let mut fab = ChaosFabric::build(0xEC0, &paired_spec(resync, false), FaultPlan::none());
        fab.submit(1, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(2, Dir::Write, 0, 4096); // version 2: peer only
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, true, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(3, Dir::Read, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab
    };
    let unsynced = drive(false);
    assert!(
        unsynced.stats.stale_reads > 0,
        "unresynchronized revival must be caught serving stale data: {:?}",
        unsynced.stats
    );
    let resynced = drive(true);
    assert_eq!(resynced.stats.stale_reads, 0, "{:?}", resynced.stats);
    assert!(resynced.engine().stats.resyncs_completed >= 1);
    // control: the same topology through the scenario runner with a
    // quiet plan passes every invariant, including the new
    // no-stale-read one (the runner fails any scenario whose fabric
    // counts a stale read — which is how a sweep seed with an
    // unresynchronized revival would surface)
    let sc = Scenario::named(
        "kill_write_revive_read_needs_resync",
        0xEC0,
        FaultPlan::none(),
    );
    assert!(run_scenario(&sc).is_ok(), "control: quiet plan passes");
}

/// The scenario *runner* end-to-end with resync disabled: the stale-read
/// invariant (5) is the only one an unresynchronized revival can break,
/// so the run either fails with the stale-read report (naming the
/// disabled protocol) or — if this seed's random workload dodges the
/// hole — passes with zero stale reads. Both outcomes are deterministic
/// per seed; what this pins is the runner's reporting path itself.
#[test]
fn runner_reports_stale_reads_when_resync_is_disabled() {
    let plan = FaultPlan::none().node_down(0, 5_000).node_up(0, 60_000);
    let sc = Scenario::named(
        "runner_reports_stale_reads_when_resync_is_disabled",
        0x57A1E,
        plan,
    )
    .without_resync();
    match run_scenario(&sc) {
        Err(e) => {
            let msg = e.to_string();
            assert!(msg.contains("stale read served"), "wrong failure: {msg}");
            assert!(msg.contains("resync is disabled"), "{msg}");
        }
        Ok(r) => {
            assert_eq!(r.stale_reads, 0, "passing runs must report none: {r:?}");
            assert_eq!(r.node_transitions, 2, "{r:?}");
        }
    }
}

/// A cluster-wide latency storm (congestion, not a single stalled QP):
/// completions slow down, the pipe stays saturated, and the admission
/// window bound — checked continuously by the runner — must hold through
/// the whole storm. No failovers and no disk degradation: slow is not
/// broken.
#[test]
fn latency_storm_keeps_window_bounded() {
    let plan = FaultPlan::none().latency_storm(5_000, 160_000, 60_000);
    let r = check(&Scenario::named("latency_storm_keeps_window_bounded", 0x5702_13, plan));
    assert!(r.stormed_wcs > 0, "the storm never bit: {r:?}");
    assert_eq!(r.failovers, 0, "a storm is slow, not broken: {r:?}");
    assert_eq!(r.disk_fallbacks, 0, "{r:?}");
    assert!(
        r.elapsed_virtual_ns >= 65_000,
        "stormed completions must actually be delayed: {r:?}"
    );
}

/// Admission-policy churn: the window is shrunk and re-grown mid-run with
/// traffic in flight. Bytes admitted under the old window must release
/// under the new one (the runner's quiescence checks fail on any stranded
/// capacity), and the in-flight level may never exceed the largest window
/// that was ever active.
#[test]
fn admission_churn_no_leak() {
    let plan = FaultPlan::none()
        .admission_window(10_000, Some(4 * 4096))
        .admission_window(70_000, Some(20 * 4096))
        .admission_window(140_000, Some(5 * 4096));
    let r = check(&Scenario::named("admission_churn_no_leak", 0xC802_7, plan));
    assert_eq!(r.window_changes, 3, "every churn executed: {r:?}");
    assert_eq!(r.retired, r.submitted, "no I/O stranded by the churn: {r:?}");
    assert_eq!(r.failovers, 0);
    assert_eq!(r.disk_fallbacks, 0);
}

/// Tentpole acceptance: two concurrent overlapping writes whose replica
/// legs fail *crossed* (write A's leg on node 1, write B's leg on node 0)
/// demote both replicas with overlapping missed ranges — the topology
/// PR 3 documented as parked forever. The seed is found by a
/// deterministic search over error-injection schedules, so the crossed
/// pattern is guaranteed, not hoped for. With the election off, both
/// nodes park in `Resyncing`; with it on, the epoch vectors elect the
/// freshest holder per range, the cluster drains to `Alive`, and reads
/// observe zero staleness.
#[test]
fn overlapping_resync_elects_freshest() {
    let drive = |seed: u64, election: bool| {
        let plan = FaultPlan::none().with_errors(0.5);
        let mut fab = ChaosFabric::build(seed, &paired_spec(true, election), plan);
        // two overlapping writes in flight concurrently (page 1 shared)
        fab.submit(1, Dir::Write, 0, 8192);
        fab.submit(2, Dir::Write, 4096, 8192);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab
    };
    // deterministic search: the first seed whose injected errors cross
    // the two writes' legs (both replicas demoted, neither write
    // degraded to disk) and whose repair traffic survives the 50% error
    // rate. The search is pure, so CI and local runs agree on the seed.
    let seed = (0..400u64)
        .find(|&s| {
            let fab = drive(s, true);
            fab.engine().stats.resync_demotions == 2
                && fab.stats.disk_fallbacks == 0
                && fab.engine().node_state(0) == Some(NodeState::Alive)
                && fab.engine().node_state(1) == Some(NodeState::Alive)
        })
        .expect("a crossed-divergence seed below 400");

    // seed branch: election off — the overlap parks both replicas
    let parked = drive(seed, false);
    assert_eq!(parked.engine().stats.resync_demotions, 2);
    assert_eq!(
        parked.engine().node_state(0),
        Some(NodeState::Resyncing),
        "seed branch: conservative rule parks node 0 (seed {seed:#x})"
    );
    assert_eq!(parked.engine().node_state(1), Some(NodeState::Resyncing));
    assert!(parked.engine().resync_backlog(0) + parked.engine().resync_backlog(1) > 0);

    // election branch: drains to Alive with zero stale reads
    let mut healed = drive(seed, true);
    assert!(healed.engine().stats.resync_elections + healed.engine().stats.resync_self_heals >= 1);
    assert_eq!(healed.engine().stats.resync_disk_surrenders, 0, "live copies existed");
    healed.submit(10, Dir::Read, 0, 4096);
    healed.submit(11, Dir::Read, 4096, 4096);
    healed.submit(12, Dir::Read, 8192, 4096);
    healed.run_to_idle(STEPS).expect("quiescent");
    assert_eq!(healed.stats.stale_reads, 0, "{:?}", healed.stats);
    assert_eq!(healed.engine().regulator().in_flight(), 0);
}

/// Tentpole acceptance: a revived node whose peers are *all* dead has no
/// live copy of its missed range. Without the election it parks in
/// `Resyncing` serving nothing; with it, the range is surrendered to the
/// disk path (the fabric marks it disk-backed, as the paging layer's
/// per-block disk bit would) and the node rejoins `Alive` — and no read
/// ever observes stale remote data.
#[test]
fn all_peers_down_recovers_via_disk() {
    let drive = |election: bool| {
        let mut fab = ChaosFabric::build(0xD15C, &paired_spec(true, election), FaultPlan::none());
        fab.submit(1, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(2, Dir::Write, 0, 4096); // v2 lives only on node 1
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(1, false, fab.now() + 1); // v2's holder dies
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, true, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab
    };
    let parked = drive(false);
    assert_eq!(
        parked.engine().node_state(0),
        Some(NodeState::Resyncing),
        "without election the node parks (no live source)"
    );
    let mut healed = drive(true);
    assert_eq!(
        healed.engine().node_state(0),
        Some(NodeState::Alive),
        "election surrenders the range to disk and promotes"
    );
    assert!(healed.engine().stats.resync_disk_surrenders >= 1);
    // the promoted node serves; the surrendered page is disk-backed, so
    // the model routes its freshness to the disk copy — no stale read
    let sub = healed.submit(3, Dir::Read, 0, 4096);
    assert!(!sub.disk_fallback, "node 0 is alive and serving");
    healed.run_to_idle(STEPS).expect("quiescent");
    assert_eq!(healed.stats.stale_reads, 0, "{:?}", healed.stats);
}

/// Regression (splitter × payload oracle): a split read whose legs
/// complete in different WCs — one leg from a freshly repaired replica,
/// one from its peer — must be checked per leg, exactly once. Before the
/// per-leg accounting, the oracle examined only a sub completing in the
/// retiring WC, so a straddling read could under- or double-count
/// staleness depending on completion order. Pinned seed; the unresynced
/// branch must count exactly one stale page (the revived replica's leg),
/// the resynced branch exactly zero.
#[test]
fn split_read_straddling_repair_accounts_once() {
    let drive = |resync: bool| {
        let mut fab =
            ChaosFabric::build(0x51EC7, &paired_spec(resync, false), FaultPlan::none());
        let addr = STRIPE_BYTES - 4096; // one page each side of the boundary
        fab.submit(1, Dir::Write, addr, 8192);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(2, Dir::Write, addr, 8192); // v2 lands only on node 1
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, true, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        // the straddling read: leg 0 (stripe 0) prefers node 0 — the
        // revived replica — leg 1 (stripe 1) prefers node 1
        fab.submit(3, Dir::Read, addr, 8192);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab
    };
    let unsynced = drive(false);
    assert!(unsynced.engine().stats.split_requests >= 3, "splitter engaged");
    assert_eq!(
        unsynced.stats.stale_reads, 1,
        "exactly the revived replica's leg is stale — regardless of \
         which leg's completion retired the read: {:?}",
        unsynced.stats
    );
    let resynced = drive(true);
    assert_eq!(resynced.stats.stale_reads, 0, "{:?}", resynced.stats);
    assert!(resynced.engine().stats.resyncs_completed >= 1);
    assert_eq!(resynced.engine().regulator().in_flight(), 0);
}

/// Recovery tentpole: a CQ that silently loses completions must never
/// hang the engine or strand the admission window — WR deadlines
/// synthesize timeout completions through the normal retirement path,
/// the regulator releases, and retries finish the work. Any lossy plan
/// auto-arms default deadlines in the runner, so this also pins that
/// arming path.
#[test]
fn lost_wc_never_hangs_the_window() {
    let plan = FaultPlan::none().with_lost_wcs(0.25);
    let r = check(&Scenario::named("lost_wc_never_hangs_the_window", 0x105C, plan));
    assert!(r.lost_wcs > 0, "loss never fired: {r:?}");
    assert!(
        r.recovery_timeouts >= r.lost_wcs,
        "every lost WC must expire into a timeout: {r:?}"
    );
    assert!(r.timer_ticks > 0, "deadline ticks must drive the recovery: {r:?}");
    assert_eq!(r.window_leaks, 0, "{r:?}");
    assert_eq!(r.retired, r.submitted, "no I/O may hang: {r:?}");
    assert_eq!(r.stale_reads, 0, "{r:?}");
}

/// Recovery tentpole: a wedged QP (silently dropping everything posted
/// to it) must flip to Error through consecutive timeouts, flush its
/// outstanding WRs as timeout completions, recover through the
/// Error → Resetting → Ok probation, and leave nothing broken at
/// quiescence — the runner fails any run ending with a QP not Ok.
#[test]
fn wedged_qp_flushes_and_recovers() {
    let plan = FaultPlan::none().wedge(0, 5_000, 300_000);
    let r = check(&Scenario::named("wedged_qp_flushes_and_recovers", 0x3ED6, plan));
    assert!(r.wedged_wcs > 0, "the wedge never bit: {r:?}");
    assert!(r.recovery_timeouts > 0, "{r:?}");
    assert!(r.recovery_resets >= 1, "the QP must complete its reset: {r:?}");
    assert_eq!(r.window_leaks, 0, "{r:?}");
    assert_eq!(r.retired, r.submitted, "no I/O stranded by the wedge: {r:?}");
    assert_eq!(r.stale_reads, 0, "{r:?}");
}

/// The recovery sweep mix end-to-end: guaranteed lost completions plus
/// a wedged QP, deadlines armed by the profile, and the runner's
/// recovery quiescence gates (no window leak, no QP left in
/// Error/Resetting) all active.
#[test]
fn recovery_profile_rides_lost_wcs_and_wedges_through_the_runner() {
    for seed in [0x2EC_1u64, 0x2EC_2] {
        let sc = Scenario::randomized_with_profile(seed, ChaosProfile::Recovery);
        assert!(sc.deadlines.is_some(), "the profile arms deadlines: {sc:?}");
        let r = check(&sc);
        assert!(r.lost_wcs + r.wedged_wcs > 0, "no recovery fault fired: {r:?}");
        assert!(r.recovery_timeouts > 0, "{r:?}");
        assert_eq!(r.window_leaks, 0, "{r:?}");
        assert_eq!(r.retired, r.submitted, "{r:?}");
        assert!(
            replay_command(&sc).starts_with("CHAOS_PROFILE=recovery "),
            "{}",
            replay_command(&sc)
        );
    }
}

/// The QoS sweep mix end-to-end: a hog-vs-victim randomized scenario
/// (two weighted tenants, a guaranteed latency storm and admission churn
/// in the plan) passes every runner invariant — including the per-tenant
/// quiescence checks (each sub-window fully released, each tenant ledger
/// balanced) — and both tenants actually moved bytes.
#[test]
fn qos_mix_isolates_tenants_under_storms() {
    let sc = Scenario::randomized_with_profile(0xB05_F00D, ChaosProfile::Qos);
    assert_eq!(sc.tenant_weights.len(), 2, "hog + victim: {sc:?}");
    assert!(
        sc.tenant_weights[0] > sc.tenant_weights[1],
        "the victim outweighs the hog: {:?}",
        sc.tenant_weights
    );
    let r = check(&sc);
    assert!(r.stormed_wcs > 0, "the guaranteed storm never bit: {r:?}");
    assert!(r.window_changes > 0, "the guaranteed churn never fired: {r:?}");
    assert!(
        r.tenant_posted_bytes.iter().all(|&b| b > 0),
        "both tenants must move bytes: {r:?}"
    );
}

// ---------------- multi-engine scenarios ----------------

/// The multi-engine sweep mix end-to-end: two peer engines over one
/// replica cluster, with the gossip plane inside the schedule. Every
/// seed guarantees at least one asymmetric link cut, and the runner
/// fails unless both engines quiesce with identical epoch-vector
/// fingerprints and zero stale reads.
#[test]
fn multi_profile_two_engines_converge_through_the_runner() {
    for seed in [0x3417u64, 0xB0B0] {
        let sc = Scenario::randomized_with_profile(seed, ChaosProfile::Multi);
        let r = check(&sc);
        assert_eq!(r.retired, r.submitted, "no I/O lost across engines: {r:?}");
        assert_eq!(r.stale_reads, 0, "{r:?}");
        assert!(r.delivered_wcs > 0, "{r:?}");
        assert!(
            replay_command(&sc).starts_with("CHAOS_PROFILE=multi "),
            "{}",
            replay_command(&sc)
        );
    }
}

/// Tentpole acceptance, driven directly: engine 0 is partitioned from
/// node 0 while both engines write the same ranges (engine 0's legs
/// error, engine 1's land — silent divergence only gossip can surface
/// to the peer). After healing, both engines must hold identical epoch
/// vectors and serve the overlapped range with zero stale reads.
#[test]
fn two_engines_overlapping_writes_partition_heals_convergent() {
    let plan = MultiPlan::none().link_down(0, 0, 0, 60_000);
    let mut fab = MultiChaos::new(0x3417, None, plan);
    for i in 0..8u64 {
        fab.submit(0, i, Dir::Write, i * PAGE_BYTES, 2 * PAGE_BYTES);
        fab.submit(1, i, Dir::Write, i * PAGE_BYTES, 2 * PAGE_BYTES);
    }
    fab.run_to_converged(STEPS).expect("quiescent");
    assert!(fab.stats.link_errors > 0, "the cut never bit: {:?}", fab.stats);
    assert!(fab.stats.gossip_delivered >= 2, "{:?}", fab.stats);
    assert_eq!(
        fab.engine(0).gossip_fingerprint(),
        fab.engine(1).gossip_fingerprint(),
        "epoch vectors identical after healing"
    );
    for i in 0..9u64 {
        fab.submit(0, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
    }
    fab.run_to_converged(STEPS).expect("quiescent");
    assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
}

// ---------------- cluster-scale scenarios ----------------

/// A whole rack (16 of 256 nodes) loses its ToR uplink mid-run: every
/// write leg into the rack errors while the members stay nominally up.
/// The engine must demote the diverged replicas, repair them through the
/// resync pipeline, and never let a read observe the divergence — at a
/// cluster size where every submit keeps hundreds of deliveries queued.
#[test]
fn rack_partition_heals_at_256_nodes() {
    let rack = rack_members(3, 256, 16);
    let plan = FaultPlan::none().rack_partition(&rack, 1_000, 400_000);
    let sc = Scenario::named_scale("rack_partition_heals_at_256_nodes", 0x2AC_0001, 256, plan);
    let r = check(&sc);
    assert!(r.partitioned_wcs > 0, "the rack partition never bit: {r:?}");
    assert!(r.resync_demotions >= 1, "diverged replicas not demoted: {r:?}");
    assert_eq!(r.stale_reads, 0, "divergence leaked to a read: {r:?}");
    assert_eq!(r.retired, r.submitted, "no I/O stranded at scale: {r:?}");
}

/// Incast at scale: 300 nodes fan into a cluster-wide latency storm and
/// admission must collapse gracefully — the window bound is checked
/// continuously by the runner through the whole storm, nothing fails
/// over, and no I/O is stranded once the congestion lifts.
#[test]
fn incast_storm_collapses_admission_gracefully_at_300_nodes() {
    let plan = FaultPlan::none().latency_storm(10_000, 400_000, 50_000);
    let sc = Scenario::named_scale(
        "incast_storm_collapses_admission_gracefully_at_300_nodes",
        0x2AC_0002,
        300,
        plan,
    );
    let r = check(&sc);
    assert!(r.stormed_wcs > 0, "the storm never bit: {r:?}");
    assert_eq!(r.failovers, 0, "a storm is slow, not broken: {r:?}");
    assert_eq!(r.disk_fallbacks, 0, "{r:?}");
    assert!(
        r.elapsed_virtual_ns >= 60_000,
        "stormed completions must actually be delayed: {r:?}"
    );
}

/// The 1000-node acceptance scenario for the calendar-queue scheduler: a
/// 50-node rack dies in a correlated burst early in the run, writes land
/// in the dark window, and the rack revives into a resync storm. Every
/// runner invariant (exactly-once retirement, bounded window, zero stale
/// reads, full quiescence) must hold with thousands of concurrently
/// scheduled events — the population the per-op O(log n) heap walk made
/// painful.
#[test]
fn thousand_node_rack_loss_and_revival() {
    let rack = rack_members(7, 1000, 50);
    let plan = FaultPlan::none()
        .rack_down(&rack, 30_000)
        .rack_up(&rack, 250_000);
    let sc = Scenario::named_scale("thousand_node_rack_loss_and_revival", 0x2AC_03E8, 1000, plan);
    let r = check(&sc);
    assert_eq!(r.node_transitions, 100, "50 deaths + 50 revivals: {r:?}");
    assert_eq!(r.stale_reads, 0, "revival gated by resync at scale: {r:?}");
    assert_eq!(r.retired, r.submitted, "no I/O stranded across the rack loss: {r:?}");
}

/// Deterministic rack-revival resync: contiguous placement (stripe `s`
/// → nodes `s, s+1, s+2`) lets the schedule *construct* missed writes
/// instead of hoping a random workload produces them. A 4-node rack
/// dies in a burst, writes land during the outage (stripes 6 and 7 keep
/// a live replica outside the rack, stripes 4 and 5 lose all three and
/// fall to disk), and the simultaneous revival must gate every member
/// that missed data behind resync — with zero stale reads afterwards.
#[test]
fn rack_revival_resync_storm_is_gated() {
    let nodes = 16;
    let spec = EngineSpec::new(nodes)
        .replicated(3)
        .resync(RESYNC_CHUNK_BYTES)
        .election();
    let mut fab = ChaosFabric::build(0x2AC_F, &spec, FaultPlan::none());
    // version 1 on every stripe whose primary lives in the doomed rack
    for s in 4..8u64 {
        fab.submit(s, Dir::Write, s * STRIPE_BYTES, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    // the rack (nodes 4..8) dies in a correlated burst, one tick apart
    let rack = rack_members(1, nodes, 4);
    assert_eq!(rack, vec![4, 5, 6, 7]);
    let at = fab.now() + 1;
    for (i, &n) in rack.iter().enumerate() {
        fab.schedule_node_event(n, false, at + i as u64);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    // version 2 lands during the outage
    for s in 4..8u64 {
        fab.submit(100 + s, Dir::Write, s * STRIPE_BYTES, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    // power restored: all four revive at once — a resync storm
    let at = fab.now() + 1;
    for (i, &n) in rack.iter().enumerate() {
        fab.schedule_node_event(n, true, at + i as u64);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    assert_eq!(fab.stats.node_transitions, 8);
    assert!(
        fab.engine().stats.resyncs_completed >= 2,
        "nodes 6 and 7 missed live-replica writes and must resync: {:?}",
        fab.engine().stats
    );
    for &n in &rack {
        assert_eq!(
            fab.engine().node_state(n),
            Some(NodeState::Alive),
            "node {n} must rejoin after the storm"
        );
    }
    // reads across the repaired rack observe only post-outage data
    for s in 4..8u64 {
        fab.submit(200 + s, Dir::Read, s * STRIPE_BYTES, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.stats);
}

// ---------------- randomized sweep + replay ----------------

/// N seeds per CI run (base pinned per run via CHAOS_SWEEP_BASE); every
/// failure names the seed and the one-command replay.
#[test]
fn randomized_sweep() {
    let base = env_u64("CHAOS_SWEEP_BASE").unwrap_or(DEFAULT_SWEEP_BASE);
    let n = env_u64("CHAOS_SWEEP_N").unwrap_or(DEFAULT_SWEEP_N);
    let profile = env_profile();
    assert!(n >= 20, "sweep needs at least 20 seeds, got {n}");
    println!("chaos sweep: {n} seeds from base {base:#x} ({profile:?} profile)");
    for i in 0..n {
        let sc = Scenario::randomized_with_profile(base.wrapping_add(i), profile);
        let r = check(&sc);
        println!(
            "  seed {:#x}: {} ios, {} wcs, {} failovers, {} dups, {} errors, \
             {} legs, {} elections, {} surrenders, peak {} B",
            sc.seed,
            r.retired,
            r.delivered_wcs,
            r.failovers,
            r.duplicate_wcs,
            r.injected_errors,
            r.split_legs,
            r.resync_elections,
            r.resync_disk_surrenders,
            r.peak_in_flight
        );
    }
}

/// Replay a single sweep seed from the environment — the target of the
/// reproducer command every failure prints (`CHAOS_PROFILE` selects the
/// mix the seed was drawn under, exactly as the reproducer pins it).
#[test]
fn replay_env_seed() {
    let Some(seed) = env_u64("CHAOS_SEED") else {
        println!("replay_env_seed: set CHAOS_SEED=<seed> to replay; nothing to do");
        return;
    };
    let sc = Scenario::randomized_with_profile(seed, env_profile());
    println!("replaying seed {seed:#x} with plan {:?}", sc.plan);
    let r = check(&sc);
    println!("seed {seed:#x} passed: {r:?}");
}
