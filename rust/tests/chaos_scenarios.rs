//! Chaos scenario suite: named regression seeds for each fault class plus
//! a randomized multi-seed sweep, all on the deterministic chaos fabric.
//!
//! Every scenario asserts the engine invariants (exactly-once retirement,
//! admission window never exceeded, no lost I/O, quiescence with a fully
//! released window) inside `run_scenario`; the tests here additionally
//! assert that the *intended* fault actually fired, so a refactor cannot
//! quietly neuter the harness.
//!
//! On failure the panic message contains a one-command reproducer (the
//! seed pinned), and the same command is written to
//! `target/chaos-repro.txt` for CI to upload:
//!
//! ```text
//! CHAOS_SEED=0x... cargo test --release --test chaos_scenarios replay_env_seed -- --nocapture
//! ```

use rdmabox::fabric::chaos::{replay_command, run_scenario, FaultPlan, Scenario, ScenarioReport};

/// Default base of the randomized sweep when CI does not pin one.
const DEFAULT_SWEEP_BASE: u64 = 0x52D3_A201;
/// Default sweep width (the acceptance floor is 20 seeds).
const DEFAULT_SWEEP_N: u64 = 24;

fn env_u64(name: &str) -> Option<u64> {
    let v = std::env::var(name).ok()?;
    let v = v.trim().to_string();
    let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => v.parse(),
    };
    match parsed {
        Ok(x) => Some(x),
        Err(_) => panic!("{name} must be a u64 (decimal or 0x-hex), got `{v}`"),
    }
}

fn write_repro(sc: &Scenario) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../target/chaos-repro.txt");
    if let Some(dir) = std::path::Path::new(path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let _ = std::fs::write(path, format!("{}\n", replay_command(sc)));
}

/// Run a scenario; on an invariant violation, persist the reproducer
/// command for CI and panic with it.
fn check(sc: &Scenario) -> ScenarioReport {
    match run_scenario(sc) {
        Ok(r) => r,
        Err(e) => {
            write_repro(sc);
            panic!("{e}");
        }
    }
}

// ---------------- named regression seeds ----------------

/// WCs overtake each other within a CQ; retirement order must not matter.
#[test]
fn wc_reordering() {
    let plan = FaultPlan::none().with_reordering(0.6, 40_000);
    let r = check(&Scenario::named("wc_reordering", 0x2E02DE2, plan));
    assert!(r.reordered_wcs > 0, "reordering never fired: {r:?}");
    assert_eq!(r.failovers, 0, "reordering alone must not fail over");
    assert_eq!(r.disk_fallbacks, 0);
}

/// The CQ replays completions; the wr_id ledger must absorb every replay.
#[test]
fn duplicate_completions() {
    let plan = FaultPlan::none().with_duplicates(0.8, 15_000);
    let r = check(&Scenario::named("duplicate_completions", 0xD0B1E, plan));
    assert!(r.duplicate_wcs > 0, "duplicates never fired: {r:?}");
    assert_eq!(r.failovers, 0);
    assert_eq!(r.disk_fallbacks, 0);
}

/// Completion errors on a replicated topology: reads must fail over to
/// the next alive replica instead of surfacing the error.
#[test]
fn completion_errors_with_read_failover() {
    let plan = FaultPlan::none().with_errors(0.3);
    let sc = Scenario::named("completion_errors_with_read_failover", 0xE2202, plan);
    let r = check(&sc);
    assert!(r.injected_errors > 0, "errors never fired: {r:?}");
    assert!(r.failovers > 0, "errors must drive failover: {r:?}");
}

/// A node dies mid-run while its QPs are stalled: everything posted to it
/// before the death is still in flight when it lands, so those WCs come
/// back as errors and reads *must* fail over; with two replicas and one
/// death no I/O may degrade to the disk path.
#[test]
fn node_death_mid_run() {
    // QPs 0 and 1 belong to node 0 on the named 3-node × 2-QP topology
    let plan = FaultPlan::none()
        .stall(0, 0, 60_000)
        .stall(1, 0, 60_000)
        .node_down(0, 30_000);
    let r = check(&Scenario::named("node_death_mid_run", 0xDEAD0, plan));
    assert_eq!(r.node_transitions, 1);
    assert!(r.failovers > 0, "no failover from the death: {r:?}");
    assert_eq!(r.disk_fallbacks, 0, "a replica survived: {r:?}");
    assert_eq!(r.disk_at_submit, 0);
}

/// Two QPs stall ("NIC cache thrash"): completions are delayed, never
/// lost, and the admission window stays bounded throughout the stall.
#[test]
fn per_qp_stall() {
    let plan = FaultPlan::none()
        .stall(0, 10_000, 150_000)
        .stall(3, 20_000, 120_000);
    let r = check(&Scenario::named("per_qp_stall", 0x57A11, plan));
    assert!(r.stalled_wcs > 0, "the stall never fired: {r:?}");
    assert_eq!(r.failovers, 0);
    assert_eq!(r.disk_fallbacks, 0);
}

/// Everything at once: errors, reordering, duplicates, a stall, and a
/// death+revival — the invariants hold under the full fault mix.
#[test]
fn combined_fault_mix() {
    let plan = FaultPlan::none()
        .with_errors(0.15)
        .with_reordering(0.4, 30_000)
        .with_duplicates(0.3, 10_000)
        .stall(2, 5_000, 90_000)
        .node_down(1, 40_000)
        .node_up(1, 140_000);
    let r = check(&Scenario::named("combined_fault_mix", 0xC0B0, plan));
    assert!(r.injected_errors > 0 && r.duplicate_wcs > 0, "{r:?}");
    assert_eq!(r.node_transitions, 2, "{r:?}");
}

// ---------------- randomized sweep + replay ----------------

/// N seeds per CI run (base pinned per run via CHAOS_SWEEP_BASE); every
/// failure names the seed and the one-command replay.
#[test]
fn randomized_sweep() {
    let base = env_u64("CHAOS_SWEEP_BASE").unwrap_or(DEFAULT_SWEEP_BASE);
    let n = env_u64("CHAOS_SWEEP_N").unwrap_or(DEFAULT_SWEEP_N);
    assert!(n >= 20, "sweep needs at least 20 seeds, got {n}");
    println!("chaos sweep: {n} seeds from base {base:#x}");
    for i in 0..n {
        let sc = Scenario::randomized(base.wrapping_add(i));
        let r = check(&sc);
        println!(
            "  seed {:#x}: {} ios, {} wcs, {} failovers, {} dups, {} errors, peak {} B",
            sc.seed,
            r.retired,
            r.delivered_wcs,
            r.failovers,
            r.duplicate_wcs,
            r.injected_errors,
            r.peak_in_flight
        );
    }
}

/// Replay a single sweep seed from the environment — the target of the
/// reproducer command every failure prints.
#[test]
fn replay_env_seed() {
    let Some(seed) = env_u64("CHAOS_SEED") else {
        println!("replay_env_seed: set CHAOS_SEED=<seed> to replay; nothing to do");
        return;
    };
    let sc = Scenario::randomized(seed);
    println!("replaying seed {seed:#x} with plan {:?}", sc.plan);
    let r = check(&sc);
    println!("seed {seed:#x} passed: {r:?}");
}
