//! Equivalence regression for the unified [`EngineSpec`] construction
//! path: the old per-feature constructor chains
//! (`new_placed`/`with_resync`/`with_donor_election`/…) are gone, so
//! these tests pin that the one remaining surface reproduces their
//! behavior exactly — on pinned chaos seeds, the shim and the spec
//! builder (in any chaining order) yield byte-identical fault/engine
//! statistics and zero stale reads across the plain, resync, and
//! election configurations.

use rdmabox::coordinator::EngineSpec;
use rdmabox::fabric::chaos::{ChaosFabric, FaultPlan, RESYNC_CHUNK_BYTES, STRIPE_BYTES};
use rdmabox::fabric::Dir;

/// Livelock guard for directly driven fabrics.
const STEPS: u64 = 4_000_000;

/// A deterministic workload exercising every pipeline feature the spec
/// can enable: replicated writes across a stripe boundary, a death with
/// writes landing on the surviving peer, a revival (resync and election
/// react here; a plain config rejoins immediately), then reads over the
/// whole range.
fn drive(mut fab: ChaosFabric) -> ChaosFabric {
    let addr = STRIPE_BYTES - 8192;
    for i in 0..8u64 {
        fab.submit(1 + i, Dir::Write, addr + i * 4096, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    fab.schedule_node_event(0, false, fab.now() + 1);
    fab.run_to_idle(STEPS).expect("quiescent");
    for i in 0..4u64 {
        fab.submit(100 + i, Dir::Write, addr + i * 4096, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    fab.schedule_node_event(0, true, fab.now() + 1);
    fab.run_to_idle(STEPS).expect("quiescent");
    for i in 0..8u64 {
        fab.submit(200 + i, Dir::Read, addr + i * 4096, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    fab
}

/// Fingerprint of everything a construction-path divergence could move:
/// the full fault-stat struct plus the engine's cumulative stats.
fn fingerprint(fab: &ChaosFabric) -> (rdmabox::fabric::chaos::ChaosStats, String) {
    (fab.stats.clone(), format!("{:?}", fab.engine().stats))
}

const SEED: u64 = 0xE9_01;
const PLAN_SEED: u64 = 0xE9_02;

fn faulty() -> FaultPlan {
    FaultPlan::none()
        .with_errors(0.2)
        .with_reordering(0.3, 20_000)
        .with_duplicates(0.2, 10_000)
}

/// Like [`drive`] but without the death/revival arc: a plain config
/// (no resync) revived mid-workload would *correctly* serve stale data
/// — the staleness assertions below are only meaningful on a
/// fully-alive cluster or a resync-gated revival.
fn drive_healthy(mut fab: ChaosFabric) -> ChaosFabric {
    let addr = STRIPE_BYTES - 8192;
    for i in 0..8u64 {
        fab.submit(1 + i, Dir::Write, addr + i * 4096, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    for i in 0..4u64 {
        fab.submit(100 + i, Dir::Write, addr + i * 4096, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    for i in 0..8u64 {
        fab.submit(200 + i, Dir::Read, addr + i * 4096, 4096);
    }
    fab.run_to_idle(STEPS).expect("quiescent");
    fab
}

/// Plain placed config: the [`ChaosFabric::new`] convenience shim must
/// stay a faithful alias of [`ChaosFabric::build`] with the equivalent
/// spec — same seed, same plan, identical stats.
#[test]
fn shim_matches_spec_build_plain() {
    for (seed, plan) in [(SEED, FaultPlan::none()), (PLAN_SEED, faulty())] {
        let via_shim = drive_healthy(ChaosFabric::new(seed, 2, 1, 2, None, plan.clone()));
        let via_spec = drive_healthy(ChaosFabric::build(
            seed,
            &EngineSpec::new(2).qps(1).window(None).replicated(2),
            plan,
        ));
        assert_eq!(
            fingerprint(&via_shim),
            fingerprint(&via_spec),
            "seed {seed:#x}: the shim diverged from the spec path"
        );
        assert_eq!(via_shim.stats.stale_reads, 0, "seed {seed:#x}");
    }
}

/// Resync config: builder chaining order must not matter — the spec is a
/// plain value, so `.replicated(2).resync(..)` and `.resync(..)` applied
/// before the replication are the same engine.
#[test]
fn spec_builder_order_is_immaterial_resync() {
    let a = drive(ChaosFabric::build(
        SEED,
        &EngineSpec::new(2).replicated(2).resync(RESYNC_CHUNK_BYTES),
        faulty(),
    ));
    let b = drive(ChaosFabric::build(
        SEED,
        &EngineSpec::new(2).resync(RESYNC_CHUNK_BYTES).replicated(2),
        faulty(),
    ));
    assert_eq!(fingerprint(&a), fingerprint(&b), "chaining order leaked");
    assert_eq!(a.stats.stale_reads, 0, "resync must gate the revival: {:?}", a.stats);
    assert!(
        a.engine().stats.resyncs_completed >= 1,
        "the revival had missed writes: {:?}",
        a.engine().stats
    );
}

/// Election config: same order-independence, and the donor election must
/// actually be armed (the workload's single death keeps a live donor, so
/// the cluster heals without disk surrenders — exactly as the old
/// `with_donor_election` chain behaved on this seed).
#[test]
fn spec_builder_order_is_immaterial_election() {
    let a = drive(ChaosFabric::build(
        SEED,
        &EngineSpec::new(2)
            .replicated(2)
            .resync(RESYNC_CHUNK_BYTES)
            .election(),
        faulty(),
    ));
    let b = drive(ChaosFabric::build(
        SEED,
        &EngineSpec::new(2)
            .election()
            .resync(RESYNC_CHUNK_BYTES)
            .replicated(2),
        faulty(),
    ));
    assert_eq!(fingerprint(&a), fingerprint(&b), "chaining order leaked");
    assert_eq!(a.stats.stale_reads, 0, "{:?}", a.stats);
    assert_eq!(
        a.engine().stats.resync_disk_surrenders,
        0,
        "a live donor existed throughout: {:?}",
        a.engine().stats
    );
}

/// The whole construction matrix is deterministic: rebuilding the same
/// spec from the same seed replays the identical run, feature by feature
/// (this is what makes every pinned-seed chaos regression in the suite
/// meaningful).
#[test]
fn same_spec_same_seed_is_bit_identical() {
    let specs = [
        EngineSpec::new(2).replicated(2),
        EngineSpec::new(2).replicated(2).resync(RESYNC_CHUNK_BYTES),
        EngineSpec::new(2)
            .replicated(2)
            .resync(RESYNC_CHUNK_BYTES)
            .election(),
    ];
    for spec in &specs {
        let a = drive(ChaosFabric::build(PLAN_SEED, spec, faulty()));
        let b = drive(ChaosFabric::build(PLAN_SEED, spec, faulty()));
        assert_eq!(fingerprint(&a), fingerprint(&b), "nondeterministic build");
        assert_eq!(a.stats.retired, 20, "8 + 4 writes + 8 reads all retire");
    }
}
