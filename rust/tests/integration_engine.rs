//! Integration tests for the `IoEngine` pipeline: multi-threaded
//! submitters over the sharded queues (exactly-once retirement), the
//! admission window bound end-to-end, and replica failure mid-run (on
//! the deterministic chaos backend).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use rdmabox::config::FabricConfig;
use rdmabox::coordinator::{EngineSpec, StackConfig};
use rdmabox::fabric::chaos::{ChaosFabric, FaultPlan};
use rdmabox::fabric::loopback::{LiveBox, LoopbackFabric};
use rdmabox::fabric::sim::run_pipeline;
use rdmabox::fabric::Dir;
use rdmabox::workloads::fio::FioDriver;
use rdmabox::workloads::DriverStats;

/// Satellite: multi-threaded submitters into the sharded queues preserve
/// per-I/O completion exactly once. Every `write` returns exactly when its
/// own I/O retires; the engine's retired count must equal the op count and
/// every byte must land where it was addressed.
#[test]
fn sharded_queues_exactly_once_under_concurrency() {
    let threads = 8u64;
    let per_thread = 96u64;
    let fab = LoopbackFabric::start_sharded(3, 16 << 20, 4);
    let lb = LiveBox::build(fab, &EngineSpec::new(3).qps(4).window(Some(7 << 20)));
    let returns = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for t in 0..threads {
        let lb = lb.clone();
        let returns = returns.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_thread {
                // interleave so adjacent pages come from different threads
                // (the §5.1 merge window) and spread over 1 MiB regions so
                // every shard carries traffic
                let page = i * threads + t;
                let node = (page % 3) as usize;
                let addr = (page % 6) * (1 << 20) + (page / 6) * 4096;
                lb.write(node, addr, &vec![(page % 250) as u8 + 1; 4096]);
                returns.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = threads * per_thread;
    assert_eq!(returns.load(Ordering::Relaxed), total, "every write returned once");
    let s = lb.stats();
    assert_eq!(s.retired, total, "exactly-once retirement");
    assert_eq!(s.bytes_written, total * 4096, "no lost or duplicated bytes");
    // contents survived the concurrency
    for t in 0..threads {
        for i in 0..per_thread {
            let page = i * threads + t;
            let node = (page % 3) as usize;
            let addr = (page % 6) * (1 << 20) + (page / 6) * 4096;
            let b = lb.read(node, addr, 4096);
            assert_eq!(b[0], (page % 250) as u8 + 1, "page {page}");
            assert_eq!(b[4095], (page % 250) as u8 + 1, "page {page}");
        }
    }
}

/// Satellite: the admission window never admits more than `window_bytes`
/// in flight, measured at the fabric across a full FIO run.
#[test]
fn admission_window_never_exceeded_end_to_end() {
    let cfg = FabricConfig::connectx3_fdr();
    let window = 24 * 4096u64;
    let stack = StackConfig::rdmabox(&cfg).with_window(Some(window));
    let stats = DriverStats::shared();
    let driver = Box::new(FioDriver::new(
        12,
        4,
        4096,
        50,
        1 << 30,
        1,
        8_000,
        11,
        stats,
    ));
    let r = run_pipeline(&cfg, &stack, 1, driver);
    assert!(r.completed_reads + r.completed_writes >= 8_000);
    assert!(
        r.peak_inflight_bytes <= window,
        "peak in-flight {} exceeded window {}",
        r.peak_inflight_bytes,
        window
    );
    assert!(r.trace.admission_blocks > 0, "the window actually bit");
}

/// Satellite: kill a replica mid-run; reads keep completing from the
/// surviving replica — the engine's failover path, not the application's.
///
/// Runs on the chaos backend: the death lands at a *virtual* time between
/// the read postings and their completions, so the race the old
/// loopback-thread version only sometimes hit (sleep-based killer) is now
/// hit on every run, deterministically.
#[test]
fn replica_killed_mid_run_reads_survive() {
    let pages = 48u64;
    // every page lives in stripe 0 -> primary node 0, replica node 1
    let mut fab = ChaosFabric::new(0x5EED, 3, 2, 2, Some(7 << 20), FaultPlan::none());
    for page in 0..pages {
        fab.submit(page, Dir::Write, page * 4096, 4096);
    }
    let written = fab.run_to_idle(1_000_000).expect("writes quiesce");
    assert_eq!(written.len() as u64, pages);
    assert!(written.iter().all(|r| !r.disk_fallback));

    // three read sweeps; node 0 dies 2µs (virtual) into the first sweep,
    // while its completions are still in flight
    fab.schedule_node_event(0, false, fab.now() + 2_000);
    let mut retired = Vec::new();
    for round in 0..3u64 {
        for page in 0..pages {
            let id = 1_000 + round * pages + page;
            fab.submit(id, Dir::Read, page * 4096, 4096);
        }
        retired.extend(fab.run_to_idle(1_000_000).expect("reads quiesce"));
    }
    assert_eq!(retired.len() as u64, 3 * pages, "each read retired once");
    assert!(
        retired.iter().all(|r| !r.disk_fallback),
        "replica 1 always alive: no disk fallback"
    );
    assert!(
        retired.iter().any(|r| r.failed_over),
        "the kill must land on in-flight reads"
    );
    assert_eq!(fab.engine().stats.duplicate_wcs, 0);
    assert_eq!(fab.engine().regulator().in_flight(), 0);
}

/// Satellite: the engine-level request splitter end-to-end — multi-stripe
/// requests are split into stripe-local legs at submission (the old
/// "callers must keep requests stripe-local" contract is gone), retire
/// exactly once, and survive a replica kill with per-leg failover.
#[test]
fn split_requests_survive_replica_kill() {
    use rdmabox::fabric::chaos::STRIPE_BYTES;
    // 3 nodes, 2 replicas: stripe 0 -> {0,1}, stripe 1 -> {1,2}
    let mut fab = ChaosFabric::new(0x517E5, 3, 2, 2, Some(7 << 20), FaultPlan::none());
    let addr = STRIPE_BYTES - 2 * 4096;
    let span = 4 * 4096u64; // two pages each side of the boundary
    for i in 0..8u64 {
        fab.submit(i, Dir::Write, addr, span);
    }
    let written = fab.run_to_idle(1_000_000).expect("writes quiesce");
    assert_eq!(written.len(), 8, "each split write retired exactly once");
    assert!(written.iter().all(|r| !r.disk_fallback));
    assert_eq!(fab.engine().stats.split_requests, 8);
    assert_eq!(fab.engine().stats.split_legs, 16);

    // node 0 dies: stripe 0 legs fail over to node 1, stripe 1 legs are
    // untouched — the read still completes whole, exactly once
    fab.schedule_node_event(0, false, fab.now() + 2_000);
    let mut retired = Vec::new();
    for round in 0..3u64 {
        for i in 0..8u64 {
            fab.submit(100 + round * 8 + i, Dir::Read, addr, span);
        }
        retired.extend(fab.run_to_idle(1_000_000).expect("reads quiesce"));
    }
    assert_eq!(retired.len(), 24, "each split read retired exactly once");
    assert!(
        retired.iter().all(|r| !r.disk_fallback),
        "replica 1 serves stripe 0 throughout"
    );
    assert_eq!(fab.stats.stale_reads, 0);
    assert_eq!(fab.engine().regulator().in_flight(), 0);
}
