//! Integration tests across the full stack: coordinator policies driving
//! the simulated fabric end to end, cross-system comparisons, and failure
//! injection through the replication path.

use rdmabox::baselines;
use rdmabox::config::FabricConfig;
use rdmabox::coordinator::node::NodeMap;
use rdmabox::coordinator::polling::PollingMode;
use rdmabox::coordinator::StackConfig;
use rdmabox::fabric::sim::engine::StackEngine;
use rdmabox::fabric::sim::{Driver, Sim};
use rdmabox::fabric::{AppIo, Dir};
use rdmabox::paging::{Pager, Target};
use rdmabox::workloads::kv::{self, run_kv, KvConfig, Mix};
use rdmabox::workloads::mltrace;

fn fabric() -> FabricConfig {
    FabricConfig::connectx3_fdr()
}

// ---------------------------------------------------------------- paging

/// Paging through a failing replica set: reads fail over replica → disk,
/// and recover when nodes return.
#[test]
fn failover_read_path_survives_node_loss() {
    let mut pager = Pager::new(4, NodeMap::new(3, 2, 1 << 20), 4096);
    // fill + dirty
    for p in 0..4 {
        pager.touch(p, true);
    }
    // evict everything to remote (2 replicas)
    for p in 4..8 {
        pager.touch(p, true);
    }
    assert!(pager.swapped_out() >= 4);

    // kill the primary of page 0's slot: refault must hit the secondary
    let out = {
        pager.node_map_mut().set_alive(0, false);
        pager.touch(0, false)
    };
    if let Some(load) = out.load {
        assert!(
            matches!(load.target, Target::Node(_)),
            "failover to secondary, not disk: {load:?}"
        );
    }

    // kill everything: disk fallback
    for n in 0..3 {
        pager.node_map_mut().set_alive(n, false);
    }
    let out = pager.touch(1, false);
    if let Some(load) = out.load {
        assert_eq!(load.target, Target::Disk, "all replicas dead -> disk");
    }
    // writebacks with all nodes dead also go to disk
    assert!(out
        .writebacks
        .iter()
        .all(|w| w.target == Target::Disk));
}

// ------------------------------------------------------ cross-system runs

/// The paper's headline: RDMAbox sustains higher app throughput than every
/// baseline configuration on the same workload.
#[test]
fn rdmabox_beats_every_baseline_on_paging_workload() {
    let cfg = fabric();
    let kv = || KvConfig {
        ops: 20_000,
        records: 50_000,
        ..KvConfig::small(kv::voltdb(), Mix::Sys)
    };
    let (_, rbox) = run_kv(&cfg, &StackConfig::rdmabox(&cfg), kv());
    for baseline in [
        baselines::nbdx(&cfg, 128 << 10),
        baselines::nbdx(&cfg, 512 << 10),
    ] {
        let name = baseline.name.clone();
        let (_, b) = run_kv(&cfg, &baseline, kv());
        assert!(
            rbox.throughput() > b.throughput(),
            "RDMAbox {} must beat {name} {}",
            rbox.throughput(),
            b.throughput()
        );
    }
}

/// Determinism across the whole stack: same seed, same world.
#[test]
fn full_stack_runs_are_deterministic() {
    let cfg = fabric();
    let kv = || KvConfig {
        ops: 10_000,
        records: 30_000,
        ..KvConfig::small(kv::redis(), Mix::Etc)
    };
    let (r1, s1) = run_kv(&cfg, &StackConfig::rdmabox(&cfg), kv());
    let (r2, s2) = run_kv(&cfg, &StackConfig::rdmabox(&cfg), kv());
    assert_eq!(r1.elapsed_ns, r2.elapsed_ns);
    assert_eq!(r1.trace.wqes_total(), r2.trace.wqes_total());
    assert_eq!(r1.trace.bytes_wire, r2.trace.bytes_wire);
    assert_eq!(s1.warm_ops, s2.warm_ops);
    assert_eq!(s1.op_lat.p99(), s2.op_lat.p99());
}

/// ML trace: every workload finishes on every stack, and the RDMAbox
/// completion time is never worse than nbdX's.
#[test]
fn ml_workloads_complete_on_all_stacks() {
    let cfg = fabric();
    let small = |p: mltrace::MlProfile| mltrace::MlProfile {
        dataset_pages: 1_500,
        state_pages: 64,
        epochs: 1,
        ..p
    };
    for profile in [
        small(mltrace::logreg()),
        small(mltrace::textrank()),
    ] {
        let (t_box, rep) = mltrace::run_ml(&cfg, &StackConfig::rdmabox(&cfg), profile, 0.25, 3);
        assert!(t_box > 0 && rep.completed_reads > 0, "{}", profile.name);
        let (t_nbdx, _) =
            mltrace::run_ml(&cfg, &baselines::nbdx(&cfg, 512 << 10), profile, 0.25, 3);
        assert!(
            t_nbdx >= t_box,
            "{}: nbdX {} must not beat RDMAbox {}",
            profile.name,
            t_nbdx,
            t_box
        );
    }
}

// ------------------------------------------------- error/edge conditions

/// A request bigger than the admission window must not deadlock (progress
/// guarantee of the regulator integration).
#[test]
fn oversized_request_does_not_deadlock() {
    struct One {
        done: bool,
    }
    impl Driver for One {
        fn on_start(&mut self, sim: &mut Sim) {
            // 1 MB write with a 128 KB window
            sim.submit_at(Dir::Write, 0, 0, 1 << 20, 0, 0);
        }
        fn on_io_done(&mut self, sim: &mut Sim, _io: &AppIo, _l: u64, _at: u64) {
            self.done = true;
            sim.request_stop();
        }
        fn on_timer(&mut self, _s: &mut Sim, _t: usize, _g: u64) {}
    }
    let cfg = fabric();
    let stack = StackConfig::rdmabox(&cfg).with_window(Some(128 << 10));
    let mut sim = Sim::new(cfg.clone(), stack.clone(), 1);
    sim.attach_engine(Box::new(StackEngine::new(&cfg, &stack, 1)));
    sim.attach_driver(Box::new(One { done: false }));
    let r = sim.run(10_000_000_000); // 10s virtual-time cap
    assert_eq!(r.completed_writes, 1, "oversized write must complete");
}

/// Every polling mode drains a mixed read/write burst completely.
#[test]
fn mixed_burst_drains_under_every_polling_mode() {
    struct Burst {
        left: u64,
    }
    impl Driver for Burst {
        fn on_start(&mut self, sim: &mut Sim) {
            for i in 0..64u64 {
                let dir = if i % 3 == 0 { Dir::Read } else { Dir::Write };
                sim.submit_at(dir, (i % 2) as usize, i * 4096, 4096, 0, 0);
            }
        }
        fn on_io_done(&mut self, sim: &mut Sim, _io: &AppIo, _l: u64, _at: u64) {
            self.left -= 1;
            if self.left == 0 {
                sim.request_stop();
            }
        }
        fn on_timer(&mut self, _s: &mut Sim, _t: usize, _g: u64) {}
    }
    let cfg = fabric();
    for polling in [
        PollingMode::Busy,
        PollingMode::Event,
        PollingMode::EventBatch { budget: 4 },
        PollingMode::Adaptive {
            batch: 8,
            max_retry: 10,
        },
        PollingMode::HybridTimer { spin_ns: 5_000 },
        PollingMode::Scq { m: 1, pollers: 2 },
    ] {
        let stack = StackConfig::rdmabox(&cfg).with_polling(polling);
        let mut sim = Sim::new(cfg.clone(), stack.clone(), 2);
        sim.attach_engine(Box::new(StackEngine::new(&cfg, &stack, 2)));
        sim.attach_driver(Box::new(Burst { left: 64 }));
        let r = sim.run(10_000_000_000);
        assert_eq!(
            r.completed_reads + r.completed_writes,
            64,
            "mode {polling:?}"
        );
    }
}
