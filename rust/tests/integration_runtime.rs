//! Integration over the PJRT runtime + live loopback path: the end-to-end
//! three-layer composition. The XLA-backed tests are gated behind the
//! `xla` cargo feature (and additionally skip gracefully when
//! `make artifacts` has not run) — default CI still covers the loopback
//! coordinator and dataset pieces.

use rdmabox::coordinator::EngineSpec;
use rdmabox::fabric::loopback::{LiveBox, LoopbackFabric};
use rdmabox::ml::{LogregData, PagedStore};

#[test]
fn live_loopback_under_concurrency_preserves_data() {
    let fabric = LoopbackFabric::start(4, 8 << 20);
    let lb = LiveBox::build(fabric, &EngineSpec::new(4).window(Some(1 << 20)));
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let lb = lb.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..64u64 {
                let page = i * 6 + t;
                let node = (page % 4) as usize;
                lb.write(node, page * 4096, &vec![(page % 199) as u8 + 1; 4096]);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    for page in 0..384u64 {
        let node = (page % 4) as usize;
        let data = lb.read(node, page * 4096, 4096);
        assert_eq!(data[0], (page % 199) as u8 + 1, "page {page}");
        assert_eq!(data[4095], (page % 199) as u8 + 1, "page {page}");
    }
}

#[test]
fn paged_store_thrashes_correctly_under_tiny_cache() {
    let fabric = LoopbackFabric::start(2, 4 << 20);
    let lb = LiveBox::build(fabric, &EngineSpec::new(2));
    let mut st = PagedStore::new(lb, 64, 2); // 2-frame cache over 64 pages
    for p in 0..64u64 {
        st.populate(p, &vec![(p + 1) as u8; 4096]);
    }
    for round in 0..3 {
        for p in 0..64u64 {
            assert_eq!(st.get(p)[0], (p + 1) as u8, "round {round} page {p}");
        }
    }
    assert!(st.faults >= 64 * 3 - 2, "almost every access must fault");
}

#[test]
fn logreg_dataset_generator_is_balanced() {
    let d = LogregData::new(512, 32, 128);
    let mut pos = 0;
    for i in 0..512 {
        let (_, y) = d.row(i);
        pos += y as usize;
    }
    // separator through the origin over gaussians: roughly balanced labels
    assert!((128..=384).contains(&pos), "positives {pos}/512");
}

#[cfg(feature = "xla")]
mod xla_backed {
    use rdmabox::ml::train_paged_logreg;
    use rdmabox::runtime::{artifacts_available, lit, Runtime, KMEANS_STEP, LOGREG_STEP};

    #[test]
    fn runtime_executes_all_three_models() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::from_artifacts().expect("client");
        // logreg
        let f = 512;
        let b = 256;
        let out = rt
            .execute(
                LOGREG_STEP,
                &[
                    lit::f32_vec(&vec![0.0; f]),
                    lit::f32_mat(&vec![0.1; b * f], b, f).unwrap(),
                    lit::f32_vec(&vec![1.0; b]),
                    lit::f32_scalar(0.1).unwrap(),
                ],
            )
            .expect("logreg_step");
        assert_eq!(out.len(), 2, "(w', loss)");
        assert_eq!(lit::to_f32(&out[0]).unwrap().len(), f);
        // kmeans
        let out = rt
            .execute(
                KMEANS_STEP,
                &[
                    lit::f32_mat(&vec![0.5; 16 * 32], 16, 32).unwrap(),
                    lit::f32_mat(&vec![0.25; 1024 * 32], 1024, 32).unwrap(),
                ],
            )
            .expect("kmeans_step");
        assert_eq!(out.len(), 2, "(centroids', inertia)");
        assert!(rt.loaded().len() >= 2);
    }

    #[test]
    fn e2e_three_layer_training_reduces_loss() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::from_artifacts().unwrap();
        let r = train_paged_logreg(&mut rt, 3, 512, 256, 512, 0.25, 25, 0.5).unwrap();
        assert!(r.losses[24] < r.losses[0]);
        assert!(r.faults > 0, "data actually came from remote memory");
    }
}
