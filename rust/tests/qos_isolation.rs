//! Tentpole acceptance for multi-tenant QoS: a hog tenant floods the
//! shared pipeline while a small weighted victim rides along, under a
//! cluster-wide latency storm and mid-run admission churn. With the QoS
//! spec (`.tenants([3, 1])`) the victim's traffic is admitted through
//! its own sub-window and drained through its weighted DRR lane, so its
//! last I/O retires far earlier in virtual time than under the
//! single-tenant FIFO baseline — while the aggregate run stays
//! work-conserving (total completion time within 10% of no-QoS).

use rdmabox::coordinator::EngineSpec;
use rdmabox::fabric::chaos::{ChaosFabric, FaultPlan};
use rdmabox::fabric::{Dir, TenantId};

const PAGE: u64 = 4096;
const HOG_PAGES: u64 = 48;
const VICTIM_PAGES: u64 = 8;
/// Livelock guard on the event loop.
const STEPS: u64 = 4_000_000;
const SEED: u64 = 0x905_11;

/// The adversarial schedule: a storm long enough to cover the whole run
/// (every WC delayed equally, so the FIFO/DRR comparison is about drain
/// *order*, not storm luck) plus two admission-window swaps with the
/// backlog in flight.
fn plan() -> FaultPlan {
    FaultPlan::none()
        .latency_storm(1, 10_000_000, 20_000)
        .admission_window(100_000, Some(4 * PAGE))
        .admission_window(300_000, Some(8 * PAGE))
}

struct Run {
    fab: ChaosFabric,
    /// Virtual time when the victim's last I/O retired.
    victim_done_ns: u64,
    /// Virtual time when the last I/O of the whole run retired.
    all_done_ns: u64,
}

/// Flood then ride: the hog submits `HOG_PAGES` writes first (they own
/// the FIFO queue head), the victim submits `VICTIM_PAGES` writes into
/// the same stripe region behind them. Both runs use the identical
/// schedule; only the spec (and the tenant billing) differs.
fn drive(spec: &EngineSpec, hog_tenant: TenantId) -> Run {
    let mut fab = ChaosFabric::build(SEED, spec, plan());
    let mut id = 1u64;
    for i in 0..HOG_PAGES {
        fab.submit_t(id, Dir::Write, (1 << 20) + i * PAGE, PAGE, hog_tenant);
        id += 1;
    }
    let victim_base = id;
    for i in 0..VICTIM_PAGES {
        fab.submit_t(id, Dir::Write, i * PAGE, PAGE, 0);
        id += 1;
    }
    let mut victim_done_ns = 0;
    let mut all_done_ns = 0;
    let mut retired = 0u64;
    let mut guard = 0u64;
    while let Some(batch) = fab.step() {
        guard += 1;
        assert!(guard < STEPS, "chaos run livelocked");
        for r in &batch {
            retired += 1;
            assert!(!r.disk_fallback, "healthy cluster: no disk degradation");
            if (victim_base..victim_base + VICTIM_PAGES).contains(&r.id) {
                victim_done_ns = victim_done_ns.max(fab.now());
            }
            all_done_ns = all_done_ns.max(fab.now());
        }
    }
    assert_eq!(retired, HOG_PAGES + VICTIM_PAGES, "every I/O retires");
    assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.stats);
    assert!(fab.stats.stormed_wcs > 0, "the storm never bit: {:?}", fab.stats);
    assert_eq!(fab.stats.window_changes, 2, "both churns executed");
    Run {
        fab,
        victim_done_ns,
        all_done_ns,
    }
}

#[test]
fn weighted_victim_cuts_through_the_hog() {
    // baseline: one FIFO lane, everything billed to tenant 0
    let fifo = drive(&EngineSpec::new(2).replicated(2).window(Some(8 * PAGE)), 0);
    // QoS: victim = tenant 0 at weight 3, hog = tenant 1 at weight 1
    let qos = drive(
        &EngineSpec::new(2)
            .replicated(2)
            .window(Some(8 * PAGE))
            .tenants(&[3, 1]),
        1,
    );

    assert!(
        qos.victim_done_ns < fifo.victim_done_ns,
        "the weighted victim must finish earlier than behind the FIFO hog: \
         qos {} ns vs fifo {} ns",
        qos.victim_done_ns,
        fifo.victim_done_ns
    );
    // work conservation: prioritizing the victim must not cost the
    // aggregate run more than 10% in virtual completion time
    assert!(
        qos.all_done_ns as f64 <= fifo.all_done_ns as f64 * 1.10,
        "QoS is not work-conserving: qos {} ns vs fifo {} ns",
        qos.all_done_ns,
        fifo.all_done_ns
    );

    // the per-tenant ledger saw exactly the split we billed
    let stats = qos.fab.engine().tenant_stats();
    assert_eq!(stats.len(), 2);
    assert_eq!(stats[0].weight, 3, "victim lane");
    assert_eq!(stats[1].weight, 1, "hog lane");
    assert!(
        stats[1].posted_bytes > stats[0].posted_bytes,
        "the hog posted ~6x the victim's bytes: {stats:?}"
    );
    assert!(stats[0].posted_bytes > 0 && stats[0].retired_bytes > 0);
    assert_eq!(
        stats[0].window_occupancy, 0,
        "quiescent: the victim's sub-window fully released"
    );
    assert_eq!(stats[1].window_occupancy, 0, "hog sub-window fully released");
    // the FIFO baseline bills everything to the single default lane
    let base_stats = fifo.fab.engine().tenant_stats();
    assert_eq!(base_stats.len(), 1);
    assert!(base_stats[0].posted_bytes >= (HOG_PAGES + VICTIM_PAGES) * PAGE);
}
