//! Smoke-level integration over every experiment harness: each figure
//! regenerates without panicking and mentions its paper comparison.

use rdmabox::experiments::{run_by_id, ExpCtx, ALL_IDS};

#[test]
fn every_figure_regenerates_and_cites_the_paper() {
    let ctx = ExpCtx::quick();
    for id in ALL_IDS {
        let out = run_by_id(id, &ctx).unwrap_or_else(|| panic!("unknown id {id}"));
        assert!(
            out.contains("paper"),
            "figure {id} must print its paper comparison:\n{out}"
        );
        assert!(out.contains('|'), "figure {id} must render a table");
    }
}

#[test]
fn figure_registry_is_complete() {
    // the paper's evaluation: figures 1,4..14 plus table 1 (figures 2 and 3
    // are design diagrams, not measurements)
    assert_eq!(ALL_IDS.len(), 14); // 12 figures + table 1 + regulator-hook ablation
    for id in ["1", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14", "table1"] {
        assert!(ALL_IDS.contains(&id), "{id} missing");
    }
}
