//! Pinned-seed replay: the calendar-queue scheduler must replay every
//! pinned scenario bit-identically to the pre-refactor `BinaryHeap`
//! event loop.
//!
//! [`rdmabox::util::eventq::ReferenceQueue`] *is* that original loop —
//! the same `(at, seq)`-ordered heap the chaos fabric carried before the
//! shared scheduler existed — kept alive behind
//! [`rdmabox::fabric::chaos::SchedulerKind::Reference`] precisely so
//! this suite can run one scenario on both backends and compare entire
//! [`rdmabox::fabric::chaos::ScenarioReport`]s. Any divergence in pop
//! order — even a FIFO tie-break — shifts virtual time, WC counts,
//! failovers or peak window occupancy somewhere in this set, so
//! "existing pinned seeds replay bit-identically" is a test, not a hope.

use rdmabox::fabric::chaos::{run_scenario, ChaosProfile, FaultPlan, Scenario};

/// Run `sc` on both schedulers and require the full reports equal.
fn assert_bit_identical(sc: Scenario) {
    let reference = sc.clone().with_reference_scheduler();
    let calendar = run_scenario(&sc).unwrap_or_else(|e| {
        panic!(
            "seed {:#x} ({:?}) must pass on the calendar queue: {e}",
            sc.seed, sc.profile
        )
    });
    let heap = run_scenario(&reference).unwrap_or_else(|e| {
        panic!(
            "seed {:#x} ({:?}) must pass on the reference heap: {e}",
            sc.seed, sc.profile
        )
    });
    assert_eq!(
        calendar, heap,
        "seed {:#x} ({:?}) diverged between schedulers",
        sc.seed, sc.profile
    );
}

/// The sweep's historical pinned seeds across every small-cluster
/// profile — the exact seed streams that existed before the calendar
/// queue landed (the profiles draw no scale randomness, so these
/// scenarios are byte-for-byte what the heap scheduler used to run).
#[test]
fn pinned_small_cluster_seeds_replay_bit_identically() {
    for (seed, profile) in [
        (0xA11CE, ChaosProfile::Standard),
        (0xBEEF, ChaosProfile::Standard),
        (0x52D3_A201, ChaosProfile::Standard),
        (0x52D3_A202, ChaosProfile::Standard),
        (0xFEED, ChaosProfile::ElectionHeavy),
        (0x1, ChaosProfile::ElectionHeavy),
        (0xB05_F00D, ChaosProfile::Qos),
        (0x2, ChaosProfile::Qos),
    ] {
        assert_bit_identical(Scenario::randomized_with_profile(seed, profile));
    }
}

/// The scale profile's own stream: hundreds of nodes with
/// rack-correlated fault bursts — the event population where the
/// calendar queue's bucketing (and its FIFO tie-breaking under
/// same-tick correlated deaths) actually matters.
#[test]
fn pinned_scale_seeds_replay_bit_identically() {
    for seed in [0x5CA1E, 0x5CA1F] {
        assert_bit_identical(Scenario::randomized_with_profile(seed, ChaosProfile::Scale));
    }
}

/// The recovery profile's stream: lost completions, a wedged QP, and
/// the deadline timer ticks they arm — the first event class the
/// fabric schedules from the engine's own timer queue. Both backends
/// must expire deadlines, flush the wedged QP and re-admit it at
/// exactly the same virtual times, or the full-report comparison
/// (timeouts, flushes, resets, window peaks, elapsed time) diverges.
#[test]
fn pinned_recovery_seeds_replay_bit_identically() {
    for seed in [0x2EC0_1u64, 0x2EC0_2] {
        assert_bit_identical(Scenario::randomized_with_profile(
            seed,
            ChaosProfile::Recovery,
        ));
    }
}

/// A named lossy + wedged plan with explicit deadline parameters — the
/// hand-built recovery schedule, replayed on both backends.
#[test]
fn named_recovery_plan_replays_bit_identically() {
    let plan = FaultPlan::none()
        .with_lost_wcs(0.2)
        .wedge(1, 10_000, 120_000)
        .with_errors(0.1);
    assert_bit_identical(
        Scenario::named("named_recovery_replay", 0x2EC0_3, plan).with_deadlines(80_000, 1),
    );
}

/// A named scenario with a dense hand-built plan: every event class the
/// fabric schedules (deliveries, reorders, duplicates, reg stalls,
/// storms, node churn) in one schedule, replayed on both backends.
#[test]
fn named_fault_mix_replays_bit_identically() {
    let plan = FaultPlan::none()
        .with_errors(0.2)
        .with_reordering(0.3, 20_000)
        .with_duplicates(0.2, 5_000)
        .with_reg_stalls(0.3, 60_000)
        .latency_storm(10_000, 90_000, 30_000)
        .node_down(1, 40_000)
        .node_up(1, 400_000);
    assert_bit_identical(Scenario::named("named_fault_mix_replay", 0x51DE0, plan));
}
