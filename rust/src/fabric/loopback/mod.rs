//! Live loopback fabric: the node-level abstraction running on real
//! threads with real memory — and, since the `IoEngine` refactor, a real
//! instance of the **same pipeline** the simulator drives: submissions go
//! through the sharded per-QP merge queues, the batch planner and the
//! admission window of [`crate::coordinator::engine::IoEngine`];
//! completions are retired through a [`PollerFsm`] completion loop.
//!
//! Topology mirrors the paper's multi-channel design (§6.1): every remote
//! node exposes `qps_per_node` QPs, each QP is a worker thread owning the
//! 1 MiB address regions the engine's address-affine sharding routes to it
//! (so K channels per node really do move bytes in parallel, like K NIC
//! processing units). "RDMA" verbs are memcpys through those regions;
//! completions flow back over a shared completion queue.
//!
//! The client is built from an [`EngineSpec`] ([`LiveBox::build`]), the
//! same construction surface the sim and chaos backends use. With
//! replication in the spec (`.replicated(r)`) the engine also runs the
//! §6 node abstraction live: replicated writes fan out, reads fail over
//! to the next alive replica on error, and all-replicas-dead surfaces
//! the disk-fallback signal instead of hanging. With resync on top
//! (`.resync(chunk)`) a revived donor re-enters in `Resyncing` state and
//! the engine replays the writes it missed — as real memcpys from an
//! alive peer, through the same pipeline — before it serves reads again,
//! so the bytes a revived node returns are never stale.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{DrainOut, IoEngine, SHARD_REGION_SHIFT};
use crate::coordinator::node::NodeState;
use crate::coordinator::polling::{PollStep, PollerFsm, PollingMode};
use crate::coordinator::spec::EngineSpec;
use crate::fabric::{
    AppIo, Dir, NodeId, OpKind, QpId, TenantId, Wc, WcStatus, WorkRequest, DEFAULT_TENANT,
};
use crate::paging::DiskSpans;
use crate::util::fxhash::FxHashMap;

const REGION_BYTES: usize = 1 << SHARD_REGION_SHIFT;

enum QpReq {
    Work {
        wr: WorkRequest,
        /// Write payload (concatenated in remote-address order for merged
        /// WRs); `None` for reads.
        payload: Option<Vec<u8>>,
    },
    Shutdown,
}

/// A completion with the read payload riding along (the live stand-in for
/// DMA into the registered destination buffer).
struct LiveWc {
    wc: Wc,
    data: Option<Vec<u8>>,
}

/// One QP worker: owns the address regions sharded onto this channel.
/// Memory is a sparse region map, zero-filled on first touch — every QP of
/// a node sees a disjoint slice of that node's address space, which is
/// what lets K channels memcpy in parallel without locks.
fn qp_worker(
    qp: QpId,
    capacity: usize,
    rx: Receiver<QpReq>,
    alive: Arc<AtomicBool>,
    cq: Sender<LiveWc>,
) {
    let mut regions: FxHashMap<u64, Vec<u8>> = FxHashMap::default();
    while let Ok(req) = rx.recv() {
        let QpReq::Work { wr, payload } = req else {
            break;
        };
        // donated-capacity invariant: addressing past what the node donated
        // is a caller bug — fail fast like the fixed-size buffer used to
        assert!(
            wr.remote_addr + wr.len <= capacity as u64,
            "loopback access beyond donated capacity: addr {} + len {} > {}",
            wr.remote_addr,
            wr.len,
            capacity
        );
        if !alive.load(Ordering::Relaxed) {
            // dead node: every verb completes in error (failover path)
            let _ = cq.send(LiveWc {
                wc: Wc {
                    wr_id: wr.wr_id,
                    qp,
                    op: wr.op,
                    len: wr.len,
                    app_ios: wr.app_ios,
                    tenant: wr.tenant,
                    status: WcStatus::Error,
                },
                data: None,
            });
            continue;
        }
        let data = match wr.op {
            OpKind::Write | OpKind::Send => {
                let payload = payload.expect("write payload");
                debug_assert_eq!(payload.len() as u64, wr.len);
                region_write(&mut regions, wr.remote_addr, &payload);
                None
            }
            OpKind::Read => {
                let mut buf = vec![0u8; wr.len as usize];
                region_read(&mut regions, wr.remote_addr, &mut buf);
                Some(buf)
            }
        };
        let _ = cq.send(LiveWc {
            wc: Wc {
                wr_id: wr.wr_id,
                qp,
                op: wr.op,
                len: wr.len,
                app_ios: wr.app_ios,
                tenant: wr.tenant,
                status: WcStatus::Success,
            },
            data,
        });
    }
}

fn region_write(regions: &mut FxHashMap<u64, Vec<u8>>, addr: u64, data: &[u8]) {
    let mut off = 0usize;
    while off < data.len() {
        let a = addr + off as u64;
        let region = a >> SHARD_REGION_SHIFT;
        let ro = (a as usize) & (REGION_BYTES - 1);
        let n = (REGION_BYTES - ro).min(data.len() - off);
        let buf = regions
            .entry(region)
            .or_insert_with(|| vec![0u8; REGION_BYTES]);
        buf[ro..ro + n].copy_from_slice(&data[off..off + n]);
        off += n;
    }
}

fn region_read(regions: &mut FxHashMap<u64, Vec<u8>>, addr: u64, out: &mut [u8]) {
    let mut off = 0usize;
    while off < out.len() {
        let a = addr + off as u64;
        let region = a >> SHARD_REGION_SHIFT;
        let ro = (a as usize) & (REGION_BYTES - 1);
        let n = (REGION_BYTES - ro).min(out.len() - off);
        match regions.get(&region) {
            Some(buf) => out[off..off + n].copy_from_slice(&buf[ro..ro + n]),
            None => out[off..off + n].fill(0),
        }
        off += n;
    }
}

/// Cluster of loopback memory donors: `qps_per_node` worker threads per
/// remote node, one shared completion queue.
pub struct LoopbackFabric {
    qp_txs: Vec<Sender<QpReq>>,
    handles: Vec<JoinHandle<()>>,
    /// Taken by [`LiveBox`] at construction (`Mutex` keeps the fabric —
    /// and therefore the client embedding it — `Sync`).
    cq_rx: Mutex<Option<Receiver<LiveWc>>>,
    alive: Vec<Arc<AtomicBool>>,
    nodes: usize,
    qps_per_node: usize,
    pub capacity_per_node: usize,
}

impl LoopbackFabric {
    /// One channel per node (back-compat default).
    pub fn start(nodes: usize, capacity_per_node: usize) -> Self {
        Self::start_sharded(nodes, capacity_per_node, 1)
    }

    /// `qps_per_node` channels per node — the §6.1 multi-channel topology.
    pub fn start_sharded(nodes: usize, capacity_per_node: usize, qps_per_node: usize) -> Self {
        assert!(nodes > 0 && qps_per_node > 0);
        let (cq_tx, cq_rx) = channel();
        let alive: Vec<Arc<AtomicBool>> =
            (0..nodes).map(|_| Arc::new(AtomicBool::new(true))).collect();
        let mut qp_txs = Vec::with_capacity(nodes * qps_per_node);
        let mut handles = Vec::with_capacity(nodes * qps_per_node);
        for qp in 0..nodes * qps_per_node {
            let node = qp / qps_per_node;
            let (tx, rx) = channel();
            let a = alive[node].clone();
            let cq = cq_tx.clone();
            let cap = capacity_per_node;
            handles.push(std::thread::spawn(move || qp_worker(qp, cap, rx, a, cq)));
            qp_txs.push(tx);
        }
        Self {
            qp_txs,
            handles,
            cq_rx: Mutex::new(Some(cq_rx)),
            alive,
            nodes,
            qps_per_node,
            capacity_per_node,
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn qps_per_node(&self) -> usize {
        self.qps_per_node
    }

    fn send(&self, qp: QpId, req: QpReq) {
        self.qp_txs[qp].send(req).expect("qp worker alive");
    }

    fn set_alive(&self, node: NodeId, alive: bool) {
        self.alive[node].store(alive, Ordering::Relaxed);
    }
}

impl Drop for LoopbackFabric {
    fn drop(&mut self) {
        for tx in &self.qp_txs {
            let _ = tx.send(QpReq::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Live statistics of the loopback coordinator.
#[derive(Debug, Default, Clone)]
pub struct LiveStats {
    pub posts: u64,
    pub wqes: u64,
    pub merged_ios: u64,
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub admission_waits: u64,
    pub retired: u64,
    pub disk_fallbacks: u64,
    pub failovers: u64,
}

/// Outcome of one retired live I/O.
struct DoneIo {
    data: Option<Vec<u8>>,
    disk_fallback: bool,
}

struct Inner {
    core: IoEngine,
    /// write sub-io id -> payload awaiting posting (leg-granular: a
    /// split write's subs carry exactly their own leg's bytes).
    payloads: HashMap<u64, Vec<u8>>,
    /// read sub-io id -> (remote addr, len), for scattering merged reads.
    read_addr: HashMap<u64, (u64, u64)>,
    /// read sub-io id -> completed payload (pre-retirement).
    read_data: HashMap<u64, Vec<u8>>,
    /// app read id -> its sub-io ids (one per stripe-local leg); the
    /// retired payload is assembled from the legs in address order.
    read_subs: HashMap<u64, Vec<u64>>,
    /// app write id -> its span, to stamp the disk-ownership maps at
    /// retirement.
    write_spans: HashMap<u64, (u64, u64)>,
    /// The paging layer's per-block disk bit, ordered by write id (ids
    /// are minted in submission order, so they double as a write
    /// sequence); fed from submit-time dead stripes, in-flight write
    /// failures, and the engine's `take_disk_surrenders` signal. See
    /// [`DiskSpans`] for the race-freedom argument.
    disk: DiskSpans,
    /// app io id -> retired outcome, awaiting pickup by the submitter.
    done: HashMap<u64, DoneIo>,
    /// Reused drain buffer: every pump fills this through
    /// [`IoEngine::drain_all_into`], keeping the post path allocation-free
    /// in steady state.
    drain: DrainOut,
    next_id: u64,
    stats: LiveStats,
}

impl Inner {
    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Does the local disk own any byte of `[addr, addr + len)`?
    fn disk_owned(&self, addr: u64, len: u64) -> bool {
        self.disk.disk_owned(addr, len)
    }
}

/// The live RDMAbox client: the full `IoEngine` pipeline (sharded merge
/// queues → batch planner → admission window → replication-aware
/// retirement) over the loopback fabric. Thread-safe; multiple app
/// threads share it — that is the point of the shared merge queues: the
/// earliest thread to reach a drain carries its peers' requests.
pub struct LiveBox {
    fabric: LoopbackFabric,
    inner: Mutex<Inner>,
    cv: Condvar,
    /// The shared completion queue; whoever holds this lock is the poller.
    cq: Mutex<Receiver<LiveWc>>,
    polling: PollingMode,
}

impl LiveBox {
    /// Build the live client from an [`EngineSpec`] — the single
    /// construction surface shared with the sim and chaos backends.
    /// Replication (`.replicated(r)`), resync (`.resync(chunk)`),
    /// donor election (`.election()`) and QoS tenants (`.tenants(w)`)
    /// are all spec fields; the spec's topology must match the fabric's.
    pub fn build(fabric: LoopbackFabric, spec: &EngineSpec) -> Arc<Self> {
        assert_eq!(
            spec.nodes,
            fabric.nodes(),
            "spec.nodes must match the loopback fabric topology"
        );
        assert_eq!(
            spec.qps_per_node,
            fabric.qps_per_node(),
            "spec.qps_per_node must match the loopback fabric topology"
        );
        let cq_rx = fabric.cq_rx.lock().unwrap().take().expect("fresh fabric");
        let core = IoEngine::build(spec);
        Arc::new(Self {
            fabric,
            inner: Mutex::new(Inner {
                core,
                payloads: HashMap::new(),
                read_addr: HashMap::new(),
                read_data: HashMap::new(),
                read_subs: HashMap::new(),
                write_spans: HashMap::new(),
                disk: DiskSpans::default(),
                done: HashMap::new(),
                drain: DrainOut::default(),
                next_id: 1,
                stats: LiveStats::default(),
            }),
            cv: Condvar::new(),
            cq: Mutex::new(cq_rx),
            polling: PollingMode::Adaptive {
                batch: 16,
                max_retry: 32,
            },
        })
    }

    pub fn stats(&self) -> LiveStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Per-tenant QoS counters of the embedded engine (one row per
    /// registered tenant; a spec without `.tenants(..)` has exactly one).
    pub fn tenant_stats(&self) -> Vec<crate::metrics::TenantStats> {
        self.inner.lock().unwrap().core.tenant_stats()
    }

    pub fn nodes(&self) -> usize {
        self.fabric.nodes()
    }

    /// Kill a node: in-flight verbs complete in error (driving read
    /// failover), and placement routing stops selecting it.
    pub fn fail_node(&self, node: NodeId) {
        self.fabric.set_alive(node, false);
        let mut g = self.inner.lock().unwrap();
        g.core.on_node_down(node);
    }

    /// Bring a node back. On a resync-enabled client (a spec with
    /// `.resync(chunk)`) it re-enters in `Resyncing`
    /// state — excluded from routing while the engine replays the writes
    /// it missed from an alive peer — and only then returns to `Alive`
    /// ([`LiveBox::wait_node_alive`] blocks on that). Without resync it
    /// rejoins immediately, and may serve stale data for blocks written
    /// during its downtime.
    pub fn revive_node(&self, node: NodeId) {
        self.fabric.set_alive(node, true);
        let mut g = self.inner.lock().unwrap();
        g.core.on_node_up(node);
        // repair copies (if any) were queued: post them
        self.pump(&mut g);
    }

    /// Lifecycle state of a node in the placement map (`None` on a
    /// direct-routing client).
    pub fn node_state(&self, node: NodeId) -> Option<NodeState> {
        self.inner.lock().unwrap().core.node_state(node)
    }

    /// Drive completions until `node` is fully `Alive` (resync done) or
    /// the timeout expires. Returns whether the node made it.
    pub fn wait_node_alive(&self, node: NodeId, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if self.node_state(node) == Some(NodeState::Alive) {
                return true;
            }
            if Instant::now() > deadline {
                return false;
            }
            if let Ok(rx) = self.cq.try_lock() {
                self.poll_burst(&rx);
            } else {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    }

    // ---------------- direct (node-addressed) API ----------------

    /// Synchronous remote write through the full pipeline: enqueue →
    /// (merge-)drain → post → wait for retirement. Returns `true` when the
    /// data was stored remotely; `false` if the node had been failed
    /// (direct routing has no failover — the bytes were not written).
    pub fn write(&self, node: NodeId, addr: u64, data: &[u8]) -> bool {
        self.write_t(DEFAULT_TENANT, node, addr, data)
    }

    /// [`LiveBox::write`] billed to a specific QoS tenant: the bytes
    /// occupy that tenant's admission sub-window and drain through its
    /// weighted merge-queue lane. The tenant must have been registered
    /// via [`EngineSpec::tenants`] on the spec this client was built
    /// from.
    pub fn write_t(&self, tenant: TenantId, node: NodeId, addr: u64, data: &[u8]) -> bool {
        let id = self.submit_write(tenant, Some(node), addr, data);
        !self.wait_done(id).disk_fallback
    }

    /// Synchronous remote read through the full pipeline.
    ///
    /// # Panics
    /// Panics if `node` has been failed with [`LiveBox::fail_node`] —
    /// direct routing has no failover; use the placed API for that.
    pub fn read(&self, node: NodeId, addr: u64, len: u64) -> Vec<u8> {
        self.read_t(DEFAULT_TENANT, node, addr, len)
    }

    /// [`LiveBox::read`] billed to a specific QoS tenant (see
    /// [`LiveBox::write_t`]).
    pub fn read_t(&self, tenant: TenantId, node: NodeId, addr: u64, len: u64) -> Vec<u8> {
        let id = self.submit_read(tenant, Some(node), addr, len);
        self.wait_done(id)
            .data
            .expect("direct read failed (node dead?) — placed routing has failover")
    }

    // ---------------- placed (replicated) API ----------------

    /// Replicated write via the node map. Returns `false` when every
    /// replica was dead and the disk-fallback signal fired instead.
    /// Requires a client built from a replicated [`EngineSpec`].
    pub fn write_placed(&self, addr: u64, data: &[u8]) -> bool {
        self.assert_placed();
        let id = self.submit_write(DEFAULT_TENANT, None, addr, data);
        !self.wait_done(id).disk_fallback
    }

    /// Replicated read via the node map (fails over across replicas).
    /// `None` means the caller owns the disk path: every replica of some
    /// leg is dead, or the span overlaps a range whose authoritative
    /// copy is the local disk (all-replicas-dead write legs, election
    /// disk surrenders) — remote bytes there would be stale.
    /// Requires a client built from a replicated [`EngineSpec`].
    pub fn read_placed(&self, addr: u64, len: u64) -> Option<Vec<u8>> {
        self.assert_placed();
        {
            let mut g = self.inner.lock().unwrap();
            if g.disk_owned(addr, len) {
                g.stats.disk_fallbacks += 1;
                return None;
            }
        }
        let id = self.submit_read(DEFAULT_TENANT, None, addr, len);
        let d = self.wait_done(id);
        if d.disk_fallback {
            None
        } else {
            Some(d.data.expect("read data"))
        }
    }

    // ---------------- pipeline internals ----------------

    /// The placed API on a direct-routing client would silently write to
    /// node 0 unreplicated — refuse loudly instead.
    fn assert_placed(&self) {
        assert!(
            self.inner.lock().unwrap().core.node_map().is_some(),
            "placed API requires a spec with replication (EngineSpec::replicated)"
        );
    }

    fn submit_write(&self, tenant: TenantId, node: Option<NodeId>, addr: u64, data: &[u8]) -> u64 {
        // the one unavoidable full copy happens outside the pipeline
        // lock; per-leg slices are cut from it while holding it
        let mut payload = data.to_vec();
        let mut g = self.inner.lock().unwrap();
        let id = g.fresh_id();
        let io = AppIo {
            id,
            dir: Dir::Write,
            node: node.unwrap_or(0),
            addr,
            len: data.len() as u64,
            thread: 0,
            tenant,
            t_submit: 0,
        };
        let sub = g.core.submit(io);
        // legs whose replicas were all dead at submit: their bytes live
        // on disk only — stamp the spans so reads take the disk path
        for &(a, l) in &sub.disk_legs {
            g.disk.mark(a, l, id);
        }
        if sub.disk_fallback {
            g.stats.disk_fallbacks += 1;
            g.done.insert(
                id,
                DoneIo {
                    data: None,
                    disk_fallback: true,
                },
            );
            return id;
        }
        // each sub carries exactly its own leg's slice of the payload
        // (the engine splits multi-stripe writes into stripe-local legs;
        // direct-mode subs have no engine-side span — they are the io).
        // The last sub takes the buffer when it covers the whole span.
        let n = sub.sub_ids.len();
        for (i, sid) in sub.sub_ids.iter().enumerate() {
            let (a, l) = match g.core.sub_span(*sid) {
                Some((a, l, _)) => (a, l),
                None => (addr, payload.len() as u64),
            };
            let p = if i + 1 == n && a == addr && l == payload.len() as u64 {
                std::mem::take(&mut payload)
            } else {
                let off = (a - addr) as usize;
                payload[off..off + l as usize].to_vec()
            };
            g.payloads.insert(*sid, p);
        }
        g.write_spans.insert(id, (addr, data.len() as u64));
        self.pump(&mut g);
        id
    }

    fn submit_read(&self, tenant: TenantId, node: Option<NodeId>, addr: u64, len: u64) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let id = g.fresh_id();
        let io = AppIo {
            id,
            dir: Dir::Read,
            node: node.unwrap_or(0),
            addr,
            len,
            thread: 0,
            tenant,
            t_submit: 0,
        };
        let sub = g.core.submit(io);
        if sub.disk_fallback {
            g.stats.disk_fallbacks += 1;
            g.done.insert(
                id,
                DoneIo {
                    data: None,
                    disk_fallback: true,
                },
            );
            return id;
        }
        for sid in &sub.sub_ids {
            let (a, l) = match g.core.sub_span(*sid) {
                Some((a, l, _)) => (a, l),
                None => (addr, len), // direct mode: the sub is the io
            };
            g.read_addr.insert(*sid, (a, l));
        }
        g.read_subs.insert(id, sub.sub_ids.to_vec());
        self.pump(&mut g);
        id
    }

    /// Drain whatever is admitted and hand the chains to the QP workers.
    /// Also absorbs any ranges the engine's donor election surrendered to
    /// the disk path since the last pump (every submit / completion /
    /// revival that can surrender is followed by a pump).
    fn pump(&self, g: &mut Inner) {
        // surrendered ranges reflect every write issued so far, so stamp
        // them with the *next* id: only a write submitted after the
        // surrender can heal them back to remote ownership
        let surrender_stamp = g.next_id;
        for (_, a, l) in g.core.take_disk_surrenders() {
            g.disk.mark(a, l, surrender_stamp);
        }
        let Inner {
            core,
            drain,
            payloads,
            stats,
            ..
        } = g;
        core.drain_all_into(0, drain);
        if drain.admission_blocked > 0 {
            stats.admission_waits += drain.admission_blocked;
        }
        stats.merged_ios += drain.merged_ios;
        let mut wrs = drain.wrs.drain(..);
        for chain in drain.chains.drain(..) {
            stats.posts += 1;
            for wr in wrs.by_ref().take(chain.end - chain.start) {
                stats.wqes += 1;
                let payload = match wr.op {
                    OpKind::Write | OpKind::Send => {
                        // merged WRs carry app_ios in remote-address order
                        // (the planner sorts runs), so concatenation
                        // reconstructs the contiguous payload
                        let mut buf = Vec::with_capacity(wr.len as usize);
                        for sid in &wr.app_ios {
                            buf.extend_from_slice(&payloads.remove(sid).expect("payload"));
                        }
                        Some(buf)
                    }
                    OpKind::Read => None,
                };
                self.fabric.send(chain.qp, QpReq::Work { wr, payload });
            }
        }
    }

    /// Block until `id` retires, polling the completion queue when this
    /// thread can take the poller role (PollerFsm-guided, like a poller
    /// thread in the sim).
    fn wait_done(&self, id: u64) -> DoneIo {
        loop {
            {
                let mut g = self.inner.lock().unwrap();
                if let Some(d) = g.done.remove(&id) {
                    return d;
                }
            }
            if let Ok(rx) = self.cq.try_lock() {
                self.poll_burst(&rx);
            } else {
                // someone else is polling; sleep until they retire work
                let g = self.inner.lock().unwrap();
                if g.done.contains_key(&id) {
                    continue;
                }
                let _ = self.cv.wait_timeout(g, Duration::from_micros(200)).unwrap();
            }
        }
    }

    /// One poller activation: run the completion state machine until it
    /// re-arms with an empty queue (then return so the caller can re-check
    /// its own I/O).
    fn poll_burst(&self, rx: &Receiver<LiveWc>) {
        let mut fsm = PollerFsm::new(self.polling);
        let mut step = fsm.on_wake(0);
        loop {
            match step {
                PollStep::Poll { max } => {
                    let mut batch = Vec::new();
                    while (batch.len() as u32) < max {
                        match rx.try_recv() {
                            Ok(w) => batch.push(w),
                            Err(_) => break,
                        }
                    }
                    let got = batch.len() as u32;
                    if got > 0 {
                        self.handle_wcs(batch);
                    }
                    step = fsm.after_poll(got, 0);
                }
                PollStep::Rearm => {
                    // "interrupt wait": one short blocking receive, then
                    // hand the poller role back
                    match rx.recv_timeout(Duration::from_micros(100)) {
                        Ok(w) => {
                            self.handle_wcs(vec![w]);
                            step = fsm.on_wake(0);
                        }
                        Err(_) => return,
                    }
                }
            }
        }
    }

    fn handle_wcs(&self, wcs: Vec<LiveWc>) {
        let mut g = self.inner.lock().unwrap();
        for LiveWc { wc, data } in wcs {
            if wc.status == WcStatus::Success {
                match wc.op {
                    OpKind::Read => g.stats.bytes_read += wc.len,
                    _ => g.stats.bytes_written += wc.len,
                }
                if let Some(buf) = data {
                    // scatter the merged read payload back to its
                    // sub-I/Os: app subs are tracked in read_addr,
                    // engine-internal resync source reads resolve their
                    // span through the engine itself
                    let mut spans: Vec<(u64, u64, u64)> = Vec::new();
                    for sid in &wc.app_ios {
                        if let Some(&(addr, len)) = g.read_addr.get(sid) {
                            spans.push((*sid, addr, len));
                        } else if let Some((addr, len, _)) = g.core.sub_span(*sid) {
                            spans.push((*sid, addr, len));
                        }
                    }
                    let base = spans.iter().map(|&(_, a, _)| a).min().unwrap_or(0);
                    for (sid, addr, len) in spans {
                        let off = (addr - base) as usize;
                        g.read_data.insert(sid, buf[off..off + len as usize].to_vec());
                    }
                }
            }
            let out = g.core.on_wc(&wc, 0);
            g.stats.failovers += out.requeued as u64;
            // advance resync copies: the bytes the source read returned
            // become the payload of the repair write to the recovering
            // node (posted by the pump below)
            for c in &out.resync_copies {
                if let Some(bytes) = g.read_data.remove(&c.read_sub) {
                    g.payloads.insert(c.write_sub, bytes);
                }
            }
            // release per-sub state of terminally failed sub-I/Os (e.g. a
            // placed read whose every replica died -> disk fallback)
            for (sid, _) in &out.failed_subs {
                g.read_addr.remove(sid);
                g.read_data.remove(sid);
                g.payloads.remove(sid);
            }
            for r in out.retired {
                // a retired read assembles its payload from its legs in
                // address order (split reads complete leg by leg, each
                // leg's bytes parked in read_data until the parent
                // retires); a retired write heals the disk-span tracker
                let data = if let Some(sids) = g.read_subs.remove(&r.id) {
                    let mut parts: Vec<(u64, Vec<u8>)> = Vec::new();
                    let mut complete = !r.disk_fallback;
                    for sid in &sids {
                        let span = g.read_addr.remove(sid);
                        match (span, g.read_data.remove(sid)) {
                            (Some((a, _)), Some(d)) => parts.push((a, d)),
                            _ => complete = false,
                        }
                    }
                    if complete {
                        parts.sort_by_key(|&(a, _)| a);
                        let mut buf = Vec::new();
                        for (_, d) in parts {
                            buf.extend_from_slice(&d);
                        }
                        Some(buf)
                    } else {
                        None
                    }
                } else {
                    if let Some((a, l)) = g.write_spans.remove(&r.id) {
                        if r.disk_fallback {
                            // some leg of this write is durable nowhere
                            // remote (e.g. every replica died while it
                            // was in flight): disk owns the span
                            g.disk.mark(a, l, r.id);
                        } else {
                            // the write is durable on every leg's
                            // replicas: the remote side owns the span
                            // (unless a *newer* write marked it disk)
                            g.disk.heal(a, l, r.id);
                        }
                    }
                    None
                };
                if r.disk_fallback {
                    g.stats.disk_fallbacks += 1;
                }
                g.stats.retired += 1;
                g.done.insert(
                    r.id,
                    DoneIo {
                        data,
                        disk_fallback: r.disk_fallback,
                    },
                );
            }
        }
        // freed window / failover requeues: one drain for the whole batch
        // keeps the pipeline moving without re-scanning shards per WC
        self.pump(&mut g);
        drop(g);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batching::BatchMode;
    use crate::coordinator::spec::DEFAULT_RESYNC_CHUNK;

    #[test]
    fn write_read_roundtrip() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::build(fab, &EngineSpec::new(2).window(Some(1 << 20)));
        let data: Vec<u8> = (0..4096u32).map(|x| (x % 251) as u8).collect();
        lb.write(1, 8192, &data);
        let back = lb.read(1, 8192, 4096);
        assert_eq!(back, data);
        let s = lb.stats();
        assert_eq!(s.bytes_written, 4096);
        assert_eq!(s.bytes_read, 4096);
    }

    #[test]
    fn distinct_nodes_are_isolated() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::build(fab, &EngineSpec::new(2));
        lb.write(0, 0, &[1u8; 64]);
        lb.write(1, 0, &[2u8; 64]);
        assert_eq!(lb.read(0, 0, 64), vec![1u8; 64]);
        assert_eq!(lb.read(1, 0, 64), vec![2u8; 64]);
    }

    #[test]
    fn concurrent_writers_merge_adjacent_pages() {
        let fab = LoopbackFabric::start(1, 1 << 22);
        let lb = LiveBox::build(fab, &EngineSpec::new(1));
        let lb2 = lb.clone();
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let lb = lb2.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..32u64 {
                    let page = t * 32 + i;
                    let byte = (page % 251) as u8;
                    lb.write(0, page * 4096, &vec![byte; 4096]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = lb.stats(); // snapshot before verification reads add wqes
        // all 256 pages landed correctly
        for page in 0..256u64 {
            let b = lb.read(0, page * 4096, 4096);
            assert_eq!(b[0], (page % 251) as u8, "page {page}");
            assert_eq!(b[4095], (page % 251) as u8);
        }
        assert_eq!(s.bytes_written, 256 * 4096);
        // writes never need more WQEs than I/Os (merging can only shrink)
        assert!(s.wqes <= 256, "wqes {} should not exceed ios", s.wqes);
    }

    #[test]
    fn sharded_channels_preserve_contents() {
        let fab = LoopbackFabric::start_sharded(2, 16 << 20, 4);
        let lb = LiveBox::build(fab, &EngineSpec::new(2).qps(4).window(Some(7 << 20)));
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let lb = lb.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..48u64 {
                    let page = i * 6 + t;
                    let node = (page % 2) as usize;
                    // spread pages over many 1 MiB regions so all 4 shards
                    // per node carry traffic
                    let addr = (page % 8) * (1 << SHARD_REGION_SHIFT) + (page / 8) * 4096;
                    lb.write(node, addr, &vec![(page % 199) as u8 + 1; 4096]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for page in 0..288u64 {
            let node = (page % 2) as usize;
            let addr = (page % 8) * (1 << SHARD_REGION_SHIFT) + (page / 8) * 4096;
            let b = lb.read(node, addr, 4096);
            assert_eq!(b[0], (page % 199) as u8 + 1, "page {page}");
            assert_eq!(b[4095], (page % 199) as u8 + 1, "page {page}");
        }
        assert_eq!(lb.stats().retired as usize, 288 + 288);
    }

    #[test]
    fn admission_window_counts_waits_under_pressure() {
        let fab = LoopbackFabric::start(1, 1 << 22);
        let lb = LiveBox::build(
            fab,
            &EngineSpec::new(1).batch(BatchMode::Single).window(Some(4096)),
        );
        for i in 0..16u64 {
            lb.write(0, i * 4096, &[7u8; 4096]);
        }
        // single-window synchronous writes never exceed the window
        assert_eq!(lb.stats().bytes_written, 16 * 4096);
    }

    #[test]
    fn placed_write_replicates_and_read_fails_over() {
        let fab = LoopbackFabric::start_sharded(3, 1 << 22, 2);
        let lb = LiveBox::build(
            fab,
            &EngineSpec::new(3).qps(2).window(Some(7 << 20)).replicated(2),
        );
        for page in 0..32u64 {
            assert!(lb.write_placed(page * 4096, &vec![(page + 1) as u8; 4096]));
        }
        // both replicas carry the data: killing any single node must not
        // lose a block
        lb.fail_node(0);
        for page in 0..32u64 {
            let b = lb.read_placed(page * 4096, 4096).expect("replica alive");
            assert_eq!(b[0], (page + 1) as u8, "page {page}");
        }
        lb.revive_node(0);
    }

    /// The live analogue of the chaos stale-read scenario: kill a
    /// replica, overwrite its blocks, revive it. With resync, the
    /// revived node's real memory is repaired (memcpys from the peer)
    /// before it serves — so even with the peer gone, every byte it
    /// returns is the post-death version.
    #[test]
    fn revived_node_resyncs_real_bytes_before_serving() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::build(
            fab,
            &EngineSpec::new(2).replicated(2).resync(DEFAULT_RESYNC_CHUNK),
        );
        let v1: Vec<u8> = (0..4096u32).map(|x| (x % 191) as u8).collect();
        for page in 0..8u64 {
            assert!(lb.write_placed(page * 4096, &v1));
        }
        lb.fail_node(0);
        // overwrite while the primary is down: only node 1 holds v2
        let v2: Vec<u8> = (0..4096u32).map(|x| (x % 113) as u8 + 1).collect();
        for page in 0..8u64 {
            assert!(lb.write_placed(page * 4096, &v2));
        }
        lb.revive_node(0);
        assert!(
            lb.wait_node_alive(0, Duration::from_secs(10)),
            "resync must complete"
        );
        // the repaired primary is the only replica left: its memcpys
        // must now hold the bytes written during its downtime
        lb.fail_node(1);
        for page in 0..8u64 {
            let b = lb.read_placed(page * 4096, 4096).expect("node 0 alive");
            assert_eq!(b, v2, "page {page} must not serve stale bytes");
        }
        assert_eq!(lb.stats().disk_fallbacks, 0);
    }

    /// The splitter end-to-end with real bytes: a request straddling a
    /// stripe (= 1 MiB region) boundary is split into stripe-local legs,
    /// each replicated on its own stripe's nodes, and the read payload is
    /// reassembled from the legs in address order.
    #[test]
    fn split_requests_roundtrip_real_bytes_across_stripes() {
        let fab = LoopbackFabric::start_sharded(3, 4 << 20, 2);
        let lb = LiveBox::build(fab, &EngineSpec::new(3).qps(2).replicated(2));
        let addr = (1u64 << SHARD_REGION_SHIFT) - 8192;
        let data: Vec<u8> = (0..4 * 4096u32).map(|x| (x % 241) as u8 + 1).collect();
        assert!(lb.write_placed(addr, &data), "split write lands remotely");
        let back = lb.read_placed(addr, data.len() as u64).expect("replicas alive");
        assert_eq!(back, data, "legs reassemble in address order");
        // stripe 0 lives on {0,1}, stripe 1 on {1,2}: killing node 0
        // only affects the first leg, which fails over to node 1
        lb.fail_node(0);
        let back = lb.read_placed(addr, data.len() as u64).expect("failover");
        assert_eq!(back, data);
        assert_eq!(lb.stats().disk_fallbacks, 0);
    }

    /// Full-cluster churn with the donor election: all peers of a
    /// revived node are dead, so its missed range has no live copy — the
    /// election surrenders the span to the disk path (reads return the
    /// disk-fallback signal, not stale bytes) and the node still rejoins
    /// `Alive`. A later write lands remotely and heals the span.
    #[test]
    fn all_peers_down_recovers_via_disk_path_live() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::build(
            fab,
            &EngineSpec::new(2)
                .replicated(2)
                .resync(DEFAULT_RESYNC_CHUNK)
                .election(),
        );
        let v1: Vec<u8> = vec![0x11; 4096];
        for page in 0..4u64 {
            assert!(lb.write_placed(page * 4096, &v1));
        }
        lb.fail_node(0);
        let v2: Vec<u8> = vec![0x22; 4096];
        for page in 0..4u64 {
            assert!(lb.write_placed(page * 4096, &v2), "peer still alive");
        }
        lb.fail_node(1); // the only holder of v2 dies
        lb.revive_node(0);
        assert!(
            lb.wait_node_alive(0, Duration::from_secs(10)),
            "no live donor: the node surrenders its backlog and rejoins"
        );
        // the surrendered span must NOT serve node 0's stale v1 bytes
        for page in 0..4u64 {
            assert!(
                lb.read_placed(page * 4096, 4096).is_none(),
                "page {page}: disk owns the span"
            );
        }
        // a fresh write (to the one alive node) heals the span remotely
        let v3: Vec<u8> = vec![0x33; 4096];
        assert!(lb.write_placed(0, &v3));
        assert_eq!(lb.read_placed(0, 4096).expect("healed"), v3);
        // untouched pages stay disk-backed
        assert!(lb.read_placed(4096, 4096).is_none());
    }

    #[test]
    fn placed_all_dead_surfaces_disk_fallback() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::build(fab, &EngineSpec::new(2).replicated(2));
        assert!(lb.write_placed(0, &[9u8; 4096]));
        lb.fail_node(0);
        lb.fail_node(1);
        assert!(!lb.write_placed(4096, &[9u8; 4096]), "disk fallback signal");
        assert!(lb.read_placed(0, 4096).is_none());
        assert!(lb.stats().disk_fallbacks >= 2);
    }

    /// A QoS-enabled spec drives the live pipeline unchanged: the client's
    /// own traffic bills to tenant 0, the idle tenant stays at zero, and
    /// the exported rows cover every registered tenant.
    #[test]
    fn qos_spec_exports_tenant_rows() {
        let fab = LoopbackFabric::start(1, 1 << 20);
        let lb = LiveBox::build(fab, &EngineSpec::new(1).tenants(&[3, 1]));
        lb.write(0, 0, &[5u8; 4096]);
        assert_eq!(lb.read(0, 0, 4096), vec![5u8; 4096]);
        let ts = lb.tenant_stats();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].weight, 3);
        assert_eq!(ts[0].posted_bytes, 2 * 4096);
        assert_eq!(ts[0].retired_bytes, 2 * 4096);
        assert_eq!(ts[0].drained_bytes, 2 * 4096);
        assert_eq!(ts[0].window_occupancy, 0);
        assert_eq!(ts[1].posted_bytes, 0);
        assert_eq!(ts[1].drained_bytes, 0);
    }
}
