//! Live loopback fabric: the node-level abstraction running on real
//! threads with real memory. Remote nodes are server threads owning their
//! donated buffers; "RDMA" verbs are memcpys through registered regions,
//! with completions flowing back over channels. The same coordinator
//! policy objects (merge queue, batch planner, admission regulator) run on
//! this backend — this is what the `examples/` use, including the
//! end-to-end ML training driver where the moved bytes feed real PJRT
//! compute.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::batching::{plan, BatchLimits, BatchMode};
use crate::coordinator::merge_queue::{MergeCheck, MergeQueues};
use crate::coordinator::regulator::Regulator;
use crate::fabric::{AppIo, Dir, NodeId};

enum Req {
    Write {
        addr: u64,
        data: Vec<u8>,
        done: Sender<u64>,
        /// emulate the two-sided receive path: staging copy before commit
        server_copy: bool,
    },
    Read {
        addr: u64,
        len: u64,
        done: Sender<Vec<u8>>,
        server_copy: bool,
    },
    Shutdown,
}

/// One remote memory donor: a thread owning `capacity` bytes.
struct RemoteNode {
    tx: Sender<Req>,
    handle: Option<JoinHandle<()>>,
}

fn node_thread(capacity: usize, rx: Receiver<Req>) {
    let mut mem = vec![0u8; capacity];
    let mut staging = vec![0u8; 1 << 20];
    while let Ok(req) = rx.recv() {
        match req {
            Req::Write {
                addr,
                data,
                done,
                server_copy,
            } => {
                let a = addr as usize;
                if server_copy {
                    // two-sided designs land in a bounce buffer first
                    let n = data.len().min(staging.len());
                    staging[..n].copy_from_slice(&data[..n]);
                }
                mem[a..a + data.len()].copy_from_slice(&data);
                let _ = done.send(data.len() as u64);
            }
            Req::Read {
                addr,
                len,
                done,
                server_copy,
            } => {
                let a = addr as usize;
                let l = len as usize;
                if server_copy {
                    let n = l.min(staging.len());
                    staging[..n].copy_from_slice(&mem[a..a + n]);
                }
                let _ = done.send(mem[a..a + l].to_vec());
            }
            Req::Shutdown => break,
        }
    }
}

/// Cluster of loopback memory donors.
pub struct LoopbackFabric {
    nodes: Vec<RemoteNode>,
    pub capacity_per_node: usize,
}

impl LoopbackFabric {
    pub fn start(nodes: usize, capacity_per_node: usize) -> Self {
        let nodes = (0..nodes)
            .map(|_| {
                let (tx, rx) = channel();
                let handle = std::thread::spawn(move || node_thread(capacity_per_node, rx));
                RemoteNode {
                    tx,
                    handle: Some(handle),
                }
            })
            .collect();
        Self {
            nodes,
            capacity_per_node,
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes.len()
    }

    fn write(&self, node: NodeId, addr: u64, data: Vec<u8>, server_copy: bool) -> Receiver<u64> {
        let (done, rx) = channel();
        self.nodes[node]
            .tx
            .send(Req::Write {
                addr,
                data,
                done,
                server_copy,
            })
            .expect("node alive");
        rx
    }

    fn read(&self, node: NodeId, addr: u64, len: u64, server_copy: bool) -> Receiver<Vec<u8>> {
        let (done, rx) = channel();
        self.nodes[node]
            .tx
            .send(Req::Read {
                addr,
                len,
                done,
                server_copy,
            })
            .expect("node alive");
        rx
    }
}

impl Drop for LoopbackFabric {
    fn drop(&mut self) {
        for n in &self.nodes {
            let _ = n.tx.send(Req::Shutdown);
        }
        for n in &mut self.nodes {
            if let Some(h) = n.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Live statistics of the loopback coordinator.
#[derive(Debug, Default, Clone)]
pub struct LiveStats {
    pub posts: u64,
    pub wqes: u64,
    pub merged_ios: u64,
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub admission_waits: u64,
}

/// The live RDMAbox client: merge queue + batch planner + admission window
/// over the loopback fabric. Thread-safe; multiple app threads share it
/// (that is the point of the single merge queue).
pub struct LiveBox {
    fabric: LoopbackFabric,
    queues: Mutex<MergeQueues>,
    regulator: Mutex<Regulator>,
    batch: BatchMode,
    limits: BatchLimits,
    two_sided: bool,
    next_id: Mutex<u64>,
    /// True while some thread is inside the merge+post section; concurrent
    /// writers enqueue and let that thread carry their requests (the
    /// "earliest arriving thread" protocol of §5.1).
    posting: Mutex<bool>,
    stats: Mutex<LiveStats>,
    /// Pending write payloads keyed by app io id.
    payloads: Mutex<HashMap<u64, Vec<u8>>>,
}

impl LiveBox {
    pub fn new(
        fabric: LoopbackFabric,
        batch: BatchMode,
        window_bytes: Option<u64>,
    ) -> Arc<Self> {
        let regulator = match window_bytes {
            Some(w) => Regulator::static_window(w),
            None => Regulator::unlimited(),
        };
        Arc::new(Self {
            fabric,
            queues: Mutex::new(MergeQueues::new()),
            regulator: Mutex::new(regulator),
            batch,
            limits: BatchLimits::default(),
            two_sided: false,
            next_id: Mutex::new(1),
            posting: Mutex::new(false),
            stats: Mutex::new(LiveStats::default()),
            payloads: Mutex::new(HashMap::new()),
        })
    }

    pub fn stats(&self) -> LiveStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn nodes(&self) -> usize {
        self.fabric.nodes()
    }

    fn fresh_id(&self) -> u64 {
        let mut g = self.next_id.lock().unwrap();
        let id = *g;
        *g += 1;
        id
    }

    /// Synchronous remote write through the full coordinator path:
    /// enqueue → merge-check → plan → post. The calling thread performs
    /// the drain it wins (load-aware batching), then waits for its own
    /// I/O to be covered by a completed WR.
    pub fn write(&self, node: NodeId, addr: u64, data: &[u8]) {
        let id = self.fresh_id();
        let len = data.len() as u64;
        self.payloads.lock().unwrap().insert(id, data.to_vec());
        let io = AppIo {
            id,
            dir: Dir::Write,
            node,
            addr,
            len,
            thread: 0,
            t_submit: 0,
        };
        // enqueue, then merge-check immediately (paper §5.1 protocol)
        {
            let mut q = self.queues.lock().unwrap();
            q.of(Dir::Write).push(io);
        }
        loop {
            // a peer inside the post section will carry our request — wait
            // for it to be consumed instead of racing for the drain
            {
                let mut gate = self.posting.lock().unwrap();
                if *gate {
                    drop(gate);
                    if !self.payloads.lock().unwrap().contains_key(&id) {
                        return; // carried and posted by the peer
                    }
                    std::thread::yield_now();
                    continue;
                }
                *gate = true;
            }
            // we are the posting thread now: drain whatever stacked up
            let window = {
                let mut r = self.regulator.lock().unwrap();
                r.available(0)
            };
            let drained = {
                let mut q = self.queues.lock().unwrap();
                match q.of(Dir::Write).merge_check(window) {
                    MergeCheck::Drained(v) => Some(v),
                    MergeCheck::Blocked => None,
                    MergeCheck::TakenByPeer => Some(Vec::new()),
                }
            };
            let done = match drained {
                Some(v) if v.is_empty() => !self.payloads.lock().unwrap().contains_key(&id),
                Some(v) => {
                    let mine = v.iter().any(|x| x.id == id);
                    self.post_writes(v);
                    mine || !self.payloads.lock().unwrap().contains_key(&id)
                }
                None => {
                    self.stats.lock().unwrap().admission_waits += 1;
                    false
                }
            };
            *self.posting.lock().unwrap() = false;
            if done {
                return;
            }
            std::thread::yield_now();
        }
    }

    fn post_writes(&self, ios: Vec<AppIo>) {
        if ios.is_empty() {
            return;
        }
        let mut wr_id = 0u64;
        let (chains, pstats) = plan(self.batch, &self.limits, ios, &mut wr_id);
        {
            let mut s = self.stats.lock().unwrap();
            s.merged_ios += pstats.merged_ios;
            s.posts += pstats.posts;
            s.wqes += pstats.wqes;
        }
        for chain in chains {
            for wr in chain.wrs {
                // merged WRs carry app_ios already in remote-address order
                // (the planner sorts runs by address), so concatenation
                // reconstructs the contiguous payload
                let mut data = Vec::with_capacity(wr.len as usize);
                {
                    let mut pl = self.payloads.lock().unwrap();
                    for id in &wr.app_ios {
                        data.extend_from_slice(&pl.remove(id).expect("payload"));
                    }
                }
                {
                    let mut r = self.regulator.lock().unwrap();
                    r.on_post(wr.len);
                }
                let rx = self
                    .fabric
                    .write(chain.node, wr.remote_addr, data, self.two_sided);
                let n = rx.recv().expect("write completion");
                {
                    let mut r = self.regulator.lock().unwrap();
                    r.on_complete(wr.len, 0);
                    let mut s = self.stats.lock().unwrap();
                    s.bytes_written += n;
                }
            }
        }
    }

    /// Synchronous remote read (page-in path: reads are latency-critical
    /// and post immediately; merging applies to them under load through
    /// the same mechanism, but the live API keeps reads simple).
    pub fn read(&self, node: NodeId, addr: u64, len: u64) -> Vec<u8> {
        {
            let mut r = self.regulator.lock().unwrap();
            while r.available(0) < len {
                drop(r);
                self.stats.lock().unwrap().admission_waits += 1;
                std::thread::yield_now();
                r = self.regulator.lock().unwrap();
            }
            r.on_post(len);
        }
        let rx = self.fabric.read(node, addr, len, self.two_sided);
        let data = rx.recv().expect("read completion");
        {
            let mut r = self.regulator.lock().unwrap();
            r.on_complete(len, 0);
            let mut s = self.stats.lock().unwrap();
            s.bytes_read += data.len() as u64;
            s.wqes += 1;
            s.posts += 1;
        }
        data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::new(fab, BatchMode::Hybrid, Some(1 << 20));
        let data: Vec<u8> = (0..4096u32).map(|x| (x % 251) as u8).collect();
        lb.write(1, 8192, &data);
        let back = lb.read(1, 8192, 4096);
        assert_eq!(back, data);
        let s = lb.stats();
        assert_eq!(s.bytes_written, 4096);
        assert_eq!(s.bytes_read, 4096);
    }

    #[test]
    fn distinct_nodes_are_isolated() {
        let fab = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::new(fab, BatchMode::Hybrid, None);
        lb.write(0, 0, &[1u8; 64]);
        lb.write(1, 0, &[2u8; 64]);
        assert_eq!(lb.read(0, 0, 64), vec![1u8; 64]);
        assert_eq!(lb.read(1, 0, 64), vec![2u8; 64]);
    }

    #[test]
    fn concurrent_writers_merge_adjacent_pages() {
        let fab = LoopbackFabric::start(1, 1 << 22);
        let lb = LiveBox::new(fab, BatchMode::Hybrid, None);
        let lb2 = lb.clone();
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let lb = lb2.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..32u64 {
                    let page = t * 32 + i;
                    let byte = (page % 251) as u8;
                    lb.write(0, page * 4096, &vec![byte; 4096]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = lb.stats(); // snapshot before verification reads add wqes
        // all 256 pages landed correctly
        for page in 0..256u64 {
            let b = lb.read(0, page * 4096, 4096);
            assert_eq!(b[0], (page % 251) as u8, "page {page}");
            assert_eq!(b[4095], (page % 251) as u8);
        }
        assert_eq!(s.bytes_written, 256 * 4096);
        // writes never need more WQEs than I/Os (merging can only shrink)
        assert!(s.wqes <= 256, "wqes {} should not exceed ios", s.wqes);
    }

    #[test]
    fn admission_window_counts_waits_under_pressure() {
        let fab = LoopbackFabric::start(1, 1 << 22);
        let lb = LiveBox::new(fab, BatchMode::Single, Some(4096));
        for i in 0..16u64 {
            lb.write(0, i * 4096, &[7u8; 4096]);
        }
        // single-window synchronous writes never exceed the window
        assert_eq!(lb.stats().bytes_written, 16 * 4096);
    }
}
