//! RDMA fabric abstraction: shared verb-level types plus four backends.
//!
//! * [`sim`] — a calibrated discrete-event simulator of the full RDMA path
//!   (host CPU → MMIO/PCIe → NIC processing units with WQE/QP/MPT caches →
//!   link → remote NIC → completion queue → polling). Regenerates every
//!   figure in the paper deterministically.
//! * [`loopback`] — a live, real-thread shared-memory fabric used by the
//!   examples: remote nodes are threads owning real buffers, "RDMA" is
//!   memcpy through registered regions, and completions flow through real
//!   queues. The same coordinator policy objects drive both backends.
//! * [`chaos`] — a deterministic fault-injecting fabric for correctness
//!   testing: virtual time, a seeded PRNG schedule, and a
//!   [`chaos::FaultPlan`] injecting completion errors, WC reordering,
//!   duplicates, per-QP stalls, partial partitions, and node
//!   death/revival. The fabric carries a payload model (per-page
//!   versioned fingerprints), so data invariants — no stale read from a
//!   revived or diverged replica — are checked alongside the
//!   completion-level ones (exactly-once retirement, admission bound,
//!   failover), all replayable from a single `u64` seed.
//! * [`socket`] — a real-socket peer fabric (TCP or Unix-domain):
//!   length-prefixed frames carrying the shared verb types plus the
//!   coordinator's gossip deltas, so two engines in *separate OS
//!   processes* can run the multi-engine anti-entropy protocol to
//!   fingerprint convergence over an actual byte stream.

pub mod chaos;
pub mod loopback;
pub mod sim;
pub mod socket;

pub use crate::util::idlist::IdList;

/// Identifies a remote peer node (memory donor / server daemon).
pub type NodeId = usize;
/// Queue-pair index (client side, global across peers and channels).
pub type QpId = usize;
/// Completion-queue index.
pub type CqId = usize;
/// Memory region key.
pub type MrKey = u64;
/// Tenant index for multi-tenant QoS: a dense id into the engine's
/// per-tenant weight/ledger tables (RDMAvisor-style RDMA-as-a-service —
/// many workloads multiplexed over shared QPs). Single-tenant setups use
/// [`DEFAULT_TENANT`] throughout and behave exactly as before.
pub type TenantId = usize;
/// The tenant every I/O belongs to unless the submitter says otherwise.
pub const DEFAULT_TENANT: TenantId = 0;

/// RDMA verb kind. One-sided WRITE/READ move payload without remote CPU;
/// two-sided SEND requires a posted RECV and remote CPU handling (the
/// paper's baselines nbdX/Accelio/GlusterFS are two-sided).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Write,
    Read,
    Send,
}

impl OpKind {
    pub fn is_read(self) -> bool {
        matches!(self, OpKind::Read)
    }
}

/// Direction of an application block I/O (paging write-out vs page-in).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    Read,
    Write,
}

impl Dir {
    pub fn op(self) -> OpKind {
        match self {
            Dir::Read => OpKind::Read,
            Dir::Write => OpKind::Write,
        }
    }
}

/// An application-level block I/O request entering the coordinator
/// (page-out/page-in from the paging system, file block from the RFS,
/// raw I/O from FIO). Address space is the *remote* address space of
/// `node` — adjacency there is what Batching-on-MR exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AppIo {
    pub id: u64,
    pub dir: Dir,
    pub node: NodeId,
    /// Remote start address.
    pub addr: u64,
    pub len: u64,
    /// Submitting application thread (for per-thread latency accounting).
    pub thread: usize,
    /// Enqueue timestamp (virtual ns in sim, monotonic ns live).
    pub t_submit: u64,
    /// Owning tenant (admission sub-window + drain lane).
    pub tenant: TenantId,
}

/// A work request as posted to a QP: possibly the merge of several AppIos
/// (Batching-on-MR), carrying a scatter-gather list.
#[derive(Debug, Clone)]
pub struct WorkRequest {
    pub wr_id: u64,
    pub op: OpKind,
    pub node: NodeId,
    pub remote_addr: u64,
    pub len: u64,
    /// Number of scatter/gather entries (merged fragments).
    pub num_sge: usize,
    /// Application I/Os completed when this WR completes. Inline up to
    /// the default SGE merge width, so building a WR does not allocate.
    pub app_ios: IdList,
    pub signaled: bool,
    /// Owning tenant — a WR never merges I/Os of different tenants, so
    /// the whole WR bills to one per-tenant sub-window.
    pub tenant: TenantId,
}

/// Work completion delivered by a CQ.
#[derive(Debug, Clone)]
pub struct Wc {
    pub wr_id: u64,
    pub qp: QpId,
    pub op: OpKind,
    pub len: u64,
    pub app_ios: IdList,
    pub status: WcStatus,
    /// Tenant of the completed WR (copied from the WR by the fabric; the
    /// engine's posted-WR ledger is authoritative for accounting).
    pub tenant: TenantId,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WcStatus {
    Success,
    /// Injected failure (replication / failover tests).
    Error,
}

/// A doorbell chain: one `post_send` of one or more linked WRs. The first
/// WR is written to the NIC by MMIO; the rest are fetched by NIC DMA reads
/// (that is exactly the PCIe saving doorbell batching buys — and why it
/// does *not* reduce the number of WQEs the NIC must process).
#[derive(Debug, Clone)]
pub struct Chain {
    pub qp: QpId,
    pub wrs: Vec<WorkRequest>,
}

impl Chain {
    pub fn total_bytes(&self) -> u64 {
        self.wrs.iter().map(|w| w.len).sum()
    }
    pub fn total_app_ios(&self) -> usize {
        self.wrs.iter().map(|w| w.app_ios.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dir_maps_to_op() {
        assert_eq!(Dir::Read.op(), OpKind::Read);
        assert_eq!(Dir::Write.op(), OpKind::Write);
        assert!(OpKind::Read.is_read());
        assert!(!OpKind::Write.is_read());
    }

    #[test]
    fn chain_totals() {
        let wr = |len: u64, ios: Vec<u64>| WorkRequest {
            wr_id: 0,
            op: OpKind::Write,
            node: 0,
            remote_addr: 0,
            len,
            num_sge: 1,
            app_ios: ios.into(),
            signaled: true,
            tenant: DEFAULT_TENANT,
        };
        let c = Chain {
            qp: 0,
            wrs: vec![wr(4096, vec![1]), wr(8192, vec![2, 3])],
        };
        assert_eq!(c.total_bytes(), 12288);
        assert_eq!(c.total_app_ios(), 3);
    }
}
