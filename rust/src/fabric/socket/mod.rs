//! The socket fabric: real byte streams between peer engines.
//!
//! The other three backends run inside one process; this one connects
//! two engines living in *separate OS processes* (or separate threads
//! over a socketpair) with length-prefixed frames over TCP or a
//! Unix-domain socket. The frame payloads are the crate's existing
//! verb-level types — [`WorkRequest`], [`Wc`] — plus the coordinator's
//! [`GossipDelta`], so the multi-engine anti-entropy protocol runs
//! unchanged over an actual wire: each side exports its delta, absorbs
//! the peer's, and compares [`gossip fingerprints`] until they agree.
//!
//! Wire format (everything little-endian):
//!
//! ```text
//! [u32 frame_len] [u8 kind] [body; frame_len - 1 bytes]
//! ```
//!
//! `frame_len` counts the kind byte plus the body. Kinds: `1` Hello
//! (peer handshake, `u32` engine id), `2` WorkRequest, `3` Wc, `4`
//! gossip delta ([`GossipDelta::encode_into`] body), `5` fingerprint
//! (`u64`). Unknown kinds, truncated bodies, trailing bytes and frames
//! over [`MAX_FRAME_BYTES`] are rejected as `InvalidData` — a corrupt
//! peer can fail the session but never corrupt engine state.
//!
//! The sync loop ([`gossip_sync`]) is deliberately lockstep — send
//! delta, receive delta, exchange fingerprints — so it needs no timers
//! or polling; the frames involved are far below any OS socket buffer,
//! which makes the symmetric send-then-receive order deadlock-free.
//!
//! [`gossip fingerprints`]: crate::coordinator::engine::IoEngine::gossip_fingerprint

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};

use crate::coordinator::engine::IoEngine;
use crate::coordinator::gossip::GossipDelta;
use crate::fabric::{IdList, OpKind, Wc, WcStatus, WorkRequest};

/// Frames larger than this are rejected before allocating.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

const KIND_HELLO: u8 = 1;
const KIND_WR: u8 = 2;
const KIND_WC: u8 = 3;
const KIND_GOSSIP: u8 = 4;
const KIND_FINGERPRINT: u8 = 5;

/// One framed message between peer engines.
#[derive(Debug, Clone)]
pub enum SocketMsg {
    /// Handshake: the sender's engine id in the gossip cluster.
    Hello { engine_id: u32 },
    /// A verb-level work request (remote-execution style peering).
    Wr(WorkRequest),
    /// A verb-level completion.
    Wc(Wc),
    /// One anti-entropy round's full-state delta.
    Gossip(GossipDelta),
    /// The sender's current gossip fingerprint (convergence check).
    Fingerprint(u64),
}

fn op_code(op: OpKind) -> u8 {
    match op {
        OpKind::Write => 0,
        OpKind::Read => 1,
        OpKind::Send => 2,
    }
}

fn op_from_code(c: u8) -> Option<OpKind> {
    match c {
        0 => Some(OpKind::Write),
        1 => Some(OpKind::Read),
        2 => Some(OpKind::Send),
        _ => None,
    }
}

fn status_code(s: WcStatus) -> u8 {
    match s {
        WcStatus::Success => 0,
        WcStatus::Error => 1,
    }
}

fn status_from_code(c: u8) -> Option<WcStatus> {
    match c {
        0 => Some(WcStatus::Success),
        1 => Some(WcStatus::Error),
        _ => None,
    }
}

fn put_ids(buf: &mut Vec<u8>, ids: &IdList) {
    buf.extend_from_slice(&(ids.len() as u32).to_le_bytes());
    for &id in ids {
        buf.extend_from_slice(&id.to_le_bytes());
    }
}

fn bad(msg: &'static str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> io::Result<&[u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| bad("socket frame: truncated body"))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn ids(&mut self) -> io::Result<IdList> {
        let n = self.u32()? as usize;
        let mut ids = IdList::new();
        for _ in 0..n {
            ids.push(self.u64()?);
        }
        Ok(ids)
    }

    fn done(&self) -> io::Result<()> {
        if self.pos == self.bytes.len() {
            Ok(())
        } else {
            Err(bad("socket frame: trailing bytes"))
        }
    }
}

impl SocketMsg {
    fn kind(&self) -> u8 {
        match self {
            SocketMsg::Hello { .. } => KIND_HELLO,
            SocketMsg::Wr(_) => KIND_WR,
            SocketMsg::Wc(_) => KIND_WC,
            SocketMsg::Gossip(_) => KIND_GOSSIP,
            SocketMsg::Fingerprint(_) => KIND_FINGERPRINT,
        }
    }

    fn encode_body(&self, buf: &mut Vec<u8>) {
        match self {
            SocketMsg::Hello { engine_id } => {
                buf.extend_from_slice(&engine_id.to_le_bytes());
            }
            SocketMsg::Wr(wr) => {
                buf.extend_from_slice(&wr.wr_id.to_le_bytes());
                buf.push(op_code(wr.op));
                buf.extend_from_slice(&(wr.node as u64).to_le_bytes());
                buf.extend_from_slice(&wr.remote_addr.to_le_bytes());
                buf.extend_from_slice(&wr.len.to_le_bytes());
                buf.extend_from_slice(&(wr.num_sge as u64).to_le_bytes());
                buf.push(wr.signaled as u8);
                buf.extend_from_slice(&(wr.tenant as u64).to_le_bytes());
                put_ids(buf, &wr.app_ios);
            }
            SocketMsg::Wc(wc) => {
                buf.extend_from_slice(&wc.wr_id.to_le_bytes());
                buf.extend_from_slice(&(wc.qp as u64).to_le_bytes());
                buf.push(op_code(wc.op));
                buf.extend_from_slice(&wc.len.to_le_bytes());
                buf.push(status_code(wc.status));
                buf.extend_from_slice(&(wc.tenant as u64).to_le_bytes());
                put_ids(buf, &wc.app_ios);
            }
            SocketMsg::Gossip(d) => d.encode_into(buf),
            SocketMsg::Fingerprint(fp) => {
                buf.extend_from_slice(&fp.to_le_bytes());
            }
        }
    }

    fn decode_body(kind: u8, body: &[u8]) -> io::Result<Self> {
        let mut cur = Cursor { bytes: body, pos: 0 };
        let msg = match kind {
            KIND_HELLO => SocketMsg::Hello {
                engine_id: cur.u32()?,
            },
            KIND_WR => {
                let wr_id = cur.u64()?;
                let op = op_from_code(cur.u8()?).ok_or_else(|| bad("socket frame: bad op"))?;
                let node = cur.u64()? as usize;
                let remote_addr = cur.u64()?;
                let len = cur.u64()?;
                let num_sge = cur.u64()? as usize;
                let signaled = cur.u8()? != 0;
                let tenant = cur.u64()? as usize;
                let app_ios = cur.ids()?;
                SocketMsg::Wr(WorkRequest {
                    wr_id,
                    op,
                    node,
                    remote_addr,
                    len,
                    num_sge,
                    app_ios,
                    signaled,
                    tenant,
                })
            }
            KIND_WC => {
                let wr_id = cur.u64()?;
                let qp = cur.u64()? as usize;
                let op = op_from_code(cur.u8()?).ok_or_else(|| bad("socket frame: bad op"))?;
                let len = cur.u64()?;
                let status =
                    status_from_code(cur.u8()?).ok_or_else(|| bad("socket frame: bad status"))?;
                let tenant = cur.u64()? as usize;
                let app_ios = cur.ids()?;
                SocketMsg::Wc(Wc {
                    wr_id,
                    qp,
                    op,
                    len,
                    app_ios,
                    status,
                    tenant,
                })
            }
            KIND_GOSSIP => {
                let mut d = GossipDelta::default();
                d.decode_from(body).map_err(bad)?;
                cur.pos = body.len(); // decode_from consumed (and checked) it all
                SocketMsg::Gossip(d)
            }
            KIND_FINGERPRINT => SocketMsg::Fingerprint(cur.u64()?),
            _ => return Err(bad("socket frame: unknown kind")),
        };
        cur.done()?;
        Ok(msg)
    }
}

/// One end of a framed peer link, generic over any byte stream (a
/// `TcpStream`, a `UnixStream`, or a socketpair end in tests). The
/// frame scratch buffer is reused across sends and receives.
#[derive(Debug)]
pub struct SocketPeer<S> {
    stream: S,
    buf: Vec<u8>,
}

impl<S: Read + Write> SocketPeer<S> {
    pub fn new(stream: S) -> Self {
        Self {
            stream,
            buf: Vec::new(),
        }
    }

    /// Write one framed message and flush it.
    pub fn send(&mut self, msg: &SocketMsg) -> io::Result<()> {
        self.buf.clear();
        self.buf.extend_from_slice(&[0; 4]); // frame length backpatch
        self.buf.push(msg.kind());
        msg.encode_body(&mut self.buf);
        let frame_len = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&frame_len.to_le_bytes());
        self.stream.write_all(&self.buf)?;
        self.stream.flush()
    }

    /// Read one framed message (blocking until a full frame arrives).
    pub fn recv(&mut self) -> io::Result<SocketMsg> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let frame_len = u32::from_le_bytes(len) as usize;
        if frame_len == 0 || frame_len > MAX_FRAME_BYTES {
            return Err(bad("socket frame: bad length"));
        }
        self.buf.clear();
        self.buf.resize(frame_len, 0);
        self.stream.read_exact(&mut self.buf)?;
        SocketMsg::decode_body(self.buf[0], &self.buf[1..])
    }

    /// Symmetric handshake: announce our engine id, return the peer's.
    /// Both sides send first, then read — tiny frames make the order
    /// deadlock-free.
    pub fn hello(&mut self, engine_id: u32) -> io::Result<u32> {
        self.send(&SocketMsg::Hello { engine_id })?;
        match self.recv()? {
            SocketMsg::Hello { engine_id } => Ok(engine_id),
            _ => Err(bad("socket peer: expected Hello")),
        }
    }
}

/// Drive one engine's side of the lockstep anti-entropy exchange until
/// the two peers' fingerprints agree: each round exports this engine's
/// delta, absorbs the peer's, then swaps fingerprints. Convergence
/// requires at least two rounds (the first round's exports predate the
/// first absorbs). Returns the converged fingerprint, or `TimedOut`
/// after `max_rounds` rounds without agreement.
pub fn gossip_sync<S: Read + Write>(
    peer: &mut SocketPeer<S>,
    engine: &mut IoEngine,
    max_rounds: usize,
) -> io::Result<u64> {
    let mut delta = GossipDelta::default();
    for round in 0..max_rounds {
        engine.export_gossip_into(&mut delta);
        peer.send(&SocketMsg::Gossip(delta.clone()))?;
        match peer.recv()? {
            SocketMsg::Gossip(d) => engine.absorb_gossip(&d),
            _ => return Err(bad("gossip sync: expected a delta")),
        }
        let fp = engine.gossip_fingerprint();
        peer.send(&SocketMsg::Fingerprint(fp))?;
        let remote = match peer.recv()? {
            SocketMsg::Fingerprint(fp) => fp,
            _ => return Err(bad("gossip sync: expected a fingerprint")),
        };
        if round >= 1 && fp == remote {
            return Ok(fp);
        }
    }
    Err(io::Error::new(
        io::ErrorKind::TimedOut,
        "gossip sync: no convergence within the round budget",
    ))
}

/// Accept exactly one peer on a fresh TCP listener at `addr`.
pub fn listen_tcp(addr: &str) -> io::Result<SocketPeer<TcpStream>> {
    let listener = TcpListener::bind(addr)?;
    let (stream, _) = listener.accept()?;
    stream.set_nodelay(true)?;
    Ok(SocketPeer::new(stream))
}

/// Connect to a TCP peer, retrying while the listener starts up.
pub fn connect_tcp(addr: &str) -> io::Result<SocketPeer<TcpStream>> {
    let stream = retry_connect(|| TcpStream::connect(addr))?;
    stream.set_nodelay(true)?;
    Ok(SocketPeer::new(stream))
}

/// Accept exactly one peer on a fresh Unix-domain listener at `path`.
#[cfg(unix)]
pub fn listen_uds(path: &str) -> io::Result<SocketPeer<UnixStream>> {
    let listener = UnixListener::bind(path)?;
    let (stream, _) = listener.accept()?;
    Ok(SocketPeer::new(stream))
}

/// Connect to a Unix-domain peer, retrying while the listener starts
/// up (the two-process quickstart races the bind).
#[cfg(unix)]
pub fn connect_uds(path: &str) -> io::Result<SocketPeer<UnixStream>> {
    Ok(SocketPeer::new(retry_connect(|| UnixStream::connect(path))?))
}

/// Retry a connect for ~5 s; peers launched "listener &; connector"
/// style shouldn't need sub-second start-up choreography.
fn retry_connect<T>(mut connect: impl FnMut() -> io::Result<T>) -> io::Result<T> {
    let mut last = None;
    for _ in 0..500 {
        match connect() {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::TimedOut, "connect retry")))
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::coordinator::EngineSpec;
    use crate::fabric::Dir;

    fn pair() -> (SocketPeer<UnixStream>, SocketPeer<UnixStream>) {
        let (a, b) = UnixStream::pair().expect("socketpair");
        (SocketPeer::new(a), SocketPeer::new(b))
    }

    #[test]
    fn frames_roundtrip_every_message_kind() {
        let (mut a, mut b) = pair();
        let wr = WorkRequest {
            wr_id: 7,
            op: OpKind::Write,
            node: 1,
            remote_addr: 4096,
            len: 8192,
            num_sge: 2,
            app_ios: vec![3, 4].into(),
            signaled: true,
            tenant: 1,
        };
        let wc = Wc {
            wr_id: 7,
            qp: 3,
            op: OpKind::Write,
            len: 8192,
            app_ios: vec![3, 4].into(),
            status: WcStatus::Error,
            tenant: 1,
        };
        let gossip = GossipDelta {
            from: 1,
            round: 9,
            epoch_counter: 4,
            required: vec![(0, 4096, 3)],
            applied: vec![(0, 0, 4096, 3)],
            states: vec![(0, 2, 1)],
            missed: vec![(1, 4096, 4096)],
            surrendered: vec![(0, 0, 4096)],
        };
        a.send(&SocketMsg::Hello { engine_id: 0 }).unwrap();
        a.send(&SocketMsg::Wr(wr.clone())).unwrap();
        a.send(&SocketMsg::Wc(wc.clone())).unwrap();
        a.send(&SocketMsg::Gossip(gossip.clone())).unwrap();
        a.send(&SocketMsg::Fingerprint(0xDEAD_BEEF)).unwrap();
        match b.recv().unwrap() {
            SocketMsg::Hello { engine_id } => assert_eq!(engine_id, 0),
            m => panic!("expected Hello, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Wr(got) => {
                assert_eq!(got.wr_id, wr.wr_id);
                assert_eq!(got.op, wr.op);
                assert_eq!(got.node, wr.node);
                assert_eq!(got.remote_addr, wr.remote_addr);
                assert_eq!(got.len, wr.len);
                assert_eq!(got.num_sge, wr.num_sge);
                assert_eq!(got.app_ios, wr.app_ios);
                assert_eq!(got.signaled, wr.signaled);
                assert_eq!(got.tenant, wr.tenant);
            }
            m => panic!("expected Wr, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Wc(got) => {
                assert_eq!(got.wr_id, wc.wr_id);
                assert_eq!(got.qp, wc.qp);
                assert_eq!(got.op, wc.op);
                assert_eq!(got.len, wc.len);
                assert_eq!(got.app_ios, wc.app_ios);
                assert_eq!(got.status, wc.status);
                assert_eq!(got.tenant, wc.tenant);
            }
            m => panic!("expected Wc, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Gossip(got) => assert_eq!(got, gossip),
            m => panic!("expected Gossip, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Fingerprint(fp) => assert_eq!(fp, 0xDEAD_BEEF),
            m => panic!("expected Fingerprint, got {m:?}"),
        }
    }

    #[test]
    fn corrupt_frames_are_rejected_not_trusted() {
        // unknown kind
        let (mut a, mut b) = pair();
        let frame = [2u8, 0, 0, 0, 99, 0];
        a.stream.write_all(&frame).unwrap();
        assert!(b.recv().is_err());
        // oversized length prefix
        let (mut a, mut b) = pair();
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        a.stream.write_all(&huge).unwrap();
        a.stream.write_all(&[KIND_HELLO]).unwrap();
        assert!(b.recv().is_err());
        // truncated body
        let (mut a, mut b) = pair();
        let frame = [3u8, 0, 0, 0, KIND_HELLO, 1, 2]; // Hello needs 4 bytes
        a.stream.write_all(&frame).unwrap();
        assert!(b.recv().is_err());
        // trailing garbage after a valid body
        let (mut a, mut b) = pair();
        let frame = [6u8, 0, 0, 0, KIND_HELLO, 1, 2, 3, 4, 9];
        a.stream.write_all(&frame).unwrap();
        assert!(b.recv().is_err());
    }

    #[test]
    fn hello_handshake_swaps_engine_ids() {
        let (mut a, mut b) = pair();
        let t = std::thread::spawn(move || a.hello(0).unwrap());
        assert_eq!(b.hello(1).unwrap(), 0);
        assert_eq!(t.join().unwrap(), 1);
    }

    /// The tentpole acceptance shape, in-process: two engines of one
    /// gossip cluster diverge (each mints epochs the other has not
    /// seen) and the lockstep sync over a real socketpair converges
    /// them to identical fingerprints.
    #[test]
    fn gossip_sync_converges_diverged_engines_over_a_socketpair() {
        let spec = |id: usize| {
            EngineSpec::new(2)
                .replicated(2)
                .resync(4 * 4096)
                .election()
                .gossip(id, 2)
        };
        let mut ea = IoEngine::build(&spec(0));
        let mut eb = IoEngine::build(&spec(1));
        // forced divergence: disjoint writes on each engine
        for i in 0..4u64 {
            drive_write(&mut ea, i, i * 4096);
            drive_write(&mut eb, 100 + i, (1 << 21) + i * 4096);
        }
        assert_ne!(ea.gossip_fingerprint(), eb.gossip_fingerprint());
        let (mut pa, mut pb) = pair();
        let t = std::thread::spawn(move || {
            let fp = gossip_sync(&mut pa, &mut ea, 16).expect("A converges");
            (fp, ea)
        });
        let fp_b = gossip_sync(&mut pb, &mut eb, 16).expect("B converges");
        let (fp_a, ea) = t.join().unwrap();
        assert_eq!(fp_a, fp_b, "both sides report the same fingerprint");
        assert_eq!(ea.gossip_fingerprint(), eb.gossip_fingerprint());
        let sa = ea.gossip_stats().unwrap();
        assert!(sa.rounds_sent >= 2 && sa.rounds_absorbed >= 2);
        assert!(sa.epoch_raises > 0, "A learned B's epochs: {sa:?}");
    }

    /// Submit one write and complete every leg successfully (the
    /// engine is its own fabric here — the socket carries gossip only).
    fn drive_write(e: &mut IoEngine, id: u64, addr: u64) {
        e.submit(crate::fabric::AppIo {
            id,
            dir: Dir::Write,
            node: 0,
            addr,
            len: 4096,
            thread: 0,
            t_submit: 0,
            tenant: 0,
        });
        loop {
            let out = e.drain_all(0);
            if out.wrs.is_empty() {
                break;
            }
            for mut wr in out.wrs {
                let wc = Wc {
                    wr_id: wr.wr_id,
                    qp: 0,
                    op: wr.op,
                    len: wr.len,
                    app_ios: std::mem::take(&mut wr.app_ios),
                    status: WcStatus::Success,
                    tenant: wr.tenant,
                };
                e.on_wc(&wc, 0);
            }
        }
    }
}
