//! The socket fabric: real byte streams between peer engines.
//!
//! The other three backends run inside one process; this one connects
//! two engines living in *separate OS processes* (or separate threads
//! over a socketpair) with length-prefixed frames over TCP or a
//! Unix-domain socket. The frame payloads are the crate's existing
//! verb-level types — [`WorkRequest`], [`Wc`] — plus the coordinator's
//! [`GossipDelta`], so the multi-engine anti-entropy protocol runs
//! unchanged over an actual wire: each side exports its delta, absorbs
//! the peer's, and compares [`gossip fingerprints`] until they agree.
//!
//! Wire format (everything little-endian):
//!
//! ```text
//! [u32 frame_len] [u8 kind] [body; frame_len - 5 bytes] [u32 crc]
//! ```
//!
//! `frame_len` counts the kind byte, the body, and the 4-byte CRC
//! trailer, so the smallest legal frame is 5 bytes. The CRC is CRC32
//! (IEEE) over kind + body; a mismatch rejects the frame before any
//! decoding. Kinds: `1` Hello (peer handshake, `u32` engine id), `2`
//! WorkRequest, `3` Wc, `4` gossip delta ([`GossipDelta::encode_into`]
//! body), `5` fingerprint (`u64`), `6` heartbeat (`u64` echo nonce).
//! Unknown kinds, CRC mismatches, truncated bodies, trailing bytes and
//! frames over [`MAX_FRAME_BYTES`] are rejected as `InvalidData` — a
//! corrupt peer can fail the session but never corrupt engine state.
//! The receive path also never trusts the length prefix for
//! allocation: the frame buffer grows in bounded chunks only as bytes
//! actually arrive, so a hostile prefix cannot balloon memory.
//!
//! The sync loop ([`gossip_sync`]) is deliberately lockstep — send
//! delta, receive delta, exchange fingerprints — so it needs no timers
//! or polling; the frames involved are far below any OS socket buffer,
//! which makes the symmetric send-then-receive order deadlock-free.
//! [`ReconnectPeer`] wraps the TCP flavor with the recovery layer's
//! capped jittered [`Backoff`]: a dead connection is torn down and
//! re-dialed (re-running the Hello handshake), and the caller restarts
//! its protocol round on the fresh transport.
//!
//! [`gossip fingerprints`]: crate::coordinator::engine::IoEngine::gossip_fingerprint

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::{Duration, Instant};

use crate::coordinator::engine::IoEngine;
use crate::coordinator::gossip::GossipDelta;
use crate::fabric::{IdList, OpKind, Wc, WcStatus, WorkRequest};

/// Frames larger than this are rejected before allocating.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// The receive buffer grows at most this much per read while a frame
/// streams in — a hostile length prefix never drives allocation ahead
/// of the bytes that actually arrive.
const RECV_CHUNK_BYTES: usize = 64 << 10;

const KIND_HELLO: u8 = 1;
const KIND_WR: u8 = 2;
const KIND_WC: u8 = 3;
const KIND_GOSSIP: u8 = 4;
const KIND_FINGERPRINT: u8 = 5;
const KIND_HEARTBEAT: u8 = 6;

/// CRC32 (IEEE 802.3) lookup table, built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE) over `bytes` — the per-frame integrity trailer.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// One framed message between peer engines.
#[derive(Debug, Clone)]
pub enum SocketMsg {
    /// Handshake: the sender's engine id in the gossip cluster.
    Hello { engine_id: u32 },
    /// A verb-level work request (remote-execution style peering).
    Wr(WorkRequest),
    /// A verb-level completion.
    Wc(Wc),
    /// One anti-entropy round's full-state delta.
    Gossip(GossipDelta),
    /// The sender's current gossip fingerprint (convergence check).
    Fingerprint(u64),
    /// Liveness probe: the receiver echoes the nonce back unchanged.
    Heartbeat(u64),
}

fn op_code(op: OpKind) -> u8 {
    match op {
        OpKind::Write => 0,
        OpKind::Read => 1,
        OpKind::Send => 2,
    }
}

fn op_from_code(c: u8) -> Option<OpKind> {
    match c {
        0 => Some(OpKind::Write),
        1 => Some(OpKind::Read),
        2 => Some(OpKind::Send),
        _ => None,
    }
}

fn status_code(s: WcStatus) -> u8 {
    match s {
        WcStatus::Success => 0,
        WcStatus::Error => 1,
    }
}

fn status_from_code(c: u8) -> Option<WcStatus> {
    match c {
        0 => Some(WcStatus::Success),
        1 => Some(WcStatus::Error),
        _ => None,
    }
}

fn put_ids(buf: &mut Vec<u8>, ids: &IdList) {
    buf.extend_from_slice(&(ids.len() as u32).to_le_bytes());
    for &id in ids {
        buf.extend_from_slice(&id.to_le_bytes());
    }
}

fn bad(msg: &'static str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> io::Result<&[u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| bad("socket frame: truncated body"))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn ids(&mut self) -> io::Result<IdList> {
        let n = self.u32()? as usize;
        // a hostile count is rejected up front, before the push loop
        // starts reserving anything on its behalf
        if n > (self.bytes.len() - self.pos) / 8 {
            return Err(bad("socket frame: id count exceeds body"));
        }
        let mut ids = IdList::new();
        for _ in 0..n {
            ids.push(self.u64()?);
        }
        Ok(ids)
    }

    fn done(&self) -> io::Result<()> {
        if self.pos == self.bytes.len() {
            Ok(())
        } else {
            Err(bad("socket frame: trailing bytes"))
        }
    }
}

impl SocketMsg {
    fn kind(&self) -> u8 {
        match self {
            SocketMsg::Hello { .. } => KIND_HELLO,
            SocketMsg::Wr(_) => KIND_WR,
            SocketMsg::Wc(_) => KIND_WC,
            SocketMsg::Gossip(_) => KIND_GOSSIP,
            SocketMsg::Fingerprint(_) => KIND_FINGERPRINT,
            SocketMsg::Heartbeat(_) => KIND_HEARTBEAT,
        }
    }

    fn encode_body(&self, buf: &mut Vec<u8>) {
        match self {
            SocketMsg::Hello { engine_id } => {
                buf.extend_from_slice(&engine_id.to_le_bytes());
            }
            SocketMsg::Wr(wr) => {
                buf.extend_from_slice(&wr.wr_id.to_le_bytes());
                buf.push(op_code(wr.op));
                buf.extend_from_slice(&(wr.node as u64).to_le_bytes());
                buf.extend_from_slice(&wr.remote_addr.to_le_bytes());
                buf.extend_from_slice(&wr.len.to_le_bytes());
                buf.extend_from_slice(&(wr.num_sge as u64).to_le_bytes());
                buf.push(wr.signaled as u8);
                buf.extend_from_slice(&(wr.tenant as u64).to_le_bytes());
                put_ids(buf, &wr.app_ios);
            }
            SocketMsg::Wc(wc) => {
                buf.extend_from_slice(&wc.wr_id.to_le_bytes());
                buf.extend_from_slice(&(wc.qp as u64).to_le_bytes());
                buf.push(op_code(wc.op));
                buf.extend_from_slice(&wc.len.to_le_bytes());
                buf.push(status_code(wc.status));
                buf.extend_from_slice(&(wc.tenant as u64).to_le_bytes());
                put_ids(buf, &wc.app_ios);
            }
            SocketMsg::Gossip(d) => d.encode_into(buf),
            SocketMsg::Fingerprint(fp) => {
                buf.extend_from_slice(&fp.to_le_bytes());
            }
            SocketMsg::Heartbeat(nonce) => {
                buf.extend_from_slice(&nonce.to_le_bytes());
            }
        }
    }

    fn decode_body(kind: u8, body: &[u8]) -> io::Result<Self> {
        let mut cur = Cursor { bytes: body, pos: 0 };
        let msg = match kind {
            KIND_HELLO => SocketMsg::Hello {
                engine_id: cur.u32()?,
            },
            KIND_WR => {
                let wr_id = cur.u64()?;
                let op = op_from_code(cur.u8()?).ok_or_else(|| bad("socket frame: bad op"))?;
                let node = cur.u64()? as usize;
                let remote_addr = cur.u64()?;
                let len = cur.u64()?;
                let num_sge = cur.u64()? as usize;
                let signaled = cur.u8()? != 0;
                let tenant = cur.u64()? as usize;
                let app_ios = cur.ids()?;
                SocketMsg::Wr(WorkRequest {
                    wr_id,
                    op,
                    node,
                    remote_addr,
                    len,
                    num_sge,
                    app_ios,
                    signaled,
                    tenant,
                })
            }
            KIND_WC => {
                let wr_id = cur.u64()?;
                let qp = cur.u64()? as usize;
                let op = op_from_code(cur.u8()?).ok_or_else(|| bad("socket frame: bad op"))?;
                let len = cur.u64()?;
                let status =
                    status_from_code(cur.u8()?).ok_or_else(|| bad("socket frame: bad status"))?;
                let tenant = cur.u64()? as usize;
                let app_ios = cur.ids()?;
                SocketMsg::Wc(Wc {
                    wr_id,
                    qp,
                    op,
                    len,
                    app_ios,
                    status,
                    tenant,
                })
            }
            KIND_GOSSIP => {
                let mut d = GossipDelta::default();
                d.decode_from(body).map_err(bad)?;
                cur.pos = body.len(); // decode_from consumed (and checked) it all
                SocketMsg::Gossip(d)
            }
            KIND_FINGERPRINT => SocketMsg::Fingerprint(cur.u64()?),
            KIND_HEARTBEAT => SocketMsg::Heartbeat(cur.u64()?),
            _ => return Err(bad("socket frame: unknown kind")),
        };
        cur.done()?;
        Ok(msg)
    }
}

/// One end of a framed peer link, generic over any byte stream (a
/// `TcpStream`, a `UnixStream`, or a socketpair end in tests). The
/// frame scratch buffer is reused across sends and receives.
#[derive(Debug)]
pub struct SocketPeer<S> {
    stream: S,
    buf: Vec<u8>,
}

impl<S: Read + Write> SocketPeer<S> {
    pub fn new(stream: S) -> Self {
        Self {
            stream,
            buf: Vec::new(),
        }
    }

    /// Write one framed message (with its CRC trailer) and flush it.
    pub fn send(&mut self, msg: &SocketMsg) -> io::Result<()> {
        self.buf.clear();
        self.buf.extend_from_slice(&[0; 4]); // frame length backpatch
        self.buf.push(msg.kind());
        msg.encode_body(&mut self.buf);
        let crc = crc32(&self.buf[4..]);
        self.buf.extend_from_slice(&crc.to_le_bytes());
        let frame_len = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&frame_len.to_le_bytes());
        self.stream.write_all(&self.buf)?;
        self.stream.flush()
    }

    /// Read one framed message (blocking until a full frame arrives),
    /// verifying the CRC trailer before any decoding.
    pub fn recv(&mut self) -> io::Result<SocketMsg> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let frame_len = u32::from_le_bytes(len) as usize;
        // kind byte + 4-byte CRC trailer is the smallest legal frame
        if frame_len < 5 || frame_len > MAX_FRAME_BYTES {
            return Err(bad("socket frame: bad length"));
        }
        // grow the buffer only as bytes actually arrive: a hostile
        // length prefix with nothing behind it stalls at the stream
        // instead of ballooning allocation to the declared size
        self.buf.clear();
        while self.buf.len() < frame_len {
            let start = self.buf.len();
            let chunk = (frame_len - start).min(RECV_CHUNK_BYTES);
            self.buf.resize(start + chunk, 0);
            self.stream.read_exact(&mut self.buf[start..])?;
        }
        let (payload, trailer) = self.buf.split_at(frame_len - 4);
        let got = u32::from_le_bytes(trailer.try_into().unwrap());
        if got != crc32(payload) {
            return Err(bad("socket frame: CRC mismatch"));
        }
        SocketMsg::decode_body(payload[0], &payload[1..])
    }

    /// Symmetric handshake: announce our engine id, return the peer's.
    /// Both sides send first, then read — tiny frames make the order
    /// deadlock-free.
    pub fn hello(&mut self, engine_id: u32) -> io::Result<u32> {
        self.send(&SocketMsg::Hello { engine_id })?;
        match self.recv()? {
            SocketMsg::Hello { engine_id } => Ok(engine_id),
            _ => Err(bad("socket peer: expected Hello")),
        }
    }
}

/// Anything that exchanges framed [`SocketMsg`]s: a raw [`SocketPeer`]
/// over any byte stream, or the self-repairing [`ReconnectPeer`].
/// Protocol loops like [`gossip_sync`] run over the trait so the same
/// lockstep code serves both transports.
pub trait FramedPeer {
    fn send_msg(&mut self, msg: &SocketMsg) -> io::Result<()>;
    fn recv_msg(&mut self) -> io::Result<SocketMsg>;
}

impl<S: Read + Write> FramedPeer for SocketPeer<S> {
    fn send_msg(&mut self, msg: &SocketMsg) -> io::Result<()> {
        self.send(msg)
    }

    fn recv_msg(&mut self) -> io::Result<SocketMsg> {
        self.recv()
    }
}

/// Drive one engine's side of the lockstep anti-entropy exchange until
/// the two peers' fingerprints agree: each round exports this engine's
/// delta, absorbs the peer's, then swaps fingerprints. Convergence
/// requires at least two rounds (the first round's exports predate the
/// first absorbs). Returns the converged fingerprint, or `TimedOut`
/// after `max_rounds` rounds without agreement. Absorbing is
/// idempotent and deltas carry full state, so a caller riding a
/// [`ReconnectPeer`] can simply restart the sync from round zero after
/// a transport failure.
pub fn gossip_sync<P: FramedPeer>(
    peer: &mut P,
    engine: &mut IoEngine,
    max_rounds: usize,
) -> io::Result<u64> {
    let mut delta = GossipDelta::default();
    for round in 0..max_rounds {
        engine.export_gossip_into(&mut delta);
        peer.send_msg(&SocketMsg::Gossip(delta.clone()))?;
        match peer.recv_msg()? {
            SocketMsg::Gossip(d) => engine.absorb_gossip(&d),
            _ => return Err(bad("gossip sync: expected a delta")),
        }
        let fp = engine.gossip_fingerprint();
        peer.send_msg(&SocketMsg::Fingerprint(fp))?;
        let remote = match peer.recv_msg()? {
            SocketMsg::Fingerprint(fp) => fp,
            _ => return Err(bad("gossip sync: expected a fingerprint")),
        };
        if round >= 1 && fp == remote {
            return Ok(fp);
        }
    }
    Err(io::Error::new(
        io::ErrorKind::TimedOut,
        "gossip sync: no convergence within the round budget",
    ))
}

/// Accept exactly one peer on a fresh TCP listener at `addr`.
pub fn listen_tcp(addr: &str) -> io::Result<SocketPeer<TcpStream>> {
    let listener = TcpListener::bind(addr)?;
    let (stream, _) = listener.accept()?;
    stream.set_nodelay(true)?;
    Ok(SocketPeer::new(stream))
}

/// Connect to a TCP peer, retrying while the listener starts up.
pub fn connect_tcp(addr: &str) -> io::Result<SocketPeer<TcpStream>> {
    let stream = retry_connect(|| TcpStream::connect(addr))?;
    stream.set_nodelay(true)?;
    Ok(SocketPeer::new(stream))
}

/// Accept exactly one peer on a fresh Unix-domain listener at `path`.
#[cfg(unix)]
pub fn listen_uds(path: &str) -> io::Result<SocketPeer<UnixStream>> {
    let listener = UnixListener::bind(path)?;
    let (stream, _) = listener.accept()?;
    Ok(SocketPeer::new(stream))
}

/// Connect to a Unix-domain peer, retrying while the listener starts
/// up (the two-process quickstart races the bind).
#[cfg(unix)]
pub fn connect_uds(path: &str) -> io::Result<SocketPeer<UnixStream>> {
    Ok(SocketPeer::new(retry_connect(|| UnixStream::connect(path))?))
}

/// Capped, jittered exponential backoff shared by the initial connect
/// retry and established-connection repair ([`ReconnectPeer`]): the
/// delay doubles from `base_ms` up to `cap_ms`, and each wait is
/// jittered into `[d/2, d]` (deterministically from the instance seed)
/// so restarted peers don't stampede the listener in lockstep.
#[derive(Debug, Clone)]
pub struct Backoff {
    next_ms: u64,
    base_ms: u64,
    cap_ms: u64,
    state: u64,
    /// Delays handed out since the last [`Backoff::reset`].
    pub attempts: u32,
}

impl Backoff {
    pub fn new(base_ms: u64, cap_ms: u64, seed: u64) -> Self {
        assert!(
            base_ms > 0 && cap_ms >= base_ms,
            "backoff needs 0 < base <= cap"
        );
        Self {
            next_ms: base_ms,
            base_ms,
            cap_ms,
            state: seed,
            attempts: 0,
        }
    }

    /// The connect-retry default: 5 ms doubling up to 320 ms.
    pub fn for_connect() -> Self {
        Self::new(5, 320, 0x5EED_C0DE)
    }

    /// The next delay: the current exponential step, jittered into
    /// `[d/2, d]`.
    pub fn next_delay(&mut self) -> Duration {
        let d = self.next_ms;
        self.next_ms = self.next_ms.saturating_mul(2).min(self.cap_ms);
        self.attempts += 1;
        // one splitmix64 step feeds the jitter draw
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Duration::from_millis(d - z % (d / 2 + 1))
    }

    /// Back to the base step (call after a successful connect).
    pub fn reset(&mut self) {
        self.next_ms = self.base_ms;
        self.attempts = 0;
    }
}

/// Run `op` until it succeeds or `budget` elapses, sleeping one
/// backoff delay between attempts (clamped to the remaining budget).
/// Returns the last error on exhaustion.
pub fn retry_with_backoff<T>(
    backoff: &mut Backoff,
    budget: Duration,
    mut op: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    let deadline = Instant::now() + budget;
    loop {
        let last = match op() {
            Ok(t) => return Ok(t),
            Err(e) => e,
        };
        let now = Instant::now();
        if now >= deadline {
            return Err(last);
        }
        std::thread::sleep(backoff.next_delay().min(deadline - now));
    }
}

/// Retry a connect for ~5 s with the shared capped jittered backoff;
/// peers launched "listener &; connector" style shouldn't need
/// sub-second start-up choreography.
fn retry_connect<T>(connect: impl FnMut() -> io::Result<T>) -> io::Result<T> {
    retry_with_backoff(&mut Backoff::for_connect(), Duration::from_secs(5), connect)
}

/// A TCP peer that survives its transport: any framed operation that
/// hits an I/O error (including a CRC-desynced stream) tears the
/// connection down; the next operation re-dials `addr` under the
/// shared [`Backoff`] and re-runs the Hello handshake on the fresh
/// stream. Errors still propagate to the caller — repair happens at
/// *connection* granularity, and the caller restarts its protocol
/// round (lockstep exchanges like [`gossip_sync`] cannot resume
/// mid-round against a restarted peer). `reconnects` counts completed
/// repairs; the smoke driver folds it into the recovery layer's
/// [`RecoveryStats::reconnects`].
///
/// [`RecoveryStats::reconnects`]: crate::metrics::RecoveryStats
pub struct ReconnectPeer {
    addr: String,
    engine_id: u32,
    peer: Option<SocketPeer<TcpStream>>,
    backoff: Backoff,
    /// Budget for one repair (dial + handshake retries).
    redial_budget: Duration,
    ever_connected: bool,
    /// Established connections beyond the first.
    pub reconnects: u64,
    /// The peer's engine id from the most recent Hello handshake.
    pub peer_id: u32,
}

impl ReconnectPeer {
    /// Dial `addr` (retrying while the listener starts) and run the
    /// Hello handshake as engine `engine_id`.
    pub fn connect(addr: &str, engine_id: u32) -> io::Result<Self> {
        let mut peer = Self {
            addr: addr.to_string(),
            engine_id,
            peer: None,
            backoff: Backoff::for_connect(),
            redial_budget: Duration::from_secs(5),
            ever_connected: false,
            reconnects: 0,
            peer_id: 0,
        };
        peer.ensure()?;
        Ok(peer)
    }

    /// The live connection, dialing + handshaking a fresh one if the
    /// last died.
    fn ensure(&mut self) -> io::Result<&mut SocketPeer<TcpStream>> {
        if self.peer.is_none() {
            let addr = self.addr.clone();
            let engine_id = self.engine_id;
            let (peer, peer_id) =
                retry_with_backoff(&mut self.backoff, self.redial_budget, || {
                    let stream = TcpStream::connect(&addr)?;
                    stream.set_nodelay(true)?;
                    let mut peer = SocketPeer::new(stream);
                    let peer_id = peer.hello(engine_id)?;
                    Ok((peer, peer_id))
                })?;
            self.backoff.reset();
            if self.ever_connected {
                self.reconnects += 1;
            }
            self.ever_connected = true;
            self.peer_id = peer_id;
            self.peer = Some(peer);
        }
        Ok(self.peer.as_mut().expect("just connected"))
    }

    /// Send one frame on the current connection (dialing one if
    /// needed). On error the connection is torn down and the error
    /// propagates — the next operation dials fresh.
    pub fn send(&mut self, msg: &SocketMsg) -> io::Result<()> {
        let r = self.ensure()?.send(msg);
        if r.is_err() {
            self.peer = None;
        }
        r
    }

    /// Receive one frame, with the same teardown-on-error contract as
    /// [`ReconnectPeer::send`].
    pub fn recv(&mut self) -> io::Result<SocketMsg> {
        let r = self.ensure()?.recv();
        if r.is_err() {
            self.peer = None;
        }
        r
    }

    /// Liveness probe: send a heartbeat nonce and wait for its echo.
    /// Unlike send/recv this *is* retried across repairs — the
    /// heartbeat is a self-contained transaction, so one that died
    /// with the old connection is simply re-sent on the fresh one.
    pub fn ping(&mut self, nonce: u64) -> io::Result<u64> {
        let mut last = None;
        for _ in 0..3 {
            match self.try_ping(nonce) {
                Ok(echo) => return Ok(echo),
                Err(e) => {
                    self.peer = None;
                    last = Some(e);
                }
            }
        }
        Err(last.expect("three attempts made"))
    }

    fn try_ping(&mut self, nonce: u64) -> io::Result<u64> {
        let peer = self.ensure()?;
        peer.send(&SocketMsg::Heartbeat(nonce))?;
        match peer.recv()? {
            SocketMsg::Heartbeat(echo) => Ok(echo),
            _ => Err(bad("heartbeat: expected an echo")),
        }
    }
}

impl FramedPeer for ReconnectPeer {
    fn send_msg(&mut self, msg: &SocketMsg) -> io::Result<()> {
        self.send(msg)
    }

    fn recv_msg(&mut self) -> io::Result<SocketMsg> {
        self.recv()
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::coordinator::EngineSpec;
    use crate::fabric::Dir;

    fn pair() -> (SocketPeer<UnixStream>, SocketPeer<UnixStream>) {
        let (a, b) = UnixStream::pair().expect("socketpair");
        (SocketPeer::new(a), SocketPeer::new(b))
    }

    #[test]
    fn frames_roundtrip_every_message_kind() {
        let (mut a, mut b) = pair();
        let wr = WorkRequest {
            wr_id: 7,
            op: OpKind::Write,
            node: 1,
            remote_addr: 4096,
            len: 8192,
            num_sge: 2,
            app_ios: vec![3, 4].into(),
            signaled: true,
            tenant: 1,
        };
        let wc = Wc {
            wr_id: 7,
            qp: 3,
            op: OpKind::Write,
            len: 8192,
            app_ios: vec![3, 4].into(),
            status: WcStatus::Error,
            tenant: 1,
        };
        let gossip = GossipDelta {
            from: 1,
            round: 9,
            epoch_counter: 4,
            required: vec![(0, 4096, 3)],
            applied: vec![(0, 0, 4096, 3)],
            states: vec![(0, 2, 1)],
            missed: vec![(1, 4096, 4096)],
            surrendered: vec![(0, 0, 4096)],
        };
        a.send(&SocketMsg::Hello { engine_id: 0 }).unwrap();
        a.send(&SocketMsg::Wr(wr.clone())).unwrap();
        a.send(&SocketMsg::Wc(wc.clone())).unwrap();
        a.send(&SocketMsg::Gossip(gossip.clone())).unwrap();
        a.send(&SocketMsg::Fingerprint(0xDEAD_BEEF)).unwrap();
        a.send(&SocketMsg::Heartbeat(99)).unwrap();
        match b.recv().unwrap() {
            SocketMsg::Hello { engine_id } => assert_eq!(engine_id, 0),
            m => panic!("expected Hello, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Wr(got) => {
                assert_eq!(got.wr_id, wr.wr_id);
                assert_eq!(got.op, wr.op);
                assert_eq!(got.node, wr.node);
                assert_eq!(got.remote_addr, wr.remote_addr);
                assert_eq!(got.len, wr.len);
                assert_eq!(got.num_sge, wr.num_sge);
                assert_eq!(got.app_ios, wr.app_ios);
                assert_eq!(got.signaled, wr.signaled);
                assert_eq!(got.tenant, wr.tenant);
            }
            m => panic!("expected Wr, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Wc(got) => {
                assert_eq!(got.wr_id, wc.wr_id);
                assert_eq!(got.qp, wc.qp);
                assert_eq!(got.op, wc.op);
                assert_eq!(got.len, wc.len);
                assert_eq!(got.app_ios, wc.app_ios);
                assert_eq!(got.status, wc.status);
                assert_eq!(got.tenant, wc.tenant);
            }
            m => panic!("expected Wc, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Gossip(got) => assert_eq!(got, gossip),
            m => panic!("expected Gossip, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Fingerprint(fp) => assert_eq!(fp, 0xDEAD_BEEF),
            m => panic!("expected Fingerprint, got {m:?}"),
        }
        match b.recv().unwrap() {
            SocketMsg::Heartbeat(nonce) => assert_eq!(nonce, 99),
            m => panic!("expected Heartbeat, got {m:?}"),
        }
    }

    #[test]
    fn crc32_matches_the_reference_vector() {
        // the IEEE 802.3 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    /// A raw wire frame around `payload` (kind + body) with an
    /// arbitrary — possibly wrong — CRC trailer.
    fn raw_frame(payload: &[u8], crc: u32) -> Vec<u8> {
        let mut frame = ((payload.len() + 4) as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(payload);
        frame.extend_from_slice(&crc.to_le_bytes());
        frame
    }

    #[test]
    fn corrupt_frames_are_rejected_not_trusted() {
        // unknown kind (CRC itself is valid)
        let (mut a, mut b) = pair();
        a.stream.write_all(&raw_frame(&[99], crc32(&[99]))).unwrap();
        assert!(b.recv().is_err());
        // length prefix below the kind + CRC minimum
        let (mut a, mut b) = pair();
        a.stream.write_all(&[4u8, 0, 0, 0, KIND_HELLO, 1, 2, 3]).unwrap();
        assert!(b.recv().is_err());
        // oversized length prefix
        let (mut a, mut b) = pair();
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        a.stream.write_all(&huge).unwrap();
        a.stream.write_all(&[KIND_HELLO]).unwrap();
        assert!(b.recv().is_err());
        // valid body, wrong CRC
        let (mut a, mut b) = pair();
        let payload = [KIND_HELLO, 1, 2, 3, 4];
        a.stream
            .write_all(&raw_frame(&payload, crc32(&payload) ^ 0xDEAD))
            .unwrap();
        assert!(b.recv().is_err());
        // truncated body (CRC valid, so the decoder catches it)
        let (mut a, mut b) = pair();
        let payload = [KIND_HELLO, 1, 2]; // Hello needs 4 body bytes
        a.stream
            .write_all(&raw_frame(&payload, crc32(&payload)))
            .unwrap();
        assert!(b.recv().is_err());
        // trailing garbage after a valid body (CRC valid)
        let (mut a, mut b) = pair();
        let payload = [KIND_HELLO, 1, 2, 3, 4, 9];
        a.stream
            .write_all(&raw_frame(&payload, crc32(&payload)))
            .unwrap();
        assert!(b.recv().is_err());
        // hostile id count inside a Wc body: claims 2^32 - 1 ids with
        // four bytes behind it
        let (mut a, mut b) = pair();
        let mut payload = vec![KIND_WC];
        payload.extend_from_slice(&7u64.to_le_bytes()); // wr_id
        payload.extend_from_slice(&0u64.to_le_bytes()); // qp
        payload.push(0); // op
        payload.extend_from_slice(&4096u64.to_le_bytes()); // len
        payload.push(0); // status
        payload.extend_from_slice(&0u64.to_le_bytes()); // tenant
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // id count
        payload.extend_from_slice(&[1, 2, 3, 4]);
        let crc = crc32(&payload);
        a.stream.write_all(&raw_frame(&payload, crc)).unwrap();
        assert!(b.recv().is_err());
    }

    #[test]
    fn hello_handshake_swaps_engine_ids() {
        let (mut a, mut b) = pair();
        let t = std::thread::spawn(move || a.hello(0).unwrap());
        assert_eq!(b.hello(1).unwrap(), 0);
        assert_eq!(t.join().unwrap(), 1);
    }

    /// The tentpole acceptance shape, in-process: two engines of one
    /// gossip cluster diverge (each mints epochs the other has not
    /// seen) and the lockstep sync over a real socketpair converges
    /// them to identical fingerprints.
    #[test]
    fn gossip_sync_converges_diverged_engines_over_a_socketpair() {
        let spec = |id: usize| {
            EngineSpec::new(2)
                .replicated(2)
                .resync(4 * 4096)
                .election()
                .gossip(id, 2)
        };
        let mut ea = IoEngine::build(&spec(0));
        let mut eb = IoEngine::build(&spec(1));
        // forced divergence: disjoint writes on each engine
        for i in 0..4u64 {
            drive_write(&mut ea, i, i * 4096);
            drive_write(&mut eb, 100 + i, (1 << 21) + i * 4096);
        }
        assert_ne!(ea.gossip_fingerprint(), eb.gossip_fingerprint());
        let (mut pa, mut pb) = pair();
        let t = std::thread::spawn(move || {
            let fp = gossip_sync(&mut pa, &mut ea, 16).expect("A converges");
            (fp, ea)
        });
        let fp_b = gossip_sync(&mut pb, &mut eb, 16).expect("B converges");
        let (fp_a, ea) = t.join().unwrap();
        assert_eq!(fp_a, fp_b, "both sides report the same fingerprint");
        assert_eq!(ea.gossip_fingerprint(), eb.gossip_fingerprint());
        let sa = ea.gossip_stats().unwrap();
        assert!(sa.rounds_sent >= 2 && sa.rounds_absorbed >= 2);
        assert!(sa.epoch_raises > 0, "A learned B's epochs: {sa:?}");
    }

    #[test]
    fn backoff_doubles_to_the_cap_with_bounded_jitter() {
        let mut b = Backoff::new(10, 80, 7);
        let mut raw = 10u64;
        for _ in 0..6 {
            let d = b.next_delay().as_millis() as u64;
            assert!(
                d >= raw / 2 && d <= raw,
                "jitter left [d/2, d]: {d} vs step {raw}"
            );
            raw = (raw * 2).min(80);
        }
        assert_eq!(b.attempts, 6);
        b.reset();
        assert_eq!(b.attempts, 0);
        let d = b.next_delay().as_millis() as u64;
        assert!(d >= 5 && d <= 10, "reset returns to the base step: {d}");
    }

    #[test]
    fn retry_with_backoff_retries_then_surfaces_the_last_error() {
        let mut b = Backoff::new(1, 2, 1);
        let mut calls = 0;
        let r: io::Result<u32> = retry_with_backoff(&mut b, Duration::from_secs(5), || {
            calls += 1;
            if calls < 3 {
                Err(io::Error::new(io::ErrorKind::ConnectionRefused, "not yet"))
            } else {
                Ok(42)
            }
        });
        assert_eq!(r.unwrap(), 42);
        assert_eq!(calls, 3);
        assert!(b.attempts >= 2, "waits actually happened");
        // a spent budget gets one attempt and the error back
        let mut b = Backoff::new(1, 2, 1);
        let r: io::Result<u32> = retry_with_backoff(&mut b, Duration::from_millis(0), || {
            Err(io::Error::new(io::ErrorKind::ConnectionRefused, "down"))
        });
        assert_eq!(r.unwrap_err().kind(), io::ErrorKind::ConnectionRefused);
    }

    /// An in-memory byte stream: writes append, reads consume from the
    /// front — enough Read + Write to frame and unframe without a
    /// socket.
    #[derive(Default)]
    struct Mem {
        buf: Vec<u8>,
        pos: usize,
    }

    impl Read for Mem {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            let n = out.len().min(self.buf.len() - self.pos);
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    impl Write for Mem {
        fn write(&mut self, bytes: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(bytes);
            Ok(bytes.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// kind + encoded body: a canonical byte form for message equality.
    fn frame_bytes(msg: &SocketMsg) -> Vec<u8> {
        let mut bytes = vec![msg.kind()];
        msg.encode_body(&mut bytes);
        bytes
    }

    fn gen_msg(rng: &mut crate::util::rng::Pcg32, size: usize) -> SocketMsg {
        let ids: IdList = (0..rng.gen_below(1 + size as u64 / 8))
            .map(|_| rng.gen_below(1 << 40))
            .collect::<Vec<_>>()
            .into();
        match rng.gen_below(6) {
            0 => SocketMsg::Hello {
                engine_id: rng.gen_below(1 << 32) as u32,
            },
            1 => SocketMsg::Wr(WorkRequest {
                wr_id: rng.gen_below(1 << 48),
                op: op_from_code(rng.gen_below(3) as u8).unwrap(),
                node: rng.gen_below(64) as usize,
                remote_addr: rng.gen_below(1 << 40),
                len: rng.gen_below(1 << 20),
                num_sge: rng.gen_below(16) as usize,
                app_ios: ids,
                signaled: rng.gen_bool(0.5),
                tenant: rng.gen_below(4) as usize,
            }),
            2 => SocketMsg::Wc(Wc {
                wr_id: rng.gen_below(1 << 48),
                qp: rng.gen_below(64) as usize,
                op: op_from_code(rng.gen_below(3) as u8).unwrap(),
                len: rng.gen_below(1 << 20),
                app_ios: ids,
                status: status_from_code(rng.gen_below(2) as u8).unwrap(),
                tenant: rng.gen_below(4) as usize,
            }),
            3 => {
                let mut d = GossipDelta {
                    from: rng.gen_below(4) as u32,
                    round: rng.gen_below(1 << 20),
                    epoch_counter: rng.gen_below(1 << 20),
                    ..GossipDelta::default()
                };
                for _ in 0..rng.gen_below(1 + size as u64 / 8) {
                    d.required
                        .push((rng.gen_below(1 << 30), rng.gen_below(1 << 30), rng.gen_below(100)));
                    d.applied.push((
                        rng.gen_below(4) as u32,
                        rng.gen_below(1 << 30),
                        rng.gen_below(1 << 30),
                        rng.gen_below(100),
                    ));
                    d.states.push((
                        rng.gen_below(4) as u32,
                        rng.gen_below(100),
                        rng.gen_below(3) as u8,
                    ));
                    d.missed.push((
                        rng.gen_below(4) as u32,
                        rng.gen_below(1 << 30),
                        rng.gen_below(1 << 20),
                    ));
                    d.surrendered.push((
                        rng.gen_below(4) as u32,
                        rng.gen_below(1 << 30),
                        rng.gen_below(1 << 20),
                    ));
                }
                SocketMsg::Gossip(d)
            }
            4 => SocketMsg::Fingerprint(rng.gen_below(u64::MAX)),
            _ => SocketMsg::Heartbeat(rng.gen_below(u64::MAX)),
        }
    }

    /// The codec property the recovery layer leans on: every message
    /// kind roundtrips bit-exact, and flipping any single bit anywhere
    /// in the frame — length prefix, kind, body, or CRC trailer — is
    /// rejected rather than decoded into something else.
    #[test]
    fn codec_property_roundtrips_and_rejects_single_byte_corruption() {
        use crate::util::prop::{self, cfg};
        prop::forall(cfg(0xC0DEC), |rng, size| {
            let msg = gen_msg(rng, size);
            // clean roundtrip
            let mut p = SocketPeer::new(Mem::default());
            p.send(&msg).map_err(|e| format!("send failed: {e}"))?;
            let got = p
                .recv()
                .map_err(|e| format!("clean frame rejected: {e}"))?;
            if frame_bytes(&got) != frame_bytes(&msg) {
                return Err(format!("roundtrip changed the message: {msg:?} -> {got:?}"));
            }
            // a single flipped bit anywhere in the frame is rejected
            let mut p = SocketPeer::new(Mem::default());
            p.send(&msg).map_err(|e| format!("send failed: {e}"))?;
            let at = rng.gen_below(p.stream.buf.len() as u64) as usize;
            p.stream.buf[at] ^= 1 << rng.gen_below(8);
            if p.recv().is_ok() {
                return Err(format!("corruption at byte {at} was accepted"));
            }
            Ok(())
        });
    }

    /// The survivability acceptance: the listener dies mid-session and
    /// a fresh incarnation takes over on the same port; the client's
    /// [`ReconnectPeer`] rides the restart — heartbeat first, then a
    /// gossip sync that converges with the second incarnation.
    #[test]
    fn peer_restart_reconverges_gossip() {
        let spec = |id: usize| {
            EngineSpec::new(2)
                .replicated(2)
                .resync(4 * 4096)
                .election()
                .gossip(id, 2)
        };
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let server = std::thread::spawn(move || {
            // incarnation 1: handshake, echo one heartbeat, die
            {
                let (stream, _) = listener.accept().expect("accept #1");
                let mut p = SocketPeer::new(stream);
                p.hello(1).expect("hello #1");
                match p.recv().expect("first heartbeat") {
                    SocketMsg::Heartbeat(n) => {
                        p.send(&SocketMsg::Heartbeat(n)).expect("echo")
                    }
                    m => panic!("expected Heartbeat, got {m:?}"),
                }
                // dropping the stream kills the established connection
            }
            // incarnation 2: a fresh engine accepts the client's
            // reconnect and runs the sync to convergence
            let (stream, _) = listener.accept().expect("accept #2");
            let mut p = SocketPeer::new(stream);
            p.hello(1).expect("hello #2");
            let mut engine = IoEngine::build(&spec(1));
            for i in 0..4u64 {
                drive_write(&mut engine, 100 + i, (1 << 21) + i * 4096);
            }
            gossip_sync(&mut p, &mut engine, 16).expect("server side converges")
        });
        let mut client = ReconnectPeer::connect(&addr, 0).expect("connect");
        assert_eq!(client.peer_id, 1);
        assert_eq!(client.ping(7).expect("echo"), 7);
        let mut engine = IoEngine::build(&spec(0));
        for i in 0..4u64 {
            drive_write(&mut engine, i, i * 4096);
        }
        // the first sync attempt dies with incarnation 1; the retry
        // dials incarnation 2 and restarts the round from scratch
        let mut fp = None;
        for _ in 0..4 {
            match gossip_sync(&mut client, &mut engine, 16) {
                Ok(converged) => {
                    fp = Some(converged);
                    break;
                }
                Err(_) => continue,
            }
        }
        let fp = fp.expect("client converged across the restart");
        assert_eq!(fp, server.join().expect("server thread"));
        assert!(
            client.reconnects >= 1,
            "the transport repair actually happened"
        );
    }

    /// Submit one write and complete every leg successfully (the
    /// engine is its own fabric here — the socket carries gossip only).
    fn drive_write(e: &mut IoEngine, id: u64, addr: u64) {
        e.submit(crate::fabric::AppIo {
            id,
            dir: Dir::Write,
            node: 0,
            addr,
            len: 4096,
            thread: 0,
            t_submit: 0,
            tenant: 0,
        });
        loop {
            let out = e.drain_all(0);
            if out.wrs.is_empty() {
                break;
            }
            for mut wr in out.wrs {
                let wc = Wc {
                    wr_id: wr.wr_id,
                    qp: 0,
                    op: wr.op,
                    len: wr.len,
                    app_ios: std::mem::take(&mut wr.app_ios),
                    status: WcStatus::Success,
                    tenant: wr.tenant,
                };
                e.on_wc(&wc, 0);
            }
        }
    }
}
