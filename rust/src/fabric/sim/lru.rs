//! Tiny LRU set used to model the NIC's on-board caches: WQE cache, QP
//! context cache, and MPT (memory protection table) cache. Only membership
//! and recency matter — a miss costs a PCIe fetch in the NIC model.

use crate::util::fxhash::FxHashMap;

#[derive(Debug)]
pub struct LruSet {
    cap: usize,
    /// key -> tick of last access
    map: FxHashMap<u64, u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl LruSet {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            map: FxHashMap::with_capacity_and_hasher(cap + 1, Default::default()),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Touch `key`; returns true on hit, false on miss (key inserted,
    /// evicting the least-recently-used entry if over capacity).
    pub fn touch(&mut self, key: u64) -> bool {
        self.tick += 1;
        let hit = self.map.insert(key, self.tick).is_some();
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
            if self.map.len() > self.cap {
                // O(n) eviction; caches are small (tens–thousands) and
                // misses are rare on the hot path, so this stays cheap.
                let (&victim, _) = self.map.iter().min_by_key(|(_, &t)| t).unwrap();
                self.map.remove(&victim);
            }
        }
        hit
    }

    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_after_insert() {
        let mut l = LruSet::new(4);
        assert!(!l.touch(1)); // miss
        assert!(l.touch(1)); // hit
        assert_eq!(l.hits, 1);
        assert_eq!(l.misses, 1);
    }

    #[test]
    fn evicts_least_recent() {
        let mut l = LruSet::new(2);
        l.touch(1);
        l.touch(2);
        l.touch(1); // 1 most recent
        l.touch(3); // evicts 2
        assert!(l.contains(1));
        assert!(!l.contains(2));
        assert!(l.contains(3));
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn working_set_within_capacity_always_hits() {
        let mut l = LruSet::new(8);
        for k in 0..8u64 {
            l.touch(k);
        }
        for round in 0..10 {
            for k in 0..8u64 {
                assert!(l.touch(k), "round {round} key {k}");
            }
        }
        assert_eq!(l.miss_rate(), 8.0 / 88.0);
    }

    #[test]
    fn working_set_over_capacity_thrashes() {
        let mut l = LruSet::new(4);
        // cyclic access over 8 keys with LRU cap 4 -> every access misses
        for _ in 0..5 {
            for k in 0..8u64 {
                l.touch(k);
            }
        }
        assert_eq!(l.hits, 0);
    }

    #[test]
    fn zero_capacity_clamped() {
        let mut l = LruSet::new(0);
        l.touch(1);
        assert_eq!(l.len(), 1); // clamped to 1
        l.touch(2);
        assert_eq!(l.len(), 1);
    }
}
