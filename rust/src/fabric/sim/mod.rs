//! Discrete-event simulator of the full RDMA path.
//!
//! One virtual-time world containing: the client host (app threads driven
//! by a [`Driver`], the coordinator stack driven by an [`Engine`], polling
//! threads), the client NIC (processing units, WQE/QP/MPT caches, PCIe),
//! the wire, and the remote nodes (PCIe + CPU for two-sided designs).
//!
//! Every effect the paper measures is a queueing/caching effect, so the
//! simulator models *resources* (PU service, PCIe and link bandwidth,
//! remote CPU, poller threads) with explicit next-free times and LRU
//! caches, and charges CPU costs (MMIO, memcpy, registration, interrupts,
//! context switches, poll calls) from the calibrated
//! [`FabricConfig`](crate::config::FabricConfig) cost model.
//!
//! Design: handlers are synchronous state-machine steps; pollers simulate
//! idle spinning in O(1) events (an idle busy-poller parks with a resume
//! deadline instead of generating one event per `poll_cq` call).

pub mod engine;
pub mod lru;
pub mod trace;

use std::collections::VecDeque;

use crate::util::eventq::EventQueue;
use crate::util::fxhash::FxHashMap;

use crate::config::FabricConfig;
use crate::coordinator::channel::ChannelMap;
use crate::coordinator::polling::{PollStep, PollerFsm, PollingMode};
use crate::coordinator::StackConfig;
use crate::fabric::{AppIo, CqId, Dir, NodeId, QpId, Wc, WcStatus, WorkRequest, DEFAULT_TENANT};
use crate::util::hist::Hist;
use lru::LruSet;
use trace::Trace;

/// The coordinator stack under test: turns app I/Os into posted chains and
/// handles completions. RDMAbox and every baseline are instances of
/// [`engine::StackEngine`] with different [`StackConfig`]s.
pub trait Engine {
    fn name(&self) -> &str;
    /// App submitted `io` at `io.t_submit`; post (or queue) it. Returns the
    /// CPU nanoseconds spent on the submit path (MR staging + MMIO).
    fn submit(&mut self, sim: &mut Sim, io: AppIo) -> u64;
    /// A WC is being handled in a poller context whose clock is `cursor`.
    fn on_wc(&mut self, sim: &mut Sim, wc: &Wc, cursor: u64) -> WcOutcome;
    /// A previously requested merge-queue drain fired (see
    /// [`Sim::schedule_engine_kick`]). The earliest-arriving thread runs
    /// the merge-check here — this is where cross-thread batching happens.
    fn on_kick(&mut self, _sim: &mut Sim, _dir: Dir) {}
}

/// Result of handling one WC.
pub struct WcOutcome {
    /// Application I/Os that completed.
    pub completed: Vec<u64>,
    /// CPU charged to the poller for this completion (dereg / copy-out /
    /// re-drains of the merge queue).
    pub handler_cpu_ns: u64,
}

/// The application model: generates I/O and reacts to completions/timers.
pub trait Driver {
    fn on_start(&mut self, sim: &mut Sim);
    fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, latency_ns: u64, done_at: u64);
    fn on_timer(&mut self, sim: &mut Sim, thread: usize, tag: u64);
}

#[derive(Debug)]
enum Ev {
    /// The PU may be able to start its next WQE.
    PuWake { pu: usize },
    /// A CQE landed in `cq`.
    CqeArrive { cq: CqId, wc: Wc },
    /// CQ event interrupt fired.
    Interrupt { cq: CqId },
    /// An idle-spinning poller reached its re-arm deadline.
    PollerDeadline { poller: usize, gen: u64 },
    /// Driver timer.
    Timer { thread: usize, tag: u64 },
    /// Deferred merge-queue drain (the "earliest arriving thread" of
    /// Load-aware Batching reaching the merge function).
    EngineKick { dir: Dir },
}

/// A WQE queued at a NIC processing unit.
#[derive(Debug)]
struct NicWqe {
    wr: WorkRequest,
    qp: QpId,
    /// When the descriptor is available to the PU (MMIO landed / DMA fetch).
    avail: u64,
    /// Non-head entry of a doorbell chain (costs a descriptor DMA read).
    chained: bool,
}

struct Pu {
    q: VecDeque<NicWqe>,
    busy_until: u64,
    /// Earliest PuWake already scheduled (avoid event floods).
    wake_at: Option<u64>,
}

struct Cq {
    q: VecDeque<Wc>,
    armed: bool,
    event_driven: bool,
    /// Pollers attached to this CQ (≥1; >1 only for SCQ).
    pollers: Vec<usize>,
    /// Serialization point for concurrent pollers on a shared CQ.
    lock_free: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PState {
    /// Event-driven poller waiting for an interrupt.
    Sleeping,
    /// In the poll loop (or idle-spinning, if `idle_from` is set).
    Active,
}

struct Poller {
    cq: CqId,
    fsm: PollerFsm,
    state: PState,
    /// Thread-local clock; may run ahead of sim time while a batch of
    /// completions is charged synchronously.
    cursor: u64,
    busy_ns: u64,
    /// Set while the poller spins on an empty CQ.
    idle_from: Option<u64>,
    /// Step to take when resumed from an idle spin.
    pending: Option<PollStep>,
    /// Invalidates stale deadline events.
    gen: u64,
}

impl Poller {
    fn is_spinning(&self) -> bool {
        self.state == PState::Active
    }
}

/// Simulation results snapshot.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub elapsed_ns: u64,
    pub completed_reads: u64,
    pub completed_writes: u64,
    pub completed_bytes: u64,
    pub read_lat: Hist,
    pub write_lat: Hist,
    pub trace: Trace,
    /// Total poller busy time (ns) — divide by elapsed for "cores burned".
    pub poller_busy_ns: u64,
    pub pollers: usize,
    /// Time-weighted mean of in-flight WRs / bytes (Fig 1b, Fig 8b).
    pub mean_inflight_ops: f64,
    pub mean_inflight_bytes: f64,
    pub peak_inflight_ops: u64,
    pub peak_inflight_bytes: u64,
}

impl SimReport {
    pub fn iops(&self) -> f64 {
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        (self.completed_reads + self.completed_writes) as f64 * 1e9 / self.elapsed_ns as f64
    }

    pub fn throughput_bytes_per_sec(&self) -> f64 {
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        self.completed_bytes as f64 * 1e9 / self.elapsed_ns as f64
    }

    pub fn poller_cpu_cores(&self) -> f64 {
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        self.poller_busy_ns as f64 / self.elapsed_ns as f64
    }
}

pub struct Sim {
    pub cfg: FabricConfig,
    pub stack: StackConfig,
    pub channels: ChannelMap,
    pub trace: Trace,

    now: u64,
    /// Shared virtual-time scheduler (same FIFO `(t, seq)` pop order as
    /// the `BinaryHeap` it replaced — see [`crate::util::eventq`]).
    events: EventQueue<Ev>,
    stopped: bool,

    // NIC + wire resources
    pus: Vec<Pu>,
    cqs: Vec<Cq>,
    nic_queue_depth: usize,
    qp_lru: LruSet,
    mpt_lru: LruSet,
    pcie_free: u64,
    link_free: u64,
    remote_pcie_free: Vec<u64>,
    remote_cpu_free: Vec<u64>,

    pollers: Vec<Poller>,

    engine: Option<Box<dyn Engine>>,
    driver: Option<Box<dyn Driver>>,

    // I/O bookkeeping
    next_io_id: u64,
    inflight_ios: FxHashMap<u64, AppIo>,
    read_lat: Hist,
    write_lat: Hist,
    completed_reads: u64,
    completed_writes: u64,
    completed_bytes: u64,

    // time-weighted in-flight WR accounting
    inflight_wrs: u64,
    inflight_bytes: u64,
    acc_ops_ns: f64,
    acc_bytes_ns: f64,
    last_inflight_change: u64,
    peak_inflight_ops: u64,
    peak_inflight_bytes: u64,
}

impl Sim {
    pub fn new(cfg: FabricConfig, stack: StackConfig, nodes: usize) -> Self {
        let mut channels = ChannelMap::new(nodes, stack.qps_per_node);
        if let PollingMode::Scq { m, .. } = stack.polling {
            channels = channels.with_shared_cqs(m as usize);
        }
        let n_cqs = channels.total_cqs();
        let event_driven = stack.polling.event_driven();

        let mut cqs: Vec<Cq> = (0..n_cqs)
            .map(|_| Cq {
                q: VecDeque::new(),
                armed: event_driven,
                event_driven,
                pollers: Vec::new(),
                lock_free: 0,
            })
            .collect();

        // Poller topology: one per CQ, except SCQ which runs `pollers`
        // busy threads per shared CQ.
        let mut pollers = Vec::new();
        let per_cq = match stack.polling {
            PollingMode::Scq { pollers, .. } => pollers as usize,
            _ => 1,
        };
        for (cq, cq_ref) in cqs.iter_mut().enumerate() {
            for _ in 0..per_cq {
                let idx = pollers.len();
                cq_ref.pollers.push(idx);
                pollers.push(Poller {
                    cq,
                    fsm: PollerFsm::new(stack.polling),
                    state: if event_driven {
                        PState::Sleeping
                    } else {
                        PState::Active
                    },
                    cursor: 0,
                    busy_ns: 0,
                    idle_from: if event_driven { None } else { Some(0) },
                    pending: None,
                    gen: 0,
                });
            }
        }

        let pus = (0..cfg.nic_pus)
            .map(|_| Pu {
                q: VecDeque::new(),
                busy_until: 0,
                wake_at: None,
            })
            .collect();

        Self {
            qp_lru: LruSet::new(cfg.qp_cache_entries),
            mpt_lru: LruSet::new(cfg.mpt_cache_entries),
            remote_pcie_free: vec![0; nodes],
            remote_cpu_free: vec![0; nodes],
            pus,
            cqs,
            pollers,
            channels,
            cfg,
            stack,
            trace: Trace::default(),
            now: 0,
            events: EventQueue::new(),
            stopped: false,
            nic_queue_depth: 0,
            pcie_free: 0,
            link_free: 0,
            engine: None,
            driver: None,
            next_io_id: 0,
            inflight_ios: FxHashMap::default(),
            read_lat: Hist::new(),
            write_lat: Hist::new(),
            completed_reads: 0,
            completed_writes: 0,
            completed_bytes: 0,
            inflight_wrs: 0,
            inflight_bytes: 0,
            acc_ops_ns: 0.0,
            acc_bytes_ns: 0.0,
            last_inflight_change: 0,
            peak_inflight_ops: 0,
            peak_inflight_bytes: 0,
        }
    }

    pub fn attach_engine(&mut self, e: Box<dyn Engine>) {
        self.engine = Some(e);
    }

    pub fn attach_driver(&mut self, d: Box<dyn Driver>) {
        self.driver = Some(d);
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn nodes(&self) -> usize {
        self.channels.nodes()
    }

    /// Number of poller threads currently burning a core (app-interference
    /// model: spinning pollers steal cores from application threads).
    pub fn spinning_pollers(&self) -> usize {
        self.pollers.iter().filter(|p| p.is_spinning()).count()
    }

    /// Inflate an app-CPU duration by core oversubscription: `app_threads`
    /// runnable app threads compete with spinning pollers for the machine's
    /// *physical* cores (`cores` counts hyperthreads; a spinning poller
    /// burns a full physical core — HT siblings add little for spin loops).
    pub fn inflate_cpu(&self, ns: u64, app_threads: usize) -> u64 {
        let phys = (self.cfg.cores / 2).max(1);
        let demand = (app_threads + self.spinning_pollers()) as f64;
        let f = (demand / phys as f64).max(1.0);
        (ns as f64 * f) as u64
    }

    // ---------------- driver API ----------------

    /// Submit an application I/O at time `at` (≥ the current event time of
    /// the calling context). Returns the io id.
    pub fn submit_at(
        &mut self,
        dir: Dir,
        node: NodeId,
        addr: u64,
        len: u64,
        thread: usize,
        at: u64,
    ) -> u64 {
        let id = self.next_io_id;
        self.next_io_id += 1;
        let io = AppIo {
            id,
            dir,
            node,
            addr,
            len,
            thread,
            t_submit: at,
            tenant: DEFAULT_TENANT,
        };
        self.inflight_ios.insert(id, io);
        let mut eng = self.engine.take().expect("engine attached");
        let _cpu = eng.submit(self, io);
        self.engine = Some(eng);
        id
    }

    pub fn set_timer(&mut self, thread: usize, at: u64, tag: u64) {
        self.schedule(at, Ev::Timer { thread, tag });
    }

    /// Engine requests a deferred drain of its merge queue at `at`. While
    /// the kick is pending, later submissions stack up behind it — exactly
    /// the window in which Load-aware Batching finds its merge candidates.
    pub fn schedule_engine_kick(&mut self, dir: Dir, at: u64) {
        self.schedule(at, Ev::EngineKick { dir });
    }

    pub fn request_stop(&mut self) {
        self.stopped = true;
    }

    /// QP selection (round-robin over the node's channels).
    pub fn select_qp(&mut self, node: NodeId) -> QpId {
        self.channels.select(node)
    }

    // ---------------- engine API ----------------

    /// Post a doorbell chain whose posting CPU completes at `cpu_done_at`.
    /// Accounting: 1 MMIO for the head, descriptor DMA reads for the rest.
    pub fn post_chain(&mut self, qp: QpId, wrs: Vec<WorkRequest>, cpu_done_at: u64) {
        debug_assert!(!wrs.is_empty());
        self.trace.mmios += 1;
        if wrs.len() > 1 {
            self.trace.desc_dma_reads += (wrs.len() - 1) as u64;
            self.trace.chains_gt1 += 1;
        }
        // The MMIO occupies PCIe briefly.
        let t0 = self.pcie_free.max(cpu_done_at);
        self.pcie_free = t0 + self.cfg.pcie_ns(self.cfg.mmio_bus_bytes);
        let head_avail = self.pcie_free;

        let pu_count = self.pus.len();
        for (i, wr) in wrs.into_iter().enumerate() {
            match wr.op {
                crate::fabric::OpKind::Read => self.trace.wqes_read += 1,
                _ => self.trace.wqes_write += 1,
            }
            // chained descriptors are contiguous in the SQ and fetched in
            // one DMA burst — a single extra latency for the whole chain
            let avail = if i == 0 {
                head_avail
            } else {
                head_avail + self.cfg.dma_read_lat_ns
            };
            let len = wr.len;
            let pu = qp % pu_count;
            self.pus[pu].q.push_back(NicWqe {
                wr,
                qp,
                avail,
                chained: i > 0,
            });
            self.nic_queue_depth += 1;
            self.trace.peak_nic_queue =
                self.trace.peak_nic_queue.max(self.nic_queue_depth as u64);
            self.update_inflight(1, len as i64);
            self.kick_pu(pu, avail);
        }
    }

    // ---------------- internals ----------------

    fn schedule(&mut self, t: u64, ev: Ev) {
        // the queue clamps t to its own popped clock, which equals
        // self.now except after a deadline cutoff — clamp here too so
        // the pre-refactor semantics hold exactly
        self.events.push(t.max(self.now), ev);
    }

    fn update_inflight(&mut self, dops: i64, dbytes: i64) {
        let dt = (self.now - self.last_inflight_change) as f64;
        self.acc_ops_ns += self.inflight_wrs as f64 * dt;
        self.acc_bytes_ns += self.inflight_bytes as f64 * dt;
        self.last_inflight_change = self.now;
        self.inflight_wrs = (self.inflight_wrs as i64 + dops) as u64;
        self.inflight_bytes = (self.inflight_bytes as i64 + dbytes) as u64;
        self.peak_inflight_ops = self.peak_inflight_ops.max(self.inflight_wrs);
        self.peak_inflight_bytes = self.peak_inflight_bytes.max(self.inflight_bytes);
    }

    fn kick_pu(&mut self, pu: usize, hint: u64) {
        let now = self.now;
        let p = &mut self.pus[pu];
        if p.busy_until > now {
            let t = p.busy_until.max(hint.min(p.busy_until));
            if p.wake_at.map_or(true, |w| w > t) {
                p.wake_at = Some(t);
                self.schedule(t, Ev::PuWake { pu });
            }
            return;
        }
        let Some(head) = p.q.front() else { return };
        if head.avail > now {
            let t = head.avail;
            if p.wake_at.map_or(true, |w| w > t) {
                p.wake_at = Some(t);
                self.schedule(t, Ev::PuWake { pu });
            }
            return;
        }
        let wqe = p.q.pop_front().unwrap();
        self.serve_wqe(pu, wqe);
    }

    /// PU takes one WQE: charge NIC service (incl. cache behaviour), then
    /// pipeline the payload over PCIe/link/remote resources and schedule
    /// the completion CQE.
    fn serve_wqe(&mut self, pu: usize, wqe: NicWqe) {
        let mut svc = self.cfg.wqe_proc_ns + self.cfg.sge_proc_ns * wqe.wr.num_sge as u64;
        if wqe.chained {
            // descriptor came via the chain's burst DMA (amortized)
            svc += self.cfg.dma_read_lat_ns / 4;
        }
        // WQE cache pressure: the NIC caches the WQEs of *outstanding*
        // requests; when in-flight work exceeds the cache, descriptors get
        // evicted and re-fetched over PCIe — the deeper the overflow, the
        // more refetch rounds each WQE suffers (the Fig 1 IOPS collapse
        // under many parallel single I/Os, relieved by the Fig 8 window).
        if self.inflight_wrs as usize > self.cfg.wqe_cache_entries {
            let factor =
                (self.inflight_wrs as usize / self.cfg.wqe_cache_entries).min(16) as u64;
            svc += self.cfg.wqe_miss_penalty_ns * factor;
            self.trace.wqe_cache_misses += 1;
        }
        if !self.qp_lru.touch(wqe.qp as u64) {
            svc += self.cfg.qp_miss_penalty_ns;
            self.trace.qp_cache_misses += 1;
        }
        // MPT keyed by (node, 16MB remote region).
        let mpt_key = ((wqe.wr.node as u64) << 40) | (wqe.wr.remote_addr >> 24);
        if !self.mpt_lru.touch(mpt_key) {
            svc += self.cfg.mpt_miss_penalty_ns;
            self.trace.mpt_misses += 1;
        }

        let svc_end = self.now + svc;
        self.nic_queue_depth -= 1;
        // the PU's DMA engine streams this WQE's payload — a single QP
        // cannot exceed the per-engine bandwidth (multi-QP engages more
        // engines; this is the §6.1 multi-channel headroom)
        let engine_busy =
            svc_end + (wqe.wr.len as f64 / self.cfg.pu_stream_bytes_per_ns) as u64;
        {
            let p = &mut self.pus[pu];
            p.busy_until = engine_busy;
            p.wake_at = Some(engine_busy);
        }
        self.schedule(engine_busy, Ev::PuWake { pu });

        let len = wqe.wr.len;
        let node = wqe.wr.node;
        let two_sided = self.stack.two_sided;
        let server_copy = self.stack.server_copy;
        let complete_t = match wqe.wr.op {
            crate::fabric::OpKind::Write | crate::fabric::OpKind::Send => {
                // payload DMA-read from host memory, then the wire
                let t = self.pcie_free.max(svc_end)
                    + self.cfg.dma_read_lat_ns
                    + self.cfg.pcie_ns(len);
                self.pcie_free = t;
                let t = self.link_free.max(t) + self.cfg.wire_ns(len);
                self.link_free = t;
                self.trace.bytes_wire += len;
                let arrive = t + self.cfg.link_prop_ns;
                let t = self.remote_pcie_free[node].max(arrive) + self.cfg.pcie_ns(len);
                self.remote_pcie_free[node] = t;
                let remote_done = if two_sided {
                    // receiver CPU: amortized interrupt + per-msg handling
                    // (+ staging copy into its storage for Accelio/Gluster)
                    let mut h = self.cfg.interrupt_ns / 4 + 600;
                    if server_copy {
                        h += self.cfg.memcpy_ns(len);
                    }
                    let t = self.remote_cpu_free[node].max(t) + h;
                    self.remote_cpu_free[node] = t;
                    t
                } else {
                    t
                };
                remote_done + self.cfg.link_prop_ns + self.cfg.cqe_dma_ns
            }
            crate::fabric::OpKind::Read => {
                // request goes out (tiny), payload flows back
                let req_arrive = svc_end + self.cfg.link_prop_ns;
                let t = self.remote_pcie_free[node].max(req_arrive)
                    + self.cfg.dma_read_lat_ns
                    + self.cfg.pcie_ns(len);
                self.remote_pcie_free[node] = t;
                let remote_done = if two_sided {
                    let mut h = self.cfg.interrupt_ns / 4 + 600;
                    if server_copy {
                        h += self.cfg.memcpy_ns(len);
                    }
                    let t2 = self.remote_cpu_free[node].max(t) + h;
                    self.remote_cpu_free[node] = t2;
                    t2
                } else {
                    t
                };
                let t = self.link_free.max(remote_done) + self.cfg.wire_ns(len);
                self.link_free = t;
                self.trace.bytes_wire += len;
                let t = self.pcie_free.max(t + self.cfg.link_prop_ns) + self.cfg.pcie_ns(len);
                self.pcie_free = t;
                t + self.cfg.cqe_dma_ns
            }
        };

        if wqe.wr.signaled {
            let wc = Wc {
                wr_id: wqe.wr.wr_id,
                qp: wqe.qp,
                op: wqe.wr.op,
                len,
                app_ios: wqe.wr.app_ios,
                status: WcStatus::Success,
                tenant: wqe.wr.tenant,
            };
            let cq = self.channels.cq_of(wqe.qp);
            self.schedule(complete_t, Ev::CqeArrive { cq, wc });
        }
    }

    fn on_cqe(&mut self, cq: CqId, wc: Wc) {
        self.trace.cqes += 1;
        self.update_inflight(-1, -(wc.len as i64));
        self.cqs[cq].q.push_back(wc);
        if self.cqs[cq].event_driven {
            // a spinning (adaptive/hybrid retry-phase) poller catches it…
            if let Some(pi) = self.idle_spinner_of(cq) {
                self.resume_spinner(pi);
                return;
            }
            // …otherwise raise an interrupt if armed.
            if self.cqs[cq].armed {
                self.cqs[cq].armed = false;
                self.trace.interrupts += 1;
                self.schedule(self.now + self.cfg.interrupt_ns, Ev::Interrupt { cq });
            }
        } else {
            // busy/SCQ: wake the best idle spinner (they are all either
            // idle-spinning or mid-loop; mid-loop ones will drain it).
            if let Some(pi) = self.idle_spinner_of(cq) {
                self.resume_spinner(pi);
            }
        }
    }

    fn idle_spinner_of(&self, cq: CqId) -> Option<usize> {
        self.cqs[cq]
            .pollers
            .iter()
            .copied()
            .filter(|&pi| {
                self.pollers[pi].state == PState::Active && self.pollers[pi].idle_from.is_some()
            })
            .min_by_key(|&pi| self.pollers[pi].cursor)
    }

    fn resume_spinner(&mut self, pi: usize) {
        let now = self.now;
        {
            let p = &mut self.pollers[pi];
            let from = p.idle_from.take().expect("spinner");
            let wake = from.max(now);
            p.busy_ns += wake - from;
            p.cursor = p.cursor.max(wake);
            p.gen += 1; // cancel any pending deadline
        }
        self.run_poller(pi);
    }

    fn on_interrupt(&mut self, cq: CqId) {
        let Some(&pi) = self.cqs[cq].pollers.first() else {
            return;
        };
        if self.pollers[pi].state != PState::Sleeping {
            return; // raced with a resume
        }
        let now = self.now;
        {
            let p = &mut self.pollers[pi];
            p.state = PState::Active;
            p.cursor = p.cursor.max(now) + self.cfg.ctx_switch_ns;
            p.busy_ns += self.cfg.ctx_switch_ns;
            let cur = p.cursor;
            let step = p.fsm.on_wake(cur);
            p.pending = Some(step);
        }
        self.trace.ctx_switches += 1;
        self.run_poller(pi);
    }

    /// Run the poller state machine until it parks (idle spin) or re-arms.
    fn run_poller(&mut self, pi: usize) {
        let cq_id = self.pollers[pi].cq;
        let shared = self.cqs[cq_id].pollers.len() > 1;
        let contention = if shared {
            1.0 + 0.5 * (self.cqs[cq_id].pollers.len() - 1) as f64
        } else {
            1.0
        };
        let poll_ns = (self.cfg.poll_call_ns as f64 * contention) as u64;

        let mut step = self.pollers[pi]
            .pending
            .take()
            .unwrap_or(PollStep::Poll { max: 1 });

        loop {
            match step {
                PollStep::Rearm => {
                    self.rearm_poller(pi);
                    return;
                }
                PollStep::Poll { max } => {
                    // serialize poll calls on shared CQs
                    let t_call = if shared {
                        self.pollers[pi].cursor.max(self.cqs[cq_id].lock_free)
                    } else {
                        self.pollers[pi].cursor
                    };
                    let call_end = t_call + poll_ns;
                    {
                        let p = &mut self.pollers[pi];
                        p.busy_ns += call_end - p.cursor;
                        p.cursor = call_end;
                    }
                    if shared {
                        self.cqs[cq_id].lock_free = call_end;
                    }
                    self.trace.poll_calls += 1;

                    let mut got = 0u32;
                    let mut wcs = Vec::new();
                    while got < max {
                        match self.cqs[cq_id].q.pop_front() {
                            Some(wc) => {
                                wcs.push(wc);
                                got += 1;
                            }
                            None => break,
                        }
                    }
                    if got == 0 {
                        self.trace.empty_polls += 1;
                    }
                    for wc in wcs {
                        let cursor = self.pollers[pi].cursor;
                        let mut eng = self.engine.take().expect("engine");
                        let outcome = eng.on_wc(self, &wc, cursor);
                        self.engine = Some(eng);
                        {
                            let p = &mut self.pollers[pi];
                            p.busy_ns += outcome.handler_cpu_ns;
                            p.cursor += outcome.handler_cpu_ns;
                        }
                        let done_at = self.pollers[pi].cursor;
                        for io_id in outcome.completed {
                            self.complete_io(io_id, done_at);
                        }
                    }

                    let cursor = self.pollers[pi].cursor;
                    step = self.pollers[pi].fsm.after_poll(got, cursor);

                    if got == 0 && self.cqs[cq_id].q.is_empty() {
                        match step {
                            PollStep::Rearm => {
                                self.rearm_poller(pi);
                                return;
                            }
                            PollStep::Poll { .. } => {
                                // park as an idle spinner; O(1) events
                                let mode = self.pollers[pi].fsm.mode();
                                let p = &mut self.pollers[pi];
                                p.idle_from = Some(p.cursor);
                                p.pending = Some(step);
                                match mode {
                                    PollingMode::Adaptive { .. } => {
                                        let deadline =
                                            p.cursor + p.fsm.retries_left() as u64 * poll_ns;
                                        let gen = p.gen;
                                        self.schedule(
                                            deadline,
                                            Ev::PollerDeadline { poller: pi, gen },
                                        );
                                    }
                                    PollingMode::HybridTimer { .. } => {
                                        let deadline = p.fsm.spin_deadline_ns().max(p.cursor);
                                        let gen = p.gen;
                                        self.schedule(
                                            deadline,
                                            Ev::PollerDeadline { poller: pi, gen },
                                        );
                                    }
                                    // busy / SCQ spin until a CQE wakes them
                                    _ => {}
                                }
                                return;
                            }
                        }
                    }
                }
            }
        }
    }

    fn rearm_poller(&mut self, pi: usize) {
        let cq_id = self.pollers[pi].cq;
        {
            let p = &mut self.pollers[pi];
            p.cursor += self.cfg.cq_arm_ns;
            p.busy_ns += self.cfg.cq_arm_ns;
        }
        // standard lost-wakeup guard: re-check queue after arming
        if !self.cqs[cq_id].q.is_empty() {
            let cursor = self.pollers[pi].cursor;
            let step = self.pollers[pi].fsm.on_wake(cursor);
            self.pollers[pi].pending = Some(step);
            self.run_poller(pi);
            return;
        }
        self.cqs[cq_id].armed = true;
        self.pollers[pi].state = PState::Sleeping;
        self.pollers[pi].idle_from = None;
    }

    fn on_poller_deadline(&mut self, pi: usize, gen: u64) {
        {
            let p = &mut self.pollers[pi];
            if p.gen != gen || p.idle_from.is_none() {
                return; // stale
            }
            let from = p.idle_from.take().unwrap();
            let t = self.now.max(from);
            p.busy_ns += t - from;
            p.cursor = p.cursor.max(t);
            p.pending = None;
        }
        self.rearm_poller(pi);
    }

    fn complete_io(&mut self, io_id: u64, done_at: u64) {
        let Some(io) = self.inflight_ios.remove(&io_id) else {
            return; // duplicate completion guard
        };
        let lat = done_at.saturating_sub(io.t_submit);
        match io.dir {
            Dir::Read => {
                self.read_lat.record(lat);
                self.completed_reads += 1;
            }
            Dir::Write => {
                self.write_lat.record(lat);
                self.completed_writes += 1;
            }
        }
        self.completed_bytes += io.len;
        let mut d = self.driver.take().expect("driver");
        d.on_io_done(self, &io, lat, done_at);
        self.driver = Some(d);
    }

    /// Run until the driver stops the sim, the event queue drains, or the
    /// hard deadline passes. Returns the report.
    pub fn run(&mut self, deadline_ns: u64) -> SimReport {
        let mut d = self.driver.take().expect("driver attached");
        d.on_start(self);
        self.driver = Some(d);

        while !self.stopped {
            let Some((t, ev)) = self.events.pop() else {
                break;
            };
            if t > deadline_ns {
                self.now = deadline_ns;
                break;
            }
            self.now = t;
            match ev {
                Ev::PuWake { pu } => {
                    self.pus[pu].wake_at = None;
                    self.kick_pu(pu, self.now);
                }
                Ev::CqeArrive { cq, wc } => self.on_cqe(cq, wc),
                Ev::Interrupt { cq } => self.on_interrupt(cq),
                Ev::PollerDeadline { poller, gen } => self.on_poller_deadline(poller, gen),
                Ev::Timer { thread, tag } => {
                    let mut d = self.driver.take().expect("driver");
                    d.on_timer(self, thread, tag);
                    self.driver = Some(d);
                }
                Ev::EngineKick { dir } => {
                    let mut e = self.engine.take().expect("engine");
                    e.on_kick(self, dir);
                    self.engine = Some(e);
                }
            }
        }
        self.finalize()
    }

    fn finalize(&mut self) -> SimReport {
        // flush idle spinners' busy time
        let now = self.now;
        for p in &mut self.pollers {
            if let Some(from) = p.idle_from {
                if now > from {
                    p.busy_ns += now - from;
                    p.idle_from = Some(now);
                }
            }
        }
        self.update_inflight(0, 0);
        let elapsed = self.now.max(1);
        SimReport {
            elapsed_ns: self.now,
            completed_reads: self.completed_reads,
            completed_writes: self.completed_writes,
            completed_bytes: self.completed_bytes,
            read_lat: self.read_lat.clone(),
            write_lat: self.write_lat.clone(),
            trace: self.trace.clone(),
            poller_busy_ns: self.pollers.iter().map(|p| p.busy_ns).sum(),
            pollers: self.pollers.len(),
            mean_inflight_ops: self.acc_ops_ns / elapsed as f64,
            mean_inflight_bytes: self.acc_bytes_ns / elapsed as f64,
            peak_inflight_ops: self.peak_inflight_ops,
            peak_inflight_bytes: self.peak_inflight_bytes,
        }
    }

    /// Outstanding WRs (tests).
    pub fn inflight_wrs_now(&self) -> u64 {
        self.inflight_wrs
    }
}

/// Assemble and run the standard pipeline: a [`Sim`] world driving an
/// [`engine::StackEngine`] adapter over the shared
/// [`IoEngine`](crate::coordinator::engine::IoEngine), fed by `driver`.
/// Every experiment harness, workload runner and example goes through
/// here instead of hand-assembling the stages.
pub fn run_pipeline(
    cfg: &FabricConfig,
    stack: &StackConfig,
    nodes: usize,
    driver: Box<dyn Driver>,
) -> SimReport {
    run_pipeline_custom(cfg, stack, nodes, driver, None)
}

/// [`run_pipeline`] with a custom admission-control policy swapped into
/// the regulator (the paper's §5.1 congestion-control hook).
pub fn run_pipeline_custom(
    cfg: &FabricConfig,
    stack: &StackConfig,
    nodes: usize,
    driver: Box<dyn Driver>,
    regulator: Option<crate::coordinator::regulator::Regulator>,
) -> SimReport {
    let mut sim = Sim::new(cfg.clone(), stack.clone(), nodes);
    let mut eng = engine::StackEngine::new(cfg, stack, nodes);
    if let Some(r) = regulator {
        eng.set_regulator(r);
    }
    sim.attach_engine(Box::new(eng));
    sim.attach_driver(driver);
    sim.run(u64::MAX / 2)
}

#[cfg(test)]
mod tests {
    use super::engine::StackEngine;
    use super::*;
    use crate::coordinator::batching::BatchMode;
    use crate::coordinator::StackConfig;

    /// Closed-loop driver: each thread keeps `qd` I/Os in flight until
    /// `target` complete. Addresses are scattered (no adjacency).
    struct Cl {
        threads: usize,
        qd: usize,
        target: u64,
        done: u64,
        len: u64,
        next_addr: u64,
        nodes: usize,
        write_frac_pct: u64,
        /// stop the sim at target (vs letting in-flight I/Os drain)
        hard_stop: bool,
    }

    impl Cl {
        fn one(&mut self, sim: &mut Sim, thread: usize, at: u64) {
            let dir = if (self.next_addr / 4096) % 100 < self.write_frac_pct {
                Dir::Write
            } else {
                Dir::Read
            };
            let node = (self.next_addr / 4096) as usize % self.nodes;
            sim.submit_at(dir, node, self.next_addr, self.len, thread, at);
            self.next_addr += self.len * 7 + 4096; // scattered
        }
    }

    impl Driver for Cl {
        fn on_start(&mut self, sim: &mut Sim) {
            for t in 0..self.threads {
                for _ in 0..self.qd {
                    self.one(sim, t, 0);
                }
            }
        }
        fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, _lat: u64, done_at: u64) {
            self.done += 1;
            if self.done >= self.target {
                if self.hard_stop {
                    sim.request_stop();
                }
                return;
            }
            self.one(sim, io.thread, done_at);
        }
        fn on_timer(&mut self, _sim: &mut Sim, _t: usize, _tag: u64) {}
    }

    fn run_stack(stack: StackConfig, nodes: usize, target: u64) -> SimReport {
        let cfg = FabricConfig::default();
        run_pipeline(
            &cfg,
            &stack,
            nodes,
            Box::new(Cl {
                threads: 4,
                qd: 4,
                target,
                done: 0,
                len: 4096,
                next_addr: 0,
                nodes,
                write_frac_pct: 50,
                hard_stop: true,
            }),
        )
    }

    #[test]
    fn completes_all_ios_adaptive() {
        let cfg = FabricConfig::default();
        let r = run_stack(StackConfig::rdmabox(&cfg), 2, 2000);
        let done = r.completed_reads + r.completed_writes;
        // merged WRs may complete a couple of extra I/Os past the target
        assert!((2000..2100).contains(&done), "done={done}");
        assert!(r.elapsed_ns > 0);
        assert!(r.iops() > 0.0);
        assert!(r.trace.wqes_total() > 0);
        // CQEs trail WQEs only by what was still in flight at the stop
        assert!(r.trace.cqes <= r.trace.wqes_total());
    }

    #[test]
    fn completes_all_ios_each_polling_mode() {
        let cfg = FabricConfig::default();
        for polling in [
            PollingMode::Busy,
            PollingMode::Event,
            PollingMode::EventBatch { budget: 16 },
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 120,
            },
            PollingMode::HybridTimer { spin_ns: 10_000 },
            PollingMode::Scq { m: 1, pollers: 1 },
            PollingMode::Scq { m: 2, pollers: 2 },
        ] {
            let stack = StackConfig::rdmabox(&cfg).with_polling(polling);
            let r = run_stack(stack, 2, 500);
            let done = r.completed_reads + r.completed_writes;
            assert!((500..600).contains(&done), "mode {polling:?}: done={done}");
        }
    }

    #[test]
    fn busy_polling_burns_more_cpu_than_event() {
        let cfg = FabricConfig::default();
        let busy = run_stack(
            StackConfig::rdmabox(&cfg).with_polling(PollingMode::Busy),
            2,
            2000,
        );
        let event = run_stack(
            StackConfig::rdmabox(&cfg).with_polling(PollingMode::Event),
            2,
            2000,
        );
        assert!(
            busy.poller_cpu_cores() > 2.0 * event.poller_cpu_cores(),
            "busy {} vs event {}",
            busy.poller_cpu_cores(),
            event.poller_cpu_cores()
        );
    }

    #[test]
    fn event_mode_pays_interrupt_per_wc() {
        let cfg = FabricConfig::default();
        let r = run_stack(
            StackConfig::rdmabox(&cfg).with_polling(PollingMode::Event),
            1,
            1000,
        );
        assert!(
            r.trace.interrupts_per_cqe() > 0.5,
            "rate {}",
            r.trace.interrupts_per_cqe()
        );
        let adaptive = run_stack(StackConfig::rdmabox(&cfg), 1, 1000);
        assert!(
            adaptive.trace.interrupts_per_cqe() < r.trace.interrupts_per_cqe(),
            "adaptive {} vs event {}",
            adaptive.trace.interrupts_per_cqe(),
            r.trace.interrupts_per_cqe()
        );
    }

    #[test]
    fn hybrid_batching_fewer_wqes_than_single() {
        let cfg = FabricConfig::default();
        // sequential addresses -> adjacency -> merging opportunity
        struct Seq {
            target: u64,
            done: u64,
            addr: u64,
        }
        impl Driver for Seq {
            fn on_start(&mut self, sim: &mut Sim) {
                for t in 0..8 {
                    for _ in 0..4 {
                        sim.submit_at(Dir::Write, 0, self.addr, 4096, t, 0);
                        self.addr += 4096;
                    }
                }
            }
            fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, _l: u64, at: u64) {
                self.done += 1;
                if self.done >= self.target {
                    sim.request_stop();
                    return;
                }
                sim.submit_at(Dir::Write, 0, self.addr, 4096, io.thread, at);
                self.addr += 4096;
            }
            fn on_timer(&mut self, _s: &mut Sim, _t: usize, _g: u64) {}
        }
        let run = |batch| {
            let stack = StackConfig::rdmabox(&cfg).with_batch(batch);
            run_pipeline(
                &cfg,
                &stack,
                1,
                Box::new(Seq {
                    target: 3000,
                    done: 0,
                    addr: 0,
                }),
            )
        };
        let single = run(BatchMode::Single);
        let hybrid = run(BatchMode::Hybrid);
        assert!(
            hybrid.trace.wqes_total() < single.trace.wqes_total(),
            "hybrid {} vs single {}",
            hybrid.trace.wqes_total(),
            single.trace.wqes_total()
        );
        assert!(hybrid.trace.mmios < single.trace.mmios);
        assert!(hybrid.trace.merged_ios > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = FabricConfig::default();
        let a = run_stack(StackConfig::rdmabox(&cfg), 3, 1500);
        let b = run_stack(StackConfig::rdmabox(&cfg), 3, 1500);
        assert_eq!(a.elapsed_ns, b.elapsed_ns);
        assert_eq!(a.trace.wqes_total(), b.trace.wqes_total());
        assert_eq!(a.trace.mmios, b.trace.mmios);
    }

    #[test]
    fn inflight_accounting_settles_to_zero() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let mut sim = Sim::new(cfg.clone(), stack.clone(), 1);
        sim.attach_engine(Box::new(StackEngine::new(&cfg, &stack, 1)));
        sim.attach_driver(Box::new(Cl {
            threads: 2,
            qd: 2,
            target: 200,
            done: 0,
            len: 4096,
            next_addr: 0,
            nodes: 1,
            write_frac_pct: 100,
            hard_stop: false, // let in-flight I/Os drain
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(sim.inflight_wrs_now(), 0, "all WRs completed");
        assert!(r.peak_inflight_ops > 0);
        assert!(r.mean_inflight_ops > 0.0);
    }

    #[test]
    fn two_sided_server_copy_slower_than_one_sided() {
        let cfg = FabricConfig::default();
        let mut two = StackConfig::rdmabox(&cfg);
        two.two_sided = true;
        two.server_copy = true;
        let one = run_stack(StackConfig::rdmabox(&cfg), 1, 1000);
        let two = run_stack(two, 1, 1000);
        assert!(
            two.elapsed_ns > one.elapsed_ns,
            "two-sided {} vs one-sided {}",
            two.elapsed_ns,
            one.elapsed_ns
        );
    }
}
