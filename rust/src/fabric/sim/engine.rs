//! [`StackEngine`] — the coordinator running inside the simulated host.
//!
//! One engine implementation covers RDMAbox *and* every baseline, because
//! each system is exactly a point in the design space the paper lays out:
//! batching mode × MR strategy × polling × sidedness × fixed-block size ×
//! admission window (see `StackConfig` and `baselines::*`).
//!
//! Since the `IoEngine` refactor this type is a thin adapter: the whole
//! merge → batch → admit → retire pipeline lives in
//! [`crate::coordinator::engine::IoEngine`] (sharded per-QP merge queues,
//! planner, admission window, replication-aware retirement), and the same
//! object drives the live loopback backend. What remains here is the
//! sim-specific cost accounting: MR staging charged on the submitting
//! thread, preMR pool slots, fixed-block coalescing (nbdX), and the
//! deferred-kick scheduling that models the serialized merge+post critical
//! section.

use crate::util::fxhash::FxHashMap;

use crate::config::FabricConfig;
use crate::coordinator::engine::{EngineCosts, IoEngine};
use crate::coordinator::mr_strategy::{completion_cost_ns, post_cost_ns, PreMrPool, ResolvedMr};
use crate::coordinator::regulator::Regulator;
use crate::coordinator::StackConfig;
use crate::fabric::{AppIo, Dir, IdList, Wc};

use super::{Engine, Sim, WcOutcome};

/// Base CPU cost of running one completion handler (dispatch, bookkeeping).
const WC_HANDLER_BASE_NS: u64 = 1_500;

pub struct StackEngine {
    stack: StackConfig,
    core: IoEngine,
    premr_pool: Option<PreMrPool>,
    /// wr_id -> preMR slots to release at completion (inline id lists —
    /// acquiring staging slots does not allocate).
    slots: FxHashMap<u64, IdList>,
    /// Fixed-block coalescing: (block_addr, dir) -> representative io id,
    /// and representative -> waiting app io ids.
    block_index: FxHashMap<(u64, u8), u64>,
    waiters: FxHashMap<u64, Vec<u64>>,
    /// Deferred-drain state per direction: is a kick pending, and until
    /// when is the merge+post critical section busy. While busy, new
    /// arrivals stack up in the queue — the load-aware merge window.
    kick_pending: [bool; 2],
    drain_end: [u64; 2],
    cfg: FabricConfig,
}

impl StackEngine {
    pub fn new(cfg: &FabricConfig, stack: &StackConfig, nodes: usize) -> Self {
        let core = IoEngine::from_stack(stack, nodes, EngineCosts::from_fabric(cfg));
        // Pool sized generously; exhaustion is tracked, not fatal.
        let premr_pool = Some(PreMrPool::new(
            cfg.page_size.max(stack.fixed_block.unwrap_or(cfg.page_size)),
            4096,
        ));
        Self {
            stack: stack.clone(),
            core,
            premr_pool,
            slots: FxHashMap::default(),
            block_index: FxHashMap::default(),
            waiters: FxHashMap::default(),
            kick_pending: [false; 2],
            drain_end: [0; 2],
            cfg: cfg.clone(),
        }
    }

    pub fn regulator(&self) -> &Regulator {
        self.core.regulator()
    }

    /// Swap in a custom admission policy (the paper's §5.1 hook; used by
    /// the `rdmabox ablation` harness to compare static vs AIMD windows).
    pub fn set_regulator(&mut self, r: Regulator) {
        self.core.set_regulator(r);
    }

    /// The shared pipeline this adapter drives.
    pub fn core(&self) -> &IoEngine {
        &self.core
    }

    fn dir_key(dir: Dir) -> u8 {
        match dir {
            Dir::Read => 0,
            Dir::Write => 1,
        }
    }

    /// Request a deferred drain of `dir`'s queues no earlier than `t` and
    /// no earlier than the end of the current merge+post critical section.
    fn request_kick(&mut self, sim: &mut Sim, dir: Dir, t: u64) {
        let d = Self::dir_key(dir) as usize;
        if self.kick_pending[d] || self.core.queued_ios_dir(dir) == 0 {
            return;
        }
        self.kick_pending[d] = true;
        sim.schedule_engine_kick(dir, t.max(self.drain_end[d]));
    }

    /// Drain one direction through the shared pipeline and post the
    /// planned chains into the simulated fabric. Returns CPU spent.
    fn drain(&mut self, sim: &mut Sim, dir: Dir, t: u64) -> u64 {
        let out = self.core.drain_dir(dir, t);
        sim.trace.merged_ios += out.merged_ios;
        sim.trace.admission_blocks += out.admission_blocked;
        let cpu_ns = out.cpu_ns;
        for (chain, chain_wrs) in out.into_chains() {
            for wr in &chain_wrs {
                // MR staging (memcpy / registration) was already charged on
                // the submitting thread (parallel across app threads); the
                // serialized critical section pays only descriptor work.
                // WRs that were *merged* into ≥928KB cross the user-space
                // threshold at WR granularity — one registration replaces
                // many staging copies (the RFS win).
                if self.stack.mr.resolve(wr.len) == ResolvedMr::PreMr {
                    if let Some(pool) = &mut self.premr_pool {
                        let mut ids = IdList::new();
                        if pool.acquire_into(wr.len, &mut ids) {
                            self.slots.insert(wr.wr_id, ids);
                        } else {
                            sim.trace.premr_stalls += 1;
                        }
                    }
                }
            }
            sim.post_chain(chain.qp, chain_wrs, t + chain.cpu_offset_ns);
        }
        cpu_ns
    }

    /// Submit-path CPU for one app I/O: the MR staging cost, paid by the
    /// submitting thread *before* it enqueues (preMR copies / dynMR
    /// registration happen in the caller's context, in parallel across
    /// threads — only the merge-check/post section is serialized).
    pub fn staging_cost_ns(&self, len: u64, is_write: bool) -> u64 {
        post_cost_ns(&self.cfg, self.stack.mr, self.stack.space, len, is_write)
    }
}

impl Engine for StackEngine {
    fn name(&self) -> &str {
        &self.stack.name
    }

    fn submit(&mut self, sim: &mut Sim, io: AppIo) -> u64 {
        let t = io.t_submit;
        // Fixed-block designs (nbdX) round every request to the device
        // block size and coalesce concurrent faults on the same block.
        let queued_io = if let Some(block) = self.stack.fixed_block {
            let baddr = io.addr / block * block;
            let key = (baddr, Self::dir_key(io.dir));
            if let Some(&rep) = self.block_index.get(&key) {
                // already in flight: piggyback
                self.waiters.get_mut(&rep).unwrap().push(io.id);
                return 0;
            }
            self.block_index.insert(key, io.id);
            self.waiters.insert(io.id, vec![io.id]);
            AppIo {
                addr: baddr,
                len: block,
                ..io
            }
        } else {
            io
        };

        self.core.submit(queued_io);
        // staging (copy/registration) happens on the submitting thread; the
        // request only becomes postable once it is staged
        let staging = self.staging_cost_ns(queued_io.len, queued_io.dir == Dir::Write);
        self.request_kick(sim, queued_io.dir, t + staging);
        staging
    }

    fn on_kick(&mut self, sim: &mut Sim, dir: Dir) {
        let d = Self::dir_key(dir) as usize;
        self.kick_pending[d] = false;
        let t = sim.now();
        let cpu = self.drain(sim, dir, t);
        self.drain_end[d] = t + cpu;
        // if the window closed mid-drain, the next completion re-kicks
    }

    fn on_wc(&mut self, sim: &mut Sim, wc: &Wc, cursor: u64) -> WcOutcome {
        // window release + RTT feedback + retirement policy
        let out = self.core.on_wc(wc, cursor);

        let is_write = !wc.op.is_read();
        let cpu = WC_HANDLER_BASE_NS
            + completion_cost_ns(&self.cfg, self.stack.mr, self.stack.space, wc.len, is_write);

        if let Some(mut slots) = self.slots.remove(&wc.wr_id) {
            if let Some(pool) = &mut self.premr_pool {
                pool.release(&mut slots);
            }
        }

        // fan out to coalesced block waiters
        let mut completed = Vec::with_capacity(out.retired.len());
        if self.stack.fixed_block.is_some() {
            for r in &out.retired {
                if let Some(ws) = self.waiters.remove(&r.id) {
                    // remove the block index entry for this rep
                    self.block_index.retain(|_, v| *v != r.id);
                    completed.extend(ws);
                } else {
                    completed.push(r.id);
                }
            }
        } else {
            completed.extend(out.retired.iter().map(|r| r.id));
        }

        // the freed window may unblock queued requests — kick both queues,
        // reads first (page-ins are synchronous, page-outs are not)
        self.request_kick(sim, Dir::Read, cursor + cpu);
        self.request_kick(sim, Dir::Write, cursor + cpu);

        WcOutcome {
            completed,
            handler_cpu_ns: cpu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::polling::PollingMode;
    use crate::fabric::sim::Driver;

    /// Submit-and-count driver used by engine-focused tests.
    struct Burst {
        n: u64,
        len: u64,
        stride: u64,
        done: u64,
    }
    impl Driver for Burst {
        fn on_start(&mut self, sim: &mut Sim) {
            for i in 0..self.n {
                sim.submit_at(Dir::Write, 0, i * self.stride, self.len, 0, 0);
            }
        }
        fn on_io_done(&mut self, sim: &mut Sim, _io: &AppIo, _l: u64, _at: u64) {
            self.done += 1;
            if self.done >= self.n {
                sim.request_stop();
            }
        }
        fn on_timer(&mut self, _s: &mut Sim, _t: usize, _g: u64) {}
    }

    fn mk(stack: &StackConfig) -> (Sim, FabricConfig) {
        let cfg = FabricConfig::default();
        let mut sim = Sim::new(cfg.clone(), stack.clone(), 1);
        sim.attach_engine(Box::new(StackEngine::new(&cfg, stack, 1)));
        (sim, cfg)
    }

    #[test]
    fn burst_of_adjacent_writes_merges() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let (mut sim, _) = mk(&stack);
        sim.attach_driver(Box::new(Burst {
            n: 64,
            len: 4096,
            stride: 4096, // adjacent
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(r.completed_writes, 64);
        assert!(
            r.trace.wqes_total() < 64,
            "adjacent burst should merge: {} WQEs",
            r.trace.wqes_total()
        );
        assert!(r.trace.merged_ios > 0);
    }

    #[test]
    fn scattered_burst_does_not_merge_but_doorbells() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let (mut sim, _) = mk(&stack);
        sim.attach_driver(Box::new(Burst {
            n: 64,
            len: 4096,
            stride: 1 << 20, // scattered
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(r.completed_writes, 64);
        assert_eq!(r.trace.wqes_total(), 64, "no adjacency, no WQE reduction");
        assert!(
            r.trace.mmios < 64,
            "doorbell chaining should reduce MMIOs: {}",
            r.trace.mmios
        );
    }

    #[test]
    fn admission_window_bounds_inflight_bytes() {
        let cfg = FabricConfig::default();
        let window = 64 * 1024;
        let stack = StackConfig::rdmabox(&cfg)
            .with_window(Some(window))
            .with_polling(PollingMode::Busy);
        let (mut sim, _) = mk(&stack);
        sim.attach_driver(Box::new(Burst {
            n: 256,
            len: 4096,
            stride: 1 << 20,
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(r.completed_writes, 256);
        assert!(
            r.peak_inflight_bytes <= window,
            "peak {} > window {}",
            r.peak_inflight_bytes,
            window
        );
        assert!(r.trace.admission_blocks > 0);
    }

    #[test]
    fn no_window_lets_inflight_grow() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg).with_window(None);
        let (mut sim, _) = mk(&stack);
        sim.attach_driver(Box::new(Burst {
            n: 256,
            len: 4096,
            stride: 1 << 20,
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert!(r.peak_inflight_bytes > 64 * 1024);
    }

    #[test]
    fn fixed_block_amplifies_bytes_and_coalesces() {
        let cfg = FabricConfig::default();
        let mut stack = StackConfig::rdmabox(&cfg).with_name("nbdX-like");
        stack.fixed_block = Some(128 * 1024);
        let (mut sim, _) = mk(&stack);
        // 32 page writes inside ONE 128K block -> 1 block WR
        sim.attach_driver(Box::new(Burst {
            n: 32,
            len: 4096,
            stride: 4096,
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(r.completed_writes, 32, "all app ios complete");
        assert!(
            r.trace.bytes_wire >= 128 * 1024,
            "block transfer on the wire"
        );
        assert!(
            r.trace.wqes_total() <= 4,
            "coalesced into few block WRs, got {}",
            r.trace.wqes_total()
        );
    }

    #[test]
    fn fixed_block_scattered_pages_each_cost_a_block() {
        let cfg = FabricConfig::default();
        let mut stack = StackConfig::rdmabox(&cfg);
        stack.fixed_block = Some(128 * 1024);
        stack.batch = BatchMode::Doorbell; // nbdX-ish
        let (mut sim, _) = mk(&stack);
        sim.attach_driver(Box::new(Burst {
            n: 16,
            len: 4096,
            stride: 1 << 20, // every page in a different block
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(r.completed_writes, 16);
        assert_eq!(r.trace.bytes_wire, 16 * 128 * 1024, "full amplification");
    }

    #[test]
    fn premr_stack_charges_copy_dynmr_charges_reg() {
        // identical workload, compare elapsed: in kernel space dynMR must
        // beat preMR (Fig 4a)
        let cfg = FabricConfig::default();
        let mk_run = |mr| {
            let stack = StackConfig::rdmabox(&cfg).with_mr(mr);
            let (mut sim, _) = mk(&stack);
            sim.attach_driver(Box::new(Burst {
                n: 512,
                len: 128 * 1024,
                stride: 1 << 22,
                done: 0,
            }));
            sim.run(u64::MAX / 2)
        };
        let pre = mk_run(crate::coordinator::mr_strategy::MrMode::PreMr);
        let dynr = mk_run(crate::coordinator::mr_strategy::MrMode::DynMr);
        // staging is charged on the submitting thread; on this serialized
        // single-stream workload the transfer dominates, so require kernel
        // dynMR to be no worse (its absolute staging costs are lower at
        // every size — see coordinator::mr_strategy tests)
        assert!(
            dynr.elapsed_ns <= pre.elapsed_ns * 102 / 100,
            "kernel dynMR {} should not lose to preMR {}",
            dynr.elapsed_ns,
            pre.elapsed_ns
        );
    }

    #[test]
    fn sharded_queues_spread_chains_over_channels() {
        // end-to-end through the sim: everything completes with K=4 shards
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg).with_qps(4);
        let (mut sim, _) = mk(&stack);
        sim.attach_driver(Box::new(Burst {
            n: 64,
            len: 4096,
            stride: 1 << 20, // one request per 1 MiB region
            done: 0,
        }));
        let r = sim.run(u64::MAX / 2);
        assert_eq!(r.completed_writes, 64);
        assert_eq!(r.trace.wqes_total(), 64);

        // and the same submission pattern really spreads over all 4 QPs
        // (checked at the shared core, where chain->QP binding is visible;
        // window lifted so a single drain shows the full spread)
        let mut core = crate::coordinator::engine::IoEngine::from_stack(
            &stack.clone().with_window(None),
            1,
            crate::coordinator::engine::EngineCosts::from_fabric(&cfg),
        );
        for i in 0..64u64 {
            core.submit(AppIo {
                id: i,
                dir: Dir::Write,
                node: 0,
                addr: i << 20,
                len: 4096,
                thread: 0,
                t_submit: 0,
                tenant: 0,
            });
        }
        let out = core.drain_all(0);
        let qps: std::collections::BTreeSet<_> = out.chains.iter().map(|c| c.qp).collect();
        assert_eq!(qps.len(), 4, "64 regions must cover all 4 shards");
    }

    use crate::coordinator::batching::BatchMode;
}
