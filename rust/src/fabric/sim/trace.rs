//! Fabric-level counters — the raw material for Table 1 (total RDMA I/Os to
//! the NIC), Fig 1b (in-flight ops), Fig 5 (interrupts / context switches)
//! and the §6.1 PCIe/MMIO accounting.

#[derive(Debug, Default, Clone)]
pub struct Trace {
    // ---- posting side ----
    /// WQEs handed to the NIC, by op — "total number of RDMA I/O to NIC".
    pub wqes_read: u64,
    pub wqes_write: u64,
    /// MMIO doorbell writes by the CPU.
    pub mmios: u64,
    /// Chained descriptors fetched by NIC DMA (doorbell batching).
    pub desc_dma_reads: u64,
    /// App I/Os that were merged into multi-fragment WRs.
    pub merged_ios: u64,
    /// Doorbell chains with more than one WR.
    pub chains_gt1: u64,

    // ---- NIC ----
    pub wqe_cache_misses: u64,
    pub qp_cache_misses: u64,
    pub mpt_misses: u64,
    /// Payload bytes that crossed the wire.
    pub bytes_wire: u64,
    /// Peak simultaneous WQEs queued in the NIC.
    pub peak_nic_queue: u64,

    // ---- completion side ----
    pub cqes: u64,
    pub interrupts: u64,
    pub ctx_switches: u64,
    pub poll_calls: u64,
    pub empty_polls: u64,

    // ---- coordinator ----
    pub admission_blocks: u64,
    pub premr_stalls: u64,
}

impl Trace {
    pub fn wqes_total(&self) -> u64 {
        self.wqes_read + self.wqes_write
    }

    /// Paper Fig 5c/5d proxy: fewer interrupts/ctx-switches per WC means
    /// poll-dominated completion handling.
    pub fn interrupts_per_cqe(&self) -> f64 {
        if self.cqes == 0 {
            0.0
        } else {
            self.interrupts as f64 / self.cqes as f64
        }
    }

    pub fn empty_poll_rate(&self) -> f64 {
        if self.poll_calls == 0 {
            0.0
        } else {
            self.empty_polls as f64 / self.poll_calls as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_rates() {
        let t = Trace {
            wqes_read: 10,
            wqes_write: 5,
            cqes: 20,
            interrupts: 5,
            poll_calls: 40,
            empty_polls: 10,
            ..Default::default()
        };
        assert_eq!(t.wqes_total(), 15);
        assert!((t.interrupts_per_cqe() - 0.25).abs() < 1e-12);
        assert!((t.empty_poll_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_division_safe() {
        let t = Trace::default();
        assert_eq!(t.interrupts_per_cqe(), 0.0);
        assert_eq!(t.empty_poll_rate(), 0.0);
    }
}
