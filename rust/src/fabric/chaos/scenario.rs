//! Seeded scenario runner: one [`Scenario`] = one topology + workload +
//! [`FaultPlan`], all derivable from a single `u64` seed, replayed
//! against the engine invariants every backend must uphold:
//!
//! 1. **Exactly-once retirement** — every submitted application I/O
//!    retires exactly once (through the fabric or as a submit-time disk
//!    fallback), never zero times, never twice.
//! 2. **Admission bound** — in-flight bytes never exceed the configured
//!    window, measured continuously and at the peak.
//! 3. **No lost I/O** — the run reaches quiescence with empty queues and
//!    a fully released window; faults may degrade I/Os to the disk path
//!    but may not strand them.
//! 4. **Quiet-plan control** — with no faults injected, no failovers,
//!    disk fallbacks, or duplicate completions may appear.
//! 5. **No stale reads** — the fabric's payload model must never observe
//!    a successful read served below the retired write floor of its
//!    pages. With the resync protocol enabled (the default here) this
//!    holds under node revival and partial partitions; disabling it
//!    ([`Scenario::without_resync`]) turns revival-after-missed-writes
//!    into a reproducible failure — which is the point.
//!
//! A violation returns an error that embeds the one-command reproducer
//! (seed included), so a CI failure is a replay away from a debugger.

use std::collections::BTreeSet;

use crate::coordinator::spec::EngineSpec;
use crate::fabric::Dir;
use crate::runtime::Result;
use crate::util::rng::Pcg32;

use super::{ChaosFabric, FaultPlan, SchedulerKind, RESYNC_CHUNK_BYTES, STRIPE_BYTES};

/// Livelock guard for one scenario run.
const MAX_STEPS: u64 = 4_000_000;
/// Default address span of the generated workload (16 MiB: enough
/// stripes to engage every node of a small cluster and several QP
/// shards). Scale scenarios widen [`Scenario::addr_span`] to one stripe
/// per node so hundreds of nodes all carry traffic.
const ADDR_SPAN: u64 = 1 << 24;
/// Largest generated I/O, in pages. This bound is load-bearing for the
/// window invariant: every generated window is at least `MAX_IO_PAGES`
/// pages (see [`Scenario::randomized`]), so the engine's oversized-head
/// progress guarantee — which legitimately posts a head *larger* than
/// the window once the pipe is idle — can never trigger, and any
/// in-flight excess the runner observes is a real violation.
const MAX_IO_PAGES: u64 = 4;

/// Which randomized fault mix a seed-derived scenario draws.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChaosProfile {
    /// The default sweep mix: every fault class at moderate probability.
    #[default]
    Standard,
    /// Election-heavy: more node churn, *overlapping* partition windows
    /// (mutual divergence on overlapping ranges), latency storms and
    /// admission churn — the mixes the epoch-vector donor election must
    /// drain. The nightly `chaos-extended` sweep runs this profile
    /// (`CHAOS_PROFILE=election`).
    ElectionHeavy,
    /// Multi-tenant QoS: two weighted tenants share the pipeline with a
    /// hog-biased workload, under guaranteed latency storms and
    /// admission churn on top of the standard mix — the per-tenant
    /// admission ledgers and DRR lanes must stay exactly balanced
    /// through it. The nightly sweep runs this as `CHAOS_PROFILE=qos`.
    Qos,
    /// Cluster scale: 256–512 nodes with rack-correlated faults
    /// ([`FaultPlan::randomized_rack_profile`]) — whole-rack death and
    /// revival (resync storms), rack-wide partitions, incast-shaped
    /// storms — on the calendar-queue scheduler. Its own seed stream:
    /// the small-cluster profiles draw none of its randomness, so their
    /// pinned seeds replay unchanged. The nightly sweep runs this as
    /// `CHAOS_PROFILE=scale`.
    Scale,
    /// Multi-engine: two peer [`crate::coordinator::engine::IoEngine`]s
    /// share one replica cluster
    /// and keep their epoch vectors convergent through the gossip
    /// anti-entropy plane, under asymmetric link cuts, gossip
    /// loss/blackout, and node churn ([`super::multi`]). Its own seed
    /// streams — no other profile's pinned seeds move. The nightly
    /// sweep runs this as `CHAOS_PROFILE=multi`.
    Multi,
    /// Completion-recovery heavy: the standard mix plus a *guaranteed*
    /// lost-WC rate and a wedged QP, with WR deadlines armed — the
    /// engine's timeout retirement, backoff requeue and QP error/reset
    /// machine must absorb every stranded completion. Extra draws land
    /// after every other profile's, so no older pinned seed moves. The
    /// nightly sweep runs this as `CHAOS_PROFILE=recovery`.
    Recovery,
}

/// One chaos scenario: everything the run needs, nameable by seed.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Test name for replay hints ("randomized" for seed-derived runs).
    pub name: &'static str,
    pub seed: u64,
    pub nodes: usize,
    pub qps_per_node: usize,
    pub replicas: usize,
    pub window_bytes: Option<u64>,
    pub n_ios: u64,
    pub read_fraction: f64,
    /// Run with the engine's epoch-based resync protocol (default: on).
    pub resync: bool,
    /// Run with the epoch-vector donor election on top of resync
    /// (default: on; ignored when `resync` is off).
    pub election: bool,
    /// Which randomized mix this seed drew (replay must match).
    pub profile: ChaosProfile,
    /// QoS weights, one per tenant (a single entry = single-tenant).
    /// Multi-tenant scenarios spread the workload hog-vs-victim across
    /// the tenants and check the per-tenant ledgers at quiescence.
    pub tenant_weights: Vec<u64>,
    /// `Some(cap)` runs the engine with the pinning-free MR cache at
    /// that pinned-bytes cap (always ≥ every window this generator
    /// draws, so the spec validates). The cache's slab bookkeeping then
    /// rides every adversarial schedule of the sweep.
    pub mr_cache_bytes: Option<u64>,
    /// Address span of the generated workload. Small-cluster scenarios
    /// use the 16 MiB default; scale scenarios widen it to one
    /// [`STRIPE_BYTES`] stripe per node so every node carries traffic.
    pub addr_span: u64,
    /// Which scheduler backs the fabric (default: the calendar queue).
    /// [`Scenario::with_reference_scheduler`] switches a run onto the
    /// pre-refactor `BinaryHeap` for bit-identity replay tests.
    pub scheduler: SchedulerKind,
    /// `Some((timeout_ns, max_retries))` arms the engine's completion
    /// deadlines ([`EngineSpec::deadlines`]). Seed-derived scenarios set
    /// this whenever their plan drew a recovery fault; the runner also
    /// arms a default for any explicit plan that needs one, since lost
    /// completions strand WRs forever without deadlines.
    pub deadlines: Option<(u64, u32)>,
    pub plan: FaultPlan,
}

impl Scenario {
    /// A scenario fully derived from `seed`: topology, window, workload
    /// shape, and fault mix. This is what the randomized sweep runs.
    pub fn randomized(seed: u64) -> Self {
        Self::randomized_with_profile(seed, ChaosProfile::Standard)
    }

    /// [`Scenario::randomized`] drawing the fault mix from a chosen
    /// [`ChaosProfile`].
    pub fn randomized_with_profile(seed: u64, profile: ChaosProfile) -> Self {
        let mut rng = Pcg32::with_stream(seed, 0x5EED5);
        if profile == ChaosProfile::Scale {
            // entirely separate draw sequence — the small-cluster
            // profiles below keep their exact historical seed streams
            return Self::randomized_scale(seed, &mut rng);
        }
        if profile == ChaosProfile::Multi {
            // the multi-engine runner derives its whole fault mix and
            // workload from `seed` on streams of its own (see
            // [`super::multi::run_multi_scenario`]); the scenario is
            // just the seed's carrier, returned before any draw here so
            // the historical small-profile streams stay untouched
            return Self {
                name: "randomized",
                seed,
                nodes: super::multi::NODES,
                qps_per_node: 1,
                replicas: 2,
                window_bytes: None,
                n_ios: 0,
                read_fraction: 0.0,
                resync: true,
                election: true,
                profile,
                tenant_weights: vec![1],
                mr_cache_bytes: None,
                addr_span: ADDR_SPAN,
                scheduler: SchedulerKind::default(),
                deadlines: None,
                plan: FaultPlan::none(),
            };
        }
        let nodes = 2 + rng.gen_below(3) as usize;
        let qps_per_node = 1 + rng.gen_below(4) as usize;
        // up to 3-way replication (topology permitting): multi-peer
        // resync source selection only exists with ≥ 3 replicas
        let replicas = 1 + rng.gen_below(nodes.min(3) as u64) as usize;
        // window floor = MAX_IO_PAGES: see the constant's invariant note
        let window_bytes = if rng.gen_bool(0.75) {
            Some((MAX_IO_PAGES + rng.gen_below(28)) * 4096)
        } else {
            None
        };
        let n_ios = 150 + rng.gen_below(250);
        let read_fraction = 0.2 + rng.gen_f64() * 0.6;
        let heavy = profile == ChaosProfile::ElectionHeavy;
        let mut plan = FaultPlan::randomized_profile(&mut rng, nodes, qps_per_node, heavy);
        let tenant_weights = if profile == ChaosProfile::Qos {
            // victim first, hog last; the victim gets the larger weight,
            // and the plan is guaranteed a latency storm + admission
            // churn so the sub-windows are squeezed while full
            let victim_w = 2 + rng.gen_below(6);
            let from = rng.gen_below(200_000);
            plan = plan
                .latency_storm(from, from + 1 + rng.gen_below(150_000), 1 + rng.gen_below(60_000))
                .admission_window(
                    rng.gen_below(300_000),
                    Some((MAX_IO_PAGES + rng.gen_below(12)) * 4096),
                );
            vec![victim_w, 1]
        } else {
            vec![1]
        };
        // drawn after the plan so older seeds keep their exact fault mix;
        // 64..256 pages ≥ every window drawn above (max 31 pages) and ≥
        // one 16-page registration span, so the spec always validates
        let mr_cache_bytes = if rng.gen_bool(0.6) {
            Some((64 + rng.gen_below(192)) * 4096)
        } else {
            None
        };
        // Recovery profile: guarantee the new fault classes on top of
        // the standard mix (drawn after everything above, so no other
        // profile's pinned seeds move)
        if profile == ChaosProfile::Recovery {
            plan = plan.with_lost_wcs(0.05 + rng.gen_f64() * 0.1);
            let qp = rng.gen_below((nodes * qps_per_node) as u64) as usize;
            let from = rng.gen_below(200_000);
            plan = plan.wedge(qp, from, from + 1 + rng.gen_below(150_000));
        }
        // deadline parameters, drawn last — and only for plans that drew
        // a recovery fault: a lost WC or a wedged QP strands its WR
        // forever unless a completion deadline retires it. The timeout
        // sits far above the fabric's delivery latency so deadlines fire
        // for stranded completions, not slow ones.
        let deadlines = if plan.needs_deadlines() {
            Some((150_000 + rng.gen_below(150_000), 1 + rng.gen_below(2) as u32))
        } else {
            None
        };
        Self {
            name: "randomized",
            seed,
            nodes,
            qps_per_node,
            replicas,
            window_bytes,
            n_ios,
            read_fraction,
            resync: true,
            election: true,
            profile,
            tenant_weights,
            mr_cache_bytes,
            addr_span: ADDR_SPAN,
            scheduler: SchedulerKind::default(),
            deadlines,
            plan,
        }
    }

    /// The `Scale` profile's draw: a 256–512 node cluster in racks of
    /// 8/16/32, a rack-correlated fault mix, and an address span of one
    /// stripe per node so the whole cluster carries traffic. Reached
    /// only through [`Scenario::randomized_with_profile`].
    fn randomized_scale(seed: u64, rng: &mut Pcg32) -> Self {
        let nodes = 256 + rng.gen_below(257) as usize;
        let qps_per_node = 1 + rng.gen_below(2) as usize;
        // 3-way replication dominates so a whole-rack loss usually
        // leaves a live replica (racks are contiguous, like placement)
        let replicas = 2 + rng.gen_below(2) as usize;
        let nodes_per_rack = 8usize << rng.gen_below(3);
        // always windowed: admission collapse under incast is one of
        // the invariants this profile exists to check
        let window_bytes = Some((MAX_IO_PAGES + rng.gen_below(60)) * 4096);
        let n_ios = 400 + rng.gen_below(400);
        let read_fraction = 0.2 + rng.gen_f64() * 0.6;
        let plan = FaultPlan::randomized_rack_profile(rng, nodes, qps_per_node, nodes_per_rack);
        // drawn after the plan (same discipline as the small profiles);
        // 256..512 pages ≥ every window drawn above (max 63 pages)
        let mr_cache_bytes = Some((256 + rng.gen_below(256)) * 4096);
        Self {
            name: "randomized",
            seed,
            nodes,
            qps_per_node,
            replicas,
            window_bytes,
            n_ios,
            read_fraction,
            resync: true,
            election: true,
            profile: ChaosProfile::Scale,
            tenant_weights: vec![1],
            mr_cache_bytes,
            addr_span: nodes as u64 * STRIPE_BYTES,
            scheduler: SchedulerKind::default(),
            deadlines: None,
            plan,
        }
    }

    /// A named scenario with an explicit fault plan on the default
    /// 3-node × 2-QP, 2-replica, windowed topology.
    pub fn named(name: &'static str, seed: u64, plan: FaultPlan) -> Self {
        Self {
            name,
            seed,
            nodes: 3,
            qps_per_node: 2,
            replicas: 2,
            window_bytes: Some(24 * 4096),
            n_ios: 300,
            read_fraction: 0.4,
            resync: true,
            election: true,
            profile: ChaosProfile::Standard,
            tenant_weights: vec![1],
            mr_cache_bytes: Some(64 * 4096),
            addr_span: ADDR_SPAN,
            scheduler: SchedulerKind::default(),
            deadlines: None,
            plan,
        }
    }

    /// A named scenario at cluster scale: `nodes` nodes × 1 QP, 3-way
    /// replication, a 64-page window, and an address span of one stripe
    /// per node — the topology the rack-fault regression scenarios and
    /// the 1000-node acceptance run drive.
    pub fn named_scale(name: &'static str, seed: u64, nodes: usize, plan: FaultPlan) -> Self {
        assert!(nodes >= 3, "scale topology needs 3-way replication");
        Self {
            name,
            seed,
            nodes,
            qps_per_node: 1,
            replicas: 3,
            window_bytes: Some(64 * 4096),
            n_ios: 1500,
            read_fraction: 0.4,
            resync: true,
            election: true,
            profile: ChaosProfile::Standard,
            tenant_weights: vec![1],
            mr_cache_bytes: Some(512 * 4096),
            addr_span: nodes as u64 * STRIPE_BYTES,
            scheduler: SchedulerKind::default(),
            deadlines: None,
            plan,
        }
    }

    /// Register QoS tenants by weight (the workload is spread across
    /// them hog-vs-victim, like the `Qos` profile does from its seed).
    pub fn with_tenants(mut self, weights: &[u64]) -> Self {
        self.tenant_weights = weights.to_vec();
        self
    }

    /// Disable the resync protocol: revived replicas rejoin routing
    /// immediately, so a revival after missed writes serves stale data —
    /// and the payload-model invariant fails the scenario.
    pub fn without_resync(mut self) -> Self {
        self.resync = false;
        self
    }

    /// Disable the epoch-vector donor election (resync stays on): the
    /// conservative donor rule applies, so a topology whose resyncing
    /// peers miss *overlapping* ranges parks in `Resyncing` — the seed
    /// branch of the `overlapping_resync_elects_freshest` acceptance
    /// scenario.
    pub fn without_election(mut self) -> Self {
        self.election = false;
        self
    }

    /// Arm the engine's completion deadlines: every posted WR must
    /// resolve within `timeout_ns` of virtual time or a synthesized
    /// timeout-WC retires it (reads get `max_retries` backed-off
    /// requeues first). Named recovery scenarios set this explicitly;
    /// seed-derived ones draw it with their plan.
    pub fn with_deadlines(mut self, timeout_ns: u64, max_retries: u32) -> Self {
        self.deadlines = Some((timeout_ns, max_retries));
        self
    }

    /// Run this scenario on the pre-refactor `BinaryHeap` scheduler
    /// instead of the calendar queue. The replay-equivalence suite
    /// (`tests/pinned_replay.rs`) runs every pinned seed both ways and
    /// asserts the full reports are identical.
    pub fn with_reference_scheduler(mut self) -> Self {
        self.scheduler = SchedulerKind::Reference;
        self
    }
}

/// What a passing scenario measured (tests assert on these to make sure
/// the intended fault actually fired, not just that nothing broke).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioReport {
    pub submitted: u64,
    pub retired: u64,
    /// I/Os that took the disk path at submit time (all replicas dead).
    pub disk_at_submit: u64,
    pub failovers: u64,
    pub disk_fallbacks: u64,
    pub duplicate_wcs: u64,
    pub delivered_wcs: u64,
    pub injected_errors: u64,
    pub reordered_wcs: u64,
    pub stalled_wcs: u64,
    /// WRs that paid a synchronous lazy-registration stall (first touch
    /// of an unregistered span under `FaultPlan::with_reg_stalls`).
    pub reg_stalled_wcs: u64,
    pub stormed_wcs: u64,
    pub window_changes: u64,
    pub partitioned_wcs: u64,
    pub node_transitions: u64,
    /// WCs the plan swallowed outright (recoverable only by deadline).
    pub lost_wcs: u64,
    /// WCs dropped by a wedged-QP window.
    pub wedged_wcs: u64,
    /// Recovery-timer service events the fabric executed.
    pub timer_ticks: u64,
    /// WRs the engine retired by deadline expiry.
    pub recovery_timeouts: u64,
    /// WRs flushed as timeout-WCs by a QP entering `Error`.
    pub recovery_flushes: u64,
    /// QP `Error → Resetting → Ok` recoveries completed.
    pub recovery_resets: u64,
    /// Always 0 in a passing report: admission-window byte-ledger leaks
    /// counted by the regulator (release larger than the charge).
    pub window_leaks: u64,
    /// Always 0 in a passing report (invariant 5).
    pub stale_reads: u64,
    pub split_requests: u64,
    pub split_legs: u64,
    pub resync_rounds: u64,
    pub resync_copies: u64,
    pub resync_demotions: u64,
    pub resync_elections: u64,
    pub resync_self_heals: u64,
    pub resync_disk_surrenders: u64,
    pub resyncs_completed: u64,
    /// MR-cache span lookups that found a live registration (0 when the
    /// scenario runs without a cache).
    pub mr_hits: u64,
    /// First-touch span registrations the cache performed lazily.
    pub mr_misses: u64,
    pub peak_in_flight: u64,
    pub elapsed_virtual_ns: u64,
    /// Bytes posted per tenant (one entry per registered tenant).
    pub tenant_posted_bytes: Vec<u64>,
    /// Work-conserving borrow events per tenant.
    pub tenant_borrows: Vec<u64>,
}

/// The one-command reproducer for a failing scenario.
pub fn replay_command(sc: &Scenario) -> String {
    if sc.name == "randomized" {
        let profile = match sc.profile {
            ChaosProfile::Standard => "",
            ChaosProfile::ElectionHeavy => "CHAOS_PROFILE=election ",
            ChaosProfile::Qos => "CHAOS_PROFILE=qos ",
            ChaosProfile::Scale => "CHAOS_PROFILE=scale ",
            ChaosProfile::Multi => "CHAOS_PROFILE=multi ",
            ChaosProfile::Recovery => "CHAOS_PROFILE=recovery ",
        };
        format!(
            "{profile}CHAOS_SEED={:#x} cargo test --release --test chaos_scenarios \
             replay_env_seed -- --nocapture",
            sc.seed
        )
    } else {
        format!(
            "cargo test --release --test chaos_scenarios {} -- --nocapture",
            sc.name
        )
    }
}

/// Run one scenario to quiescence, checking every engine invariant along
/// the way. `Err` carries the violation plus the replay command.
pub fn run_scenario(sc: &Scenario) -> Result<ScenarioReport> {
    if sc.profile == ChaosProfile::Multi {
        // two-engine runs live in their own harness: two pipelines, one
        // shared cluster, the gossip plane inside the schedule
        return super::multi::run_multi_scenario(sc);
    }
    let fail = |msg: String| -> crate::runtime::Error {
        format!(
            "chaos scenario `{}` (seed {:#x}) failed: {msg}\n  replay: {}",
            sc.name,
            sc.seed,
            replay_command(sc)
        )
        .into()
    };

    if let Some(w) = sc.window_bytes {
        assert!(
            w >= MAX_IO_PAGES * 4096,
            "scenario window smaller than the largest generated I/O"
        );
    }
    for c in &sc.plan.churns {
        if let Some(w) = c.window_bytes {
            assert!(
                w >= MAX_IO_PAGES * 4096,
                "churned window smaller than the largest generated I/O"
            );
        }
    }
    // the in-flight bound under admission churn: every admission honors
    // the window active at its post, so in-flight (and the peak) can
    // never exceed the largest window that was ever active. Unbounded if
    // the run starts — or ever churns to — unlimited.
    let window_cap: Option<u64> = if sc.window_bytes.is_none()
        || sc.plan.churns.iter().any(|c| c.window_bytes.is_none())
    {
        None
    } else {
        let churn_max = sc.plan.churns.iter().filter_map(|c| c.window_bytes).max();
        Some(match (sc.window_bytes, churn_max) {
            (Some(w), Some(cm)) => w.max(cm),
            (Some(w), None) => w,
            (None, _) => unreachable!("handled above"),
        })
    };
    let mut spec = EngineSpec::new(sc.nodes)
        .qps(sc.qps_per_node)
        .window(sc.window_bytes)
        .replicated(sc.replicas)
        .tenants(&sc.tenant_weights);
    if sc.resync {
        spec = spec.resync(RESYNC_CHUNK_BYTES);
        if sc.election {
            spec = spec.election();
        }
    }
    if let Some(cap) = sc.mr_cache_bytes {
        spec = spec.mr_cache(cap);
    }
    // a plan that swallows completions needs deadlines to quiesce; arm
    // a conservative default for explicit plans that forgot to set them
    let deadlines = sc
        .deadlines
        .or_else(|| sc.plan.needs_deadlines().then_some((200_000, 2)));
    if let Some((timeout_ns, max_retries)) = deadlines {
        spec = spec.deadlines(timeout_ns, max_retries);
    }
    let mut fab = ChaosFabric::build_with_scheduler(sc.seed, &spec, sc.plan.clone(), sc.scheduler);
    let n_tenants = sc.tenant_weights.len();
    // workload stream is independent of the fabric's fault stream
    let mut rng = Pcg32::with_stream(sc.seed, 0x10AD5);
    let mut retired: BTreeSet<u64> = BTreeSet::new();
    let mut disk_at_submit = 0u64;
    let mut submitted = 0u64;
    let mut steps = 0u64;
    // Submit a warm-up batch before stepping: the virtual clock advances
    // only through events, so without traffic in flight the first step
    // would jump straight to the plan's first node event and a "mid-run"
    // death would land on an empty pipeline.
    let warmup = sc.n_ios.min(32);

    while submitted < sc.n_ios || fab.pending_events() > 0 {
        steps += 1;
        if steps > MAX_STEPS {
            return Err(fail(format!(
                "livelock: {} of {} retired after {MAX_STEPS} steps",
                retired.len(),
                sc.n_ios
            )));
        }
        // interleave submissions with fabric progress so faults land on a
        // part-submitted, part-in-flight pipeline (the adversarial case)
        let can_submit = submitted < sc.n_ios;
        let do_submit = can_submit
            && (submitted < warmup || fab.pending_events() == 0 || rng.gen_bool(0.5));
        if do_submit {
            let id = submitted;
            let dir = if rng.gen_bool(sc.read_fraction) {
                Dir::Read
            } else {
                Dir::Write
            };
            let len = 4096 * (1 + rng.gen_below(MAX_IO_PAGES));
            let mut addr = rng.gen_below(sc.addr_span / 4096) * 4096;
            // the engine-level splitter lifted the old stripe-local
            // contract: multi-stripe I/Os are split into stripe-local
            // legs at submission. Bias a slice of the workload onto
            // stripe boundaries so every sweep seed exercises the
            // splitter (and the per-leg staleness accounting behind it).
            if len > 4096 && rng.gen_bool(0.15) {
                addr = (addr / STRIPE_BYTES + 1) * STRIPE_BYTES - 4096;
            }
            // hog-vs-victim spread: the last tenant is the hog and
            // carries most of the stream; the rest split the remainder
            let tenant = if n_tenants > 1 {
                if rng.gen_bool(0.7) {
                    n_tenants - 1
                } else {
                    rng.gen_below(n_tenants as u64 - 1) as usize
                }
            } else {
                0
            };
            let sub = fab.submit_t(id, dir, addr, len, tenant);
            submitted += 1;
            if sub.disk_fallback {
                disk_at_submit += 1;
                if !retired.insert(id) {
                    return Err(fail(format!("io {id} retired twice (submit path)")));
                }
            }
        } else if let Some(rs) = fab.step() {
            for r in rs {
                if !retired.insert(r.id) {
                    return Err(fail(format!("io {} retired twice", r.id)));
                }
            }
        }
        if let Some(w) = window_cap {
            let in_flight = fab.engine().regulator().in_flight();
            if in_flight > w {
                return Err(fail(format!(
                    "admission window exceeded: {in_flight} in flight > {w}"
                )));
            }
        }
    }

    // quiescence invariants
    if retired.len() as u64 != sc.n_ios {
        let lost: Vec<u64> = (0..sc.n_ios).filter(|i| !retired.contains(i)).collect();
        return Err(fail(format!(
            "lost I/O: {} of {} retired, missing {lost:?}",
            retired.len(),
            sc.n_ios
        )));
    }
    if fab.engine().queued_ios() != 0 {
        return Err(fail(format!(
            "{} requests still queued at quiescence",
            fab.engine().queued_ios()
        )));
    }
    if fab.engine().regulator().in_flight() != 0 {
        return Err(fail(format!(
            "window not fully released at quiescence: {} bytes stranded",
            fab.engine().regulator().in_flight()
        )));
    }
    // the regulator counts (instead of panicking on) over-releases of
    // the byte ledger; any count is a double-release bug
    if fab.engine().stats.window_leaks != 0 {
        return Err(fail(format!(
            "admission window over-released {} time(s)",
            fab.engine().stats.window_leaks
        )));
    }
    // every QP the error machine tripped must have walked back to `Ok`
    // through probation by quiescence (probes are timer events, so a
    // parked QP would also show up as a non-empty schedule)
    if fab.engine().qps_not_ok() != 0 {
        return Err(fail(format!(
            "{} QP(s) still in Error/Resetting at quiescence",
            fab.engine().qps_not_ok()
        )));
    }
    // per-tenant ledgers: every sub-window fully released, every posted
    // byte matched by a completion on the tenant that posted it
    let tenant_stats = fab.engine().tenant_stats();
    for t in &tenant_stats {
        if t.window_occupancy != 0 {
            return Err(fail(format!(
                "tenant {} sub-window not released: {} bytes stranded",
                t.tenant, t.window_occupancy
            )));
        }
        if t.posted_bytes != t.retired_bytes {
            return Err(fail(format!(
                "tenant {} ledger unbalanced: posted {} != retired {}",
                t.tenant, t.posted_bytes, t.retired_bytes
            )));
        }
    }
    let peak = fab.engine().regulator().peak_in_flight;
    if let Some(w) = window_cap {
        if peak > w {
            return Err(fail(format!("peak in-flight {peak} exceeded window {w}")));
        }
    }
    if sc.plan.is_quiet()
        && (fab.stats.failovers != 0
            || fab.stats.disk_fallbacks != 0
            || disk_at_submit != 0
            || fab.engine().stats.duplicate_wcs != 0)
    {
        return Err(fail(format!(
            "quiet plan produced fault artifacts: {:?}",
            fab.stats
        )));
    }
    if fab.stats.stale_reads > 0 {
        return Err(fail(format!(
            "stale read served: {} successful read(s) returned data below \
             the retired write floor (first: {}){}",
            fab.stats.stale_reads,
            fab.first_stale.as_deref().unwrap_or("?"),
            if sc.resync {
                ""
            } else {
                " — resync is disabled for this scenario, so an \
                 unresynchronized revival is expected to fail exactly here"
            },
        )));
    }

    // MR-cache wiring tripwire: with a cache attached, every drained WR
    // probes it before posting — a run that delivered completions but
    // never touched a span means the lazy-registration path fell out of
    // the pipeline
    if sc.mr_cache_bytes.is_some()
        && fab.stats.delivered_wcs > 0
        && fab.engine().stats.mr_hits + fab.engine().stats.mr_misses == 0
    {
        return Err(fail(
            "MR cache enabled but no span was ever touched on the drain path".into(),
        ));
    }

    Ok(ScenarioReport {
        submitted,
        retired: retired.len() as u64,
        disk_at_submit,
        failovers: fab.stats.failovers,
        disk_fallbacks: fab.stats.disk_fallbacks,
        duplicate_wcs: fab.engine().stats.duplicate_wcs,
        delivered_wcs: fab.stats.delivered_wcs,
        injected_errors: fab.stats.injected_errors,
        reordered_wcs: fab.stats.reordered_wcs,
        stalled_wcs: fab.stats.stalled_wcs,
        reg_stalled_wcs: fab.stats.reg_stalled_wcs,
        stormed_wcs: fab.stats.stormed_wcs,
        window_changes: fab.stats.window_changes,
        partitioned_wcs: fab.stats.partitioned_wcs,
        node_transitions: fab.stats.node_transitions,
        lost_wcs: fab.stats.lost_wcs,
        wedged_wcs: fab.stats.wedged_wcs,
        timer_ticks: fab.stats.timer_ticks,
        recovery_timeouts: fab.engine().recovery_stats().timeouts,
        recovery_flushes: fab.engine().recovery_stats().flushes,
        recovery_resets: fab.engine().recovery_stats().resets,
        window_leaks: fab.engine().stats.window_leaks,
        stale_reads: fab.stats.stale_reads,
        split_requests: fab.engine().stats.split_requests,
        split_legs: fab.engine().stats.split_legs,
        resync_rounds: fab.engine().stats.resync_rounds,
        resync_copies: fab.engine().stats.resync_copies,
        resync_demotions: fab.engine().stats.resync_demotions,
        resync_elections: fab.engine().stats.resync_elections,
        resync_self_heals: fab.engine().stats.resync_self_heals,
        resync_disk_surrenders: fab.engine().stats.resync_disk_surrenders,
        resyncs_completed: fab.engine().stats.resyncs_completed,
        mr_hits: fab.engine().stats.mr_hits,
        mr_misses: fab.engine().stats.mr_misses,
        peak_in_flight: fab.engine().regulator().peak_in_flight,
        elapsed_virtual_ns: fab.now(),
        tenant_posted_bytes: tenant_stats.iter().map(|t| t.posted_bytes).collect(),
        tenant_borrows: tenant_stats.iter().map(|t| t.borrow_events).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn randomized_scenario_is_seed_deterministic() {
        let a = run_scenario(&Scenario::randomized(0xA11CE)).expect("passes");
        let b = run_scenario(&Scenario::randomized(0xA11CE)).expect("passes");
        assert_eq!(a, b, "same seed, same report");
    }

    #[test]
    fn quiet_named_scenario_passes_cleanly() {
        let r = run_scenario(&Scenario::named("quiet", 1, FaultPlan::none())).expect("passes");
        assert_eq!(r.retired, r.submitted);
        assert_eq!(r.failovers, 0);
        assert_eq!(r.disk_fallbacks, 0);
        // named scenarios run with the MR cache attached: lazy
        // registration fired at least once per touched span
        assert!(r.mr_misses > 0, "cache never lazily registered");
        assert_eq!(r.reg_stalled_wcs, 0, "quiet plan cannot stall");
    }

    #[test]
    fn replay_command_names_the_seed() {
        let sc = Scenario::randomized(0xBEEF);
        let cmd = replay_command(&sc);
        assert!(cmd.contains("CHAOS_SEED=0xbeef"), "{cmd}");
        let named = Scenario::named("wc_reordering", 5, FaultPlan::none());
        assert!(replay_command(&named).contains("wc_reordering"));
    }

    #[test]
    fn without_resync_builder_flips_the_knob() {
        let sc = Scenario::randomized(7);
        assert!(sc.resync, "resync defaults to on");
        assert!(!sc.without_resync().resync);
    }

    #[test]
    fn election_knob_and_heavy_profile_replay() {
        let sc = Scenario::randomized(9);
        assert!(sc.election, "election defaults to on");
        assert!(!sc.clone().without_election().election);
        let heavy = Scenario::randomized_with_profile(0xFEED, ChaosProfile::ElectionHeavy);
        assert!(
            replay_command(&heavy).starts_with("CHAOS_PROFILE=election "),
            "heavy-profile replay must pin the profile: {}",
            replay_command(&heavy)
        );
        let std = Scenario::randomized(0xFEED);
        assert!(!replay_command(&std).contains("CHAOS_PROFILE"));
    }

    #[test]
    fn heavy_profile_seeds_pass_the_runner() {
        for seed in 0..3u64 {
            let sc = Scenario::randomized_with_profile(seed, ChaosProfile::ElectionHeavy);
            if let Err(e) = run_scenario(&sc) {
                panic!("{e}");
            }
        }
    }

    #[test]
    fn qos_profile_seeds_pass_with_balanced_tenants() {
        for seed in 0..3u64 {
            let sc = Scenario::randomized_with_profile(seed, ChaosProfile::Qos);
            assert_eq!(sc.tenant_weights.len(), 2, "victim + hog");
            assert!(!sc.plan.storms.is_empty(), "qos profile guarantees a storm");
            assert!(!sc.plan.churns.is_empty(), "qos profile guarantees churn");
            let r = match run_scenario(&sc) {
                Ok(r) => r,
                Err(e) => panic!("{e}"),
            };
            assert_eq!(r.tenant_posted_bytes.len(), 2);
            assert!(
                r.tenant_posted_bytes.iter().all(|&b| b > 0),
                "both tenants carried traffic: {:?}",
                r.tenant_posted_bytes
            );
        }
        let sc = Scenario::randomized_with_profile(0xFEED, ChaosProfile::Qos);
        assert!(
            replay_command(&sc).starts_with("CHAOS_PROFILE=qos "),
            "{}",
            replay_command(&sc)
        );
    }

    #[test]
    fn scale_profile_seeds_pass_the_runner() {
        for seed in 0..2u64 {
            let sc = Scenario::randomized_with_profile(seed, ChaosProfile::Scale);
            assert!(sc.nodes >= 256, "scale means hundreds of nodes");
            assert!(sc.window_bytes.is_some(), "scale is always windowed");
            assert_eq!(sc.addr_span, sc.nodes as u64 * STRIPE_BYTES);
            if let Err(e) = run_scenario(&sc) {
                panic!("{e}");
            }
        }
        let sc = Scenario::randomized_with_profile(0xFEED, ChaosProfile::Scale);
        assert!(
            replay_command(&sc).starts_with("CHAOS_PROFILE=scale "),
            "{}",
            replay_command(&sc)
        );
    }

    #[test]
    fn multi_profile_seeds_pass_the_runner() {
        for seed in 0..3u64 {
            let sc = Scenario::randomized_with_profile(seed, ChaosProfile::Multi);
            assert_eq!(sc.nodes, crate::fabric::chaos::multi::NODES);
            match run_scenario(&sc) {
                Ok(report) => {
                    assert_eq!(report.retired, report.submitted, "every I/O accounted");
                    assert_eq!(report.stale_reads, 0);
                    assert!(report.delivered_wcs > 0);
                }
                Err(e) => panic!("{e}"),
            }
        }
        let sc = Scenario::randomized_with_profile(0xFEED, ChaosProfile::Multi);
        assert!(
            replay_command(&sc).starts_with("CHAOS_PROFILE=multi "),
            "{}",
            replay_command(&sc)
        );
    }

    #[test]
    fn recovery_profile_seeds_pass_with_deadlines() {
        for seed in 0..3u64 {
            let sc = Scenario::randomized_with_profile(seed, ChaosProfile::Recovery);
            assert!(
                sc.deadlines.is_some(),
                "recovery profile always arms deadlines"
            );
            assert!(sc.plan.lost_rate > 0.0, "lost WCs guaranteed");
            assert!(!sc.plan.wedges.is_empty(), "a wedged QP guaranteed");
            let r = match run_scenario(&sc) {
                Ok(r) => r,
                Err(e) => panic!("{e}"),
            };
            assert!(
                r.lost_wcs + r.wedged_wcs > 0,
                "the recovery faults actually fired"
            );
            assert!(r.recovery_timeouts > 0, "deadlines retired stranded WRs");
            assert!(r.timer_ticks > 0, "the fabric serviced recovery timers");
            assert_eq!(r.window_leaks, 0);
            assert_eq!(r.stale_reads, 0);
        }
        let sc = Scenario::randomized_with_profile(0xFEED, ChaosProfile::Recovery);
        assert!(
            replay_command(&sc).starts_with("CHAOS_PROFILE=recovery "),
            "{}",
            replay_command(&sc)
        );
    }

    #[test]
    fn explicit_lossy_plan_gets_default_deadlines() {
        // a named scenario whose plan swallows WCs but forgot
        // .with_deadlines(..): the runner arms the conservative default
        // rather than livelocking on stranded WRs
        let sc = Scenario::named("lossy_default", 0x105E, FaultPlan::none().with_lost_wcs(0.1));
        assert!(sc.deadlines.is_none());
        let r = match run_scenario(&sc) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        };
        assert!(r.lost_wcs > 0, "losses fired");
        assert!(r.recovery_timeouts >= r.lost_wcs);
        assert_eq!(r.window_leaks, 0);
    }

    #[test]
    fn reference_scheduler_builder_flips_the_knob() {
        let sc = Scenario::randomized(3);
        assert_eq!(sc.scheduler, SchedulerKind::Calendar, "calendar is the default");
        assert_eq!(
            sc.with_reference_scheduler().scheduler,
            SchedulerKind::Reference
        );
    }

    #[test]
    fn a_small_seed_sweep_passes_in_unit_tests() {
        // the broad sweep lives in tests/chaos_scenarios.rs; keep a
        // smoke-sized one next to the implementation
        for seed in 0..4u64 {
            if let Err(e) = run_scenario(&Scenario::randomized(seed)) {
                panic!("{e}");
            }
        }
    }
}
