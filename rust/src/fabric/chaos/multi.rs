//! Multi-engine chaos: two peer [`IoEngine`]s — two client *hosts*, each
//! with its own admission window, QPs, and resync ledgers — share one
//! replicated page-store cluster and keep each other honest through the
//! gossip anti-entropy plane ([`crate::coordinator::gossip`]).
//!
//! The single-engine [`super::ChaosFabric`] proves one pipeline upholds
//! the invariants under a hostile completion schedule; this harness
//! proves two pipelines *converge* under one: overlapping writes during
//! asymmetric link partitions (engine A's legs to a node error while
//! engine B's land), conflicting resync elections minting epochs
//! concurrently, gossip rounds lost, reordered, and blacked out — all in
//! virtual time on the shared calendar queue, a pure function of
//! `(seed, MultiPlan, workload)`.
//!
//! Gossip is carried *inside* the schedule: each engine's tick exports a
//! [`GossipDelta`] plus a snapshot of the sender's retired-floor and
//! disk-ownership knowledge, delivered to the peer after loss/jitter
//! draws. Piggybacking the floor on the delta is what keeps the
//! staleness oracle causal: a receiver's floor only ever tightens
//! together with the missed-range and node-state knowledge that makes
//! the tighter floor safe to enforce. A read is stale when a replica
//! serves a page below the version the *submitting engine* causally
//! knew had retired — exactly the invariant the ISSUE's acceptance
//! demands after healing.
//!
//! Quiescence is convergence-gated: gossip ticks re-arm after every
//! event until both engines have absorbed at least one round and their
//! [`IoEngine::gossip_fingerprint`]s agree, so an empty queue *implies*
//! identical epoch vectors (and a livelock shows up as a bounded-step
//! error naming the divergence, not a hang).

use std::collections::BTreeSet;

use crate::coordinator::engine::{DrainOut, IoEngine, RetiredIo, Submitted, RESYNC_PARENT};
use crate::coordinator::gossip::GossipDelta;
use crate::coordinator::spec::EngineSpec;
use crate::fabric::{AppIo, Dir, NodeId, OpKind, QpId, Wc, WcStatus, WorkRequest, DEFAULT_TENANT};
use crate::util::eventq::EventQueue;
use crate::util::fxhash::FxHashMap;
use crate::util::rng::Pcg32;

use super::scenario::{replay_command, Scenario, ScenarioReport};
use super::{
    pages_of, stamp_fp, PageSet, PageStamp, LAT_BASE_NS, LAT_JITTER_NS, PAGE_BYTES,
    RESYNC_CHUNK_BYTES,
};

/// Peer engines per cluster (the protocol generalizes; the harness pins
/// the two-host shape the acceptance scenarios name).
pub const ENGINES: usize = 2;
/// Storage nodes of the shared replica cluster.
pub const NODES: usize = 2;
/// Livelock guard for one multi-engine run.
const MAX_STEPS: u64 = 4_000_000;

/// The multi-engine fault mix: everything the single-engine
/// [`super::FaultPlan`] cannot express because it needs *two* views of
/// one cluster — asymmetric link cuts, gossip-channel loss/blackout —
/// plus cluster-wide node churn both engines observe.
#[derive(Debug, Clone)]
pub struct MultiPlan {
    /// Per-delivery completion error probability (either engine).
    pub error_rate: f64,
    /// Probability a gossip send is dropped on the floor.
    pub gossip_loss: f64,
    /// Gossip tick interval in virtual ns.
    pub gossip_every_ns: u64,
    /// Uniform delivery jitter on gossip sends; above the tick interval
    /// it reorders whole rounds in flight.
    pub gossip_jitter_ns: u64,
    /// `(engine, node, from_ns, to_ns)`: that engine's deliveries to
    /// that node error inside the window — the peer engine's do not.
    pub links: Vec<(usize, NodeId, u64, u64)>,
    /// Both directions of the gossip channel are dark in this window.
    pub gossip_down: Option<(u64, u64)>,
    /// Established-connection drops ([`super::ConnDrop`]): each window
    /// models the transport under the gossip channel dying and
    /// reconnecting (the socket fabric's peer-restart case) — rounds
    /// inside a window are lost, the channel returns by itself, and the
    /// protocol must reconverge without outside help.
    pub conn_drops: Vec<super::ConnDrop>,
    /// `(node, up, at_ns)`: cluster-wide death/revival, observed by
    /// both engines at the same virtual instant.
    pub node_events: Vec<(NodeId, bool, u64)>,
}

impl MultiPlan {
    /// No faults: gossip at the default cadence, nothing cut or lost.
    pub fn none() -> Self {
        Self {
            error_rate: 0.0,
            gossip_loss: 0.0,
            gossip_every_ns: 10_000,
            gossip_jitter_ns: 4_000,
            links: Vec::new(),
            gossip_down: None,
            conn_drops: Vec::new(),
            node_events: Vec::new(),
        }
    }

    pub fn with_errors(mut self, rate: f64) -> Self {
        self.error_rate = rate;
        self
    }

    pub fn with_gossip_loss(mut self, rate: f64) -> Self {
        self.gossip_loss = rate;
        self
    }

    pub fn gossip_cadence(mut self, every_ns: u64, jitter_ns: u64) -> Self {
        self.gossip_every_ns = every_ns.max(1);
        self.gossip_jitter_ns = jitter_ns;
        self
    }

    /// Cut one engine's path to one node for a window (the asymmetric
    /// divergence driver: the peer keeps writing the same ranges).
    pub fn link_down(mut self, eng: usize, node: NodeId, from_ns: u64, to_ns: u64) -> Self {
        self.links.push((eng, node, from_ns, to_ns));
        self
    }

    pub fn gossip_blackout(mut self, from_ns: u64, to_ns: u64) -> Self {
        self.gossip_down = Some((from_ns, to_ns));
        self
    }

    /// Drop the established gossip connection for `[from_ns, until_ns)`
    /// — composable (several windows allowed), unlike the single
    /// blackout window.
    pub fn conn_drop(mut self, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty connection-drop window");
        self.conn_drops.push(super::ConnDrop { from_ns, until_ns });
        self
    }

    /// Is the gossip transport down at `at_ns` (any drop window)?
    pub fn conn_dropped(&self, at_ns: u64) -> bool {
        self.conn_drops
            .iter()
            .any(|d| (d.from_ns..d.until_ns).contains(&at_ns))
    }

    pub fn node_down(mut self, node: NodeId, at_ns: u64) -> Self {
        self.node_events.push((node, false, at_ns));
        self
    }

    pub fn node_up(mut self, node: NodeId, at_ns: u64) -> Self {
        self.node_events.push((node, true, at_ns));
        self
    }
}

/// What the multi-engine fabric did to the schedule.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct MultiStats {
    pub delivered_wcs: u64,
    pub injected_errors: u64,
    /// Error completions caused by a link-partition window.
    pub link_errors: u64,
    /// Error completions caused by the target node being dead.
    pub dead_node_errors: u64,
    pub node_transitions: u64,
    /// Gossip rounds put on the wire (after blackout, before loss).
    pub gossip_sent: u64,
    /// Gossip rounds dropped by blackout or loss.
    pub gossip_dropped: u64,
    /// Gossip rounds absorbed by a receiver.
    pub gossip_delivered: u64,
    pub retired: u64,
    pub failovers: u64,
    pub disk_fallbacks: u64,
    /// Successful reads served below the submitting engine's causal
    /// floor — the cross-engine invariant this harness exists to check.
    pub stale_reads: u64,
}

enum MEvent {
    Deliver {
        eng: usize,
        qp: QpId,
        node: NodeId,
        wr: WorkRequest,
        inject_error: bool,
    },
    Gossip {
        to: usize,
        delta: GossipDelta,
        /// Sender's per-page retired floor at export time.
        floor: Vec<(u64, u64)>,
        /// Sender's per-page disk-ownership versions at export time.
        disk: Vec<(u64, u64)>,
    },
    Tick {
        eng: usize,
    },
    Node {
        node: NodeId,
        up: bool,
    },
}

/// Two placed [`IoEngine`]s over one shared page-store cluster, with the
/// gossip plane carried as scheduled events. See the module docs for the
/// model; the single-engine payload bookkeeping of
/// [`super::ChaosFabric`] is reproduced here keyed by `(engine, id)`,
/// with the floor and disk-ownership oracles split per engine.
pub struct MultiChaos {
    engines: Vec<IoEngine>,
    plan: MultiPlan,
    rng: Pcg32,
    now_ns: u64,
    events: EventQueue<MEvent>,
    /// Ground truth: is the node up (both engines are notified of every
    /// transition, so views differ only through link partitions).
    node_live: Vec<bool>,
    /// Shared per-node page stores — the one replica cluster.
    stores: Vec<FxHashMap<u64, PageStamp>>,
    /// Global monotone version counter per page: writes from either
    /// engine are totally ordered, so the stores merge newest-wins.
    versions: FxHashMap<u64, u64>,
    /// Per-engine causal floor: highest version this engine knows has
    /// retired (own retirements + floors learned through gossip).
    floor: Vec<FxHashMap<u64, u64>>,
    /// Per-engine disk-ownership versions (own surrenders + learned).
    disk_vers: Vec<FxHashMap<u64, u64>>,
    write_stamps: FxHashMap<(usize, u64), Vec<PageStamp>>,
    parent_stamps: FxHashMap<(usize, u64), Vec<PageStamp>>,
    durable: FxHashMap<(usize, u64), Vec<PageStamp>>,
    read_subs: FxHashMap<(usize, u64), Vec<u64>>,
    read_floor: FxHashMap<(usize, u64), Vec<(u64, u64)>>,
    served: FxHashMap<(usize, u64), Vec<PageStamp>>,
    tick_armed: Vec<bool>,
    drain: DrainOut,
    /// Per-engine log of ranges that engine surrendered to the disk
    /// path (its own elections plus spans learned through gossip).
    pub surrendered_log: Vec<Vec<(u64, u64)>>,
    pub first_stale: Option<String>,
    pub stats: MultiStats,
}

impl MultiChaos {
    /// The paired-host spec: `NODES` nodes × 2-way placement with
    /// resync, the donor election, and the gossip plane for engine
    /// `eng` of [`ENGINES`].
    fn engine_spec(eng: usize, window_bytes: Option<u64>) -> EngineSpec {
        EngineSpec::new(NODES)
            .qps(1)
            .window(window_bytes)
            .replicated(2)
            .resync(RESYNC_CHUNK_BYTES)
            .election()
            .gossip(eng, ENGINES)
    }

    pub fn new(seed: u64, window_bytes: Option<u64>, plan: MultiPlan) -> Self {
        let engines = (0..ENGINES)
            .map(|e| IoEngine::build(&Self::engine_spec(e, window_bytes)))
            .collect();
        let node_events = plan.node_events.clone();
        let mut fab = Self {
            engines,
            plan,
            rng: Pcg32::with_stream(seed, 0xB0551),
            now_ns: 0,
            events: EventQueue::new(),
            node_live: vec![true; NODES],
            stores: (0..NODES).map(|_| FxHashMap::default()).collect(),
            versions: FxHashMap::default(),
            floor: (0..ENGINES).map(|_| FxHashMap::default()).collect(),
            disk_vers: (0..ENGINES).map(|_| FxHashMap::default()).collect(),
            write_stamps: FxHashMap::default(),
            parent_stamps: FxHashMap::default(),
            durable: FxHashMap::default(),
            read_subs: FxHashMap::default(),
            read_floor: FxHashMap::default(),
            served: FxHashMap::default(),
            tick_armed: vec![false; ENGINES],
            drain: DrainOut::default(),
            surrendered_log: (0..ENGINES).map(|_| Vec::new()).collect(),
            first_stale: None,
            stats: MultiStats::default(),
        };
        for (node, up, at) in node_events {
            fab.events.push(at, MEvent::Node { node, up });
        }
        fab.arm_ticks();
        fab
    }

    pub fn now(&self) -> u64 {
        self.now_ns
    }

    pub fn engine(&self, eng: usize) -> &IoEngine {
        &self.engines[eng]
    }

    pub fn pending_events(&self) -> usize {
        self.events.len()
    }

    /// Both engines have absorbed at least one round and their gossip
    /// fingerprints agree — the protocol's convergence condition, and
    /// the condition under which ticks stop re-arming.
    pub fn converged(&self) -> bool {
        let exchanged = self
            .engines
            .iter()
            .all(|e| e.gossip_stats().is_some_and(|s| s.rounds_absorbed > 0));
        let fp0 = self.engines[0].gossip_fingerprint();
        let fp1 = self.engines[1].gossip_fingerprint();
        exchanged && fp0 == fp1
    }

    /// Submit one application I/O on `eng` at the current virtual time
    /// and drain its pipeline. Write stamps mint from the *global*
    /// version counter; read floors snapshot the *submitting engine's*
    /// causal floor.
    pub fn submit(&mut self, eng: usize, id: u64, dir: Dir, addr: u64, len: u64) -> Submitted {
        let io = AppIo {
            id,
            dir,
            node: 0,
            addr,
            len,
            thread: 0,
            tenant: DEFAULT_TENANT,
            t_submit: self.now_ns,
        };
        let stamps: Vec<PageStamp> = match dir {
            Dir::Write => pages_of(addr, len)
                .map(|page| {
                    let v = self.versions.entry(page).or_insert(0);
                    *v += 1;
                    PageStamp {
                        page,
                        version: *v,
                        fp: stamp_fp(page, *v),
                    }
                })
                .collect(),
            Dir::Read => Vec::new(),
        };
        let sub = self.engines[eng].submit(io);
        self.absorb_surrenders(eng);
        match dir {
            Dir::Write => {
                for &(a, l) in &sub.disk_legs {
                    for page in pages_of(a, l) {
                        let v = self.versions.get(&page).copied().unwrap_or(0);
                        self.mark_disk(eng, page, v);
                    }
                }
                if !sub.sub_ids.is_empty() {
                    for sid in &sub.sub_ids {
                        let (a, l, _) = self.engines[eng].sub_span(*sid).expect("live sub");
                        let leg_pages = pages_of(a, l);
                        let leg: Vec<PageStamp> = stamps
                            .iter()
                            .filter(|st| leg_pages.contains(&st.page))
                            .copied()
                            .collect();
                        self.write_stamps.insert((eng, *sid), leg);
                    }
                    self.parent_stamps.insert((eng, id), stamps);
                }
            }
            Dir::Read => {
                if !sub.sub_ids.is_empty() {
                    for sid in &sub.sub_ids {
                        let (a, l, _) = self.engines[eng].sub_span(*sid).expect("live sub");
                        let floors: Vec<(u64, u64)> = pages_of(a, l)
                            .map(|page| {
                                let fv = if self.disk_backed(eng, page) {
                                    0
                                } else {
                                    self.floor[eng].get(&page).copied().unwrap_or(0)
                                };
                                (page, fv)
                            })
                            .collect();
                        self.read_floor.insert((eng, *sid), floors);
                    }
                    self.read_subs.insert((eng, id), sub.sub_ids.to_vec());
                }
            }
        }
        self.pump(eng);
        sub
    }

    fn pump(&mut self, eng: usize) {
        let mut drain = std::mem::take(&mut self.drain);
        self.engines[eng].drain_all_into(self.now_ns, &mut drain);
        {
            let mut wrs = drain.wrs.drain(..);
            for chain in drain.chains.drain(..) {
                for wr in wrs.by_ref().take(chain.end - chain.start) {
                    self.schedule_wr(eng, chain.qp, chain.node, wr);
                }
            }
        }
        self.drain = drain;
    }

    fn schedule_wr(&mut self, eng: usize, qp: QpId, node: NodeId, wr: WorkRequest) {
        let at = self.now_ns + LAT_BASE_NS + self.rng.gen_below(LAT_JITTER_NS);
        let inject_error = self.plan.error_rate > 0.0 && self.rng.gen_bool(self.plan.error_rate);
        self.events.push(
            at,
            MEvent::Deliver {
                eng,
                qp,
                node,
                wr,
                inject_error,
            },
        );
    }

    fn link_down(&self, eng: usize, node: NodeId) -> bool {
        let now = self.now_ns;
        self.plan
            .links
            .iter()
            .any(|&(e, n, from, to)| e == eng && n == node && now >= from && now < to)
    }

    fn mark_disk(&mut self, eng: usize, page: u64, v: u64) {
        let e = self.disk_vers[eng].entry(page).or_insert(0);
        if v > *e {
            *e = v;
        }
    }

    fn disk_backed(&self, eng: usize, page: u64) -> bool {
        match self.disk_vers[eng].get(&page) {
            Some(&dv) => dv >= self.floor[eng].get(&page).copied().unwrap_or(0),
            None => false,
        }
    }

    fn absorb_surrenders(&mut self, eng: usize) {
        for (_, addr, len) in self.engines[eng].take_disk_surrenders() {
            self.surrendered_log[eng].push((addr, len));
            for page in pages_of(addr, len) {
                let v = self.versions.get(&page).copied().unwrap_or(0);
                self.mark_disk(eng, page, v);
            }
        }
    }

    fn arm_tick(&mut self, eng: usize) {
        if self.tick_armed[eng] {
            return;
        }
        self.tick_armed[eng] = true;
        // stagger the engines half a period apart so rounds interleave
        let phase = (eng as u64 + 1) * self.plan.gossip_every_ns / ENGINES as u64;
        self.events.push(self.now_ns + phase.max(1), MEvent::Tick { eng });
    }

    fn arm_ticks(&mut self) {
        for eng in 0..ENGINES {
            self.arm_tick(eng);
        }
    }

    /// Export `eng`'s delta + oracle snapshots and put the round in
    /// flight to the peer — unless the blackout window or the loss draw
    /// eats it (the protocol tolerates both; the round counter makes
    /// stragglers detectable as stale on the receive side).
    fn send_gossip(&mut self, eng: usize) {
        if let Some((from, to)) = self.plan.gossip_down {
            if self.now_ns >= from && self.now_ns < to {
                self.stats.gossip_dropped += 1;
                return;
            }
        }
        // a dropped transport eats the round exactly like a blackout —
        // the difference is semantic (the socket under the channel died
        // and is reconnecting) and compositional (many windows)
        if self.plan.conn_dropped(self.now_ns) {
            self.stats.gossip_dropped += 1;
            return;
        }
        self.stats.gossip_sent += 1;
        if self.plan.gossip_loss > 0.0 && self.rng.gen_bool(self.plan.gossip_loss) {
            self.stats.gossip_dropped += 1;
            return;
        }
        let mut delta = GossipDelta::default();
        self.engines[eng].export_gossip_into(&mut delta);
        // causal piggyback: the floor tightens only together with the
        // repair knowledge that makes enforcing it safe (module docs)
        let floor: Vec<(u64, u64)> = self.floor[eng].iter().map(|(&p, &v)| (p, v)).collect();
        let disk: Vec<(u64, u64)> = self.disk_vers[eng].iter().map(|(&p, &v)| (p, v)).collect();
        let at = self.now_ns + 1 + self.rng.gen_below(self.plan.gossip_jitter_ns.max(1));
        let to_eng = (eng + 1) % ENGINES;
        self.events.push(
            at,
            MEvent::Gossip {
                to: to_eng,
                delta,
                floor,
                disk,
            },
        );
    }

    /// Advance to the next event and process it. Returns the
    /// application I/Os that retired as `(engine, io)`, or `None` at
    /// quiescence — which, by the tick re-arm rule, implies convergence.
    pub fn step(&mut self) -> Option<Vec<(usize, RetiredIo)>> {
        let (at, kind) = self.events.pop()?;
        debug_assert!(at >= self.now_ns, "virtual time ran backwards");
        self.now_ns = at;
        let mut retired = Vec::new();
        match kind {
            MEvent::Node { node, up } => {
                self.stats.node_transitions += 1;
                self.node_live[node] = up;
                for eng in 0..ENGINES {
                    if up {
                        self.engines[eng].on_node_up(node);
                    } else {
                        self.engines[eng].on_node_down(node);
                    }
                    self.absorb_surrenders(eng);
                    self.pump(eng);
                }
            }
            MEvent::Tick { eng } => {
                self.tick_armed[eng] = false;
                self.send_gossip(eng);
            }
            MEvent::Gossip {
                to,
                delta,
                floor,
                disk,
            } => {
                self.stats.gossip_delivered += 1;
                self.engines[to].absorb_gossip(&delta);
                // the absorb may have adopted surrendered disk spans
                self.absorb_surrenders(to);
                for (page, v) in floor {
                    let f = self.floor[to].entry(page).or_insert(0);
                    if v > *f {
                        *f = v;
                    }
                }
                for (page, v) in disk {
                    self.mark_disk(to, page, v);
                }
                // the absorb may have kicked repair rounds
                self.pump(to);
            }
            MEvent::Deliver {
                eng,
                qp,
                node,
                wr,
                inject_error,
            } => {
                let up = self.node_live[node];
                let cut = self.link_down(eng, node);
                let status = if inject_error || !up || cut {
                    WcStatus::Error
                } else {
                    WcStatus::Success
                };
                if inject_error {
                    self.stats.injected_errors += 1;
                } else if !up {
                    self.stats.dead_node_errors += 1;
                } else if cut {
                    self.stats.link_errors += 1;
                }
                self.stats.delivered_wcs += 1;
                if status == WcStatus::Success {
                    self.move_payloads(eng, node, &wr);
                }
                let wc = Wc {
                    wr_id: wr.wr_id,
                    qp,
                    op: wr.op,
                    len: wr.len,
                    app_ios: wr.app_ios,
                    tenant: wr.tenant,
                    status,
                };
                let out = self.engines[eng].on_wc(&wc, self.now_ns);
                self.stats.failovers += u64::from(out.requeued);
                for c in &out.resync_copies {
                    if let Some(stamps) = self.served.remove(&(eng, c.read_sub)) {
                        self.write_stamps.insert((eng, c.write_sub), stamps);
                    }
                }
                for (sid, parent) in &out.completed_subs {
                    if *parent != RESYNC_PARENT {
                        if let Some(st) = self.write_stamps.get(&(eng, *sid)) {
                            self.durable
                                .entry((eng, *parent))
                                .or_default()
                                .extend(st.iter().copied());
                        }
                    }
                }
                for r in &out.retired {
                    self.stats.retired += 1;
                    if r.disk_fallback {
                        self.stats.disk_fallbacks += 1;
                    }
                    self.note_retired(eng, r);
                }
                for (sid, _) in out.completed_subs.iter().chain(out.failed_subs.iter()) {
                    self.write_stamps.remove(&(eng, *sid));
                }
                retired.extend(out.retired.into_iter().map(|r| (eng, r)));
                self.absorb_surrenders(eng);
                self.pump(eng);
            }
        }
        // convergence-gated quiescence: while the epoch vectors differ
        // (or no round has landed yet) the ticks stay armed, so the
        // queue can only drain once the engines agree
        if !self.converged() {
            self.arm_ticks();
        }
        Some(retired)
    }

    fn move_payloads(&mut self, eng: usize, node: NodeId, wr: &WorkRequest) {
        match wr.op {
            OpKind::Write | OpKind::Send => {
                for &sid in &wr.app_ios {
                    let Some(stamps) = self.write_stamps.get(&(eng, sid)) else {
                        continue; // late duplicate: already cleaned up
                    };
                    for st in stamps {
                        let e = self.stores[node].entry(st.page).or_insert(*st);
                        if st.version > e.version {
                            *e = *st;
                        }
                    }
                }
            }
            OpKind::Read => {
                for &sid in &wr.app_ios {
                    let Some((addr, len, _)) = self.engines[eng].sub_span(sid) else {
                        continue;
                    };
                    let stamps: Vec<PageStamp> = pages_of(addr, len)
                        .map(|page| {
                            self.stores[node].get(&page).copied().unwrap_or_else(|| {
                                PageStamp {
                                    page,
                                    version: 0,
                                    fp: stamp_fp(page, 0),
                                }
                            })
                        })
                        .collect();
                    self.served.insert((eng, sid), stamps);
                }
            }
        }
    }

    fn note_retired(&mut self, eng: usize, r: &RetiredIo) {
        if let Some(stamps) = self.parent_stamps.remove(&(eng, r.id)) {
            let durable = self.durable.remove(&(eng, r.id)).unwrap_or_default();
            let durable_pages: PageSet = durable.iter().map(|st| st.page).collect();
            for st in &stamps {
                if durable_pages.contains(&st.page) {
                    let f = self.floor[eng].entry(st.page).or_insert(0);
                    if st.version > *f {
                        *f = st.version;
                    }
                } else {
                    self.mark_disk(eng, st.page, st.version);
                }
            }
            return;
        }
        let Some(sids) = self.read_subs.remove(&(eng, r.id)) else {
            return;
        };
        for sid in sids {
            let served = self.served.remove(&(eng, sid));
            let floors = self.read_floor.remove(&(eng, sid));
            if r.disk_fallback {
                continue;
            }
            let (Some(served), Some(floors)) = (served, floors) else {
                continue;
            };
            for (st, &(page, floor_v)) in served.iter().zip(floors.iter()) {
                debug_assert_eq!(st.page, page, "served stamps misaligned with floor");
                if st.version < floor_v {
                    self.stats.stale_reads += 1;
                    if self.first_stale.is_none() {
                        self.first_stale = Some(format!(
                            "engine {eng} io {} page {:#x}: served version {} below \
                             its causal floor {}",
                            r.id, st.page, st.version, floor_v
                        ));
                    }
                }
            }
        }
    }

    /// Run until the queue drains, bounded by `max_steps`. Because ticks
    /// re-arm while the engines disagree, `Ok` implies convergence; the
    /// error names the pending-event count and the convergence state.
    pub fn run_to_converged(
        &mut self,
        max_steps: u64,
    ) -> crate::runtime::Result<Vec<(usize, RetiredIo)>> {
        let mut all = Vec::new();
        for _ in 0..max_steps {
            match self.step() {
                Some(r) => all.extend(r),
                None => {
                    debug_assert!(self.converged(), "quiescent but diverged");
                    return Ok(all);
                }
            }
        }
        Err(crate::runtime::err(format!(
            "multi-engine fabric not quiescent after {max_steps} events \
             ({} pending, converged: {})",
            self.events.len(),
            self.converged()
        )))
    }
}

/// Randomized two-engine run for the sweep (`CHAOS_PROFILE=multi`):
/// workload and fault mix derive from the scenario's seed on streams of
/// their own, so no small-profile or scale seed stream moves. Reached
/// through [`super::run_scenario`], which dispatches
/// [`super::ChaosProfile::Multi`] scenarios here; the report maps the
/// multi-engine counters onto the shared [`ScenarioReport`] shape
/// (engine counters summed, link errors under `partitioned_wcs`).
pub fn run_multi_scenario(sc: &Scenario) -> crate::runtime::Result<ScenarioReport> {
    let fail = |msg: String| -> crate::runtime::Error {
        format!(
            "chaos scenario `{}` (seed {:#x}) failed: {msg}\n  replay: {}",
            sc.name,
            sc.seed,
            replay_command(sc)
        )
        .into()
    };

    let mut rng = Pcg32::with_stream(sc.seed, 0x3417E);
    let mut plan = MultiPlan::none();
    plan.gossip_every_ns = 8_000 + rng.gen_below(24_000);
    plan.gossip_jitter_ns = 1 + rng.gen_below(plan.gossip_every_ns * 2);
    plan.gossip_loss = rng.gen_f64() * 0.6;
    if rng.gen_bool(0.5) {
        plan.error_rate = rng.gen_f64() * 0.2;
    }
    // at least one asymmetric link cut per seed: the divergence driver
    let cuts = 1 + rng.gen_below(3);
    for _ in 0..cuts {
        let eng = rng.gen_below(ENGINES as u64) as usize;
        let node = rng.gen_below(NODES as u64) as usize;
        let from = rng.gen_below(150_000);
        let to = from + 20_000 + rng.gen_below(150_000);
        plan = plan.link_down(eng, node, from, to);
    }
    if rng.gen_bool(0.4) {
        let from = rng.gen_below(150_000);
        plan = plan.gossip_blackout(from, from + 20_000 + rng.gen_below(100_000));
    }
    if rng.gen_bool(0.3) {
        let node = rng.gen_below(NODES as u64) as usize;
        let at = 20_000 + rng.gen_below(100_000);
        plan = plan
            .node_down(node, at)
            .node_up(node, at + 30_000 + rng.gen_below(150_000));
    }
    let window_bytes = if rng.gen_bool(0.75) {
        Some((4 + rng.gen_below(28)) * PAGE_BYTES)
    } else {
        None
    };
    let per_engine = 120 + rng.gen_below(180);
    let read_fraction = 0.2 + rng.gen_f64() * 0.6;
    // transport drops, drawn after every older draw so pinned multi
    // seeds keep their exact pre-recovery schedules
    if rng.gen_bool(0.35) {
        let from = rng.gen_below(200_000);
        plan = plan.conn_drop(from, from + 10_000 + rng.gen_below(120_000));
    }
    // a 2 MiB working set: two placement stripes, shared by both
    // engines, so overlapping writes and split legs are the common case
    let span_pages = 512u64;

    let mut fab = MultiChaos::new(sc.seed, window_bytes, plan);
    let mut retired: Vec<BTreeSet<u64>> = (0..ENGINES).map(|_| BTreeSet::new()).collect();
    let mut submitted = [0u64; ENGINES];
    let mut disk_at_submit = 0u64;
    let mut steps = 0u64;
    let warmup = per_engine.min(16);

    while submitted.iter().any(|&s| s < per_engine) || fab.pending_events() > 0 {
        steps += 1;
        if steps > MAX_STEPS {
            return Err(fail(format!(
                "livelock: {}+{} of 2×{per_engine} retired after {MAX_STEPS} steps",
                retired[0].len(),
                retired[1].len()
            )));
        }
        // alternate submit opportunities between the engines so faults
        // land on two part-submitted, part-in-flight pipelines
        let eng = (steps % ENGINES as u64) as usize;
        let can_submit = submitted[eng] < per_engine;
        let do_submit = can_submit
            && (submitted[eng] < warmup || fab.pending_events() == 0 || rng.gen_bool(0.5));
        if do_submit {
            let id = submitted[eng];
            let dir = if rng.gen_bool(read_fraction) {
                Dir::Read
            } else {
                Dir::Write
            };
            let len = PAGE_BYTES * (1 + rng.gen_below(4));
            let addr = rng.gen_below(span_pages) * PAGE_BYTES;
            let sub = fab.submit(eng, id, dir, addr, len);
            submitted[eng] += 1;
            if sub.disk_fallback {
                disk_at_submit += 1;
                if !retired[eng].insert(id) {
                    return Err(fail(format!("engine {eng} io {id} retired twice (submit)")));
                }
            }
        } else if let Some(rs) = fab.step() {
            for (e, r) in rs {
                if !retired[e].insert(r.id) {
                    return Err(fail(format!("engine {e} io {} retired twice", r.id)));
                }
            }
        }
    }

    // quiescence + convergence invariants, per engine and cross-engine
    for eng in 0..ENGINES {
        if retired[eng].len() as u64 != per_engine {
            return Err(fail(format!(
                "engine {eng} lost I/O: {} of {per_engine} retired",
                retired[eng].len()
            )));
        }
        if fab.engine(eng).queued_ios() != 0 {
            return Err(fail(format!(
                "engine {eng}: {} requests still queued at quiescence",
                fab.engine(eng).queued_ios()
            )));
        }
        if fab.engine(eng).regulator().in_flight() != 0 {
            return Err(fail(format!(
                "engine {eng} window not released: {} bytes stranded",
                fab.engine(eng).regulator().in_flight()
            )));
        }
        if let Some(w) = window_bytes {
            let peak = fab.engine(eng).regulator().peak_in_flight;
            if peak > w {
                return Err(fail(format!(
                    "engine {eng} peak in-flight {peak} exceeded window {w}"
                )));
            }
        }
    }
    let fps = [
        fab.engine(0).gossip_fingerprint(),
        fab.engine(1).gossip_fingerprint(),
    ];
    if fps[0] != fps[1] || !fab.converged() {
        return Err(fail(format!(
            "epoch vectors diverged at quiescence: {:#018x} vs {:#018x}",
            fps[0], fps[1]
        )));
    }
    if fab.stats.gossip_delivered == 0 {
        return Err(fail("gossip plane never exchanged a round".into()));
    }
    if fab.stats.stale_reads > 0 {
        return Err(fail(format!(
            "stale read served across engines: {} (first: {})",
            fab.stats.stale_reads,
            fab.first_stale.as_deref().unwrap_or("?")
        )));
    }

    let sum = |f: fn(&IoEngine) -> u64| -> u64 { (0..ENGINES).map(|e| f(fab.engine(e))).sum() };
    Ok(ScenarioReport {
        submitted: submitted.iter().sum(),
        retired: retired.iter().map(|r| r.len() as u64).sum(),
        disk_at_submit,
        failovers: fab.stats.failovers,
        disk_fallbacks: fab.stats.disk_fallbacks,
        duplicate_wcs: sum(|e| e.stats.duplicate_wcs),
        delivered_wcs: fab.stats.delivered_wcs,
        injected_errors: fab.stats.injected_errors,
        reordered_wcs: 0,
        stalled_wcs: 0,
        reg_stalled_wcs: 0,
        stormed_wcs: 0,
        window_changes: 0,
        partitioned_wcs: fab.stats.link_errors,
        node_transitions: fab.stats.node_transitions,
        lost_wcs: 0,
        wedged_wcs: 0,
        timer_ticks: 0,
        recovery_timeouts: 0,
        recovery_flushes: 0,
        recovery_resets: 0,
        window_leaks: sum(|e| e.stats.window_leaks),
        stale_reads: fab.stats.stale_reads,
        split_requests: sum(|e| e.stats.split_requests),
        split_legs: sum(|e| e.stats.split_legs),
        resync_rounds: sum(|e| e.stats.resync_rounds),
        resync_copies: sum(|e| e.stats.resync_copies),
        resync_demotions: sum(|e| e.stats.resync_demotions),
        resync_elections: sum(|e| e.stats.resync_elections),
        resync_self_heals: sum(|e| e.stats.resync_self_heals),
        resync_disk_surrenders: sum(|e| e.stats.resync_disk_surrenders),
        resyncs_completed: sum(|e| e.stats.resyncs_completed),
        mr_hits: 0,
        mr_misses: 0,
        peak_in_flight: (0..ENGINES)
            .map(|e| fab.engine(e).regulator().peak_in_flight)
            .max()
            .unwrap_or(0),
        elapsed_virtual_ns: fab.now(),
        tenant_posted_bytes: (0..ENGINES)
            .map(|e| fab.engine(e).tenant_stats()[0].posted_bytes)
            .collect(),
        tenant_borrows: (0..ENGINES)
            .map(|e| fab.engine(e).tenant_stats()[0].borrow_events)
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::NodeState;
    use crate::fabric::chaos::ChaosProfile;

    fn assert_all_alive(fab: &MultiChaos) {
        for eng in 0..ENGINES {
            for node in 0..NODES {
                assert_eq!(
                    fab.engine(eng).node_state(node),
                    Some(NodeState::Alive),
                    "engine {eng} view of node {node}"
                );
            }
        }
    }

    #[test]
    fn quiet_two_engine_run_converges_without_faults() {
        let mut fab = MultiChaos::new(7, None, MultiPlan::none());
        for i in 0..8u64 {
            fab.submit(0, i, Dir::Write, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, i, Dir::Write, (8 + i) * PAGE_BYTES, PAGE_BYTES);
        }
        let retired = fab.run_to_converged(MAX_STEPS).expect("quiescent");
        for eng in 0..ENGINES {
            let mut ids: Vec<u64> = retired
                .iter()
                .filter(|(e, _)| *e == eng)
                .map(|(_, r)| r.id)
                .collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..8).collect::<Vec<_>>(), "engine {eng}");
        }
        // cross reads: each engine reads what the *peer* wrote — the
        // floor knowledge arrived through the gossip piggyback
        for i in 0..8u64 {
            fab.submit(0, 100 + i, Dir::Read, (8 + i) * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
        assert!(fab.stats.gossip_delivered >= 2, "{:?}", fab.stats);
        assert!(fab.converged());
        assert_eq!(
            fab.engine(0).gossip_fingerprint(),
            fab.engine(1).gossip_fingerprint()
        );
        assert_eq!(fab.stats.failovers, 0);
        assert_eq!(fab.stats.disk_fallbacks, 0);
    }

    /// The tentpole acceptance shape: engine 0 is partitioned from node
    /// 0 while both engines write overlapping ranges — engine 0's legs
    /// on node 0 error (divergence), engine 1's land. After the window
    /// heals, gossip must drive both engines to identical epoch vectors
    /// with zero stale reads.
    #[test]
    fn overlapping_writes_under_link_partition_converge() {
        let plan = MultiPlan::none().link_down(0, 0, 0, 60_000);
        let mut fab = MultiChaos::new(0x3417, None, plan);
        for i in 0..8u64 {
            fab.submit(0, i, Dir::Write, i * PAGE_BYTES, 2 * PAGE_BYTES);
            fab.submit(1, i, Dir::Write, i * PAGE_BYTES, 2 * PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert!(fab.stats.link_errors > 0, "the cut never bit: {:?}", fab.stats);
        assert!(
            fab.engine(0).stats.resync_demotions >= 1,
            "engine 0 demoted the diverged replica: {:?}",
            fab.engine(0).stats
        );
        // engine 1 learned about the divergence it never saw directly
        let gs = fab.engine(1).gossip_stats().expect("gossip on");
        assert!(gs.epoch_raises >= 1, "peer absorbed the epoch floors: {gs:?}");
        assert_all_alive(&fab);
        assert_eq!(
            fab.engine(0).gossip_fingerprint(),
            fab.engine(1).gossip_fingerprint(),
            "epoch vectors identical after healing"
        );
        // both engines read the whole overlapped range: zero staleness
        for i in 0..9u64 {
            fab.submit(0, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
    }

    /// Crossed cuts: engine 0 loses node 0 while engine 1 loses node 1,
    /// both writing the same ranges — so both engines run elections and
    /// mint epochs concurrently. The interleaved minting keeps the
    /// epochs disjoint and the semilattice joins drive both ledgers to
    /// the same fixed point.
    #[test]
    fn crossed_partitions_drive_conflicting_elections_to_convergence() {
        let plan = MultiPlan::none()
            .link_down(0, 0, 0, 80_000)
            .link_down(1, 1, 0, 80_000);
        let mut fab = MultiChaos::new(0xC2055, None, plan);
        for i in 0..8u64 {
            fab.submit(0, i, Dir::Write, i * PAGE_BYTES, 2 * PAGE_BYTES);
            fab.submit(1, i, Dir::Write, i * PAGE_BYTES, 2 * PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert!(
            fab.engine(0).stats.resync_demotions >= 1
                && fab.engine(1).stats.resync_demotions >= 1,
            "both engines diverged a replica: {:?} / {:?}",
            fab.engine(0).stats,
            fab.engine(1).stats
        );
        assert_all_alive(&fab);
        assert_eq!(
            fab.engine(0).gossip_fingerprint(),
            fab.engine(1).gossip_fingerprint()
        );
        for i in 0..9u64 {
            fab.submit(0, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
    }

    /// Gossip-channel hostility: a blackout eats every round for 50 µs
    /// (virtual) while a link cut diverges engine 0, then 50% loss and
    /// jitter three times the tick interval reorder what remains. The
    /// round counters absorb the reordering, re-sends absorb the loss,
    /// and the run still converges.
    #[test]
    fn gossip_loss_blackout_and_reorder_still_converge() {
        let plan = MultiPlan::none()
            .link_down(0, 0, 0, 40_000)
            .gossip_blackout(0, 50_000)
            .with_gossip_loss(0.5)
            .gossip_cadence(10_000, 30_000);
        let mut fab = MultiChaos::new(0x6055, None, plan);
        for i in 0..8u64 {
            fab.submit(0, i, Dir::Write, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, i, Dir::Write, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert!(
            fab.stats.gossip_dropped >= 2,
            "the blackout ate whole rounds: {:?}",
            fab.stats
        );
        assert!(fab.stats.gossip_delivered >= 2, "{:?}", fab.stats);
        assert_eq!(
            fab.engine(0).gossip_fingerprint(),
            fab.engine(1).gossip_fingerprint()
        );
        for i in 0..8u64 {
            fab.submit(0, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
    }

    /// Transport death under the gossip channel: two separate
    /// connection-drop windows (a peer restarting twice) eat every round
    /// they cover while a link cut diverges engine 0. The channel comes
    /// back on its own — reconnect semantics — and the plane still
    /// reconverges to identical fingerprints with a fresh payload model.
    #[test]
    fn conn_drops_reconverge_like_reconnects() {
        let plan = MultiPlan::none()
            .link_down(0, 0, 0, 40_000)
            .conn_drop(0, 30_000)
            .conn_drop(60_000, 90_000)
            .gossip_cadence(10_000, 4_000);
        assert!(plan.conn_dropped(0) && plan.conn_dropped(89_999));
        assert!(!plan.conn_dropped(30_000) && !plan.conn_dropped(90_000));
        let mut fab = MultiChaos::new(0xD409, None, plan);
        for i in 0..8u64 {
            fab.submit(0, i, Dir::Write, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, i, Dir::Write, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert!(
            fab.stats.gossip_dropped >= 2,
            "the drop windows ate whole rounds: {:?}",
            fab.stats
        );
        assert!(fab.stats.gossip_delivered >= 2, "{:?}", fab.stats);
        assert_eq!(
            fab.engine(0).gossip_fingerprint(),
            fab.engine(1).gossip_fingerprint()
        );
        for i in 0..8u64 {
            fab.submit(0, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
    }

    /// Cluster-wide node churn: node 1 dies with writes from both
    /// engines in flight (their legs error), revives, and both engines
    /// gate it behind resync — independently, then agree via gossip.
    #[test]
    fn node_churn_heals_across_engines() {
        let plan = MultiPlan::none().node_down(1, 2_000).node_up(1, 60_000);
        let mut fab = MultiChaos::new(0xC402, None, plan);
        for i in 0..16u64 {
            fab.submit(0, i, Dir::Write, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, i, Dir::Write, (i + 4) * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.node_transitions, 2);
        assert!(fab.stats.dead_node_errors > 0, "{:?}", fab.stats);
        assert_all_alive(&fab);
        assert_eq!(
            fab.engine(0).gossip_fingerprint(),
            fab.engine(1).gossip_fingerprint()
        );
        for i in 0..20u64 {
            fab.submit(0, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
            fab.submit(1, 100 + i, Dir::Read, i * PAGE_BYTES, PAGE_BYTES);
        }
        fab.run_to_converged(MAX_STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
    }

    #[test]
    fn multi_scenario_runs_are_seed_deterministic() {
        let sc = Scenario::randomized_with_profile(0x3417, ChaosProfile::Multi);
        let a = run_multi_scenario(&sc).expect("passes");
        let b = run_multi_scenario(&sc).expect("passes");
        assert_eq!(a, b, "same seed, same report");
        let other = Scenario::randomized_with_profile(0x3418, ChaosProfile::Multi);
        let c = run_multi_scenario(&other).expect("passes");
        assert_ne!(a, c, "a different seed must produce a different run");
    }
}
