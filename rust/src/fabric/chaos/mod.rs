//! Chaos fabric: the third backend of the I/O stack — a deterministic,
//! seeded, fault-injecting fabric for correctness testing.
//!
//! Where [`crate::fabric::sim`] models a *calibrated* RDMA path (to
//! regenerate the paper's figures) and [`crate::fabric::loopback`] moves
//! real bytes on real threads, the chaos fabric executes the same
//! [`IoEngine`] pipeline under an *adversarial* schedule: virtual time
//! (no wall clock anywhere), a seeded PRNG interleaving per-QP progress,
//! and a [`FaultPlan`] injecting completion errors, WC reordering within
//! a CQ, duplicate/late completions, per-QP stalls ("NIC cache thrash"),
//! and node death/revival at chosen virtual times.
//!
//! Everything is a pure function of the `(seed, FaultPlan, workload)`
//! triple: a failing schedule replays exactly from its seed, which is
//! what makes the scenario harness in [`scenario`] (and the CI sweep on
//! top of it) a regression suite rather than a flake generator. This is
//! the template every future backend must pass: production policy code
//! runs unmodified; only the completion schedule is hostile.
//!
//! **The fabric carries data.** Each node owns a page store of
//! [`PageStamp`]s: every write carries a deterministic content
//! fingerprint (a per-page monotone version plus a version-derived
//! fingerprint), applied to the serving node's store on delivery; every
//! read's completion returns the stamps the serving replica actually
//! holds. A client-side model tracks, per page, the highest version
//! whose write has *retired* — so a replica serving an older version to
//! a later read is a **stale read**, counted in
//! [`ChaosStats::stale_reads`] and failed by the scenario runner. This
//! is what makes unresynchronized node revival (and silent replica
//! divergence under partial partitions) assertable instead of
//! invisible; enable the engine's repair protocol through the
//! [`EngineSpec`] (`.resync(chunk)`) handed to [`ChaosFabric::build`].
//!
//! The [`multi`] submodule scales this to *two* peer engines sharing one
//! replica cluster, with the gossip anti-entropy plane carried inside
//! the same schedule (lost, reordered, blacked-out rounds) — see its
//! docs for the cross-engine convergence invariants.

pub mod multi;
pub mod plan;
pub mod scenario;

pub use multi::{run_multi_scenario, MultiChaos, MultiPlan, MultiStats};
pub use plan::{
    rack_members, AdmissionChurn, ConnDrop, FaultPlan, LatencyStorm, NodeEvent, Partition, QpStall,
    QpWedge,
};
pub use scenario::{replay_command, run_scenario, ChaosProfile, Scenario, ScenarioReport};

use std::collections::HashSet;

use crate::coordinator::engine::{
    DrainOut, IoEngine, RetiredIo, Submitted, WcOut, RESYNC_PARENT, SHARD_REGION_SHIFT,
};
use crate::coordinator::node::NodeState;
use crate::coordinator::spec::EngineSpec;
use crate::fabric::{
    AppIo, Dir, NodeId, OpKind, QpId, TenantId, Wc, WcStatus, WorkRequest, DEFAULT_TENANT,
};
use crate::util::eventq::{EventQueue, ReferenceQueue};
use crate::util::fxhash::{FxBuildHasher, FxHashMap};
use crate::util::rng::Pcg32;

/// Replication stripe size (mirrors the loopback fabric: one 1 MiB shard
/// region per stripe, so placement and QP sharding line up).
pub const STRIPE_BYTES: u64 = 1 << SHARD_REGION_SHIFT;

/// Page granularity of the data model.
pub const PAGE_BYTES: u64 = 4096;

/// Resync copy chunk chaos specs should use: equal to the smallest
/// admission window the scenario generator produces, so repair traffic
/// can never force the window's oversized-head escape hatch.
pub const RESYNC_CHUNK_BYTES: u64 = 4 * PAGE_BYTES;

type PageSet = HashSet<u64, FxBuildHasher>;

/// What one page of one replica holds: a monotone per-page version and
/// the deterministic content fingerprint derived from it (the stand-in
/// for the actual bytes — two stores agree on a page iff they hold the
/// same stamp).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageStamp {
    /// Page index (`addr / PAGE_BYTES`).
    pub page: u64,
    /// 0 = never written.
    pub version: u64,
    pub fp: u64,
}

/// Deterministic content fingerprint of (page, version) — what the
/// "bytes" of that write would hash to.
pub fn stamp_fp(page: u64, version: u64) -> u64 {
    let mut x = page
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(version.rotate_left(17) ^ 0xC4A0_5D47_A11C_E5EB);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn pages_of(addr: u64, len: u64) -> std::ops::RangeInclusive<u64> {
    let first = addr / PAGE_BYTES;
    let last = (addr + len.max(1) - 1) / PAGE_BYTES;
    first..=last
}

/// Base completion latency of a WR in virtual ns.
const LAT_BASE_NS: u64 = 1_000;
/// Uniform jitter on top of the base latency (this alone interleaves
/// per-QP progress: two WRs posted together complete in PRNG order).
const LAT_JITTER_NS: u64 = 8_000;

/// A WR in flight through the chaos fabric, with its fault decisions
/// (drawn at post time, so the schedule is fixed the moment it is posted).
#[derive(Debug, Clone)]
struct Flight {
    qp: QpId,
    node: NodeId,
    wr: WorkRequest,
    inject_error: bool,
    /// This delivery is the duplicate copy (stats only; the engine's
    /// wr_id ledger is what actually de-duplicates).
    duplicate: bool,
}

#[derive(Debug)]
enum EventKind {
    Deliver(Flight),
    Node { node: NodeId, up: bool },
    /// Mid-run admission-window swap (policy churn).
    Churn { window: Option<u64> },
    /// Service the engine's recovery timers (WR deadlines, backoff
    /// releases, QP probes) at this virtual time. Idempotent: a stale
    /// tick whose deadline already retired is a no-op.
    Tick,
}

/// Which scheduler backs the fabric's event queue. Both pop the
/// globally minimal `(at, seq)` with FIFO tie-breaking, so they produce
/// identical schedules — an equality `tests/pinned_replay.rs` asserts
/// over full scenario reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    /// The shared calendar queue ([`EventQueue`]) — the production
    /// scheduler, O(1) amortized per event at thousands of nodes.
    #[default]
    Calendar,
    /// The pre-refactor `BinaryHeap` scheduler, kept verbatim in
    /// [`ReferenceQueue`] as the bit-identity oracle for replay tests.
    Reference,
}

/// The fabric's event queue behind either scheduler. An enum (rather
/// than a generic parameter) keeps `ChaosFabric` a plain type and keeps
/// the private [`EventKind`] out of public signatures.
enum Queue {
    Calendar(EventQueue<EventKind>),
    Reference(ReferenceQueue<EventKind>),
}

impl Queue {
    fn new(kind: SchedulerKind) -> Self {
        match kind {
            SchedulerKind::Calendar => Queue::Calendar(EventQueue::new()),
            SchedulerKind::Reference => Queue::Reference(ReferenceQueue::new()),
        }
    }

    fn push(&mut self, at: u64, kind: EventKind) {
        match self {
            Queue::Calendar(q) => q.push(at, kind),
            Queue::Reference(q) => q.push(at, kind),
        }
    }

    fn pop(&mut self) -> Option<(u64, EventKind)> {
        match self {
            Queue::Calendar(q) => q.pop(),
            Queue::Reference(q) => q.pop(),
        }
    }

    fn len(&self) -> usize {
        match self {
            Queue::Calendar(q) => q.len(),
            Queue::Reference(q) => q.len(),
        }
    }
}

/// What the chaos fabric did to the schedule (all injected counts).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ChaosStats {
    pub delivered_wcs: u64,
    pub injected_errors: u64,
    /// Error completions caused by the target node being dead at delivery.
    pub dead_node_errors: u64,
    /// Error completions caused by a partial-partition window.
    pub partitioned_wcs: u64,
    pub duplicates_delivered: u64,
    pub reordered_wcs: u64,
    pub stalled_wcs: u64,
    /// WRs that first-touched an unregistered MR span and paid a
    /// synchronous lazy-registration stall before posting (the
    /// pinning-free path's miss cost landing on the critical path).
    pub reg_stalled_wcs: u64,
    /// WCs delayed by a cluster-wide latency storm window.
    pub stormed_wcs: u64,
    /// Mid-run admission-window swaps executed (policy churn).
    pub window_changes: u64,
    pub node_transitions: u64,
    /// WCs swallowed outright by the plan's `lost_rate` — only the
    /// engine's completion deadlines can retire those WRs.
    pub lost_wcs: u64,
    /// WCs dropped by a wedge window (a QP that stopped completing).
    pub wedged_wcs: u64,
    /// Recovery-timer service events executed (deadline expiries,
    /// backoff releases and QP probes ride these).
    pub timer_ticks: u64,
    pub retired: u64,
    pub disk_fallbacks: u64,
    pub failovers: u64,
    /// Successful reads that returned a page version older than the
    /// highest version already retired for that page at read-submit time
    /// — the replica served data it does not hold. The one defect the
    /// completion-level invariants cannot see; the payload model can.
    pub stale_reads: u64,
}

/// The deterministic fault-injecting fabric: drives a placed [`IoEngine`]
/// (replica fan-out, read failover, disk-fallback signal) through the
/// shared calendar-queue scheduler ([`crate::util::eventq`]) in virtual
/// time.
pub struct ChaosFabric {
    engine: IoEngine,
    plan: FaultPlan,
    rng: Pcg32,
    now_ns: u64,
    events: Queue,
    /// Per-node page store: what each replica actually holds.
    stores: Vec<FxHashMap<u64, PageStamp>>,
    /// Client-side monotone version counter per page (bumped at submit).
    versions: FxHashMap<u64, u64>,
    /// Client-side floor: highest version whose write has retired, per
    /// page — the staleness oracle.
    floor: FxHashMap<u64, u64>,
    /// Highest version per page whose write took the disk path (all
    /// replicas down/failed, or an election surrender): the page is
    /// disk-backed while this is at or above the durable floor — in the
    /// paper's design the paging layer's per-block disk bit sends such
    /// reads to disk, which is outside this fabric. Tracking the
    /// *version* (not a bare bit) keeps the ownership ordered: an older
    /// concurrent write retiring durably cannot cancel a newer write's
    /// disk ownership.
    disk_vers: FxHashMap<u64, u64>,
    /// Write sub-I/O id → stamps it carries (applied on delivery). Leg
    /// granular: a split write's subs carry only their own leg's stamps.
    write_stamps: FxHashMap<u64, Vec<PageStamp>>,
    /// Application write id → its full-span stamps (floor update at
    /// retirement).
    parent_stamps: FxHashMap<u64, Vec<PageStamp>>,
    /// Application write id → stamps of legs that completed on at least
    /// one replica. At retirement, exactly these pages raise the floor;
    /// the rest are disk-backed — so a split write with one failed leg
    /// does not credit (or double-count) pages the fabric never stored.
    durable: FxHashMap<u64, Vec<PageStamp>>,
    /// Application read id → its sub-I/O ids (one per stripe-local leg).
    /// Per-leg floor snapshots and served stamps are retained until the
    /// read *retires*, then every leg is checked exactly once — a split
    /// read whose legs complete in different WCs is neither under- nor
    /// double-counted by the staleness oracle.
    read_subs: FxHashMap<u64, Vec<u64>>,
    /// Read sub-I/O id → per-page floor snapshot taken at submit.
    read_floor: FxHashMap<u64, Vec<(u64, u64)>>,
    /// Read sub-I/O id → stamps served by its last successful delivery.
    served: FxHashMap<u64, Vec<PageStamp>>,
    /// MR spans ([`crate::coordinator::mr_cache::MR_SPAN_BYTES`]-sized)
    /// some WR of this run has already touched: re-touches never pay a
    /// registration stall, mirroring the MR cache's lazy-registration
    /// contract (only first touches miss).
    reg_seen: PageSet,
    /// Detail of the first stale read (for failure messages).
    pub first_stale: Option<String>,
    /// Every `(addr, len)` range the engine's election surrendered to
    /// the disk path, in order. The fabric's own payload model absorbs
    /// them into `disk_vers`; this log is the externally visible copy a
    /// paging layer consumes to set its per-block disk bit (see
    /// `Pager::surrender`) — the end-to-end test of the
    /// `take_disk_surrenders` wiring feeds a real `Pager` from it.
    pub surrendered_log: Vec<(u64, u64)>,
    /// Reused drain buffer: every pump fills this through
    /// [`IoEngine::drain_all_into`] (allocation-free in steady state).
    drain: DrainOut,
    /// Earliest recovery-timer tick currently in the schedule
    /// (`u64::MAX` = none). Arming only when a new timer is strictly
    /// earlier bounds the tick events a run can accumulate.
    tick_at: u64,
    pub stats: ChaosStats,
}

impl ChaosFabric {
    /// Convenience shim over [`ChaosFabric::build`]: the common placed
    /// topology (`nodes` × `qps_per_node` QPs, `replicas`-way placement,
    /// one tenant) without spelling out a spec. Resync, election and QoS
    /// tenants need the spec path.
    pub fn new(
        seed: u64,
        nodes: usize,
        qps_per_node: usize,
        replicas: usize,
        window_bytes: Option<u64>,
        plan: FaultPlan,
    ) -> Self {
        Self::build(
            seed,
            &EngineSpec::new(nodes)
                .qps(qps_per_node)
                .window(window_bytes)
                .replicated(replicas),
            plan,
        )
    }

    /// Build the chaos cluster from an [`EngineSpec`] — the single
    /// construction surface shared with the sim and loopback backends.
    /// The spec must be replicated (the chaos fabric drives a *placed*
    /// engine); its stripe defaults to [`STRIPE_BYTES`], lining placement
    /// up with QP sharding. The plan's node events are pre-loaded into
    /// the schedule; everything else is drawn from `seed` as WRs are
    /// posted.
    pub fn build(seed: u64, spec: &EngineSpec, plan: FaultPlan) -> Self {
        Self::build_with_scheduler(seed, spec, plan, SchedulerKind::default())
    }

    /// [`ChaosFabric::build`] with an explicit [`SchedulerKind`]. The
    /// `Reference` scheduler exists for the pre/post-refactor replay
    /// equivalence tests; everything else wants the default.
    pub fn build_with_scheduler(
        seed: u64,
        spec: &EngineSpec,
        plan: FaultPlan,
        scheduler: SchedulerKind,
    ) -> Self {
        assert!(
            spec.replicas.is_some(),
            "the chaos fabric drives a placed engine: spec needs .replicated(r)"
        );
        let nodes = spec.nodes;
        let engine = IoEngine::build(spec);
        let node_events: Vec<NodeEvent> = plan.node_events.clone();
        let churns: Vec<AdmissionChurn> = plan.churns.clone();
        let mut fab = Self {
            engine,
            plan,
            rng: Pcg32::with_stream(seed, 0xC4A05),
            now_ns: 0,
            events: Queue::new(scheduler),
            stores: (0..nodes).map(|_| FxHashMap::default()).collect(),
            versions: FxHashMap::default(),
            floor: FxHashMap::default(),
            disk_vers: FxHashMap::default(),
            write_stamps: FxHashMap::default(),
            parent_stamps: FxHashMap::default(),
            durable: FxHashMap::default(),
            read_subs: FxHashMap::default(),
            read_floor: FxHashMap::default(),
            served: FxHashMap::default(),
            reg_seen: PageSet::default(),
            first_stale: None,
            surrendered_log: Vec::new(),
            drain: DrainOut::default(),
            tick_at: u64::MAX,
            stats: ChaosStats::default(),
        };
        for ev in node_events {
            fab.schedule_node_event(ev.node, ev.up, ev.at_ns);
        }
        for c in churns {
            let window = c.window_bytes;
            fab.push(c.at_ns, EventKind::Churn { window });
        }
        fab
    }

    pub fn now(&self) -> u64 {
        self.now_ns
    }

    pub fn engine(&self) -> &IoEngine {
        &self.engine
    }

    pub fn pending_events(&self) -> usize {
        self.events.len()
    }

    /// Schedule a node death (`up = false`) or revival at a virtual time
    /// (in addition to whatever the plan pre-loaded — tests use this to
    /// place a death relative to the current virtual time).
    pub fn schedule_node_event(&mut self, node: NodeId, up: bool, at_ns: u64) {
        self.push(at_ns.max(self.now_ns), EventKind::Node { node, up });
    }

    fn push(&mut self, at: u64, kind: EventKind) {
        self.events.push(at, kind);
    }

    /// Submit one application I/O at the current virtual time and drain
    /// the pipeline. The returned routing outcome surfaces the
    /// disk-fallback signal when every replica of `addr` is already dead.
    ///
    /// Writes mint fresh [`PageStamp`]s (monotone version + fingerprint)
    /// for every page they cover; reads snapshot the per-page floor so
    /// their eventual completion can be checked for staleness.
    pub fn submit(&mut self, id: u64, dir: Dir, addr: u64, len: u64) -> Submitted {
        self.submit_t(id, dir, addr, len, DEFAULT_TENANT)
    }

    /// [`ChaosFabric::submit`] on behalf of a QoS tenant: the I/O bills
    /// to `tenant`'s sub-window and drains through its DRR lane. The
    /// spec must have registered the tenant (`.tenants(weights)`).
    pub fn submit_t(
        &mut self,
        id: u64,
        dir: Dir,
        addr: u64,
        len: u64,
        tenant: TenantId,
    ) -> Submitted {
        let io = AppIo {
            id,
            dir,
            node: 0,
            addr,
            len,
            thread: 0,
            tenant,
            t_submit: self.now_ns,
        };
        let stamps: Vec<PageStamp> = match dir {
            Dir::Write => pages_of(addr, len)
                .map(|page| {
                    let v = self.versions.entry(page).or_insert(0);
                    *v += 1;
                    PageStamp {
                        page,
                        version: *v,
                        fp: stamp_fp(page, *v),
                    }
                })
                .collect(),
            Dir::Read => Vec::new(),
        };
        let sub = self.engine.submit(io);
        // the submit may have kicked an election round that surrendered
        // ranges to the disk path — absorb before taking floor snapshots
        self.absorb_surrenders();
        match dir {
            Dir::Write => {
                // legs whose replicas were all dead at submit: the latest
                // data for those pages lives on disk, remote stores are
                // allowed to lag until a *newer* remote write retires
                for &(a, l) in &sub.disk_legs {
                    for page in pages_of(a, l) {
                        let v = self.versions.get(&page).copied().unwrap_or(0);
                        self.mark_disk(page, v);
                    }
                }
                if !sub.sub_ids.is_empty() {
                    // each sub carries exactly its own leg's stamps (the
                    // splitter routes legs independently)
                    for sid in &sub.sub_ids {
                        let (a, l, _) = self.engine.sub_span(*sid).expect("live sub");
                        let leg_pages = pages_of(a, l);
                        let leg_stamps: Vec<PageStamp> = stamps
                            .iter()
                            .filter(|st| leg_pages.contains(&st.page))
                            .copied()
                            .collect();
                        self.write_stamps.insert(*sid, leg_stamps);
                    }
                    self.parent_stamps.insert(id, stamps);
                }
            }
            Dir::Read => {
                if !sub.sub_ids.is_empty() {
                    for sid in &sub.sub_ids {
                        let (a, l, _) = self.engine.sub_span(*sid).expect("live sub");
                        let floors: Vec<(u64, u64)> = pages_of(a, l)
                            .map(|page| {
                                let fv = if self.disk_backed(page) {
                                    0 // disk-backed: remote may legitimately lag
                                } else {
                                    self.floor.get(&page).copied().unwrap_or(0)
                                };
                                (page, fv)
                            })
                            .collect();
                        self.read_floor.insert(*sid, floors);
                    }
                    self.read_subs.insert(id, sub.sub_ids.to_vec());
                }
            }
        }
        self.pump();
        sub
    }

    /// Fold ranges the engine's election surrendered to the disk path
    /// into the fabric's disk-backed page set: no live replica holds the
    /// required version, so — as with all-replicas-failed writes — the
    /// paging layer's local-disk copy owns reads of these pages until a
    /// newer remote write retires. Stamped with the page's latest issued
    /// version (the election deferred around in-flight writes, so that
    /// is exactly the version no live replica holds).
    fn absorb_surrenders(&mut self) {
        for (_, addr, len) in self.engine.take_disk_surrenders() {
            self.surrendered_log.push((addr, len));
            for page in pages_of(addr, len) {
                let v = self.versions.get(&page).copied().unwrap_or(0);
                self.mark_disk(page, v);
            }
        }
    }

    /// Record that version `v` of `page` went to the disk path.
    fn mark_disk(&mut self, page: u64, v: u64) {
        let e = self.disk_vers.entry(page).or_insert(0);
        if v > *e {
            *e = v;
        }
    }

    /// Is `page`'s authoritative copy on disk? True while the newest
    /// version that went to the disk path is at or above the durable
    /// remote floor — so only a *newer* durably-retired write flips the
    /// page back to remote ownership (version-ordered, like the paging
    /// layer's per-block disk bit).
    fn disk_backed(&self, page: u64) -> bool {
        match self.disk_vers.get(&page) {
            Some(&dv) => dv >= self.floor.get(&page).copied().unwrap_or(0),
            None => false,
        }
    }

    /// Drain admitted requests and put the planned WRs in flight, drawing
    /// each WR's latency and fault decisions from the seed stream.
    fn pump(&mut self) {
        // take the reused buffer so schedule_wr can borrow self mutably;
        // putting it back preserves its capacity across pumps
        let mut drain = std::mem::take(&mut self.drain);
        self.engine.drain_all_into(self.now_ns, &mut drain);
        {
            let mut wrs = drain.wrs.drain(..);
            for chain in drain.chains.drain(..) {
                for wr in wrs.by_ref().take(chain.end - chain.start) {
                    self.schedule_wr(chain.qp, chain.node, wr);
                }
            }
        }
        self.drain = drain;
        self.arm_timer_tick();
    }

    /// Keep the schedule holding a tick at the engine's earliest pending
    /// recovery timer. Armed only when strictly earlier than what is
    /// already scheduled; a tick that fires with nothing due is a no-op,
    /// so over-arming is safe and under-arming impossible — every
    /// deadline, backoff release and QP probe gets its event.
    fn arm_timer_tick(&mut self) {
        if let Some(t) = self.engine.next_timer_at() {
            let at = t.max(self.now_ns);
            if at < self.tick_at {
                self.tick_at = at;
                self.push(at, EventKind::Tick);
            }
        }
    }

    fn schedule_wr(&mut self, qp: QpId, node: NodeId, wr: WorkRequest) {
        let mut at = self.now_ns + LAT_BASE_NS + self.rng.gen_below(LAT_JITTER_NS);
        if self.plan.reg_stall_rate > 0.0 {
            // lazy registration: the WR's first touch of an unregistered
            // span may stall synchronously before it can post; spans the
            // run already registered never stall again. Guarded so quiet
            // plans leave the seed stream byte-identical.
            use crate::coordinator::mr_cache::MR_SPAN_BYTES;
            let mut first_touch = false;
            for span in (wr.remote_addr / MR_SPAN_BYTES)
                ..=((wr.remote_addr + wr.len.max(1) - 1) / MR_SPAN_BYTES)
            {
                first_touch |= self.reg_seen.insert(span);
            }
            if first_touch && self.rng.gen_bool(self.plan.reg_stall_rate) {
                at += self.plan.reg_stall_ns;
                self.stats.reg_stalled_wcs += 1;
            }
        }
        if self.plan.reorder_rate > 0.0 && self.rng.gen_bool(self.plan.reorder_rate) {
            // hold this WC back so later-posted WRs overtake it in the CQ
            at += 1 + self.rng.gen_below(self.plan.reorder_jitter_ns.max(1));
            self.stats.reordered_wcs += 1;
        }
        // cluster-wide latency storm: congestion delay on top of whatever
        // the WC already picked up
        let storm = self.plan.storm_extra(at);
        if storm > 0 {
            at += storm;
            self.stats.stormed_wcs += 1;
        }
        if let Some(release) = self.plan.stall_release(qp, at) {
            // the QP's context fell out of the NIC cache: nothing comes
            // back until the stall window ends
            at = release;
            self.stats.stalled_wcs += 1;
        }
        let inject_error = self.plan.error_rate > 0.0 && self.rng.gen_bool(self.plan.error_rate);
        let dup_lag = if self.plan.duplicate_rate > 0.0 && self.rng.gen_bool(self.plan.duplicate_rate)
        {
            Some(1 + self.rng.gen_below(self.plan.duplicate_lag_ns.max(1)))
        } else {
            None
        };
        // recovery faults — drawn after every older fault class so
        // pinned seeds keep their exact pre-recovery schedules
        let lost = self.plan.lost_rate > 0.0 && self.rng.gen_bool(self.plan.lost_rate);
        if let Some(lag) = dup_lag {
            if self.plan.wedged(qp, at + lag) {
                self.stats.wedged_wcs += 1;
            } else {
                self.push(
                    at + lag,
                    EventKind::Deliver(Flight {
                        qp,
                        node,
                        wr: wr.clone(),
                        inject_error,
                        duplicate: true,
                    }),
                );
            }
        }
        if lost {
            // the WC is gone: nothing scheduled, the WR's deadline is
            // the only thing that can ever release its window bytes
            self.stats.lost_wcs += 1;
        } else if self.plan.wedged(qp, at) {
            self.stats.wedged_wcs += 1;
        } else {
            self.push(
                at,
                EventKind::Deliver(Flight {
                    qp,
                    node,
                    wr,
                    inject_error,
                    duplicate: false,
                }),
            );
        }
    }

    /// Advance virtual time to the next scheduled event and process it.
    /// Returns the application I/Os that retired, or `None` when the
    /// fabric is quiescent (no events left).
    pub fn step(&mut self) -> Option<Vec<RetiredIo>> {
        let (at, kind) = self.events.pop()?;
        debug_assert!(at >= self.now_ns, "virtual time ran backwards");
        self.now_ns = at;
        let mut retired = Vec::new();
        match kind {
            EventKind::Node { node, up } => {
                self.stats.node_transitions += 1;
                // the engine owns the lifecycle decision: up means Alive
                // without resync, Resyncing (with repair copies queued)
                // when resync is on and the node missed writes
                if up {
                    self.engine.on_node_up(node);
                } else {
                    self.engine.on_node_down(node);
                }
            }
            EventKind::Churn { window } => {
                // live window swap: in-flight bytes carry over, so a
                // shrink blocks without leaking and a grow admits backlog
                self.engine.set_window(window);
                self.stats.window_changes += 1;
            }
            EventKind::Deliver(f) => {
                // a Resyncing node is up for the fabric (its QPs answer);
                // it is the *routing* layers that must avoid it
                let up = self.engine.node_map().expect("placed").state(f.node) != NodeState::Dead;
                let partitioned = self.plan.partitioned(f.node, self.now_ns);
                let status = if f.inject_error || !up || partitioned {
                    WcStatus::Error
                } else {
                    WcStatus::Success
                };
                if f.duplicate {
                    self.stats.duplicates_delivered += 1;
                } else if f.inject_error {
                    self.stats.injected_errors += 1;
                } else if !up {
                    self.stats.dead_node_errors += 1;
                } else if partitioned {
                    self.stats.partitioned_wcs += 1;
                }
                self.stats.delivered_wcs += 1;
                if status == WcStatus::Success {
                    // move the "bytes": writes land their stamps in the
                    // node's store, reads serve whatever the store holds
                    self.move_payloads(f.node, &f.wr);
                }
                let wc = Wc {
                    wr_id: f.wr.wr_id,
                    qp: f.qp,
                    op: f.wr.op,
                    len: f.wr.len,
                    app_ios: f.wr.app_ios,
                    tenant: f.wr.tenant,
                    status,
                };
                let out = self.engine.on_wc(&wc, self.now_ns);
                retired = self.absorb_wc_out(out);
            }
            EventKind::Tick => {
                // recovery timers: expire overdue WRs (synthesizing
                // timeout-WCs through the same completion path a real
                // WC takes), release backoffs, step QP probes
                self.tick_at = u64::MAX;
                self.stats.timer_ticks += 1;
                let mut out = WcOut::default();
                self.engine.service_timers(self.now_ns, &mut out);
                retired = self.absorb_wc_out(out);
            }
        }
        // the completion (or node event) may have surrendered ranges
        self.absorb_surrenders();
        // failover requeues and freed window capacity both need a drain
        self.pump();
        Some(retired)
    }

    /// Engine-output bookkeeping shared by real deliveries and synthetic
    /// timeout completions: count failovers, hand resync copies the
    /// stamps their source read served, credit durable write legs, and
    /// account retirements. Returns the retired I/Os for the caller.
    fn absorb_wc_out(&mut self, out: WcOut) -> Vec<RetiredIo> {
        self.stats.failovers += u64::from(out.requeued);
        // repair writes inherit the stamps their source read served
        for c in &out.resync_copies {
            if let Some(stamps) = self.served.remove(&c.read_sub) {
                self.write_stamps.insert(c.write_sub, stamps);
            }
        }
        // a write leg that completed on some replica is durable:
        // its stamps raise the floor when the parent retires
        // (split writes credit exactly their landed legs)
        for (sid, parent) in &out.completed_subs {
            if *parent != RESYNC_PARENT {
                if let Some(st) = self.write_stamps.get(sid) {
                    self.durable
                        .entry(*parent)
                        .or_default()
                        .extend(st.iter().copied());
                }
            }
        }
        for r in &out.retired {
            self.stats.retired += 1;
            if r.disk_fallback {
                self.stats.disk_fallbacks += 1;
            }
            self.note_retired(r);
        }
        // write-stamp payloads are per-sub state; read bookkeeping
        // (floor snapshots, served stamps) is retained until the
        // *parent* retires so every leg of a split read is
        // checked exactly once by note_retired
        for (sid, _) in out.completed_subs.iter().chain(out.failed_subs.iter()) {
            self.write_stamps.remove(sid);
        }
        out.retired
    }

    /// The data plane of a successful delivery: apply write stamps to the
    /// serving node's store (newest version wins — an idempotent model of
    /// page content, so duplicate/reordered deliveries cannot corrupt
    /// it), and record what the store holds for each read sub-I/O.
    fn move_payloads(&mut self, node: NodeId, wr: &WorkRequest) {
        match wr.op {
            OpKind::Write | OpKind::Send => {
                for sid in &wr.app_ios {
                    let Some(stamps) = self.write_stamps.get(sid) else {
                        continue; // late duplicate: already cleaned up
                    };
                    for st in stamps {
                        let e = self.stores[node].entry(st.page).or_insert(*st);
                        if st.version > e.version {
                            *e = *st;
                        }
                    }
                }
            }
            OpKind::Read => {
                for sid in &wr.app_ios {
                    // sub still live in the engine ⇒ this is its first
                    // completion; a merged WR is sliced per sub-span
                    let Some((addr, len, _)) = self.engine.sub_span(*sid) else {
                        continue;
                    };
                    let stamps: Vec<PageStamp> = pages_of(addr, len)
                        .map(|page| {
                            self.stores[node].get(&page).copied().unwrap_or_else(|| {
                                PageStamp {
                                    page,
                                    version: 0,
                                    fp: stamp_fp(page, 0),
                                }
                            })
                        })
                        .collect();
                    self.served.insert(*sid, stamps);
                }
            }
        }
    }

    /// Model bookkeeping when an application I/O retires. Writes raise
    /// the per-page floor for exactly the pages some replica durably
    /// stored (the `durable` set — all pages for an unsplit write that
    /// retired remotely) and mark the rest disk-backed. Reads are checked
    /// **per leg** against the floor snapshots taken at submit — every
    /// leg of a split read is examined exactly once, here, even when its
    /// completion arrived in an earlier WC than the one that retired the
    /// read (serving an older version on any leg is a stale read).
    fn note_retired(&mut self, r: &RetiredIo) {
        if let Some(stamps) = self.parent_stamps.remove(&r.id) {
            // a write retired
            let durable = self.durable.remove(&r.id).unwrap_or_default();
            let durable_pages: PageSet = durable.iter().map(|st| st.page).collect();
            for st in &stamps {
                if durable_pages.contains(&st.page) {
                    // raising the durable floor past the disk version is
                    // what flips the page back to remote ownership — an
                    // older write's floor raise leaves a newer disk mark
                    // in charge (see disk_backed)
                    let f = self.floor.entry(st.page).or_insert(0);
                    if st.version > *f {
                        *f = st.version;
                    }
                } else {
                    // no replica stored this page (failed or
                    // dead-at-submit leg): disk owns it at this version
                    self.mark_disk(st.page, st.version);
                }
            }
            return;
        }
        // a read retired: walk every leg once, then drop the bookkeeping
        let Some(sids) = self.read_subs.remove(&r.id) else {
            return;
        };
        for sid in sids {
            let served = self.served.remove(&sid);
            let floors = self.read_floor.remove(&sid);
            if r.disk_fallback {
                // some leg exhausted every replica: the caller redoes the
                // whole read via the disk path, no freshness to assert
                continue;
            }
            let (Some(served), Some(floors)) = (served, floors) else {
                continue;
            };
            for (st, &(page, floor_v)) in served.iter().zip(floors.iter()) {
                debug_assert_eq!(st.page, page, "served stamps misaligned with floor");
                debug_assert_eq!(
                    st.fp,
                    stamp_fp(st.page, st.version),
                    "fingerprint does not match its version: store corrupted"
                );
                if st.version < floor_v {
                    self.stats.stale_reads += 1;
                    if self.first_stale.is_none() {
                        self.first_stale = Some(format!(
                            "io {} page {:#x}: served version {} (fp {:#018x}) \
                             below retired floor {}",
                            r.id, st.page, st.version, st.fp, floor_v
                        ));
                    }
                }
            }
        }
    }

    /// Run until no events remain, bounded by `max_steps` (livelock
    /// guard). Returns every I/O retired along the way.
    pub fn run_to_idle(&mut self, max_steps: u64) -> crate::runtime::Result<Vec<RetiredIo>> {
        let mut all = Vec::new();
        for _ in 0..max_steps {
            match self.step() {
                Some(r) => all.extend(r),
                None => return Ok(all),
            }
        }
        Err(crate::runtime::err(format!(
            "chaos fabric not quiescent after {max_steps} events \
             ({} still pending)",
            self.events.len()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::NodeMap;

    const STEPS: u64 = 1_000_000;

    /// Replicated 2×1 spec with resync (and optionally election) — the
    /// recovering-cluster shape most tests here drive.
    fn resync_spec(election: bool) -> EngineSpec {
        let s = EngineSpec::new(2).replicated(2).resync(RESYNC_CHUNK_BYTES);
        if election {
            s.election()
        } else {
            s
        }
    }

    fn submit_pages(fab: &mut ChaosFabric, n: u64, read_every: u64) -> u64 {
        for i in 0..n {
            let dir = if read_every > 0 && i % read_every == 0 {
                Dir::Read
            } else {
                Dir::Write
            };
            fab.submit(i, dir, (i % 64) * 4096, 4096);
        }
        n
    }

    #[test]
    fn quiet_plan_retires_everything_exactly_once() {
        let mut fab = ChaosFabric::new(7, 3, 2, 2, Some(16 * 4096), FaultPlan::none());
        let n = submit_pages(&mut fab, 100, 3);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        assert_eq!(fab.stats.failovers, 0);
        assert_eq!(fab.stats.disk_fallbacks, 0);
        assert_eq!(fab.engine().stats.duplicate_wcs, 0);
        assert_eq!(fab.engine().queued_ios(), 0);
        assert_eq!(fab.engine().regulator().in_flight(), 0);
    }

    #[test]
    fn same_seed_same_schedule() {
        let run = |seed: u64| {
            let plan = FaultPlan::none()
                .with_errors(0.2)
                .with_reordering(0.3, 20_000)
                .with_duplicates(0.2, 5_000)
                .node_down(1, 40_000)
                .node_up(1, 120_000);
            let mut fab = ChaosFabric::new(seed, 3, 2, 2, Some(24 * 4096), plan);
            submit_pages(&mut fab, 120, 2);
            let mut retired = fab.run_to_idle(STEPS).expect("quiescent");
            retired.sort_by_key(|r| r.id);
            (retired, fab.stats.clone(), fab.now())
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a.0, b.0, "retired set + flags identical");
        assert_eq!(a.1, b.1, "fault schedule identical");
        assert_eq!(a.2, b.2, "virtual clock identical");
        let c = run(43);
        assert_ne!(
            (a.1, a.2),
            (c.1, c.2),
            "a different seed must produce a different schedule"
        );
    }

    /// The tentpole bit-identity claim at the fabric level: the calendar
    /// queue and the pre-refactor `BinaryHeap` scheduler produce the
    /// same retirement order, the same fault schedule, and the same
    /// virtual clock under a full fault mix.
    #[test]
    fn calendar_and_reference_schedulers_agree() {
        let run = |kind: SchedulerKind| {
            let plan = FaultPlan::none()
                .with_errors(0.2)
                .with_reordering(0.3, 20_000)
                .with_duplicates(0.2, 5_000)
                .with_reg_stalls(0.4, 80_000)
                .latency_storm(10_000, 90_000, 30_000)
                .node_down(1, 40_000)
                .node_up(1, 400_000);
            let spec = EngineSpec::new(3)
                .qps(2)
                .window(Some(24 * 4096))
                .replicated(2)
                .resync(RESYNC_CHUNK_BYTES);
            let mut fab = ChaosFabric::build_with_scheduler(0xB17, &spec, plan, kind);
            submit_pages(&mut fab, 120, 2);
            let retired = fab.run_to_idle(STEPS).expect("quiescent");
            let ids: Vec<(u64, bool)> = retired.iter().map(|r| (r.id, r.disk_fallback)).collect();
            (ids, fab.stats.clone(), fab.now())
        };
        let cal = run(SchedulerKind::Calendar);
        let reference = run(SchedulerKind::Reference);
        assert_eq!(cal.0, reference.0, "retirement order identical");
        assert_eq!(cal.1, reference.1, "fault schedule identical");
        assert_eq!(cal.2, reference.2, "virtual clock identical");
    }

    #[test]
    fn all_errors_exhaust_replicas_into_disk_fallback() {
        let mut fab = ChaosFabric::new(11, 2, 1, 2, None, FaultPlan::none().with_errors(1.0));
        let n = submit_pages(&mut fab, 40, 2);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n, "every io still retires");
        assert!(retired.iter().all(|r| r.disk_fallback));
        assert_eq!(fab.engine().regulator().in_flight(), 0);
    }

    #[test]
    fn duplicates_are_absorbed_by_the_wr_ledger() {
        let plan = FaultPlan::none().with_duplicates(1.0, 10_000);
        let mut fab = ChaosFabric::new(13, 2, 2, 2, Some(32 * 4096), plan);
        let n = submit_pages(&mut fab, 80, 4);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n, "exactly-once despite dups");
        assert!(fab.stats.duplicates_delivered > 0);
        assert_eq!(
            fab.engine().stats.duplicate_wcs,
            fab.stats.duplicates_delivered,
            "every duplicate was dropped at the ledger"
        );
    }

    #[test]
    fn stalled_qp_delays_but_does_not_lose_completions() {
        // one node, one QP: everything rides the stalled channel
        let plan = FaultPlan::none().stall(0, 0, 200_000);
        let mut fab = ChaosFabric::new(17, 1, 1, 1, Some(8 * 4096), plan);
        let n = submit_pages(&mut fab, 30, 0);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n);
        assert!(fab.stats.stalled_wcs > 0, "the stall actually bit");
        assert!(fab.now() >= 200_000, "nothing completed in the stall");
    }

    /// Registration stalls bite only on the *first* touch of a span:
    /// a workload confined to one 64 KiB span pays exactly one stall
    /// however many WRs it posts, and the stall delays — never loses —
    /// the request (the admission window drains to empty).
    #[test]
    fn reg_stalls_hit_first_touch_once_and_leak_nothing() {
        let plan = FaultPlan::none().with_reg_stalls(1.0, 150_000);
        let mut fab = ChaosFabric::new(31, 2, 1, 2, Some(8 * 4096), plan);
        for i in 0..30u64 {
            fab.submit(i, Dir::Write, (i % 8) * 4096, 4096);
        }
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len(), 30, "stalled requests still retire");
        assert_eq!(
            fab.stats.reg_stalled_wcs, 1,
            "one span, one first touch, one stall"
        );
        assert!(fab.now() >= 150_000, "the stall actually delayed delivery");
        assert_eq!(fab.engine().regulator().in_flight(), 0, "window released");
        assert_eq!(fab.engine().queued_ios(), 0);
        assert_eq!(fab.stats.stale_reads, 0);
    }

    #[test]
    fn quiet_plan_reads_serve_the_retired_versions() {
        let mut fab = ChaosFabric::new(23, 2, 1, 2, None, FaultPlan::none());
        fab.submit(1, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(2, Dir::Write, 0, 4096); // second version of page 0
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(3, Dir::Read, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0);
        assert!(fab.first_stale.is_none());
    }

    /// The hole the completion-level invariants cannot see: a replica
    /// dies, misses a write, revives without resync, and serves the old
    /// version — the payload model catches it.
    #[test]
    fn unresynced_revival_serves_stale_and_is_detected() {
        // 2 nodes, 2 replicas: stripe 0 lives on {0, 1}, primary 0
        let mut fab = ChaosFabric::new(0xA5, 2, 1, 2, None, FaultPlan::none());
        fab.submit(1, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        // version 2 of page 0 retires on the surviving replica only
        fab.submit(2, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, true, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        // the revived primary serves the read — with version 1
        fab.submit(3, Dir::Read, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        assert!(fab.stats.stale_reads > 0, "stale read must be detected");
        let detail = fab.first_stale.as_deref().expect("stale detail");
        assert!(detail.contains("below retired floor"), "{detail}");
    }

    /// Same schedule with resync enabled: the revived node re-enters in
    /// `Resyncing`, the engine replays the missed write from the peer,
    /// and no stale data is ever served — even after the peer dies and
    /// the repaired node is the only replica left.
    #[test]
    fn resync_gates_revival_and_repairs_the_replica() {
        let mut fab = ChaosFabric::build(0xA5, &resync_spec(false), FaultPlan::none());
        fab.submit(1, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.submit(2, Dir::Write, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, true, fab.now() + 1);
        // run_to_idle drives the resync copies to completion
        fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(fab.engine().node_state(0), Some(NodeState::Alive));
        assert!(fab.engine().stats.resyncs_completed >= 1);
        fab.submit(3, Dir::Read, 0, 4096);
        fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "resync prevented the stale read");
        // the repaired replica now carries the data alone
        fab.schedule_node_event(1, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        let sub = fab.submit(4, Dir::Read, 0, 4096);
        assert!(!sub.disk_fallback, "node 0 is alive and repaired");
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert!(retired.iter().all(|r| !r.disk_fallback));
        assert_eq!(fab.stats.stale_reads, 0);
    }

    /// A partial partition diverges a replica without killing it: the
    /// failed replica write demotes the node, resync repairs it, and no
    /// read ever observes the divergence.
    #[test]
    fn partition_divergence_is_demoted_and_repaired() {
        let plan = FaultPlan::none().partition(0, 0, 50_000);
        let mut fab = ChaosFabric::build(29, &resync_spec(false), plan);
        // writes during the partition: node 0's legs all error
        for i in 0..8u64 {
            fab.submit(i, Dir::Write, i * 4096, 4096);
        }
        fab.run_to_idle(STEPS).expect("quiescent");
        assert!(fab.stats.partitioned_wcs > 0, "partition never bit");
        assert!(fab.engine().stats.resync_demotions >= 1, "diverged replica demoted");
        // after the window, repair completes and reads are fresh
        for i in 0..8u64 {
            fab.submit(100 + i, Dir::Read, i * 4096, 4096);
        }
        fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(fab.stats.stale_reads, 0, "demotion + resync hid the divergence");
        assert_eq!(fab.engine().regulator().in_flight(), 0);
    }

    /// ISSUE 5 satellite: the engine's disk-surrender signal drives the
    /// *paging layer's* per-block disk bit end-to-end. The chaos run
    /// produces a surrender (all peers of a revived node dead); feeding
    /// the surrendered ranges into a real `Pager` via
    /// `Pager::surrender` must flip exactly those swap slots to the
    /// disk path, so a subsequent fault of a surrendered page routes
    /// its load to `Target::Disk` — not to a remote replica that no
    /// longer holds the required version.
    #[test]
    fn surrendered_ranges_route_reads_to_disk_via_pager() {
        use crate::paging::{Pager, Target};

        let mut fab = ChaosFabric::build(0xD15C, &resync_spec(true), FaultPlan::none());
        // 8 pages live remotely, then node 0 misses an overwrite and
        // every peer dies before it revives: the election surrenders
        for i in 0..8u64 {
            fab.submit(i, Dir::Write, i * 4096, 4096);
        }
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(0, false, fab.now() + 1);
        fab.run_to_idle(STEPS).expect("quiescent");
        for i in 0..4u64 {
            fab.submit(100 + i, Dir::Write, i * 4096, 4096); // only node 1
        }
        fab.run_to_idle(STEPS).expect("quiescent");
        fab.schedule_node_event(1, false, fab.now() + 1);
        fab.schedule_node_event(0, true, fab.now() + 2);
        fab.run_to_idle(STEPS).expect("quiescent");
        assert!(
            fab.engine().stats.resync_disk_surrenders > 0,
            "the scenario must actually surrender"
        );
        assert!(!fab.surrendered_log.is_empty());

        // a pager whose swap device mirrors the chaos address space
        // (page p <-> slot p): pages 0..8 are swapped out remotely
        let mut pager = Pager::new(1, NodeMap::new(2, 2, 1 << 20), 4096);
        pager.prepopulate(8);
        let mut flipped = 0;
        for &(addr, len) in &fab.surrendered_log {
            flipped += pager.surrender(addr, len);
        }
        assert!(flipped > 0, "surrendered span covered swapped-out pages");
        // every surrendered page now faults to the local disk replica…
        for &(addr, len) in &fab.surrendered_log {
            for page in pages_of(addr, len) {
                if page >= 8 {
                    continue;
                }
                assert!(pager.disk_backed(page), "page {page} disk bit set");
                let o = pager.touch(page, false);
                let load = o.load.expect("non-resident page needs a load");
                assert_eq!(load.target, Target::Disk, "page {page} reads disk");
            }
        }
        // …and an untouched remote page still reads from a replica
        let remote_page = (0..8u64)
            .find(|p| !pager.disk_backed(*p) && !pager.cache().contains(*p))
            .expect("some page stayed remote");
        let o = pager.touch(remote_page, false);
        assert!(matches!(o.load.expect("load").target, Target::Node(_)));
    }

    /// ISSUE 5 satellite: duplicate/late WCs against the slab ledgers.
    /// Every WR is delivered twice and errors drive failover re-queues,
    /// so stale wr_ids and stale sub ids arrive constantly while their
    /// slots are being recycled — the generation check must drop every
    /// one (exactly-once retirement, fully released window, and every
    /// duplicate accounted).
    #[test]
    fn duplicates_with_failover_never_resolve_recycled_slots() {
        let plan = FaultPlan::none()
            .with_duplicates(1.0, 20_000)
            .with_errors(0.3)
            .with_reordering(0.3, 15_000);
        let mut fab = ChaosFabric::new(0x51AB, 3, 2, 2, Some(32 * 4096), plan);
        let n = submit_pages(&mut fab, 120, 3);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n, "exactly-once despite dup+failover");
        assert!(fab.stats.duplicates_delivered > 0);
        assert!(fab.stats.failovers > 0, "errors actually drove failover");
        assert_eq!(
            fab.engine().stats.duplicate_wcs,
            fab.stats.duplicates_delivered,
            "every duplicate died at the generation check"
        );
        assert_eq!(fab.engine().regulator().in_flight(), 0);
        assert_eq!(fab.engine().queued_ios(), 0);
    }

    #[test]
    fn node_death_mid_run_drives_failover_not_loss() {
        // all addresses in stripe 0 -> primary node 0, replica node 1
        let plan = FaultPlan::none().node_down(0, 4_000);
        let mut fab = ChaosFabric::new(19, 2, 1, 2, None, plan);
        for i in 0..32u64 {
            fab.submit(i, Dir::Read, (i % 8) * 4096, 4096);
        }
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len(), 32);
        assert!(
            retired.iter().all(|r| !r.disk_fallback),
            "replica 1 survived: no disk fallback"
        );
        assert!(fab.stats.failovers > 0, "reads were in flight to node 0");
    }

    /// Per-tenant accounting stays exactly balanced under injected
    /// errors, duplicates and failover: every tenant's posted bytes are
    /// matched by completions, both sub-windows drain to empty, and the
    /// payload model stays fresh.
    #[test]
    fn tenants_account_exactly_under_faults() {
        let plan = FaultPlan::none()
            .with_errors(0.2)
            .with_duplicates(0.5, 10_000);
        let spec = EngineSpec::new(2)
            .qps(2)
            .window(Some(8 * 4096))
            .replicated(2)
            .tenants(&[3, 1]);
        let mut fab = ChaosFabric::build(0x7E4A, &spec, plan);
        for i in 0..80u64 {
            let t = (i % 2) as usize;
            let dir = if i % 5 == 0 { Dir::Read } else { Dir::Write };
            fab.submit_t(i, dir, (i % 32) * 4096, 4096, t);
        }
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len(), 80, "every io retires exactly once");
        let ts = fab.engine().tenant_stats();
        assert_eq!(ts.len(), 2);
        for t in &ts {
            assert!(t.posted_bytes > 0, "tenant {} carried traffic", t.tenant);
            assert_eq!(
                t.posted_bytes, t.retired_bytes,
                "tenant {} window balanced",
                t.tenant
            );
            assert_eq!(t.window_occupancy, 0);
            assert!(t.drained_bytes > 0);
        }
        assert_eq!(fab.engine().regulator().in_flight(), 0);
        assert_eq!(fab.stats.stale_reads, 0);
    }

    /// ISSUE 10 tentpole: WCs swallowed outright (`lost_rate`) can only
    /// be recovered by the engine's completion deadlines. Every I/O must
    /// still retire exactly once, the admission window must drain to
    /// empty with zero counted leaks, and the payload model must stay
    /// fresh — a lost completion delays work, it never strands it.
    #[test]
    fn lost_wcs_never_hang_the_window() {
        let plan = FaultPlan::none().with_lost_wcs(0.2);
        let spec = resync_spec(false)
            .window(Some(16 * 4096))
            .deadlines(100_000, 2);
        let mut fab = ChaosFabric::build(0x10C7, &spec, plan);
        let n = submit_pages(&mut fab, 100, 3);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(
            ids,
            (0..n).collect::<Vec<_>>(),
            "exactly-once despite lost completions"
        );
        assert!(fab.stats.lost_wcs > 0, "losses actually bit");
        assert!(fab.stats.timer_ticks > 0, "deadlines were serviced");
        let rec = fab.engine().recovery_stats();
        assert!(
            rec.timeouts >= fab.stats.lost_wcs,
            "every lost WC was retired by a deadline ({} timeouts, {} lost)",
            rec.timeouts,
            fab.stats.lost_wcs
        );
        assert_eq!(fab.engine().stats.window_leaks, 0);
        assert_eq!(fab.engine().regulator().in_flight(), 0);
        assert_eq!(fab.engine().queued_ios(), 0);
        assert_eq!(fab.engine().qps_not_ok(), 0, "probation walked QPs back");
        assert_eq!(fab.stats.stale_reads, 0, "{:?}", fab.first_stale);
    }

    /// ISSUE 10 tentpole: a wedged QP (completions silently dropped)
    /// trips the per-QP error machine — outstanding WRs flush as
    /// timeout-WCs, the node goes down while every one of its QPs is
    /// bad, and probation walks the QP back to `Ok`, after which it
    /// serves traffic again.
    #[test]
    fn wedged_qp_flushes_recovers_and_serves_again() {
        let plan = FaultPlan::none().wedge(0, 0, 60_000);
        let spec = EngineSpec::new(2)
            .window(None)
            .replicated(2)
            .deadlines(20_000, 0);
        let mut fab = ChaosFabric::build(0x3ED6E, &spec, plan);
        for i in 0..6u64 {
            fab.submit(i, Dir::Write, i * 4096, 4096);
        }
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len(), 6, "every write retires despite the wedge");
        assert!(
            retired.iter().all(|r| !r.disk_fallback),
            "replica 1 kept every write durable"
        );
        assert_eq!(fab.stats.wedged_wcs, 6, "all node-0 deliveries dropped");
        let rec = fab.engine().recovery_stats();
        assert_eq!(rec.timeouts, 6);
        assert!(rec.flushes > 0, "the Error transition flushed the rest");
        assert_eq!(rec.resets, 1, "probation completed exactly one reset");
        assert_eq!(fab.engine().qps_not_ok(), 0);
        assert_eq!(
            fab.engine().node_map().expect("placed").state(0),
            NodeState::Alive,
            "the auto-downed node was revived with its QP"
        );
        assert_eq!(fab.engine().stats.window_leaks, 0);
        assert_eq!(fab.engine().regulator().in_flight(), 0);
        // the recovered QP serves traffic again (wedge window is over)
        assert!(fab.now() > 60_000);
        for i in 0..6u64 {
            fab.submit(100 + i, Dir::Write, i * 4096, 4096);
        }
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len(), 6);
        assert_eq!(
            fab.engine().recovery_stats().timeouts,
            rec.timeouts,
            "no new timeouts once the QP recovered"
        );
        assert_eq!(fab.stats.stale_reads, 0);
    }

    /// The new fault classes stay inside the determinism contract:
    /// identical seeds replay identical schedules, retirements and
    /// recovery counters.
    #[test]
    fn recovery_faults_are_deterministic_per_seed() {
        let run = |seed: u64| {
            let plan = FaultPlan::none()
                .with_lost_wcs(0.15)
                .wedge(1, 10_000, 90_000)
                .with_errors(0.1);
            let spec = resync_spec(false)
                .window(Some(24 * 4096))
                .deadlines(60_000, 1);
            let mut fab = ChaosFabric::build(seed, &spec, plan);
            submit_pages(&mut fab, 80, 4);
            let mut retired = fab.run_to_idle(STEPS).expect("quiescent");
            retired.sort_by_key(|r| r.id);
            (retired, fab.stats.clone(), fab.now())
        };
        let a = run(0xA11CE);
        let b = run(0xA11CE);
        assert_eq!(a, b, "recovery faults are a pure function of the seed");
        assert!(
            a.1.lost_wcs + a.1.wedged_wcs > 0,
            "the new faults actually fired"
        );
        assert_eq!(a.1.stale_reads, 0);
    }
}
