//! Chaos fabric: the third backend of the I/O stack — a deterministic,
//! seeded, fault-injecting fabric for correctness testing.
//!
//! Where [`crate::fabric::sim`] models a *calibrated* RDMA path (to
//! regenerate the paper's figures) and [`crate::fabric::loopback`] moves
//! real bytes on real threads, the chaos fabric executes the same
//! [`IoEngine`] pipeline under an *adversarial* schedule: virtual time
//! (no wall clock anywhere), a seeded PRNG interleaving per-QP progress,
//! and a [`FaultPlan`] injecting completion errors, WC reordering within
//! a CQ, duplicate/late completions, per-QP stalls ("NIC cache thrash"),
//! and node death/revival at chosen virtual times.
//!
//! Everything is a pure function of the `(seed, FaultPlan, workload)`
//! triple: a failing schedule replays exactly from its seed, which is
//! what makes the scenario harness in [`scenario`] (and the CI sweep on
//! top of it) a regression suite rather than a flake generator. This is
//! the template every future backend must pass: production policy code
//! runs unmodified; only the completion schedule is hostile.

pub mod plan;
pub mod scenario;

pub use plan::{FaultPlan, NodeEvent, QpStall};
pub use scenario::{replay_command, run_scenario, Scenario, ScenarioReport};

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::coordinator::batching::{BatchLimits, BatchMode};
use crate::coordinator::engine::{EngineCosts, IoEngine, RetiredIo, Submitted, SHARD_REGION_SHIFT};
use crate::coordinator::node::NodeMap;
use crate::fabric::{AppIo, Dir, NodeId, QpId, Wc, WcStatus, WorkRequest};
use crate::util::rng::Pcg32;

/// Replication stripe size (mirrors the loopback fabric: one 1 MiB shard
/// region per stripe, so placement and QP sharding line up).
pub const STRIPE_BYTES: u64 = 1 << SHARD_REGION_SHIFT;

/// Base completion latency of a WR in virtual ns.
const LAT_BASE_NS: u64 = 1_000;
/// Uniform jitter on top of the base latency (this alone interleaves
/// per-QP progress: two WRs posted together complete in PRNG order).
const LAT_JITTER_NS: u64 = 8_000;

/// A WR in flight through the chaos fabric, with its fault decisions
/// (drawn at post time, so the schedule is fixed the moment it is posted).
#[derive(Debug, Clone)]
struct Flight {
    qp: QpId,
    node: NodeId,
    wr: WorkRequest,
    inject_error: bool,
    /// This delivery is the duplicate copy (stats only; the engine's
    /// wr_id ledger is what actually de-duplicates).
    duplicate: bool,
}

#[derive(Debug)]
enum EventKind {
    Deliver(Flight),
    Node { node: NodeId, up: bool },
}

/// A scheduled event in virtual time. Total order is `(at, seq)`; `seq`
/// is unique per event, so heap pops are fully deterministic.
#[derive(Debug)]
struct Event {
    at: u64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// What the chaos fabric did to the schedule (all injected counts).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ChaosStats {
    pub delivered_wcs: u64,
    pub injected_errors: u64,
    /// Error completions caused by the target node being dead at delivery.
    pub dead_node_errors: u64,
    pub duplicates_delivered: u64,
    pub reordered_wcs: u64,
    pub stalled_wcs: u64,
    pub node_transitions: u64,
    pub retired: u64,
    pub disk_fallbacks: u64,
    pub failovers: u64,
}

/// The deterministic fault-injecting fabric: drives a placed [`IoEngine`]
/// (replica fan-out, read failover, disk-fallback signal) through an
/// event heap in virtual time.
pub struct ChaosFabric {
    engine: IoEngine,
    plan: FaultPlan,
    rng: Pcg32,
    now_ns: u64,
    events: BinaryHeap<Reverse<Event>>,
    next_seq: u64,
    pub stats: ChaosStats,
}

impl ChaosFabric {
    /// Build a cluster of `nodes` × `qps_per_node` chaos QPs with
    /// `replicas`-way placement. The plan's node events are pre-loaded
    /// into the schedule; everything else is drawn from `seed` as WRs
    /// are posted.
    pub fn new(
        seed: u64,
        nodes: usize,
        qps_per_node: usize,
        replicas: usize,
        window_bytes: Option<u64>,
        plan: FaultPlan,
    ) -> Self {
        let map = NodeMap::new(nodes, replicas, STRIPE_BYTES);
        let engine = IoEngine::new(
            BatchMode::Hybrid,
            BatchLimits::default(),
            nodes,
            qps_per_node,
            window_bytes,
            EngineCosts::free(),
        )
        .with_placement(map);
        let node_events: Vec<NodeEvent> = plan.node_events.clone();
        let mut fab = Self {
            engine,
            plan,
            rng: Pcg32::with_stream(seed, 0xC4A05),
            now_ns: 0,
            events: BinaryHeap::new(),
            next_seq: 0,
            stats: ChaosStats::default(),
        };
        for ev in node_events {
            fab.schedule_node_event(ev.node, ev.up, ev.at_ns);
        }
        fab
    }

    pub fn now(&self) -> u64 {
        self.now_ns
    }

    pub fn engine(&self) -> &IoEngine {
        &self.engine
    }

    pub fn pending_events(&self) -> usize {
        self.events.len()
    }

    /// Schedule a node death (`up = false`) or revival at a virtual time
    /// (in addition to whatever the plan pre-loaded — tests use this to
    /// place a death relative to the current virtual time).
    pub fn schedule_node_event(&mut self, node: NodeId, up: bool, at_ns: u64) {
        self.push(at_ns.max(self.now_ns), EventKind::Node { node, up });
    }

    fn push(&mut self, at: u64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.events.push(Reverse(Event { at, seq, kind }));
    }

    /// Submit one application I/O at the current virtual time and drain
    /// the pipeline. The returned routing outcome surfaces the
    /// disk-fallback signal when every replica of `addr` is already dead.
    pub fn submit(&mut self, id: u64, dir: Dir, addr: u64, len: u64) -> Submitted {
        let io = AppIo {
            id,
            dir,
            node: 0,
            addr,
            len,
            thread: 0,
            t_submit: self.now_ns,
        };
        let sub = self.engine.submit(io);
        self.pump();
        sub
    }

    /// Drain admitted requests and put the planned WRs in flight, drawing
    /// each WR's latency and fault decisions from the seed stream.
    fn pump(&mut self) {
        let out = self.engine.drain_all(self.now_ns);
        for chain in out.chains {
            let (qp, node) = (chain.qp, chain.node);
            for wr in chain.wrs {
                self.schedule_wr(qp, node, wr);
            }
        }
    }

    fn schedule_wr(&mut self, qp: QpId, node: NodeId, wr: WorkRequest) {
        let mut at = self.now_ns + LAT_BASE_NS + self.rng.gen_below(LAT_JITTER_NS);
        if self.plan.reorder_rate > 0.0 && self.rng.gen_bool(self.plan.reorder_rate) {
            // hold this WC back so later-posted WRs overtake it in the CQ
            at += 1 + self.rng.gen_below(self.plan.reorder_jitter_ns.max(1));
            self.stats.reordered_wcs += 1;
        }
        if let Some(release) = self.plan.stall_release(qp, at) {
            // the QP's context fell out of the NIC cache: nothing comes
            // back until the stall window ends
            at = release;
            self.stats.stalled_wcs += 1;
        }
        let inject_error = self.plan.error_rate > 0.0 && self.rng.gen_bool(self.plan.error_rate);
        if self.plan.duplicate_rate > 0.0 && self.rng.gen_bool(self.plan.duplicate_rate) {
            let lag = 1 + self.rng.gen_below(self.plan.duplicate_lag_ns.max(1));
            self.push(
                at + lag,
                EventKind::Deliver(Flight {
                    qp,
                    node,
                    wr: wr.clone(),
                    inject_error,
                    duplicate: true,
                }),
            );
        }
        self.push(
            at,
            EventKind::Deliver(Flight {
                qp,
                node,
                wr,
                inject_error,
                duplicate: false,
            }),
        );
    }

    /// Advance virtual time to the next scheduled event and process it.
    /// Returns the application I/Os that retired, or `None` when the
    /// fabric is quiescent (no events left).
    pub fn step(&mut self) -> Option<Vec<RetiredIo>> {
        let Reverse(ev) = self.events.pop()?;
        debug_assert!(ev.at >= self.now_ns, "virtual time ran backwards");
        self.now_ns = ev.at;
        let mut retired = Vec::new();
        match ev.kind {
            EventKind::Node { node, up } => {
                self.stats.node_transitions += 1;
                self.engine
                    .node_map_mut()
                    .expect("chaos engine is placed")
                    .set_alive(node, up);
            }
            EventKind::Deliver(f) => {
                let alive = self.engine.node_map().expect("placed").is_alive(f.node);
                let status = if f.inject_error || !alive {
                    WcStatus::Error
                } else {
                    WcStatus::Success
                };
                if f.duplicate {
                    self.stats.duplicates_delivered += 1;
                } else if f.inject_error {
                    self.stats.injected_errors += 1;
                } else if !alive {
                    self.stats.dead_node_errors += 1;
                }
                self.stats.delivered_wcs += 1;
                let wc = Wc {
                    wr_id: f.wr.wr_id,
                    qp: f.qp,
                    op: f.wr.op,
                    len: f.wr.len,
                    app_ios: f.wr.app_ios,
                    status,
                };
                let out = self.engine.on_wc(&wc, self.now_ns);
                self.stats.failovers += u64::from(out.requeued);
                for r in &out.retired {
                    self.stats.retired += 1;
                    if r.disk_fallback {
                        self.stats.disk_fallbacks += 1;
                    }
                }
                retired = out.retired;
            }
        }
        // failover requeues and freed window capacity both need a drain
        self.pump();
        Some(retired)
    }

    /// Run until no events remain, bounded by `max_steps` (livelock
    /// guard). Returns every I/O retired along the way.
    pub fn run_to_idle(&mut self, max_steps: u64) -> crate::runtime::Result<Vec<RetiredIo>> {
        let mut all = Vec::new();
        for _ in 0..max_steps {
            match self.step() {
                Some(r) => all.extend(r),
                None => return Ok(all),
            }
        }
        Err(crate::runtime::err(format!(
            "chaos fabric not quiescent after {max_steps} events \
             ({} still pending)",
            self.events.len()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const STEPS: u64 = 1_000_000;

    fn submit_pages(fab: &mut ChaosFabric, n: u64, read_every: u64) -> u64 {
        for i in 0..n {
            let dir = if read_every > 0 && i % read_every == 0 {
                Dir::Read
            } else {
                Dir::Write
            };
            fab.submit(i, dir, (i % 64) * 4096, 4096);
        }
        n
    }

    #[test]
    fn quiet_plan_retires_everything_exactly_once() {
        let mut fab = ChaosFabric::new(7, 3, 2, 2, Some(16 * 4096), FaultPlan::none());
        let n = submit_pages(&mut fab, 100, 3);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        assert_eq!(fab.stats.failovers, 0);
        assert_eq!(fab.stats.disk_fallbacks, 0);
        assert_eq!(fab.engine().stats.duplicate_wcs, 0);
        assert_eq!(fab.engine().queued_ios(), 0);
        assert_eq!(fab.engine().regulator().in_flight(), 0);
    }

    #[test]
    fn same_seed_same_schedule() {
        let run = |seed: u64| {
            let plan = FaultPlan::none()
                .with_errors(0.2)
                .with_reordering(0.3, 20_000)
                .with_duplicates(0.2, 5_000)
                .node_down(1, 40_000)
                .node_up(1, 120_000);
            let mut fab = ChaosFabric::new(seed, 3, 2, 2, Some(24 * 4096), plan);
            submit_pages(&mut fab, 120, 2);
            let mut retired = fab.run_to_idle(STEPS).expect("quiescent");
            retired.sort_by_key(|r| r.id);
            (retired, fab.stats.clone(), fab.now())
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a.0, b.0, "retired set + flags identical");
        assert_eq!(a.1, b.1, "fault schedule identical");
        assert_eq!(a.2, b.2, "virtual clock identical");
        let c = run(43);
        assert_ne!(
            (a.1, a.2),
            (c.1, c.2),
            "a different seed must produce a different schedule"
        );
    }

    #[test]
    fn all_errors_exhaust_replicas_into_disk_fallback() {
        let mut fab = ChaosFabric::new(11, 2, 1, 2, None, FaultPlan::none().with_errors(1.0));
        let n = submit_pages(&mut fab, 40, 2);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n, "every io still retires");
        assert!(retired.iter().all(|r| r.disk_fallback));
        assert_eq!(fab.engine().regulator().in_flight(), 0);
    }

    #[test]
    fn duplicates_are_absorbed_by_the_wr_ledger() {
        let plan = FaultPlan::none().with_duplicates(1.0, 10_000);
        let mut fab = ChaosFabric::new(13, 2, 2, 2, Some(32 * 4096), plan);
        let n = submit_pages(&mut fab, 80, 4);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n, "exactly-once despite dups");
        assert!(fab.stats.duplicates_delivered > 0);
        assert_eq!(
            fab.engine().stats.duplicate_wcs,
            fab.stats.duplicates_delivered,
            "every duplicate was dropped at the ledger"
        );
    }

    #[test]
    fn stalled_qp_delays_but_does_not_lose_completions() {
        // one node, one QP: everything rides the stalled channel
        let plan = FaultPlan::none().stall(0, 0, 200_000);
        let mut fab = ChaosFabric::new(17, 1, 1, 1, Some(8 * 4096), plan);
        let n = submit_pages(&mut fab, 30, 0);
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len() as u64, n);
        assert!(fab.stats.stalled_wcs > 0, "the stall actually bit");
        assert!(fab.now() >= 200_000, "nothing completed in the stall");
    }

    #[test]
    fn node_death_mid_run_drives_failover_not_loss() {
        // all addresses in stripe 0 -> primary node 0, replica node 1
        let plan = FaultPlan::none().node_down(0, 4_000);
        let mut fab = ChaosFabric::new(19, 2, 1, 2, None, plan);
        for i in 0..32u64 {
            fab.submit(i, Dir::Read, (i % 8) * 4096, 4096);
        }
        let retired = fab.run_to_idle(STEPS).expect("quiescent");
        assert_eq!(retired.len(), 32);
        assert!(
            retired.iter().all(|r| !r.disk_fallback),
            "replica 1 survived: no disk fallback"
        );
        assert!(fab.stats.failovers > 0, "reads were in flight to node 0");
    }
}
