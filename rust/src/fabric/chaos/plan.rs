//! [`FaultPlan`] — the declarative fault schedule a [`super::ChaosFabric`]
//! executes against the `IoEngine`.
//!
//! Every fault class maps to a misbehavior a real RDMA deployment exhibits
//! (RDMAvisor's argument: shared NICs serve degraded, contended QPs — a
//! pristine fabric is the exception, not the rule):
//!
//! * **completion errors** — flush errors / retry-exceeded WCs,
//! * **reordering** — WCs of independent WRs overtaking each other in a CQ,
//! * **duplicate / late completions** — a CQ replaying an entry after the
//!   WR already retired,
//! * **per-QP stalls** — a QP whose context fell out of the NIC cache
//!   ("cache thrash") delivering nothing for a stretch of time,
//! * **node death / revival** — a memory donor disappearing mid-run and
//!   possibly coming back (with whatever data it held when it died),
//! * **partial partitions** — a window in which every WR to one node
//!   errors while the node stays up, silently diverging that replica.
//!
//! Rates are probabilities evaluated against the fabric's seeded PRNG, so
//! a `(seed, FaultPlan)` pair names one exact adversarial schedule.

use crate::fabric::{NodeId, QpId};
use crate::util::rng::Pcg32;

/// The member nodes of rack `rack` under contiguous placement: rack `r`
/// holds nodes `r * nodes_per_rack ..` up to the next rack (the last
/// rack may be short). The rack combinators ([`FaultPlan::rack_down`],
/// [`FaultPlan::rack_up`], [`FaultPlan::rack_partition`]) take any node
/// slice, but this is the topology the scale scenarios assume.
pub fn rack_members(rack: usize, nodes: usize, nodes_per_rack: usize) -> Vec<NodeId> {
    assert!(nodes_per_rack > 0, "a rack holds at least one node");
    let first = rack * nodes_per_rack;
    let end = (first + nodes_per_rack).min(nodes);
    assert!(first < nodes, "rack {rack} is beyond the cluster");
    (first..end).collect()
}

/// A window of virtual time during which one QP delivers no completions;
/// WCs that would land inside the window slip to its end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QpStall {
    pub qp: QpId,
    pub from_ns: u64,
    pub until_ns: u64,
}

/// A node liveness transition at a chosen virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeEvent {
    pub at_ns: u64,
    pub node: NodeId,
    pub up: bool,
}

/// A partial partition: during the window, every WR to `node` completes
/// in error *without* the node being marked dead — placement keeps
/// routing to it, exactly like a client that lost its path to one donor
/// while the donor itself stays up. Replica writes that fail this way
/// leave the node diverged from its peers, which is what the engine's
/// demotion + resync path exists to repair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    pub node: NodeId,
    pub from_ns: u64,
    pub until_ns: u64,
}

/// A wedged QP: during the window, every WC the QP would deliver is
/// silently dropped — not delayed like a [`QpStall`], *gone*, the way a
/// QP whose send queue wedged after a transport error never completes
/// its posted WRs. Only the engine's completion deadlines can recover
/// the window bytes and requests such a QP swallows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QpWedge {
    pub qp: QpId,
    pub from_ns: u64,
    pub until_ns: u64,
}

/// A connection blackout on the coordination plane: during the window,
/// inter-engine gossip exchanges are dropped (the socket between peers
/// died and is reconnecting). Engines keep serving I/O; convergence must
/// resume once the window closes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConnDrop {
    pub from_ns: u64,
    pub until_ns: u64,
}

/// A latency storm: a window of virtual time during which every WC
/// (cluster-wide) picks up `extra_ns` of delivery delay — congestion on
/// the shared NIC/fabric rather than one stalled QP. Storms stress the
/// admission window: completions slow down, the window stays full, and
/// the in-flight bound must hold throughout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyStorm {
    pub from_ns: u64,
    pub until_ns: u64,
    pub extra_ns: u64,
}

/// Admission-policy churn: at `at_ns`, the engine's admission window is
/// swapped to `window_bytes` (`None` = unlimited) mid-run, with in-flight
/// bytes carried over. A shrink below the current in-flight level must
/// block without stranding capacity; a grow must admit the backlog — the
/// `admission_churn_no_leak` scenario asserts both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionChurn {
    pub at_ns: u64,
    pub window_bytes: Option<u64>,
}

/// The fault schedule. Build with [`FaultPlan::none`] plus the `with_*` /
/// `stall` / `node_down` / `node_up` combinators, or draw a random mix
/// from a seed stream with [`FaultPlan::randomized`].
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Probability a posted WR completes with `WcStatus::Error`.
    pub error_rate: f64,
    /// Probability a WC gets an extra delivery delay so later-posted WRs
    /// overtake it in the CQ.
    pub reorder_rate: f64,
    /// Maximum extra delay of a reordered WC.
    pub reorder_jitter_ns: u64,
    /// Probability a WC is delivered a second time (duplicate).
    pub duplicate_rate: f64,
    /// How long after the original the duplicate arrives.
    pub duplicate_lag_ns: u64,
    /// Per-QP delivery stalls ("NIC cache thrash").
    pub stalls: Vec<QpStall>,
    /// Node death / revival schedule.
    pub node_events: Vec<NodeEvent>,
    /// Partial partitions (per-node error windows without death).
    pub partitions: Vec<Partition>,
    /// Cluster-wide latency storms (extra WC delay windows).
    pub storms: Vec<LatencyStorm>,
    /// Mid-run admission-window swaps.
    pub churns: Vec<AdmissionChurn>,
    /// Probability that a WR whose span has never been touched before
    /// pays a synchronous registration stall (the pinning-free memory
    /// path's lazy-registration miss landing on the critical path).
    pub reg_stall_rate: f64,
    /// Extra delivery delay of a registration-stalled WR.
    pub reg_stall_ns: u64,
    /// Probability a WR's completion is *never* delivered (lost WC).
    /// Plans with lost WCs require an engine with completion deadlines —
    /// nothing else can ever retire the swallowed request.
    pub lost_rate: f64,
    /// Per-QP wedge windows (every WC in the window is dropped).
    pub wedges: Vec<QpWedge>,
    /// Coordination-plane connection blackouts (gossip exchanges dropped).
    pub conn_drops: Vec<ConnDrop>,
}

impl FaultPlan {
    /// The empty plan: a perfectly behaved fabric (the control run every
    /// scenario is implicitly compared against).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_errors(mut self, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.error_rate = rate;
        self
    }

    pub fn with_reordering(mut self, rate: f64, jitter_ns: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.reorder_rate = rate;
        self.reorder_jitter_ns = jitter_ns;
        self
    }

    pub fn with_duplicates(mut self, rate: f64, lag_ns: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.duplicate_rate = rate;
        self.duplicate_lag_ns = lag_ns;
        self
    }

    pub fn stall(mut self, qp: QpId, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty stall window");
        self.stalls.push(QpStall {
            qp,
            from_ns,
            until_ns,
        });
        self
    }

    pub fn node_down(mut self, node: NodeId, at_ns: u64) -> Self {
        self.node_events.push(NodeEvent {
            at_ns,
            node,
            up: false,
        });
        self
    }

    /// Revive a node at a virtual time. What happens next depends on the
    /// engine: with resync disabled the node rejoins placement
    /// immediately and — since the fabric now carries a payload model —
    /// any stale read it serves for blocks written during its downtime
    /// is *detected and counted* (`stale_reads`). With resync enabled
    /// the node re-enters in `Resyncing` state, is excluded from routing
    /// until the engine has replayed its missed writes from an alive
    /// peer, and only then serves reads again.
    pub fn node_up(mut self, node: NodeId, at_ns: u64) -> Self {
        self.node_events.push(NodeEvent {
            at_ns,
            node,
            up: true,
        });
        self
    }

    /// A partial partition window: WRs to `node` complete in error while
    /// the node stays nominally alive (see [`Partition`]).
    pub fn partition(mut self, node: NodeId, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty partition window");
        self.partitions.push(Partition {
            node,
            from_ns,
            until_ns,
        });
        self
    }

    /// Is `node` partitioned from the client at virtual time `at_ns`?
    pub fn partitioned(&self, node: NodeId, at_ns: u64) -> bool {
        self.partitions
            .iter()
            .any(|p| p.node == node && (p.from_ns..p.until_ns).contains(&at_ns))
    }

    /// A cluster-wide latency storm window (see [`LatencyStorm`]).
    pub fn latency_storm(mut self, from_ns: u64, until_ns: u64, extra_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty storm window");
        assert!(extra_ns > 0, "storm without extra latency");
        self.storms.push(LatencyStorm {
            from_ns,
            until_ns,
            extra_ns,
        });
        self
    }

    /// Swap the admission window to `window_bytes` at virtual time
    /// `at_ns` (see [`AdmissionChurn`]).
    pub fn admission_window(mut self, at_ns: u64, window_bytes: Option<u64>) -> Self {
        self.churns.push(AdmissionChurn {
            at_ns,
            window_bytes,
        });
        self
    }

    /// Registration stalls: a WR that first-touches an unregistered MR
    /// span pays the lazy-registration latency with probability `rate`
    /// before it can post — the cost the dynamic MR cache moves off the
    /// hot path only for *resident* spans. Re-touches of a span the run
    /// already registered never stall (the fabric tracks first touches),
    /// which is exactly the cache's contract; the scenario runner's
    /// admission-window invariant must hold through the stalls.
    pub fn with_reg_stalls(mut self, rate: f64, stall_ns: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        assert!(stall_ns > 0, "registration stall without latency");
        self.reg_stall_rate = rate;
        self.reg_stall_ns = stall_ns;
        self
    }

    /// Correlated rack loss: every node in `members` dies in a tight
    /// burst starting at `at_ns` (one virtual ns apart, in node order,
    /// modeling a ToR switch or PDU failure taking the whole rack down
    /// at once rather than independent node deaths). Expands into plain
    /// [`NodeEvent`]s, so replay, quiescence checks, and the scenario
    /// runner see nothing new — the correlation *is* the schedule.
    pub fn rack_down(mut self, members: &[NodeId], at_ns: u64) -> Self {
        assert!(!members.is_empty(), "rack_down with no members");
        for (i, &node) in members.iter().enumerate() {
            self = self.node_down(node, at_ns + i as u64);
        }
        self
    }

    /// Correlated rack revival: every node in `members` comes back in a
    /// tight burst starting at `at_ns` — the power-restored moment that
    /// triggers a **resync storm** (with resync enabled, every revived
    /// replica re-enters `Resyncing` and the engine repairs them all
    /// concurrently through the normal admission window, which must stay
    /// bounded throughout).
    pub fn rack_up(mut self, members: &[NodeId], at_ns: u64) -> Self {
        assert!(!members.is_empty(), "rack_up with no members");
        for (i, &node) in members.iter().enumerate() {
            self = self.node_up(node, at_ns + i as u64);
        }
        self
    }

    /// Rack-wide partial partition: one window during which every WR to
    /// any node in `members` errors while the nodes stay nominally up —
    /// the client losing its path through one ToR uplink. Expands into
    /// per-node [`Partition`]s sharing the window.
    pub fn rack_partition(mut self, members: &[NodeId], from_ns: u64, until_ns: u64) -> Self {
        assert!(!members.is_empty(), "rack_partition with no members");
        for &node in members {
            self = self.partition(node, from_ns, until_ns);
        }
        self
    }

    /// Extra delivery delay a WC scheduled at `at_ns` picks up from
    /// storms (the largest covering window wins).
    pub fn storm_extra(&self, at_ns: u64) -> u64 {
        self.storms
            .iter()
            .filter(|s| (s.from_ns..s.until_ns).contains(&at_ns))
            .map(|s| s.extra_ns)
            .max()
            .unwrap_or(0)
    }

    /// Lost completions: a posted WR whose WC is swallowed with
    /// probability `rate` — never errored, never delayed, just gone.
    /// The engine's WR deadlines are the only recovery path, so
    /// [`FaultPlan::needs_deadlines`] turns true.
    pub fn with_lost_wcs(mut self, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.lost_rate = rate;
        self
    }

    /// A wedge window: `qp` drops (rather than delays) every WC it
    /// would deliver in `[from_ns, until_ns)` — see [`QpWedge`].
    pub fn wedge(mut self, qp: QpId, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty wedge window");
        self.wedges.push(QpWedge {
            qp,
            from_ns,
            until_ns,
        });
        self
    }

    /// Is (`qp`, `at_ns`) inside a wedge window?
    pub fn wedged(&self, qp: QpId, at_ns: u64) -> bool {
        self.wedges
            .iter()
            .any(|w| w.qp == qp && (w.from_ns..w.until_ns).contains(&at_ns))
    }

    /// A coordination-plane blackout window: gossip exchanges scheduled
    /// inside it are dropped — see [`ConnDrop`].
    pub fn conn_drop(mut self, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty connection-drop window");
        self.conn_drops.push(ConnDrop { from_ns, until_ns });
        self
    }

    /// Is the coordination plane blacked out at virtual time `at_ns`?
    pub fn conn_dropped(&self, at_ns: u64) -> bool {
        self.conn_drops
            .iter()
            .any(|d| (d.from_ns..d.until_ns).contains(&at_ns))
    }

    /// Does this plan swallow completions? If so, the engine under test
    /// must run with completion deadlines or the run can never quiesce.
    pub fn needs_deadlines(&self) -> bool {
        self.lost_rate > 0.0 || !self.wedges.is_empty()
    }

    /// Does this plan inject anything at all?
    pub fn is_quiet(&self) -> bool {
        self.error_rate == 0.0
            && self.reorder_rate == 0.0
            && self.duplicate_rate == 0.0
            && self.stalls.is_empty()
            && self.node_events.is_empty()
            && self.partitions.is_empty()
            && self.storms.is_empty()
            && self.churns.is_empty()
            && self.reg_stall_rate == 0.0
            && self.lost_rate == 0.0
            && self.wedges.is_empty()
            && self.conn_drops.is_empty()
    }

    /// The end of the stall window covering (`qp`, `at_ns`), if any.
    pub fn stall_release(&self, qp: QpId, at_ns: u64) -> Option<u64> {
        self.stalls
            .iter()
            .filter(|s| s.qp == qp && (s.from_ns..s.until_ns).contains(&at_ns))
            .map(|s| s.until_ns)
            .max()
    }

    /// Draw a random fault mix for a cluster of `nodes` × `qps_per_node`
    /// QPs from the given seed stream. Every knob is exercised with
    /// moderate probability so a sweep over seeds covers single faults,
    /// fault combinations, and the quiet plan.
    pub fn randomized(rng: &mut Pcg32, nodes: usize, qps_per_node: usize) -> Self {
        Self::randomized_profile(rng, nodes, qps_per_node, false)
    }

    /// [`FaultPlan::randomized`] with an optional **election-heavy**
    /// bias: more node churn, *overlapping* partition windows on
    /// different nodes (the mutual-divergence topology the epoch-vector
    /// election exists for), and mid-run admission churn + latency
    /// storms. The nightly `chaos-extended` sweep runs this profile.
    pub fn randomized_profile(
        rng: &mut Pcg32,
        nodes: usize,
        qps_per_node: usize,
        heavy: bool,
    ) -> Self {
        let mut plan = FaultPlan::none();
        if rng.gen_bool(0.55) {
            plan.error_rate = rng.gen_f64() * 0.35;
        }
        if rng.gen_bool(0.55) {
            plan.reorder_rate = rng.gen_f64() * 0.5;
            plan.reorder_jitter_ns = 1 + rng.gen_below(60_000);
        }
        if rng.gen_bool(0.5) {
            plan.duplicate_rate = rng.gen_f64() * 0.3;
            plan.duplicate_lag_ns = 1 + rng.gen_below(25_000);
        }
        if rng.gen_bool(0.45) {
            let total_qps = (nodes * qps_per_node) as u64;
            for _ in 0..=rng.gen_below(3) {
                let qp = rng.gen_below(total_qps) as usize;
                let from = rng.gen_below(400_000);
                plan = plan.stall(qp, from, from + 1 + rng.gen_below(250_000));
            }
        }
        if rng.gen_bool(if heavy { 0.7 } else { 0.45 }) {
            let deaths = if heavy {
                1 + rng.gen_below(3)
            } else {
                rng.gen_below(2)
            };
            for _ in 0..=deaths {
                let node = rng.gen_below(nodes as u64) as usize;
                let at = rng.gen_below(300_000);
                plan = plan.node_down(node, at);
                // revive-with-stale-data: with the payload model in the
                // fabric, a revival after missed writes is only safe if
                // the resync protocol gates it — sweep it aggressively
                if rng.gen_bool(0.7) {
                    plan = plan.node_up(node, at + 1 + rng.gen_below(200_000));
                }
            }
        }
        if rng.gen_bool(if heavy { 0.8 } else { 0.35 }) {
            let node = rng.gen_below(nodes as u64) as usize;
            let from = rng.gen_below(250_000);
            let until = from + 1 + rng.gen_below(150_000);
            plan = plan.partition(node, from, until);
            // overlapping-divergence mix: a second partition whose window
            // overlaps the first on a *different* node diverges two
            // replicas on overlapping write ranges — only the donor
            // election can drain that topology without parking
            if rng.gen_bool(if heavy { 0.75 } else { 0.4 }) && nodes > 1 {
                let other = (node + 1 + rng.gen_below(nodes as u64 - 1) as usize) % nodes;
                let from2 = from + rng.gen_below((until - from).max(1));
                plan = plan.partition(other, from2, from2 + 1 + rng.gen_below(150_000));
            }
        }
        if rng.gen_bool(if heavy { 0.5 } else { 0.3 }) {
            let from = rng.gen_below(300_000);
            let until = from + 1 + rng.gen_below(200_000);
            plan = plan.latency_storm(from, until, 1 + rng.gen_below(80_000));
        }
        if rng.gen_bool(if heavy { 0.5 } else { 0.25 }) {
            // churn between bounded windows only (≥ the workload's max
            // I/O size, so the runner's window invariant stays checkable)
            for _ in 0..=rng.gen_below(2) {
                let at = rng.gen_below(400_000);
                let w = (4 + rng.gen_below(28)) * 4096;
                plan = plan.admission_window(at, Some(w));
            }
        }
        if rng.gen_bool(if heavy { 0.5 } else { 0.35 }) {
            // lazy-registration stalls on first-touched spans (drawn
            // last so older seeds keep their exact earlier fault mix)
            plan = plan.with_reg_stalls(rng.gen_f64() * 0.6, 1 + rng.gen_below(50_000));
        }
        // recovery faults — appended after every older draw so pinned
        // seeds keep their exact pre-recovery fault mix
        if rng.gen_bool(if heavy { 0.45 } else { 0.3 }) {
            plan.lost_rate = 0.01 + rng.gen_f64() * 0.04;
        }
        if rng.gen_bool(if heavy { 0.4 } else { 0.25 }) {
            let total_qps = (nodes * qps_per_node) as u64;
            let qp = rng.gen_below(total_qps) as usize;
            let from = rng.gen_below(300_000);
            plan = plan.wedge(qp, from, from + 1 + rng.gen_below(200_000));
        }
        if rng.gen_bool(0.2) {
            let from = rng.gen_below(300_000);
            plan = plan.conn_drop(from, from + 1 + rng.gen_below(150_000));
        }
        plan
    }

    /// Draw a **rack-correlated** fault mix for a multi-hundred-node
    /// cluster under contiguous `nodes_per_rack` placement: light
    /// single-WR noise, plus the faults only scale exhibits — a whole
    /// rack dying in a burst (usually revived later, triggering a
    /// resync storm), a rack-wide partition, cluster-wide storms and
    /// admission churn. Its own seed-stream consumer: the existing
    /// `Standard`/`ElectionHeavy`/`Qos` profiles never draw from it, so
    /// their pinned seeds are untouched.
    pub fn randomized_rack_profile(
        rng: &mut Pcg32,
        nodes: usize,
        qps_per_node: usize,
        nodes_per_rack: usize,
    ) -> Self {
        assert!(nodes_per_rack > 0, "a rack holds at least one node");
        let racks = nodes.div_ceil(nodes_per_rack);
        let mut plan = FaultPlan::none();
        // background noise: kept light so rack faults dominate the run
        if rng.gen_bool(0.5) {
            plan.error_rate = rng.gen_f64() * 0.15;
        }
        if rng.gen_bool(0.5) {
            plan.reorder_rate = rng.gen_f64() * 0.4;
            plan.reorder_jitter_ns = 1 + rng.gen_below(40_000);
        }
        if rng.gen_bool(0.4) {
            plan.duplicate_rate = rng.gen_f64() * 0.2;
            plan.duplicate_lag_ns = 1 + rng.gen_below(20_000);
        }
        if rng.gen_bool(0.3) {
            let total_qps = (nodes * qps_per_node) as u64;
            let qp = rng.gen_below(total_qps) as usize;
            let from = rng.gen_below(400_000);
            plan = plan.stall(qp, from, from + 1 + rng.gen_below(200_000));
        }
        // the headline fault: correlated rack loss, usually revived —
        // the revival burst is the resync storm the runner must bound
        if rng.gen_bool(0.75) {
            let rack = rng.gen_below(racks as u64) as usize;
            let members = rack_members(rack, nodes, nodes_per_rack);
            let at = rng.gen_below(250_000);
            plan = plan.rack_down(&members, at);
            if rng.gen_bool(0.8) {
                plan = plan.rack_up(&members, at + 1 + rng.gen_below(200_000));
            }
        }
        // ToR uplink loss: a rack-wide partial partition
        if rng.gen_bool(0.5) {
            let rack = rng.gen_below(racks as u64) as usize;
            let members = rack_members(rack, nodes, nodes_per_rack);
            let from = rng.gen_below(250_000);
            plan = plan.rack_partition(&members, from, from + 1 + rng.gen_below(150_000));
        }
        if rng.gen_bool(0.5) {
            let from = rng.gen_below(300_000);
            let until = from + 1 + rng.gen_below(200_000);
            plan = plan.latency_storm(from, until, 1 + rng.gen_below(60_000));
        }
        if rng.gen_bool(0.4) {
            for _ in 0..=rng.gen_below(2) {
                let at = rng.gen_below(400_000);
                let w = (4 + rng.gen_below(60)) * 4096;
                plan = plan.admission_window(at, Some(w));
            }
        }
        if rng.gen_bool(0.35) {
            plan = plan.with_reg_stalls(rng.gen_f64() * 0.5, 1 + rng.gen_below(40_000));
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let p = FaultPlan::none()
            .with_errors(0.1)
            .with_reordering(0.2, 1000)
            .with_duplicates(0.3, 500)
            .stall(2, 10, 20)
            .node_down(0, 5)
            .node_up(0, 15);
        assert_eq!(p.error_rate, 0.1);
        assert_eq!(p.stalls.len(), 1);
        assert_eq!(p.node_events.len(), 2);
        assert!(!p.is_quiet());
        assert!(FaultPlan::none().is_quiet());
    }

    #[test]
    fn stall_release_picks_covering_window() {
        let p = FaultPlan::none().stall(1, 100, 200).stall(1, 150, 300);
        assert_eq!(p.stall_release(1, 160), Some(300), "longest window wins");
        assert_eq!(p.stall_release(1, 99), None);
        assert_eq!(p.stall_release(1, 200), None, "window end is exclusive");
        assert_eq!(p.stall_release(0, 160), None, "other QPs unaffected");
    }

    #[test]
    fn randomized_is_deterministic_per_seed() {
        let a = FaultPlan::randomized(&mut Pcg32::new(9), 3, 2);
        let b = FaultPlan::randomized(&mut Pcg32::new(9), 3, 2);
        assert_eq!(a.error_rate, b.error_rate);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.node_events, b.node_events);
    }

    #[test]
    #[should_panic(expected = "empty stall window")]
    fn stall_rejects_empty_window() {
        let _ = FaultPlan::none().stall(0, 50, 50);
    }

    #[test]
    fn partition_windows_cover_their_node_only() {
        let p = FaultPlan::none().partition(1, 100, 200);
        assert!(!p.is_quiet());
        assert!(p.partitioned(1, 100));
        assert!(p.partitioned(1, 199));
        assert!(!p.partitioned(1, 200), "window end is exclusive");
        assert!(!p.partitioned(1, 99));
        assert!(!p.partitioned(0, 150), "other nodes unaffected");
    }

    #[test]
    #[should_panic(expected = "empty partition window")]
    fn partition_rejects_empty_window() {
        let _ = FaultPlan::none().partition(0, 50, 50);
    }

    #[test]
    fn storm_extra_covers_window_and_max_wins() {
        let p = FaultPlan::none()
            .latency_storm(100, 200, 5_000)
            .latency_storm(150, 300, 9_000);
        assert!(!p.is_quiet());
        assert_eq!(p.storm_extra(99), 0);
        assert_eq!(p.storm_extra(100), 5_000);
        assert_eq!(p.storm_extra(160), 9_000, "largest covering storm wins");
        assert_eq!(p.storm_extra(299), 9_000);
        assert_eq!(p.storm_extra(300), 0, "window end is exclusive");
    }

    #[test]
    #[should_panic(expected = "empty storm window")]
    fn storm_rejects_empty_window() {
        let _ = FaultPlan::none().latency_storm(10, 10, 100);
    }

    #[test]
    fn admission_churn_composes_and_breaks_quiet() {
        let p = FaultPlan::none()
            .admission_window(1_000, Some(8 * 4096))
            .admission_window(5_000, None);
        assert_eq!(p.churns.len(), 2);
        assert_eq!(p.churns[1].window_bytes, None);
        assert!(!p.is_quiet());
    }

    #[test]
    fn reg_stalls_compose_and_break_quiet() {
        let p = FaultPlan::none().with_reg_stalls(0.25, 30_000);
        assert_eq!(p.reg_stall_rate, 0.25);
        assert_eq!(p.reg_stall_ns, 30_000);
        assert!(!p.is_quiet());
    }

    #[test]
    #[should_panic(expected = "registration stall without latency")]
    fn reg_stall_rejects_zero_latency() {
        let _ = FaultPlan::none().with_reg_stalls(0.5, 0);
    }

    #[test]
    fn rack_members_cover_the_cluster_without_overlap() {
        // 10 nodes, 4 per rack: racks are {0..4}, {4..8}, {8..10}
        assert_eq!(rack_members(0, 10, 4), vec![0, 1, 2, 3]);
        assert_eq!(rack_members(1, 10, 4), vec![4, 5, 6, 7]);
        assert_eq!(rack_members(2, 10, 4), vec![8, 9], "short last rack");
        let mut all: Vec<NodeId> = (0..3).flat_map(|r| rack_members(r, 10, 4)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "beyond the cluster")]
    fn rack_members_rejects_out_of_range_rack() {
        let _ = rack_members(3, 10, 4);
    }

    #[test]
    fn rack_combinators_expand_into_plain_events() {
        let members = rack_members(1, 12, 4); // nodes 4..8
        let p = FaultPlan::none()
            .rack_down(&members, 10_000)
            .rack_up(&members, 50_000)
            .rack_partition(&members, 60_000, 90_000);
        assert_eq!(p.node_events.len(), 8, "4 deaths + 4 revivals");
        // deaths burst one ns apart, in node order
        assert_eq!(
            p.node_events[..4]
                .iter()
                .map(|e| (e.node, e.at_ns, e.up))
                .collect::<Vec<_>>(),
            vec![(4, 10_000, false), (5, 10_001, false), (6, 10_002, false), (7, 10_003, false)]
        );
        assert!(p.node_events[4..].iter().all(|e| e.up));
        assert_eq!(p.partitions.len(), 4);
        assert!(p.partitioned(5, 70_000));
        assert!(!p.partitioned(3, 70_000), "other racks unaffected");
        assert!(!p.is_quiet());
    }

    #[test]
    fn rack_profile_is_deterministic_and_rack_shaped() {
        let a = FaultPlan::randomized_rack_profile(&mut Pcg32::new(5), 256, 1, 16);
        let b = FaultPlan::randomized_rack_profile(&mut Pcg32::new(5), 256, 1, 16);
        assert_eq!(a.node_events, b.node_events);
        assert_eq!(a.partitions, b.partitions);
        assert_eq!(a.storms, b.storms);
        assert_eq!(a.churns, b.churns);
        // deaths come in whole-rack bursts: group by at-window and check
        // each burst is one contiguous rack
        let deaths: Vec<&NodeEvent> = a.node_events.iter().filter(|e| !e.up).collect();
        if let Some(first) = deaths.first() {
            let rack = first.node / 16;
            assert!(
                deaths.iter().all(|e| e.node / 16 == rack),
                "one draw kills exactly one rack: {deaths:?}"
            );
            assert_eq!(deaths.len(), 16, "the whole rack dies");
        }
    }

    #[test]
    fn recovery_faults_compose_and_break_quiet() {
        let p = FaultPlan::none()
            .with_lost_wcs(0.05)
            .wedge(1, 100, 200)
            .conn_drop(50, 150);
        assert_eq!(p.lost_rate, 0.05);
        assert!(p.wedged(1, 100));
        assert!(p.wedged(1, 199));
        assert!(!p.wedged(1, 200), "window end is exclusive");
        assert!(!p.wedged(0, 150), "other QPs unaffected");
        assert!(p.conn_dropped(50));
        assert!(!p.conn_dropped(150), "window end is exclusive");
        assert!(p.needs_deadlines());
        assert!(!p.is_quiet());
        assert!(!FaultPlan::none().conn_drop(1, 2).needs_deadlines());
        assert!(!FaultPlan::none().conn_drop(1, 2).is_quiet());
    }

    #[test]
    #[should_panic(expected = "empty wedge window")]
    fn wedge_rejects_empty_window() {
        let _ = FaultPlan::none().wedge(0, 50, 50);
    }

    #[test]
    fn heavy_profile_is_deterministic_and_richer() {
        let a = FaultPlan::randomized_profile(&mut Pcg32::new(77), 4, 2, true);
        let b = FaultPlan::randomized_profile(&mut Pcg32::new(77), 4, 2, true);
        assert_eq!(a.partitions, b.partitions);
        assert_eq!(a.storms, b.storms);
        assert_eq!(a.churns, b.churns);
        assert_eq!(a.node_events, b.node_events);
    }
}
