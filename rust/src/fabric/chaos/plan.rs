//! [`FaultPlan`] — the declarative fault schedule a [`super::ChaosFabric`]
//! executes against the `IoEngine`.
//!
//! Every fault class maps to a misbehavior a real RDMA deployment exhibits
//! (RDMAvisor's argument: shared NICs serve degraded, contended QPs — a
//! pristine fabric is the exception, not the rule):
//!
//! * **completion errors** — flush errors / retry-exceeded WCs,
//! * **reordering** — WCs of independent WRs overtaking each other in a CQ,
//! * **duplicate / late completions** — a CQ replaying an entry after the
//!   WR already retired,
//! * **per-QP stalls** — a QP whose context fell out of the NIC cache
//!   ("cache thrash") delivering nothing for a stretch of time,
//! * **node death / revival** — a memory donor disappearing mid-run and
//!   possibly coming back (with whatever data it held when it died),
//! * **partial partitions** — a window in which every WR to one node
//!   errors while the node stays up, silently diverging that replica.
//!
//! Rates are probabilities evaluated against the fabric's seeded PRNG, so
//! a `(seed, FaultPlan)` pair names one exact adversarial schedule.

use crate::fabric::{NodeId, QpId};
use crate::util::rng::Pcg32;

/// A window of virtual time during which one QP delivers no completions;
/// WCs that would land inside the window slip to its end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QpStall {
    pub qp: QpId,
    pub from_ns: u64,
    pub until_ns: u64,
}

/// A node liveness transition at a chosen virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeEvent {
    pub at_ns: u64,
    pub node: NodeId,
    pub up: bool,
}

/// A partial partition: during the window, every WR to `node` completes
/// in error *without* the node being marked dead — placement keeps
/// routing to it, exactly like a client that lost its path to one donor
/// while the donor itself stays up. Replica writes that fail this way
/// leave the node diverged from its peers, which is what the engine's
/// demotion + resync path exists to repair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    pub node: NodeId,
    pub from_ns: u64,
    pub until_ns: u64,
}

/// The fault schedule. Build with [`FaultPlan::none`] plus the `with_*` /
/// `stall` / `node_down` / `node_up` combinators, or draw a random mix
/// from a seed stream with [`FaultPlan::randomized`].
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Probability a posted WR completes with `WcStatus::Error`.
    pub error_rate: f64,
    /// Probability a WC gets an extra delivery delay so later-posted WRs
    /// overtake it in the CQ.
    pub reorder_rate: f64,
    /// Maximum extra delay of a reordered WC.
    pub reorder_jitter_ns: u64,
    /// Probability a WC is delivered a second time (duplicate).
    pub duplicate_rate: f64,
    /// How long after the original the duplicate arrives.
    pub duplicate_lag_ns: u64,
    /// Per-QP delivery stalls ("NIC cache thrash").
    pub stalls: Vec<QpStall>,
    /// Node death / revival schedule.
    pub node_events: Vec<NodeEvent>,
    /// Partial partitions (per-node error windows without death).
    pub partitions: Vec<Partition>,
}

impl FaultPlan {
    /// The empty plan: a perfectly behaved fabric (the control run every
    /// scenario is implicitly compared against).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_errors(mut self, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.error_rate = rate;
        self
    }

    pub fn with_reordering(mut self, rate: f64, jitter_ns: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.reorder_rate = rate;
        self.reorder_jitter_ns = jitter_ns;
        self
    }

    pub fn with_duplicates(mut self, rate: f64, lag_ns: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate));
        self.duplicate_rate = rate;
        self.duplicate_lag_ns = lag_ns;
        self
    }

    pub fn stall(mut self, qp: QpId, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty stall window");
        self.stalls.push(QpStall {
            qp,
            from_ns,
            until_ns,
        });
        self
    }

    pub fn node_down(mut self, node: NodeId, at_ns: u64) -> Self {
        self.node_events.push(NodeEvent {
            at_ns,
            node,
            up: false,
        });
        self
    }

    /// Revive a node at a virtual time. What happens next depends on the
    /// engine: with resync disabled the node rejoins placement
    /// immediately and — since the fabric now carries a payload model —
    /// any stale read it serves for blocks written during its downtime
    /// is *detected and counted* (`stale_reads`). With resync enabled
    /// the node re-enters in `Resyncing` state, is excluded from routing
    /// until the engine has replayed its missed writes from an alive
    /// peer, and only then serves reads again.
    pub fn node_up(mut self, node: NodeId, at_ns: u64) -> Self {
        self.node_events.push(NodeEvent {
            at_ns,
            node,
            up: true,
        });
        self
    }

    /// A partial partition window: WRs to `node` complete in error while
    /// the node stays nominally alive (see [`Partition`]).
    pub fn partition(mut self, node: NodeId, from_ns: u64, until_ns: u64) -> Self {
        assert!(from_ns < until_ns, "empty partition window");
        self.partitions.push(Partition {
            node,
            from_ns,
            until_ns,
        });
        self
    }

    /// Is `node` partitioned from the client at virtual time `at_ns`?
    pub fn partitioned(&self, node: NodeId, at_ns: u64) -> bool {
        self.partitions
            .iter()
            .any(|p| p.node == node && (p.from_ns..p.until_ns).contains(&at_ns))
    }

    /// Does this plan inject anything at all?
    pub fn is_quiet(&self) -> bool {
        self.error_rate == 0.0
            && self.reorder_rate == 0.0
            && self.duplicate_rate == 0.0
            && self.stalls.is_empty()
            && self.node_events.is_empty()
            && self.partitions.is_empty()
    }

    /// The end of the stall window covering (`qp`, `at_ns`), if any.
    pub fn stall_release(&self, qp: QpId, at_ns: u64) -> Option<u64> {
        self.stalls
            .iter()
            .filter(|s| s.qp == qp && (s.from_ns..s.until_ns).contains(&at_ns))
            .map(|s| s.until_ns)
            .max()
    }

    /// Draw a random fault mix for a cluster of `nodes` × `qps_per_node`
    /// QPs from the given seed stream. Every knob is exercised with
    /// moderate probability so a sweep over seeds covers single faults,
    /// fault combinations, and the quiet plan.
    pub fn randomized(rng: &mut Pcg32, nodes: usize, qps_per_node: usize) -> Self {
        let mut plan = FaultPlan::none();
        if rng.gen_bool(0.55) {
            plan.error_rate = rng.gen_f64() * 0.35;
        }
        if rng.gen_bool(0.55) {
            plan.reorder_rate = rng.gen_f64() * 0.5;
            plan.reorder_jitter_ns = 1 + rng.gen_below(60_000);
        }
        if rng.gen_bool(0.5) {
            plan.duplicate_rate = rng.gen_f64() * 0.3;
            plan.duplicate_lag_ns = 1 + rng.gen_below(25_000);
        }
        if rng.gen_bool(0.45) {
            let total_qps = (nodes * qps_per_node) as u64;
            for _ in 0..=rng.gen_below(3) {
                let qp = rng.gen_below(total_qps) as usize;
                let from = rng.gen_below(400_000);
                plan = plan.stall(qp, from, from + 1 + rng.gen_below(250_000));
            }
        }
        if rng.gen_bool(0.45) {
            for _ in 0..=rng.gen_below(2) {
                let node = rng.gen_below(nodes as u64) as usize;
                let at = rng.gen_below(300_000);
                plan = plan.node_down(node, at);
                // revive-with-stale-data: with the payload model in the
                // fabric, a revival after missed writes is only safe if
                // the resync protocol gates it — sweep it aggressively
                if rng.gen_bool(0.7) {
                    plan = plan.node_up(node, at + 1 + rng.gen_below(200_000));
                }
            }
        }
        if rng.gen_bool(0.35) {
            let node = rng.gen_below(nodes as u64) as usize;
            let from = rng.gen_below(250_000);
            plan = plan.partition(node, from, from + 1 + rng.gen_below(150_000));
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let p = FaultPlan::none()
            .with_errors(0.1)
            .with_reordering(0.2, 1000)
            .with_duplicates(0.3, 500)
            .stall(2, 10, 20)
            .node_down(0, 5)
            .node_up(0, 15);
        assert_eq!(p.error_rate, 0.1);
        assert_eq!(p.stalls.len(), 1);
        assert_eq!(p.node_events.len(), 2);
        assert!(!p.is_quiet());
        assert!(FaultPlan::none().is_quiet());
    }

    #[test]
    fn stall_release_picks_covering_window() {
        let p = FaultPlan::none().stall(1, 100, 200).stall(1, 150, 300);
        assert_eq!(p.stall_release(1, 160), Some(300), "longest window wins");
        assert_eq!(p.stall_release(1, 99), None);
        assert_eq!(p.stall_release(1, 200), None, "window end is exclusive");
        assert_eq!(p.stall_release(0, 160), None, "other QPs unaffected");
    }

    #[test]
    fn randomized_is_deterministic_per_seed() {
        let a = FaultPlan::randomized(&mut Pcg32::new(9), 3, 2);
        let b = FaultPlan::randomized(&mut Pcg32::new(9), 3, 2);
        assert_eq!(a.error_rate, b.error_rate);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.node_events, b.node_events);
    }

    #[test]
    #[should_panic(expected = "empty stall window")]
    fn stall_rejects_empty_window() {
        let _ = FaultPlan::none().stall(0, 50, 50);
    }

    #[test]
    fn partition_windows_cover_their_node_only() {
        let p = FaultPlan::none().partition(1, 100, 200);
        assert!(!p.is_quiet());
        assert!(p.partitioned(1, 100));
        assert!(p.partitioned(1, 199));
        assert!(!p.partitioned(1, 200), "window end is exclusive");
        assert!(!p.partitioned(1, 99));
        assert!(!p.partitioned(0, 150), "other nodes unaffected");
    }

    #[test]
    #[should_panic(expected = "empty partition window")]
    fn partition_rejects_empty_window() {
        let _ = FaultPlan::none().partition(0, 50, 50);
    }
}
