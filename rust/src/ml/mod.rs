//! Live ML training over paged remote memory — the end-to-end composition
//! of all three layers: the dataset lives on loopback remote nodes behind
//! the RDMAbox coordinator (L3), minibatches are paged in on demand, and
//! each step executes the AOT-compiled JAX/Pallas graph via PJRT (L2/L1).
//!
//! Used by `examples/ml_train_e2e.rs`; EXPERIMENTS.md records a run.

use std::sync::Arc;

use crate::fabric::loopback::LiveBox;
use crate::paging::cache::{Access, ClockCache};
use crate::util::rng::Pcg32;
#[cfg(feature = "xla")]
use crate::runtime::{lit, Result, Runtime, LOGREG_STEP};

pub const PAGE: usize = 4096;

/// A page-granular tensor store: data striped across loopback nodes,
/// faulted into a bounded local cache through the live coordinator.
pub struct PagedStore {
    lb: Arc<LiveBox>,
    cache: ClockCache,
    /// local frames backing resident pages: page -> frame index
    frames: Vec<Vec<u8>>,
    frame_of: std::collections::HashMap<u64, usize>,
    free_frames: Vec<usize>,
    total_pages: u64,
    pub faults: u64,
    pub hits: u64,
}

impl PagedStore {
    pub fn new(lb: Arc<LiveBox>, total_pages: u64, resident_pages: usize) -> Self {
        Self {
            lb,
            cache: ClockCache::new(resident_pages),
            frames: (0..resident_pages).map(|_| vec![0u8; PAGE]).collect(),
            frame_of: std::collections::HashMap::new(),
            free_frames: (0..resident_pages).rev().collect(),
            total_pages,
            faults: 0,
            hits: 0,
        }
    }

    fn place(&self, page: u64) -> (usize, u64) {
        let nodes = self.lb.nodes() as u64;
        ((page % nodes) as usize, (page / nodes) * PAGE as u64)
    }

    /// Seed remote memory with `data` for `page` (setup path).
    pub fn populate(&mut self, page: u64, data: &[u8]) {
        assert!(page < self.total_pages);
        assert_eq!(data.len(), PAGE);
        let (node, addr) = self.place(page);
        self.lb.write(node, addr, data);
    }

    /// Access a page read-only; faults it in via the coordinator if not
    /// resident. Returns the frame contents.
    pub fn get(&mut self, page: u64) -> &[u8] {
        assert!(page < self.total_pages);
        match self.cache.access(page, false) {
            Access::Hit => {
                self.hits += 1;
            }
            Access::Miss { evicted } => {
                self.faults += 1;
                if let Some((victim, dirty)) = evicted {
                    let fi = self.frame_of.remove(&victim).expect("victim frame");
                    if dirty {
                        let (node, addr) = self.place(victim);
                        let buf = self.frames[fi].clone();
                        self.lb.write(node, addr, &buf);
                    }
                    self.free_frames.push(fi);
                }
                let fi = self.free_frames.pop().expect("free frame");
                let (node, addr) = self.place(page);
                let data = self.lb.read(node, addr, PAGE as u64);
                self.frames[fi].copy_from_slice(&data);
                self.frame_of.insert(page, fi);
            }
        }
        let fi = self.frame_of[&page];
        &self.frames[fi]
    }
}

/// Synthetic logistic-regression dataset with a known separator.
pub struct LogregData {
    pub batch: usize,
    pub features: usize,
    pub rows: usize,
    pub floats_per_page: usize,
}

impl LogregData {
    pub fn new(rows: usize, batch: usize, features: usize) -> Self {
        Self {
            batch,
            features,
            rows,
            floats_per_page: PAGE / 4,
        }
    }

    pub fn pages_per_row(&self) -> usize {
        (self.features * 4).div_ceil(PAGE)
    }

    pub fn total_pages(&self) -> u64 {
        (self.rows * self.pages_per_row()) as u64
    }

    /// Deterministically generate row `i` (features + label) from the true
    /// separator; the same generator seeds remote memory and the oracle.
    pub fn row(&self, i: usize) -> (Vec<f32>, f32) {
        let mut rng = Pcg32::with_stream(0xDA7A, i as u64);
        let mut x = Vec::with_capacity(self.features);
        let mut dot = 0f64;
        for j in 0..self.features {
            let v = rng.gen_normal() as f32;
            // true weights: alternating ±1 on the first 32 features
            if j < 32 {
                dot += v as f64 * if j % 2 == 0 { 1.0 } else { -1.0 };
            }
            x.push(v);
        }
        let y = if dot > 0.0 { 1.0 } else { 0.0 };
        (x, y)
    }
}

/// End-to-end result for the example/EXPERIMENTS.md.
#[derive(Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub wall_ms: u128,
    pub faults: u64,
    pub hits: u64,
    pub bytes_read: u64,
    pub merged_ios: u64,
}

/// Train logistic regression for `steps` minibatch steps with the dataset
/// paged through the live coordinator. Every step gathers its batch rows
/// via `PagedStore::get` (real remote memcpys through the merge queue +
/// admission window) and executes the AOT logreg_step via PJRT.
/// Requires the `xla` feature (PJRT bindings).
#[cfg(feature = "xla")]
pub fn train_paged_logreg(
    rt: &mut Runtime,
    nodes: usize,
    rows: usize,
    batch: usize,
    features: usize,
    resident_frac: f64,
    steps: usize,
    lr: f32,
) -> Result<TrainReport> {
    use crate::coordinator::EngineSpec;
    use crate::fabric::loopback::LoopbackFabric;
    let data = LogregData::new(rows, batch, features);
    let total_pages = data.total_pages();
    let per_node = (total_pages as usize / nodes + 2) * PAGE;
    let fabric = LoopbackFabric::start(nodes, per_node);
    let lb = LiveBox::build(fabric, &EngineSpec::new(nodes).window(Some(7 << 20)));
    let resident = ((total_pages as f64 * resident_frac) as usize).max(8);
    let mut store = PagedStore::new(lb.clone(), total_pages, resident);

    // --- populate remote memory with the dataset (build path) ---
    let ppr = data.pages_per_row();
    for i in 0..rows {
        let (x, y) = data.row(i);
        let mut bytes = Vec::with_capacity(ppr * PAGE);
        for &v in &x {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        // label stored at the end of the row's last page
        bytes.resize(ppr * PAGE - 4, 0);
        bytes.extend_from_slice(&y.to_le_bytes());
        for p in 0..ppr {
            store.populate((i * ppr + p) as u64, &bytes[p * PAGE..(p + 1) * PAGE]);
        }
    }

    // --- training loop: page in each batch, run the PJRT step ---
    let t0 = std::time::Instant::now();
    let mut w = vec![0f32; features];
    let mut losses = Vec::with_capacity(steps);
    let mut rng = Pcg32::new(0x7EA1);
    for _ in 0..steps {
        let mut xbuf = Vec::with_capacity(batch * features);
        let mut ybuf = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = rng.gen_below(rows as u64) as usize;
            let mut row_bytes: Vec<u8> = Vec::with_capacity(ppr * PAGE);
            for p in 0..ppr {
                row_bytes.extend_from_slice(store.get((i * ppr + p) as u64));
            }
            for j in 0..features {
                let o = j * 4;
                xbuf.push(f32::from_le_bytes(
                    row_bytes[o..o + 4].try_into().unwrap(),
                ));
            }
            let lo = ppr * PAGE - 4;
            ybuf.push(f32::from_le_bytes(row_bytes[lo..lo + 4].try_into().unwrap()));
        }
        let out = rt.execute(
            LOGREG_STEP,
            &[
                lit::f32_vec(&w),
                lit::f32_mat(&xbuf, batch, features)?,
                lit::f32_vec(&ybuf),
                lit::f32_scalar(lr)?,
            ],
        )?;
        w = lit::to_f32(&out[0])?;
        losses.push(lit::to_f32(&out[1])?[0]);
    }
    let wall_ms = t0.elapsed().as_millis();
    let s = lb.stats();
    Ok(TrainReport {
        losses,
        steps,
        wall_ms,
        faults: store.faults,
        hits: store.hits,
        bytes_read: s.bytes_read,
        merged_ios: s.merged_ios,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineSpec;
    use crate::fabric::loopback::LoopbackFabric;

    #[test]
    fn paged_store_roundtrips_through_remote_memory() {
        let fabric = LoopbackFabric::start(2, 1 << 20);
        let lb = LiveBox::build(fabric, &EngineSpec::new(2));
        let mut st = PagedStore::new(lb, 16, 4);
        for p in 0..16u64 {
            st.populate(p, &vec![(p % 251) as u8; PAGE]);
        }
        // sweep twice: second sweep re-faults (resident 4 < 16)
        for _ in 0..2 {
            for p in 0..16u64 {
                let b = st.get(p);
                assert_eq!(b[0], (p % 251) as u8);
                assert_eq!(b[PAGE - 1], (p % 251) as u8);
            }
        }
        assert!(st.faults >= 16, "capacity misses force refaults");
    }

    #[test]
    fn hot_page_stays_resident() {
        let fabric = LoopbackFabric::start(1, 1 << 20);
        let lb = LiveBox::build(fabric, &EngineSpec::new(1));
        let mut st = PagedStore::new(lb, 8, 4);
        for p in 0..8u64 {
            st.populate(p, &[1u8; PAGE]);
        }
        st.get(0);
        let f0 = st.faults;
        for _ in 0..10 {
            st.get(0);
        }
        assert_eq!(st.faults, f0, "repeated access hits");
        assert!(st.hits >= 10);
    }

    #[test]
    fn dataset_rows_are_deterministic() {
        let d = LogregData::new(100, 16, 128);
        let (x1, y1) = d.row(42);
        let (x2, y2) = d.row(42);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = d.row(43);
        assert_ne!(x1, x3);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn e2e_training_reduces_loss_if_artifacts_present() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::from_artifacts().unwrap();
        let r = train_paged_logreg(&mut rt, 2, 512, 256, 512, 0.25, 30, 0.5).unwrap();
        assert_eq!(r.losses.len(), 30);
        assert!(
            r.losses[29] < r.losses[0],
            "loss curve: {:?} ... {:?}",
            &r.losses[..3],
            &r.losses[27..]
        );
        assert!(r.faults > 0, "paging actually happened");
        assert!(r.bytes_read > 0);
    }
}
