//! Baseline systems, expressed as design points of the same stack
//! (`StackConfig`) — exactly how the paper characterizes them in §7.2:
//!
//! | System    | posting  | MR     | polling     | verb      | extra |
//! |-----------|----------|--------|-------------|-----------|-------|
//! | nbdX      | doorbell | dynMR  | event-batch | two-sided | server copy, fixed 128K/512K block I/O |
//! | Accelio   | doorbell | dynMR  | event-batch | two-sided | server copy |
//! | Octopus   | single   | preMR  | busy        | one-sided | multi-QP |
//! | GlusterFS | single   | dynMR  | event-batch | two-sided | extra storage copy |
//!
//! None of the baselines has Load-aware Batching, an admission window, or
//! Adaptive Polling — those are the paper's contributions.

use crate::config::FabricConfig;
use crate::coordinator::batching::{BatchLimits, BatchMode};
use crate::coordinator::mr_strategy::{AddrSpace, MrMode};
use crate::coordinator::polling::PollingMode;
use crate::coordinator::StackConfig;

fn base_limits(cfg: &FabricConfig) -> BatchLimits {
    BatchLimits {
        max_sge: cfg.max_sge,
        max_chain: cfg.max_doorbell_chain,
        max_wr_bytes: 1 << 20,
    }
}

/// nbdX (Mellanox network block device over Accelio): the paper's main
/// remote-paging comparator. Fixed block I/O size (128 KB originally,
/// 512 KB in the latest version), doorbell batching, dynMR, event-batch
/// completion handling, two-sided messaging with a server-side copy.
pub fn nbdx(cfg: &FabricConfig, block_bytes: u64) -> StackConfig {
    StackConfig {
        name: format!("nbdX-{}K", block_bytes / 1024),
        batch: BatchMode::Doorbell,
        limits: base_limits(cfg),
        mr: MrMode::DynMr,
        space: AddrSpace::Kernel,
        polling: PollingMode::EventBatch { budget: 16 },
        qps_per_node: 1,
        window_bytes: None, // no admission control
        two_sided: true,
        server_copy: true,
        fixed_block: Some(block_bytes),
    }
}

/// Accelio-based FUSE file system (user space): same stack as nbdX but at
/// request granularity (the FS passes through record-sized I/Os).
pub fn accelio_fs(cfg: &FabricConfig) -> StackConfig {
    StackConfig {
        name: "Accelio".into(),
        batch: BatchMode::Doorbell,
        limits: base_limits(cfg),
        mr: MrMode::DynMr,
        space: AddrSpace::User,
        polling: PollingMode::EventBatch { budget: 16 },
        qps_per_node: 2,
        window_bytes: None,
        two_sided: true,
        server_copy: true,
        fixed_block: None,
    }
}

/// Octopus (RDMA persistent-memory FS, run RAM-backed as in the paper):
/// single I/O with preMR, busy polling, one-sided verbs, multi-QP.
pub fn octopus(cfg: &FabricConfig) -> StackConfig {
    StackConfig {
        name: "Octopus".into(),
        batch: BatchMode::Single,
        limits: base_limits(cfg),
        mr: MrMode::PreMr,
        space: AddrSpace::User,
        polling: PollingMode::Busy,
        qps_per_node: 2,
        window_bytes: None,
        two_sided: false,
        server_copy: false,
        fixed_block: None,
    }
}

/// GlusterFS on an RDMA volume (ramdisk-backed): single I/O with dynMR,
/// event-batch polling, two-sided with an extra storage copy on the
/// server (the receive path the paper calls out).
pub fn glusterfs(cfg: &FabricConfig) -> StackConfig {
    StackConfig {
        name: "GlusterFS".into(),
        batch: BatchMode::Single,
        limits: base_limits(cfg),
        mr: MrMode::DynMr,
        space: AddrSpace::User,
        polling: PollingMode::EventBatch { budget: 16 },
        qps_per_node: 1,
        window_bytes: None,
        two_sided: true,
        server_copy: true,
        fixed_block: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nbdx_matches_paper_characterization() {
        let cfg = FabricConfig::default();
        let n = nbdx(&cfg, 128 * 1024);
        assert_eq!(n.batch, BatchMode::Doorbell);
        assert_eq!(n.mr, MrMode::DynMr);
        assert!(n.two_sided && n.server_copy);
        assert_eq!(n.fixed_block, Some(128 * 1024));
        assert_eq!(n.window_bytes, None);
        assert_eq!(n.name, "nbdX-128K");
        assert_eq!(nbdx(&cfg, 512 * 1024).name, "nbdX-512K");
    }

    #[test]
    fn octopus_is_premr_busy_one_sided() {
        let cfg = FabricConfig::default();
        let o = octopus(&cfg);
        assert_eq!(o.batch, BatchMode::Single);
        assert_eq!(o.mr, MrMode::PreMr);
        assert_eq!(o.polling, PollingMode::Busy);
        assert!(!o.two_sided);
    }

    #[test]
    fn glusterfs_pays_server_copy() {
        let cfg = FabricConfig::default();
        let g = glusterfs(&cfg);
        assert!(g.two_sided && g.server_copy);
        assert_eq!(g.batch, BatchMode::Single);
        assert_eq!(g.mr, MrMode::DynMr);
    }

    #[test]
    fn no_baseline_has_rdmabox_contributions() {
        let cfg = FabricConfig::default();
        for s in [
            nbdx(&cfg, 128 << 10),
            accelio_fs(&cfg),
            octopus(&cfg),
            glusterfs(&cfg),
        ] {
            assert!(s.window_bytes.is_none(), "{}: no admission control", s.name);
            assert!(
                !matches!(s.polling, PollingMode::Adaptive { .. }),
                "{}: no adaptive polling",
                s.name
            );
            assert!(
                !matches!(s.batch, BatchMode::Hybrid | BatchMode::BatchOnMr),
                "{}: no batching-on-MR",
                s.name
            );
        }
    }
}
