//! Fig 14 — Remote File System throughput (IOzone over FUSE, 1 client, 10
//! server nodes) vs record size: RDMAbox beats Octopus by 1.7–6×,
//! GlusterFS by 1.2–2.2×, Accelio by 1.2–1.6×; Octopus ≈ GlusterFS past
//! the ~928 KB preMR/dynMR crossover.

use crate::baselines;
use crate::cli::Table;
use crate::coordinator::StackConfig;
use crate::util::fmt;

use super::ExpCtx;
use crate::rfs::run_iozone;

pub const RECORDS: [u64; 6] = [
    64 << 10,
    128 << 10,
    256 << 10,
    512 << 10,
    1 << 20,
    4 << 20,
];

pub fn run(ctx: &ExpCtx) -> String {
    let nodes = 10;
    let file = if ctx.quick { 64 << 20 } else { 1 << 30 };
    let stacks: Vec<(&str, StackConfig)> = vec![
        ("RDMAbox", StackConfig::rdmabox_user(&ctx.fabric)),
        ("Octopus", baselines::octopus(&ctx.fabric)),
        ("GlusterFS", baselines::glusterfs(&ctx.fabric)),
        ("Accelio", baselines::accelio_fs(&ctx.fabric)),
    ];
    let mut out = String::new();
    let mut all: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for (name, stack) in &stacks {
        let series: Vec<(f64, f64)> = RECORDS
            .iter()
            .map(|&r| run_iozone(&ctx.fabric, stack, nodes, r, file))
            .collect();
        all.push((name.to_string(), series));
    }
    for (phase, idx) in [("write", 0usize), ("read", 1usize)] {
        let mut t = Table::new(&format!(
            "Fig 14 ({phase}) — RFS throughput (GB/s), 1 client / {nodes} servers, {} file",
            fmt::bytes(file)
        ))
        .headers(&["system", "64K", "128K", "256K", "512K", "1M", "4M"]);
        for (name, series) in &all {
            let mut row = vec![name.clone()];
            for s in series {
                row.push(format!("{:.2}", if idx == 0 { s.0 } else { s.1 }));
            }
            t.row(&row);
        }
        // ratio summary at the largest record
        let get = |n: &str| {
            let s = &all.iter().find(|(x, _)| x == n).unwrap().1;
            s.iter()
                .map(|p| if idx == 0 { p.0 } else { p.1 })
                .collect::<Vec<f64>>()
        };
        let rbox = get("RDMAbox");
        let oct = get("Octopus");
        let glu = get("GlusterFS");
        let acc = get("Accelio");
        let maxr = |a: &[f64], b: &[f64]| {
            a.iter()
                .zip(b)
                .map(|(x, y)| x / y.max(1e-9))
                .fold(0.0f64, f64::max)
        };
        t.note(&format!(
            "paper: 1.7-6x over Octopus, 1.2-2.2x over GlusterFS, 1.2-1.6x over Accelio -> measured max {:.2}x / {:.2}x / {:.2}x",
            maxr(&rbox, &oct),
            maxr(&rbox, &glu),
            maxr(&rbox, &acc)
        ));
        // Octopus ≈ Gluster at large sizes (preMR copy cost dominates)
        let big = RECORDS.len() - 1;
        t.note(&format!(
            "paper: Octopus ≈ GlusterFS past the 928KB crossover -> measured 4M ratio {:.2}",
            oct[big] / glu[big].max(1e-9)
        ));
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdmabox_wins_across_record_sizes() {
        let ctx = ExpCtx::quick();
        let file = 16 << 20;
        let rbox = StackConfig::rdmabox_user(&ctx.fabric);
        let oct = baselines::octopus(&ctx.fabric);
        let acc = baselines::accelio_fs(&ctx.fabric);
        for record in [128 << 10, 1 << 20] {
            let (wb, rb) = run_iozone(&ctx.fabric, &rbox, 10, record, file);
            let (wo, ro) = run_iozone(&ctx.fabric, &oct, 10, record, file);
            let (wa, ra) = run_iozone(&ctx.fabric, &acc, 10, record, file);
            assert!(wb > wo && rb > ro, "record {record}: rbox {wb:.2}/{rb:.2} vs octopus {wo:.2}/{ro:.2}");
            assert!(wb > wa && rb > ra, "record {record}: rbox vs accelio {wa:.2}/{ra:.2}");
        }
    }

    #[test]
    fn accelio_beats_octopus_and_gluster() {
        // paper §7.2: doorbell+dynMR+eventbatch > single I/O designs
        let ctx = ExpCtx::quick();
        let file = 16 << 20;
        let oct = baselines::octopus(&ctx.fabric);
        let glu = baselines::glusterfs(&ctx.fabric);
        let acc = baselines::accelio_fs(&ctx.fabric);
        let record = 1 << 20;
        let (wa, _) = run_iozone(&ctx.fabric, &acc, 10, record, file);
        let (wo, _) = run_iozone(&ctx.fabric, &oct, 10, record, file);
        let (wg, _) = run_iozone(&ctx.fabric, &glu, 10, record, file);
        assert!(wa > wo, "accelio {wa:.2} vs octopus {wo:.2}");
        assert!(wa > wg, "accelio {wa:.2} vs gluster {wg:.2}");
    }
}
