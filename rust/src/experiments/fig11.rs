//! Fig 11 — multi-channel (K QPs per remote node) optimization: K=4 is the
//! sweet spot on ConnectX-3; K=8 thrashes the NIC's QP-context cache.

use crate::cli::Table;
use crate::coordinator::batching::BatchMode;
use crate::coordinator::mr_strategy::MrMode;
use crate::coordinator::StackConfig;
use crate::workloads::kv::{run_kv, voltdb, KvConfig, Mix};

use super::ExpCtx;

pub const QPS: [usize; 4] = [1, 2, 4, 8];

pub fn run(ctx: &ExpCtx) -> String {
    let approaches = [
        ("Single preMR", BatchMode::Single, MrMode::PreMr),
        ("Batch dynMR", BatchMode::BatchOnMr, MrMode::DynMr),
        ("Hybrid dynMR", BatchMode::Hybrid, MrMode::DynMr),
    ];
    let mut t = Table::new("Fig 11 — multi-channel optimization (VoltDB ETC, Kops/s)")
        .headers(&["approach", "K=1", "K=2", "K=4", "K=8", "best K"]);
    let mut hybrid_tps = Vec::new();
    for (name, batch, mr) in approaches {
        let mut row = vec![name.to_string()];
        let mut tps = Vec::new();
        for &k in QPS.iter() {
            let stack = StackConfig::rdmabox(&ctx.fabric)
                .with_batch(batch)
                .with_mr(mr)
                .with_qps(k);
            let kv = KvConfig {
                ops: ctx.ops(48_000),
                ..KvConfig::small(voltdb(), Mix::Etc)
            };
            let (_, s) = run_kv(&ctx.fabric, &stack, kv);
            tps.push(s.throughput());
            row.push(format!("{:.1}", s.throughput() / 1e3));
        }
        let best = tps
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        row.push(format!("K={}", QPS[best]));
        t.row(&row);
        if name == "Hybrid dynMR" {
            hybrid_tps = tps;
        }
    }
    t.note(&format!(
        "paper: 4 channels per remote node is best; 8 thrashes the QP cache -> measured hybrid K=8/K=4 ratio {:.2}",
        hybrid_tps[3] / hybrid_tps[2]
    ));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::kv::run_kv;

    #[test]
    fn k4_beats_k1_and_k8_does_not_beat_k4() {
        let ctx = ExpCtx::quick();
        let run_k = |k: usize| {
            let stack = StackConfig::rdmabox(&ctx.fabric).with_qps(k);
            let kv = KvConfig {
                ops: ctx.ops(30_000),
                ..KvConfig::small(voltdb(), Mix::Etc)
            };
            run_kv(&ctx.fabric, &stack, kv)
        };
        let (r1, s1) = run_k(1);
        let (r4, s4) = run_k(4);
        let (r8, s8) = run_k(8);
        // at quick scale the NIC is lightly loaded, so K=4's gain is small
        // (paper's Fig 11 runs at NIC saturation); require K=4 to be within
        // noise of K=1 and K=8 to not beat K=4 (QP-cache thrash).
        assert!(
            s4.throughput() > s1.throughput() * 0.90,
            "K=4 {} vs K=1 {}",
            s4.throughput(),
            s1.throughput()
        );
        assert!(
            s8.throughput() <= s4.throughput() * 1.05,
            "K=8 {} should not beat K=4 {}",
            s8.throughput(),
            s4.throughput()
        );
        // the mechanism: K=8 sees QP-cache misses
        assert!(r8.trace.qp_cache_misses > r4.trace.qp_cache_misses);
        let _ = r1;
    }
}
