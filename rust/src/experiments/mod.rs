//! Experiment harnesses: one module per paper figure/table (DESIGN.md §6).
//!
//! Every harness regenerates its figure's rows/series on the simulated
//! fabric, prints them next to the paper's reported numbers, and returns
//! the rendered text (so `rdmabox fig N`, `cargo bench` and the
//! integration tests all share one code path). `quick=true` shrinks the
//! workloads ~5–10× for CI-speed runs; `rdmabox fig N --full` runs closer
//! to paper scale.

pub mod fig01;
pub mod fig04;
pub mod fig05;
pub mod fig06;
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod table1;

use crate::config::FabricConfig;

/// Everything a harness needs.
#[derive(Clone)]
pub struct ExpCtx {
    pub fabric: FabricConfig,
    pub quick: bool,
}

impl ExpCtx {
    pub fn quick() -> Self {
        Self {
            fabric: FabricConfig::connectx3_fdr(),
            quick: true,
        }
    }

    pub fn full() -> Self {
        Self {
            fabric: FabricConfig::connectx3_fdr(),
            quick: false,
        }
    }

    /// Scale an op count by the quick factor.
    pub fn ops(&self, full: u64) -> u64 {
        if self.quick {
            (full / 8).max(2_000)
        } else {
            full
        }
    }
}

/// Registry used by the CLI and `all`.
pub fn run_by_id(id: &str, ctx: &ExpCtx) -> Option<String> {
    Some(match id {
        "1" => fig01::run(ctx),
        "4" => fig04::run(ctx),
        "5" => fig05::run(ctx),
        "6" => fig06::run(ctx),
        "7" => fig06::run_fig7(ctx),
        "8" => fig08::run(ctx),
        "9" => fig09::run(ctx),
        "10" => fig10::run(ctx),
        "11" => fig11::run(ctx),
        "12" => fig12::run(ctx),
        "13" => fig13::run(ctx),
        "14" => fig14::run(ctx),
        "table1" => table1::run(ctx),
        "ablation" => fig08::run_ablation(ctx),
        _ => return None,
    })
}

pub const ALL_IDS: [&str; 14] = [
    "1", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14", "table1",
    "ablation",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_figure() {
        let ctx = ExpCtx::quick();
        // only check registry dispatch for a cheap figure here; the heavy
        // ones run in the integration suite
        assert!(run_by_id("4", &ctx).is_some());
        assert!(run_by_id("nope", &ctx).is_none());
    }

    #[test]
    fn quick_scaling() {
        let q = ExpCtx::quick();
        let f = ExpCtx::full();
        assert!(q.ops(80_000) < f.ops(80_000));
        assert!(q.ops(1_000) >= 1_000.min(2_000));
    }
}
