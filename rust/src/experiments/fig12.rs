//! Fig 12 — BigData applications (MongoDB, VoltDB, Redis × ETC/SYS ×
//! 50%/25% resident) on the remote paging system: RDMAbox vs
//! nbdX+Accelio at 128 KB and 512 KB block I/O. The paper's headline:
//! up to 3.87×/4.74× (Mongo), 4.01×/6.48× (VoltDB), 2.73×/4.33× (Redis)
//! throughput, with the gap growing as more of the working set is remote,
//! and 45–66× worse p99 latency for nbdX.

use crate::baselines;
use crate::cli::Table;
use crate::coordinator::StackConfig;
use crate::util::fmt;
use crate::workloads::kv::{mongodb, redis, run_kv, voltdb, AppProfile, KvConfig, Mix};
use crate::workloads::DriverStats;

use super::ExpCtx;

pub struct Fig12Row {
    pub app: &'static str,
    pub mix: Mix,
    pub resident: f64,
    pub rbox: DriverStats,
    pub nbdx128: DriverStats,
    pub nbdx512: DriverStats,
}

pub fn run_cell(
    ctx: &ExpCtx,
    profile: AppProfile,
    mix: Mix,
    resident: f64,
) -> Fig12Row {
    let kv = |_: &str| KvConfig {
        resident_frac: resident,
        ops: ctx.ops(60_000),
        ..KvConfig::small(profile, mix)
    };
    let rbox_stack = StackConfig::rdmabox(&ctx.fabric);
    let nbdx128 = baselines::nbdx(&ctx.fabric, 128 << 10);
    let nbdx512 = baselines::nbdx(&ctx.fabric, 512 << 10);
    let (_, rbox) = run_kv(&ctx.fabric, &rbox_stack, kv("rbox"));
    let (_, n128) = run_kv(&ctx.fabric, &nbdx128, kv("n128"));
    let (_, n512) = run_kv(&ctx.fabric, &nbdx512, kv("n512"));
    Fig12Row {
        app: profile.name,
        mix,
        resident,
        rbox,
        nbdx128: n128,
        nbdx512: n512,
    }
}

pub fn paper_ratios(app: &str) -> (f64, f64) {
    match app {
        "MongoDB" => (3.87, 4.74),
        "VoltDB" => (4.01, 6.48),
        "Redis" => (2.73, 4.33),
        _ => (1.0, 1.0),
    }
}

pub fn run(ctx: &ExpCtx) -> String {
    let mut t = Table::new(
        "Fig 12 — BigData apps on remote paging: RDMAbox vs nbdX (throughput ratio, avg & p99 latency ratio)",
    )
    .headers(&[
        "app / mix / resident",
        "RDMAbox tput",
        "x vs nbdX-128K",
        "x vs nbdX-512K",
        "paper max x (128/512)",
        "nbdX-512K avg-lat x",
        "nbdX-512K p99 x",
    ]);
    let mut worst128: f64 = 0.0;
    let mut worst512: f64 = 0.0;
    for profile in [mongodb(), voltdb(), redis()] {
        for mix in [Mix::Etc, Mix::Sys] {
            for resident in [0.50, 0.25] {
                let row = run_cell(ctx, profile, mix, resident);
                let x128 = row.rbox.throughput() / row.nbdx128.throughput().max(1e-9);
                let x512 = row.rbox.throughput() / row.nbdx512.throughput().max(1e-9);
                worst128 = worst128.max(x128);
                worst512 = worst512.max(x512);
                let (p128, p512) = paper_ratios(row.app);
                let lat_x =
                    row.nbdx512.op_lat.mean() / row.rbox.op_lat.mean().max(1e-9);
                let p99_x = row.nbdx512.op_lat.p99() as f64
                    / row.rbox.op_lat.p99().max(1) as f64;
                t.row(&[
                    format!("{} {} {:.0}%", row.app, row.mix.label(), resident * 100.0),
                    fmt::ops(row.rbox.throughput()),
                    format!("{x128:.2}x"),
                    format!("{x512:.2}x"),
                    format!("{p128:.2}/{p512:.2}"),
                    format!("{lat_x:.1}x"),
                    format!("{p99_x:.1}x"),
                ]);
            }
        }
    }
    t.note(&format!(
        "paper: up to 6.48x over nbdX; measured max {:.2}x (128K) / {:.2}x (512K)",
        worst128, worst512
    ));
    t.note("gap grows with more swapping (25% resident rows vs 50% rows) — paper §7.1.1");
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdmabox_beats_nbdx_on_every_cell_tested() {
        let ctx = ExpCtx::quick();
        let row = run_cell(&ctx, voltdb(), Mix::Etc, 0.25);
        let x128 = row.rbox.throughput() / row.nbdx128.throughput();
        let x512 = row.rbox.throughput() / row.nbdx512.throughput();
        assert!(x128 > 1.0, "vs nbdX-128K: {x128}");
        assert!(x512 > 1.0, "vs nbdX-512K: {x512}");
        // larger blocks amplify more -> 512K worse than 128K (paper)
        assert!(x512 >= x128 * 0.9, "512K should be at least as bad: {x512} vs {x128}");
    }

    #[test]
    fn gap_grows_with_more_swapping() {
        let ctx = ExpCtx::quick();
        let r50 = run_cell(&ctx, voltdb(), Mix::Sys, 0.50);
        let r25 = run_cell(&ctx, voltdb(), Mix::Sys, 0.25);
        let x50 = r50.rbox.throughput() / r50.nbdx512.throughput();
        let x25 = r25.rbox.throughput() / r25.nbdx512.throughput();
        assert!(
            x25 > x50 * 0.9,
            "gap should grow (or hold) with more swapping: 25% {x25} vs 50% {x50}"
        );
    }

    #[test]
    fn nbdx_tail_latency_much_worse() {
        let ctx = ExpCtx::quick();
        let row = run_cell(&ctx, redis(), Mix::Etc, 0.25);
        let p99_x = row.nbdx512.op_lat.p99() as f64 / row.rbox.op_lat.p99() as f64;
        assert!(p99_x > 1.5, "nbdX p99 should be much worse: {p99_x}");
    }
}
