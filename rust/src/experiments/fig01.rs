//! Fig 1 — I/O thrashing on the NIC: FIO IOPS rises then *drops* as
//! threads increase (1 QP, no admission control), while in-flight ops and
//! RDMA completion time keep growing — the NIC, not the network, is the
//! bottleneck.

use crate::cli::Table;
use crate::coordinator::polling::PollingMode;
use crate::coordinator::StackConfig;
use crate::fabric::sim::{run_pipeline, SimReport};
use crate::util::fmt;
use crate::workloads::fio::FioDriver;
use crate::workloads::DriverStats;

use super::ExpCtx;

pub const THREADS: [usize; 6] = [1, 2, 4, 7, 8, 16];

pub fn run_one(ctx: &ExpCtx, threads: usize, qps: usize, window: Option<u64>) -> SimReport {
    let stack = StackConfig::rdmabox(&ctx.fabric)
        .with_qps(qps)
        .with_window(window)
        .with_polling(PollingMode::Adaptive {
            batch: 16,
            max_retry: 120,
        });
    let stats = DriverStats::shared();
    let driver = Box::new(FioDriver::new(
        threads,
        2, // FIO with modest per-thread depth: threads are the pressure axis
        4096,
        50,
        1 << 30,
        1,
        ctx.ops(64_000),
        42,
        stats,
    ));
    run_pipeline(&ctx.fabric, &stack, 1, driver)
}

pub fn run(ctx: &ExpCtx) -> String {
    let mut t = Table::new("Fig 1 — FIO on remote block device, 1 QP, no admission control")
        .headers(&[
            "FIO threads",
            "IOPS",
            "mean in-flight ops",
            "mean RDMA completion",
            "WQE cache misses",
        ]);
    let mut iops = Vec::new();
    for &threads in THREADS.iter() {
        let r = run_one(ctx, threads, 1, None);
        iops.push(r.iops());
        let mean_lat = (r.read_lat.mean() + r.write_lat.mean()) / 2.0;
        t.row(&[
            threads.to_string(),
            format!("{:.0}", r.iops()),
            format!("{:.1}", r.mean_inflight_ops),
            fmt::dur_ns_f(mean_lat),
            fmt::count(r.trace.wqe_cache_misses),
        ]);
    }
    let peak = iops.iter().cloned().fold(0.0f64, f64::max);
    let peak_at = THREADS[iops.iter().position(|&x| x == peak).unwrap()];
    let last = *iops.last().unwrap();
    t.note(&format!(
        "paper: IOPS peaks around 4 threads then declines; measured peak at {} threads, {}-thread IOPS is {:.0}% of peak",
        peak_at,
        THREADS.last().unwrap(),
        last / peak * 100.0
    ));
    t.note("in-flight ops and completion time keep rising past the peak -> NIC bottleneck (paper Fig 1b/1c)");
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_holds() {
        let ctx = ExpCtx::quick();
        let out = run(&ctx);
        assert!(out.contains("FIO threads"));
        // shape: the 16-thread point is below peak
        let r4 = run_one(&ctx, 4, 1, None);
        let r16 = run_one(&ctx, 16, 1, None);
        assert!(
            r16.iops() < r4.iops(),
            "decline: 16t {} vs 4t {}",
            r16.iops(),
            r4.iops()
        );
        // and in-flight keeps growing (Fig 1b)
        assert!(r16.mean_inflight_ops > r4.mean_inflight_ops);
        // and completion time keeps growing (Fig 1c)
        assert!(r16.write_lat.mean() > r4.write_lat.mean());
    }
}
