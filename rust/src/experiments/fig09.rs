//! Fig 9 — scalability of WC-handling approaches with peer count (VoltDB
//! SYS, single I/O + preMR, 1 QP per peer): Busy wins at few peers then
//! collapses under its own CPU burn; Event stays flat; SCQ(1) sits between
//! them; Adaptive matches the best at both ends.

use crate::cli::Table;
use crate::coordinator::batching::BatchMode;
use crate::coordinator::mr_strategy::MrMode;
use crate::coordinator::polling::PollingMode;
use crate::coordinator::StackConfig;
use crate::fabric::sim::SimReport;
use crate::workloads::kv::{run_kv, AppProfile, KvConfig, Mix};
use crate::workloads::DriverStats;

use super::ExpCtx;

pub const PEERS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn approaches() -> Vec<(&'static str, PollingMode)> {
    vec![
        ("Event", PollingMode::Event),
        ("EventBatch", PollingMode::EventBatch { budget: 16 }),
        ("Busy", PollingMode::Busy),
        ("SCQ(1)", PollingMode::Scq { m: 1, pollers: 1 }),
        ("SCQ(2)", PollingMode::Scq { m: 2, pollers: 1 }),
        (
            "AdaptivePoll",
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 120,
            },
        ),
    ]
}

pub fn run_one(ctx: &ExpCtx, polling: PollingMode, peers: usize) -> (SimReport, DriverStats) {
    // paper setting: single I/O with preMR, 1 channel per remote node
    let stack = StackConfig::rdmabox(&ctx.fabric)
        .with_batch(BatchMode::Single)
        .with_mr(MrMode::PreMr)
        .with_qps(1)
        // single-I/O at page granularity: the regulator is set to the NIC's
        // WQE capability so the polling comparison is not confounded by
        // WQE-cache thrash (§6.2 isolates completion handling)
        .with_window(Some(16 * 4096))
        .with_polling(polling);
    // §6.2 uses "the CPU-intensive VoltDB": SQL transaction work dominates
    // each op, with paging as the tail — so poller CPU burn (Fig 9b) and
    // completion-handling latency both show up in app throughput (Fig 9a).
    let profile = AppProfile {
        name: "VoltDB",
        record_bytes: 1024,
        cpu_per_op_ns: 40_000,
        second_page_prob: 0.15,
        uniform_touch_prob: 0.25,
    };
    let kv = KvConfig {
        nodes: peers,
        replicas: 2.min(peers),
        ops: ctx.ops(48_000),
        // core-hungry: with 28 runnable app threads, every core a poller
        // burns is a core the application loses
        threads: 28,
        resident_frac: 0.5,
        ..KvConfig::small(profile, Mix::Sys)
    };
    run_kv(&ctx.fabric, &stack, kv)
}

pub fn run(ctx: &ExpCtx) -> String {
    let mut t = Table::new("Fig 9a — throughput (Kops/s) vs number of peer nodes (VoltDB SYS)")
        .headers(&["approach", "1", "2", "4", "8", "16"]);
    let mut tc = Table::new("Fig 9b — poller CPU (cores) vs number of peer nodes")
        .headers(&["approach", "1", "2", "4", "8", "16"]);
    let mut results: Vec<(&str, Vec<(SimReport, DriverStats)>)> = Vec::new();
    for (name, polling) in approaches() {
        let runs: Vec<_> = PEERS.iter().map(|&p| run_one(ctx, polling, p)).collect();
        let tp_row: Vec<String> = std::iter::once(name.to_string())
            .chain(runs.iter().map(|(_, s)| format!("{:.1}", s.throughput() / 1e3)))
            .collect();
        let cpu_row: Vec<String> = std::iter::once(name.to_string())
            .chain(runs.iter().map(|(r, _)| format!("{:.2}", r.poller_cpu_cores())))
            .collect();
        t.row(&tp_row);
        tc.row(&cpu_row);
        results.push((name, runs));
    }
    let find = |n: &str| &results.iter().find(|(x, _)| *x == n).unwrap().1;
    let busy = find("Busy");
    let event = find("Event");
    let adaptive = find("AdaptivePoll");
    let scq1 = find("SCQ(1)");
    t.note(&format!(
        "paper: Busy best at ≤4 peers, collapses at many peers -> measured busy/adaptive at 16 peers: {:.2}",
        busy[4].1.throughput() / adaptive[4].1.throughput()
    ));
    t.note(&format!(
        "paper: Event beats SCQ(1) at ≥8 peers (parallel CQs) -> measured event/scq1 at 16 peers: {:.2}",
        event[4].1.throughput() / scq1[4].1.throughput()
    ));
    tc.note("busy-poller CPU grows linearly with peers; event/adaptive stay near zero");
    format!("{}{}", t.render(), tc.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_crossovers() {
        let ctx = ExpCtx::quick();
        // busy CPU grows with peers, adaptive stays low
        let (busy_16, _) = run_one(&ctx, PollingMode::Busy, 16);
        let (adapt_16, s_adapt) = run_one(
            &ctx,
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 120,
            },
            16,
        );
        // under saturated load adaptive legitimately keeps spinning (that
        // is its design); busy still burns meaningfully more because it
        // spins on *idle* CQs too
        assert!(
            busy_16.poller_cpu_cores() > 1.4 * adapt_16.poller_cpu_cores(),
            "busy {} vs adaptive {} cores",
            busy_16.poller_cpu_cores(),
            adapt_16.poller_cpu_cores()
        );
        // adaptive throughput at scale at least matches busy (whose CPU
        // burn steals app cores)
        let (_, s_busy) = run_one(&ctx, PollingMode::Busy, 16);
        assert!(
            s_adapt.throughput() >= s_busy.throughput() * 0.95,
            "adaptive {} vs busy {}",
            s_adapt.throughput(),
            s_busy.throughput()
        );
    }
}
