//! Fig 6 / Fig 7 — comparison of batching approaches on VoltDB with YCSB
//! ETC and SYS (Zipfian): Single/Batch × preMR/dynMR, Doorbell, Hybrid.
//! Hybrid (Batching-on-MR + doorbell, dynMR) wins throughput (Fig 6) and
//! has the shortest 99th-percentile tail (Fig 7).

use crate::cli::Table;
use crate::coordinator::batching::BatchMode;
use crate::coordinator::mr_strategy::MrMode;
use crate::coordinator::StackConfig;
use crate::fabric::sim::SimReport;
use crate::util::fmt;
use crate::workloads::kv::{run_kv, voltdb, KvConfig, Mix};
use crate::workloads::DriverStats;

use super::ExpCtx;

/// The six design points of Fig 6, in paper order.
pub fn variants(ctx: &ExpCtx) -> Vec<StackConfig> {
    let base = StackConfig::rdmabox(&ctx.fabric);
    vec![
        base.clone()
            .with_batch(BatchMode::Single)
            .with_mr(MrMode::PreMr)
            .with_name("Single preMR"),
        base.clone()
            .with_batch(BatchMode::Single)
            .with_mr(MrMode::DynMr)
            .with_name("Single dynMR"),
        base.clone()
            .with_batch(BatchMode::BatchOnMr)
            .with_mr(MrMode::PreMr)
            .with_name("Batch preMR"),
        base.clone()
            .with_batch(BatchMode::BatchOnMr)
            .with_mr(MrMode::DynMr)
            .with_name("Batch dynMR"),
        base.clone()
            .with_batch(BatchMode::Doorbell)
            .with_mr(MrMode::DynMr)
            .with_name("Door dynMR"),
        base.with_batch(BatchMode::Hybrid)
            .with_mr(MrMode::DynMr)
            .with_name("Hybrid dynMR"),
    ]
}

pub fn kv_cfg(ctx: &ExpCtx, mix: Mix) -> KvConfig {
    KvConfig {
        ops: ctx.ops(80_000),
        ..KvConfig::small(voltdb(), mix)
    }
}

pub fn run_all(ctx: &ExpCtx, mix: Mix) -> Vec<(String, SimReport, DriverStats)> {
    variants(ctx)
        .into_iter()
        .map(|stack| {
            let (r, s) = run_kv(&ctx.fabric, &stack, kv_cfg(ctx, mix));
            (stack.name, r, s)
        })
        .collect()
}

pub fn run(ctx: &ExpCtx) -> String {
    let mut out = String::new();
    for mix in [Mix::Etc, Mix::Sys] {
        let rows = run_all(ctx, mix);
        let base_tp = rows[0].2.throughput();
        let mut t = Table::new(&format!(
            "Fig 6{} — batching approaches, VoltDB {} (Zipfian)",
            if mix == Mix::Etc { "a" } else { "b" },
            mix.label()
        ))
        .headers(&["approach", "throughput", "vs Single preMR", "RDMA I/Os (WQEs)", "MMIOs"]);
        for (name, r, s) in &rows {
            t.row(&[
                name.clone(),
                fmt::ops(s.throughput()),
                format!("{:+.1}%", (s.throughput() / base_tp - 1.0) * 100.0),
                fmt::count(r.trace.wqes_total()),
                fmt::count(r.trace.mmios),
            ]);
        }
        let hybrid = rows.last().unwrap().2.throughput();
        t.note(&format!(
            "paper: Hybrid +22.2–47.7% over Single preMR -> measured {:+.1}%",
            (hybrid / base_tp - 1.0) * 100.0
        ));
        out.push_str(&t.render());
    }
    out
}

/// Fig 7 — 99th-percentile application tail latency for the same runs.
pub fn run_fig7(ctx: &ExpCtx) -> String {
    let mut out = String::new();
    for mix in [Mix::Etc, Mix::Sys] {
        let rows = run_all(ctx, mix);
        let mut t = Table::new(&format!(
            "Fig 7 — 99th percentile app latency, VoltDB {}",
            mix.label()
        ))
        .headers(&["approach", "p50", "p99", "mean"]);
        for (name, _, s) in &rows {
            t.row(&[
                name.clone(),
                fmt::dur_ns(s.op_lat.p50()),
                fmt::dur_ns(s.op_lat.p99()),
                fmt::dur_ns_f(s.op_lat.mean()),
            ]);
        }
        let single_pre = rows[0].2.op_lat.p99();
        let hybrid = rows.last().unwrap().2.op_lat.p99();
        t.note(&format!(
            "paper: batching does not hurt tail latency; hybrid shortest -> measured hybrid p99 = {:.0}% of Single preMR",
            hybrid as f64 / single_pre as f64 * 100.0
        ));
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(ctx: &mut ExpCtx) {
        ctx.quick = true;
    }

    #[test]
    fn hybrid_wins_throughput_and_reduces_wqes() {
        let mut ctx = ExpCtx::quick();
        tiny(&mut ctx);
        let rows = run_all(&ctx, Mix::Sys);
        let single_pre = &rows[0];
        let doorbell = &rows[4];
        let hybrid = rows.last().unwrap();
        // paper: hybrid +22-48% over single, +7.5-22% over doorbell
        assert!(
            hybrid.2.throughput() > single_pre.2.throughput() * 1.02,
            "hybrid {} vs single {}",
            hybrid.2.throughput(),
            single_pre.2.throughput()
        );
        assert!(
            hybrid.2.throughput() > doorbell.2.throughput(),
            "hybrid {} vs doorbell {}",
            hybrid.2.throughput(),
            doorbell.2.throughput()
        );
        assert!(hybrid.1.trace.wqes_total() < single_pre.1.trace.wqes_total());
        // doorbell does NOT reduce WQEs vs single (paper's core point)
        let single_dyn = &rows[1];
        let doorbell = &rows[4];
        let ratio =
            doorbell.1.trace.wqes_total() as f64 / single_dyn.1.trace.wqes_total() as f64;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "doorbell wqes ≈ single wqes, ratio {ratio}"
        );
        // but doorbell DOES reduce MMIOs
        assert!(doorbell.1.trace.mmios < single_dyn.1.trace.mmios);
    }

    #[test]
    fn fig7_hybrid_tail_not_worse() {
        let mut ctx = ExpCtx::quick();
        tiny(&mut ctx);
        let rows = run_all(&ctx, Mix::Etc);
        let single_pre_p99 = rows[0].2.op_lat.p99();
        let hybrid_p99 = rows.last().unwrap().2.op_lat.p99();
        assert!(
            hybrid_p99 <= single_pre_p99 * 12 / 10,
            "hybrid p99 {} should not blow up vs single {}",
            hybrid_p99,
            single_pre_p99
        );
    }
}
