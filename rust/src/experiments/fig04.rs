//! Fig 4 — MR registration vs memcpy, kernel vs user space. Kernel-space
//! registration (physical addresses, no PTE walk / NIC translation cache)
//! beats copying at *every* size; user space crosses over near 928 KB.

use crate::cli::Table;
use crate::util::fmt;

use super::ExpCtx;

pub const SIZES: [u64; 8] = [
    4 << 10,
    16 << 10,
    64 << 10,
    256 << 10,
    512 << 10,
    928 << 10,
    1 << 20,
    4 << 20,
];

pub fn run(ctx: &ExpCtx) -> String {
    let c = &ctx.fabric;
    let mut t = Table::new("Fig 4 — memcpy (preMR) vs MR registration (dynMR) cost").headers(&[
        "size",
        "kernel memcpy",
        "kernel reg",
        "kernel winner",
        "user memcpy",
        "user reg",
        "user winner",
    ]);
    let mut kernel_reg_always_wins = true;
    let mut user_cross = None;
    let mut prev_user_winner = "memcpy";
    for &sz in SIZES.iter() {
        let km = c.memcpy_ns(sz);
        let kr = c.reg_ns(sz, true);
        let um = c.memcpy_ns(sz);
        let ur = c.reg_ns(sz, false);
        if kr >= km {
            kernel_reg_always_wins = false;
        }
        let user_winner = if ur < um { "reg" } else { "memcpy" };
        if user_winner == "reg" && prev_user_winner == "memcpy" {
            user_cross = Some(sz);
        }
        prev_user_winner = user_winner;
        t.row(&[
            fmt::bytes(sz),
            fmt::dur_ns(km),
            fmt::dur_ns(kr),
            if kr < km { "reg (dynMR)" } else { "memcpy" }.to_string(),
            fmt::dur_ns(um),
            fmt::dur_ns(ur),
            format!("{user_winner} ({})", if ur < um { "dynMR" } else { "preMR" }),
        ]);
    }
    let analytic = c.user_crossover_bytes();
    t.note(&format!(
        "paper: kernel dynMR favored at all sizes -> measured: {}",
        if kernel_reg_always_wins { "holds" } else { "VIOLATED" }
    ));
    t.note(&format!(
        "paper: user-space crossover at 928KB -> measured: analytic {} (first table row where reg wins: {})",
        fmt::bytes(analytic),
        user_cross.map(fmt::bytes).unwrap_or_else(|| "none".into())
    ));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_claims_hold() {
        let ctx = ExpCtx::quick();
        let out = run(&ctx);
        assert!(out.contains("holds"), "kernel claim violated:\n{out}");
        assert!(!out.contains("VIOLATED"), "{out}");
        // analytic crossover within 15% of 928KB
        let x = ctx.fabric.user_crossover_bytes() as f64;
        let paper = (928 * 1024) as f64;
        assert!((x - paper).abs() / paper < 0.15, "crossover {x}");
    }
}
