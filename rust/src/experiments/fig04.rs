//! Fig 4 — MR registration vs memcpy, kernel vs user space. Kernel-space
//! registration (physical addresses, no PTE walk / NIC translation cache)
//! beats copying at *every* size; user space crosses over near 928 KB.
//!
//! Beyond the paper's static table, the figure now also drives the
//! pinning-free [`MrCache`] over working sets on both sides of its
//! pinned-bytes cap, so the analytic per-size model sits next to measured
//! cache behaviour (hit rate, evictions, amortized per-I/O cost).

use crate::cli::Table;
use crate::config::FabricConfig;
use crate::coordinator::mr_cache::{MrCache, MR_SPAN_BYTES};
use crate::util::fmt;

use super::ExpCtx;

pub const SIZES: [u64; 8] = [
    4 << 10,
    16 << 10,
    64 << 10,
    256 << 10,
    512 << 10,
    928 << 10,
    1 << 20,
    4 << 20,
];

/// First table size where user-space registration beats memcpy, or `None`
/// if memcpy wins everywhere. This is a *first-win* scan, not a
/// transition detector: if the winner flips back at a larger size (a
/// non-monotone cost model), the reported crossover is still the first
/// size where reg won — use [`user_winner_flips_back`] to surface the
/// flip-back itself.
pub fn measured_user_crossover(c: &FabricConfig) -> Option<u64> {
    SIZES
        .iter()
        .copied()
        .find(|&sz| c.reg_ns(sz, false) < c.memcpy_ns(sz))
}

/// True when, after the first size where user-space reg wins, some larger
/// table size flips back to memcpy — a non-monotone winner column that
/// the old transition-based scan silently mis-reported (it kept the
/// *last* memcpy→reg transition as "the" crossover).
pub fn user_winner_flips_back(c: &FabricConfig) -> bool {
    let mut seen_reg_win = false;
    for &sz in SIZES.iter() {
        let reg_wins = c.reg_ns(sz, false) < c.memcpy_ns(sz);
        if seen_reg_win && !reg_wins {
            return true;
        }
        seen_reg_win |= reg_wins;
    }
    false
}

/// One measured MR-cache data point: drive `cache` with `passes`
/// sequential sweeps of `io_bytes` requests over a `ws_bytes` working
/// set, then read the counters back.
struct CachePoint {
    ws_bytes: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    touches: u64,
}

fn drive_cache(cache: &mut MrCache, ws_bytes: u64, io_bytes: u64, passes: usize) -> CachePoint {
    let mut touches = 0u64;
    for _ in 0..passes {
        let mut addr = 0u64;
        while addr < ws_bytes {
            cache.touch(addr, io_bytes.min(ws_bytes - addr));
            touches += 1;
            addr += io_bytes;
        }
    }
    let s = cache.snapshot();
    CachePoint {
        ws_bytes,
        hits: s.mr_hits,
        misses: s.mr_misses,
        evictions: s.mr_evictions,
        touches,
    }
}

pub fn run(ctx: &ExpCtx) -> String {
    let c = &ctx.fabric;
    let mut t = Table::new("Fig 4 — memcpy (preMR) vs MR registration (dynMR) cost").headers(&[
        "size",
        "kernel memcpy",
        "kernel reg",
        "kernel winner",
        "user memcpy",
        "user reg",
        "user winner",
    ]);
    let mut kernel_reg_always_wins = true;
    for &sz in SIZES.iter() {
        let km = c.memcpy_ns(sz);
        let kr = c.reg_ns(sz, true);
        let um = c.memcpy_ns(sz);
        let ur = c.reg_ns(sz, false);
        if kr >= km {
            kernel_reg_always_wins = false;
        }
        t.row(&[
            fmt::bytes(sz),
            fmt::dur_ns(km),
            fmt::dur_ns(kr),
            if kr < km { "reg (dynMR)" } else { "memcpy" }.to_string(),
            fmt::dur_ns(um),
            fmt::dur_ns(ur),
            format!(
                "{} ({})",
                if ur < um { "reg" } else { "memcpy" },
                if ur < um { "dynMR" } else { "preMR" }
            ),
        ]);
    }
    let analytic = c.user_crossover_bytes();
    let user_cross = measured_user_crossover(c);
    t.note(&format!(
        "paper: kernel dynMR favored at all sizes -> measured: {}",
        if kernel_reg_always_wins { "holds" } else { "VIOLATED" }
    ));
    t.note(&format!(
        "paper: user-space crossover at 928KB -> measured: analytic {} \
         (first table size where reg wins: {}{})",
        fmt::bytes(analytic),
        user_cross.map(fmt::bytes).unwrap_or_else(|| "none".into()),
        if user_winner_flips_back(c) {
            ", winner flips back at a larger size"
        } else {
            ""
        }
    ));

    // Measured counterpart: the pinning-free MR cache over working sets on
    // both sides of its cap. Steady-state hits amortize registration away;
    // a working set past the cap degenerates to dynMR-per-span plus
    // eviction churn.
    let cap = 16u64 << 20;
    let io = 16u64 << 10;
    let title = "Fig 4b — measured MR-cache (cap 16 MiB, 16 KiB I/Os, 4 passes)";
    let mut m = Table::new(title).headers(&[
        "working set",
        "hit rate",
        "evictions",
        "amortized/IO",
        "dynMR/IO",
        "preMR memcpy/IO",
    ]);
    let hit_ns = c.mr_cache_hit_ns;
    let miss_ns = c.reg_ns(MR_SPAN_BYTES, true);
    for ws in [cap / 2, 4 * cap] {
        let mut cache = MrCache::new(cap);
        let p = drive_cache(&mut cache, ws, io, 4);
        let amortized = (p.hits * hit_ns + p.misses * miss_ns) / p.touches.max(1);
        m.row(&[
            fmt::bytes(p.ws_bytes),
            format!("{:.1}%", cache.snapshot().hit_rate() * 100.0),
            p.evictions.to_string(),
            fmt::dur_ns(amortized),
            fmt::dur_ns(c.reg_ns(io, true)),
            fmt::dur_ns(c.memcpy_ns(io)),
        ]);
    }
    m.note(
        "in-cap working set: lazy registration amortizes to ~the lkey-lookup cost; \
         over-cap: every span re-registers (dynMR floor) plus clock eviction churn",
    );
    format!("{}\n{}", t.render(), m.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_claims_hold() {
        let ctx = ExpCtx::quick();
        let out = run(&ctx);
        assert!(out.contains("holds"), "kernel claim violated:\n{out}");
        assert!(!out.contains("VIOLATED"), "{out}");
        // analytic crossover within 15% of 928KB
        let x = ctx.fabric.user_crossover_bytes() as f64;
        let paper = (928 * 1024) as f64;
        assert!((x - paper).abs() / paper < 0.15, "crossover {x}");
        // the default cost model is monotone: no flip-back note
        assert!(!out.contains("flips back"), "{out}");
        // measured cache table is present with both working-set rows
        assert!(out.contains("Fig 4b"), "{out}");
    }

    #[test]
    fn crossover_scan_reports_first_reg_win() {
        // Skew the model so user-space registration wins from the very
        // first size: the scan must report SIZES[0], not a later
        // transition.
        let c = FabricConfig {
            user_reg_base_ns: 1,
            user_reg_per_page_ns: 0,
            ..FabricConfig::default()
        };
        assert_eq!(measured_user_crossover(&c), Some(SIZES[0]));
        assert!(!user_winner_flips_back(&c));
    }

    #[test]
    fn crossover_scan_reports_none_when_memcpy_always_wins() {
        // Skew the other way: registration never pays off inside the
        // table, so there is no crossover to report ("none"), where the
        // old transition detector could latch onto a stale value.
        let c = FabricConfig {
            user_reg_base_ns: 1 << 40,
            ..FabricConfig::default()
        };
        assert_eq!(measured_user_crossover(&c), None);
        assert!(!user_winner_flips_back(&c));
    }

    #[test]
    fn flip_back_is_detected_and_does_not_move_the_crossover() {
        // A per-page user reg cost above the memcpy byte rate makes reg
        // win only while the base-cost gap dominates (small sizes), then
        // lose again as size grows: first-win must stay at the smallest
        // winning size and the flip-back must be flagged.
        let c = FabricConfig {
            user_reg_base_ns: 1,
            user_reg_per_page_ns: 600, // > 4096B / 10B-per-ns ≈ 410ns per page
            ..FabricConfig::default()
        };
        let first = measured_user_crossover(&c);
        assert_eq!(first, Some(SIZES[0]), "reg wins at 4KB on base cost");
        assert!(user_winner_flips_back(&c), "per-page cost overtakes memcpy");
    }

    #[test]
    fn measured_cache_fits_vs_thrash() {
        let cap = 1u64 << 20;
        // In-cap: second pass is all hits.
        let mut fit = MrCache::new(cap);
        let p = drive_cache(&mut fit, cap / 2, 16 << 10, 4);
        let spans = (cap / 2) / MR_SPAN_BYTES;
        assert_eq!(p.misses, spans, "one lazy registration per span");
        assert!(p.hits > p.misses * 10, "steady state is hit-dominated");
        assert_eq!(p.evictions, 0);
        // Over-cap sequential sweep: the clock can never keep a span long
        // enough for the next pass — every span touch re-registers.
        let mut thrash = MrCache::new(cap);
        let q = drive_cache(&mut thrash, 4 * cap, 64 << 10, 4);
        assert_eq!(q.hits, 0, "sequential over-cap sweep never hits");
        assert!(q.evictions > 0);
    }
}
