//! Fig 13 — ML workloads on remote paging: completion-time ratios of
//! nbdX-128K/512K vs RDMAbox for LogisticRegression, GradientBoost,
//! K-means and TextRank. Paper: 2.83/2.73×, 1.5/1.54×, 1.8/2.28×,
//! 4.62/6.08× — memory-hungry jobs gain most, compute-bound least.

use crate::baselines;
use crate::cli::Table;
use crate::coordinator::StackConfig;
use crate::util::fmt;
use crate::workloads::mltrace::{gboost, kmeans, logreg, run_ml, textrank, MlProfile};

use super::ExpCtx;

pub fn profiles(ctx: &ExpCtx) -> Vec<MlProfile> {
    let scale = if ctx.quick { 8 } else { 1 };
    [logreg(), gboost(), kmeans(), textrank()]
        .into_iter()
        .map(|p| MlProfile {
            dataset_pages: p.dataset_pages / scale,
            state_pages: (p.state_pages / scale).max(16),
            ..p
        })
        .collect()
}

pub fn paper_ratios(name: &str) -> (f64, f64) {
    match name {
        "LogisticRegression" => (2.83, 2.73),
        "GradientBoost" => (1.50, 1.54),
        "KMeans" => (1.80, 2.28),
        "TextRank" => (4.62, 6.08),
        _ => (1.0, 1.0),
    }
}

pub fn run(ctx: &ExpCtx) -> String {
    let rbox = StackConfig::rdmabox(&ctx.fabric);
    let n128 = baselines::nbdx(&ctx.fabric, 128 << 10);
    let n512 = baselines::nbdx(&ctx.fabric, 512 << 10);
    let mut t = Table::new("Fig 13 — ML training completion time (25% resident, 3 peers)")
        .headers(&[
            "workload",
            "RDMAbox",
            "nbdX-128K x",
            "nbdX-512K x",
            "paper x (128/512)",
        ]);
    let mut ratios = Vec::new();
    for p in profiles(ctx) {
        let (t_box, _) = run_ml(&ctx.fabric, &rbox, p, 0.25, 3);
        let (t_128, _) = run_ml(&ctx.fabric, &n128, p, 0.25, 3);
        let (t_512, _) = run_ml(&ctx.fabric, &n512, p, 0.25, 3);
        let x128 = t_128 as f64 / t_box as f64;
        let x512 = t_512 as f64 / t_box as f64;
        let (p128, p512) = paper_ratios(p.name);
        ratios.push((p.name, x128, x512));
        t.row(&[
            p.name.to_string(),
            fmt::dur_ns(t_box),
            format!("{x128:.2}x"),
            format!("{x512:.2}x"),
            format!("{p128:.2}/{p512:.2}"),
        ]);
    }
    let text = ratios.iter().find(|r| r.0 == "TextRank").unwrap();
    let km = ratios.iter().find(|r| r.0 == "KMeans").unwrap();
    t.note(&format!(
        "paper: TextRank (memory-hungry) gains most, K-means/GBoost (compute-bound) least -> measured TextRank {:.2}x vs KMeans {:.2}x",
        text.2, km.2
    ));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_memory_hungry_gains_most() {
        let ctx = ExpCtx::quick();
        let rbox = StackConfig::rdmabox(&ctx.fabric);
        let n512 = baselines::nbdx(&ctx.fabric, 512 << 10);
        let ps = profiles(&ctx);
        let ratio = |p: MlProfile| {
            let (a, _) = run_ml(&ctx.fabric, &rbox, p, 0.25, 3);
            let (b, _) = run_ml(&ctx.fabric, &n512, p, 0.25, 3);
            b as f64 / a as f64
        };
        let text = ratio(ps[3]);
        let gb = ratio(ps[1]);
        assert!(text > 1.0, "TextRank must gain: {text}");
        assert!(gb > 0.9, "GBoost roughly at parity or better: {gb}");
        assert!(
            text > gb,
            "memory-hungry ({text}) should gain more than compute-bound ({gb})"
        );
    }
}
