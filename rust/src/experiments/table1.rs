//! Table 1 — total number of RDMA I/Os (WQEs) to the NIC, VoltDB ETC.
//! Batching-on-MR reduces both reads and writes (paper: RD 13.2M→11M,
//! WR 308K→272K); doorbell-only matches Single (it chains, it does not
//! merge); Hybrid matches Batch.

use crate::cli::Table;
use crate::util::fmt;
use crate::workloads::kv::Mix;

use super::fig06;
use super::ExpCtx;

pub fn run(ctx: &ExpCtx) -> String {
    let rows = fig06::run_all(ctx, Mix::Etc);
    let mut t = Table::new("Table 1 — total RDMA I/O to NIC (VoltDB ETC)").headers(&[
        "approach", "RD WQEs", "WR WQEs", "RD vs single", "WR vs single",
    ]);
    let base_rd = rows[0].1.trace.wqes_read.max(1);
    let base_wr = rows[0].1.trace.wqes_write.max(1);
    for (name, r, _) in &rows {
        t.row(&[
            name.clone(),
            fmt::count(r.trace.wqes_read),
            fmt::count(r.trace.wqes_write),
            format!("{:.2}x", r.trace.wqes_read as f64 / base_rd as f64),
            format!("{:.2}x", r.trace.wqes_write as f64 / base_wr as f64),
        ]);
    }
    let batch_dyn = &rows[3].1;
    let door = &rows[4].1;
    t.note(&format!(
        "paper: Batch dynMR RD = 11M/13.2M = 0.83x of Single -> measured {:.2}x",
        batch_dyn.trace.wqes_read as f64 / base_rd as f64
    ));
    t.note(&format!(
        "paper: Doorbell RD ≈ Single (no WQE reduction) -> measured {:.2}x",
        door.trace.wqes_read as f64 / base_rd as f64
    ));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ratios_match_paper_direction() {
        let ctx = ExpCtx::quick();
        let rows = fig06::run_all(&ctx, Mix::Etc);
        let single = &rows[1].1; // Single dynMR
        let batch = &rows[3].1; // Batch dynMR
        let door = &rows[4].1; // Doorbell dynMR
        let hybrid = &rows[5].1;
        // batching reduces RDMA I/Os
        assert!(batch.trace.wqes_total() < single.trace.wqes_total());
        // doorbell does not (within 10%)
        let dr = door.trace.wqes_total() as f64 / single.trace.wqes_total() as f64;
        assert!((0.9..=1.1).contains(&dr), "doorbell ratio {dr}");
        // hybrid ≈ batch (its doorbell part adds no WQEs)
        let hr = hybrid.trace.wqes_total() as f64 / batch.trace.wqes_total() as f64;
        assert!((0.8..=1.2).contains(&hr), "hybrid vs batch ratio {hr}");
    }
}
