//! Fig 10 — number of busy-polling threads on M shared CQs vs throughput:
//! a second polling thread helps slightly on SCQ(1); beyond that the CPU
//! overhead dominates, regardless of M.

use crate::cli::Table;
use crate::coordinator::polling::PollingMode;

use super::fig09::run_one;
use super::ExpCtx;

pub const POLLERS: [u32; 4] = [1, 2, 4, 8];
pub const M: [u32; 3] = [1, 2, 4];

pub fn run(ctx: &ExpCtx) -> String {
    let peers = 8;
    let mut t = Table::new(&format!(
        "Fig 10 — throughput (Kops/s) vs #busy pollers on SCQ(M), {} peers",
        peers
    ))
    .headers(&["config", "1 poller", "2 pollers", "4 pollers", "8 pollers"]);
    let mut by_m = Vec::new();
    for &m in M.iter() {
        let mut row = vec![format!("SCQ({m})")];
        let mut tps = Vec::new();
        for &p in POLLERS.iter() {
            let (_, s) = run_one(ctx, PollingMode::Scq { m, pollers: p }, peers);
            tps.push(s.throughput());
            row.push(format!("{:.1}", s.throughput() / 1e3));
        }
        t.row(&row);
        by_m.push(tps);
    }
    let scq1 = &by_m[0];
    t.note(&format!(
        "paper: CPU overhead dominates past ~2-4 pollers -> measured SCQ(1) 8-poller/1-poller ratio: {:.2}",
        scq1[3] / scq1[0]
    ));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn many_pollers_hurt() {
        let ctx = ExpCtx::quick();
        let (_, s1) = run_one(&ctx, PollingMode::Scq { m: 1, pollers: 1 }, 8);
        let (_, s8) = run_one(&ctx, PollingMode::Scq { m: 1, pollers: 8 }, 8);
        assert!(
            s8.throughput() < s1.throughput() * 1.05,
            "8 pollers {} should not beat 1 poller {} meaningfully",
            s8.throughput(),
            s1.throughput()
        );
    }
}
