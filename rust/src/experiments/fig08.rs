//! Fig 8 — RDMA-level admission control. Methodology follows the paper:
//! run the Fig 1 FIO sweep with multi-QP (4), find the peak, measure the
//! in-flight bytes there, then use that as the regulator window — IOPS
//! keeps rising past the old knee (+~30%) and in-flight bytes stabilize.

use crate::cli::Table;
use crate::util::fmt;

use super::fig01::run_one;
use super::ExpCtx;

pub const THREADS: [usize; 6] = [1, 2, 4, 7, 8, 16];

pub fn run(ctx: &ExpCtx) -> String {
    // pass 1: no admission control, 4 QPs
    let mut no_ac = Vec::new();
    for &th in THREADS.iter() {
        let r = run_one(ctx, th, 4, None);
        no_ac.push((th, r));
    }
    let peak_idx = no_ac
        .iter()
        .enumerate()
        .max_by(|a, b| a.1 .1.iops().partial_cmp(&b.1 .1.iops()).unwrap())
        .unwrap()
        .0;
    // window := mean in-flight bytes at the knee (paper: ~7 MB)
    let window = (no_ac[peak_idx].1.mean_inflight_bytes as u64).max(64 * 1024);

    // pass 2: with the measured window
    let mut with_ac = Vec::new();
    for &th in THREADS.iter() {
        let r = run_one(ctx, th, 4, Some(window));
        with_ac.push((th, r));
    }

    let mut t = Table::new("Fig 8 — FIO with and without admission control (4 QPs)").headers(&[
        "threads",
        "IOPS (no AC)",
        "in-flight (no AC)",
        "IOPS (AC)",
        "in-flight (AC)",
    ]);
    for i in 0..THREADS.len() {
        t.row(&[
            THREADS[i].to_string(),
            format!("{:.0}", no_ac[i].1.iops()),
            fmt::bytes_f(no_ac[i].1.mean_inflight_bytes),
            format!("{:.0}", with_ac[i].1.iops()),
            fmt::bytes_f(with_ac[i].1.mean_inflight_bytes),
        ]);
    }
    let heavy_no = no_ac.last().unwrap().1.iops();
    let heavy_ac = with_ac.last().unwrap().1.iops();
    t.note(&format!(
        "window set to measured in-flight at the knee: {}",
        fmt::bytes(window)
    ));
    t.note(&format!(
        "paper: +29.9% IOPS under heavy load with the regulator -> measured {:+.1}% at {} threads",
        (heavy_ac / heavy_no - 1.0) * 100.0,
        THREADS.last().unwrap()
    ));
    t.note("with AC, in-flight bytes stabilize at the window instead of growing with threads");
    t.render()
}

/// Ablation for the paper's §5.1 extension hook ("RDMAbox also provides a
/// hook to implement custom admission control policy"): no regulator vs
/// the prototype's static window vs an AIMD controller on completion RTT
/// implemented through the same `AdmissionPolicy` trait.
pub fn run_ablation(ctx: &ExpCtx) -> String {
    use crate::coordinator::regulator::{AimdWindow, Regulator};
    use crate::coordinator::StackConfig;
    use crate::fabric::sim::run_pipeline_custom;
    use crate::workloads::fio::FioDriver;
    use crate::workloads::DriverStats;

    let threads = 16;
    let run = |reg: Option<Regulator>| {
        let stack = StackConfig::rdmabox(&ctx.fabric)
            .with_qps(4)
            .with_window(None);
        let stats = DriverStats::shared();
        let driver = Box::new(FioDriver::new(
            threads,
            2,
            4096,
            50,
            1 << 30,
            1,
            ctx.ops(64_000),
            42,
            stats,
        ));
        run_pipeline_custom(&ctx.fabric, &stack, 1, driver, reg)
    };

    let none = run(None);
    let knee = run_one(ctx, 8, 4, None);
    let window = (knee.mean_inflight_bytes as u64).max(16 * 4096);
    let stat = run(Some(Regulator::static_window(window)));
    // target RTT = healthy completion time at the knee (no-thrash regime)
    let target_rtt = (knee.read_lat.mean() as u64).max(10_000);
    let aimd = run(Some(Regulator::new(Box::new(AimdWindow::new(
        window,
        16 * 4096,
        4 << 20,
        target_rtt,
    )))));

    let mut t = Table::new("Ablation — admission-control policy hook (FIO, 16 threads, 4 QPs)")
        .headers(&["policy", "IOPS", "mean in-flight", "WQE cache misses"]);
    for (name, r) in [("none", &none), ("static (paper)", &stat), ("AIMD (hook)", &aimd)] {
        t.row(&[
            name.to_string(),
            format!("{:.0}", r.iops()),
            fmt::bytes_f(r.mean_inflight_bytes),
            fmt::count(r.trace.wqe_cache_misses),
        ]);
    }
    t.note("the AIMD controller is implemented purely through the AdmissionPolicy trait — the paper's proposed congestion-control hook");
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_control_improves_heavy_load() {
        let ctx = ExpCtx::quick();
        let no_ac = run_one(&ctx, 16, 4, None);
        // window from the 7-thread knee, as the harness does
        let knee = run_one(&ctx, 7, 4, None);
        let window = (knee.mean_inflight_bytes as u64).max(64 * 1024);
        let ac = run_one(&ctx, 16, 4, Some(window));
        assert!(
            ac.iops() > no_ac.iops(),
            "AC should help at 16 threads: {} vs {}",
            ac.iops(),
            no_ac.iops()
        );
        assert!(ac.peak_inflight_bytes <= window);
    }

    #[test]
    fn ablation_policies_all_complete_and_regulate() {
        let ctx = ExpCtx::quick();
        let out = run_ablation(&ctx);
        assert!(out.contains("AIMD"));
        assert!(out.contains("static"));
    }

    #[test]
    fn multiqp_beats_single_qp_at_peak() {
        // §6.1: multi-QP improves peak IOPS by engaging more NIC PUs
        let ctx = ExpCtx::quick();
        let q1 = run_one(&ctx, 4, 1, None);
        let q4 = run_one(&ctx, 8, 4, None);
        assert!(
            q4.iops() > q1.iops(),
            "4QP {} should beat 1QP {}",
            q4.iops(),
            q1.iops()
        );
    }
}
