//! Fig 5 — Adaptive Polling microbenchmark: 1M synchronous 4 KB writes,
//! one QP, two nodes. Sweeping MAX_RETRY moves Adaptive between
//! event-like (low CPU, interrupts) and busy-like (full bandwidth, no
//! interrupts) behaviour; at MAX_RETRY≈120 it reaches busy bandwidth at
//! lower CPU.

use crate::cli::Table;
use crate::coordinator::polling::PollingMode;
use crate::coordinator::StackConfig;
use crate::fabric::sim::{run_pipeline, SimReport};
use crate::util::fmt;
use crate::workloads::micro::SyncWriteDriver;

use super::ExpCtx;

pub const RETRIES: [u32; 6] = [0, 15, 30, 60, 120, 240];

pub fn run_one(ctx: &ExpCtx, polling: PollingMode) -> SimReport {
    let stack = StackConfig::rdmabox(&ctx.fabric)
        .with_polling(polling)
        .with_qps(1)
        .with_window(None);
    let driver = Box::new(SyncWriteDriver::new(ctx.ops(1_000_000), 4096));
    run_pipeline(&ctx.fabric, &stack, 1, driver)
}

pub fn run(ctx: &ExpCtx) -> String {
    let mut t = Table::new("Fig 5 — Adaptive Polling microbench (sync 4KB writes, 1 QP)")
        .headers(&[
            "mode",
            "bandwidth",
            "poller CPU (cores)",
            "interrupts",
            "ctx switches",
            "interrupts/WC",
        ]);
    let mut rows: Vec<(String, SimReport)> = Vec::new();
    rows.push(("Event".into(), run_one(ctx, PollingMode::Event)));
    for &r in RETRIES.iter() {
        rows.push((
            format!("Adaptive r={r}"),
            run_one(
                ctx,
                PollingMode::Adaptive {
                    batch: 16,
                    max_retry: r,
                },
            ),
        ));
    }
    rows.push(("Busy".into(), run_one(ctx, PollingMode::Busy)));

    for (name, r) in &rows {
        t.row(&[
            name.clone(),
            fmt::rate(r.throughput_bytes_per_sec()),
            format!("{:.3}", r.poller_cpu_cores()),
            fmt::count(r.trace.interrupts),
            fmt::count(r.trace.ctx_switches),
            format!("{:.3}", r.trace.interrupts_per_cqe()),
        ]);
    }
    let busy = &rows.last().unwrap().1;
    let r120 = &rows.iter().find(|(n, _)| n == "Adaptive r=120").unwrap().1;
    t.note(&format!(
        "paper: at MAX_RETRY=120 bandwidth matches busy polling at lower CPU -> measured: {:.0}% of busy bandwidth at {:.0}% of busy CPU",
        r120.throughput_bytes_per_sec() / busy.throughput_bytes_per_sec() * 100.0,
        r120.poller_cpu_cores() / busy.poller_cpu_cores() * 100.0
    ));
    t.note("interrupts/ctx-switches fall as MAX_RETRY grows (paper Fig 5c/5d)");
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_tunable_behaviour() {
        let mut ctx = ExpCtx::quick();
        ctx.quick = true;
        let out = run(&ctx);
        assert!(out.contains("Adaptive r=120"));
        // core claims, re-checked cheaply:
        let busy = run_one(&ctx, PollingMode::Busy);
        let r120 = run_one(
            &ctx,
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 120,
            },
        );
        let r0 = run_one(
            &ctx,
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 0,
            },
        );
        assert!(r120.throughput_bytes_per_sec() >= 0.9 * busy.throughput_bytes_per_sec());
        assert!(r120.poller_cpu_cores() < busy.poller_cpu_cores());
        assert!(r0.trace.interrupts > r120.trace.interrupts);
        assert!(r0.throughput_bytes_per_sec() < r120.throughput_bytes_per_sec());
    }
}
