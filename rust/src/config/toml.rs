//! TOML-subset parser (serde/toml are not in the offline registry).
//!
//! Supports exactly what our config files need: `[section]` headers,
//! `key = value` with integer (incl. size suffix k/m/g and `_`), float,
//! bool, and quoted-string values, plus `#` comments and blank lines.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

pub type Section = BTreeMap<String, Value>;
pub type Doc = BTreeMap<String, Section>;

/// Parse a TOML-subset document. Keys before any `[section]` land in the
/// section named `""`.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc: Doc = BTreeMap::new();
    let mut cur = String::new();
    doc.entry(cur.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
            cur = name.trim().to_string();
            doc.entry(cur.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let key = k.trim().to_string();
        let val = parse_value(v.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&cur).unwrap().insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // no '#' inside strings in our configs; keep it simple
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    let cleaned = s.replace('_', "");
    // size suffix?
    if let Some(last) = cleaned.chars().last() {
        if matches!(last, 'k' | 'K' | 'm' | 'M' | 'g' | 'G') {
            let mult: i64 = match last {
                'k' | 'K' => 1 << 10,
                'm' | 'M' => 1 << 20,
                _ => 1 << 30,
            };
            if let Ok(n) = cleaned[..cleaned.len() - 1].parse::<i64>() {
                return Ok(Value::Int(n * mult));
            }
        }
    }
    if let Ok(n) = cleaned.parse::<i64>() {
        return Ok(Value::Int(n));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Load and parse a file.
pub fn load(path: &str) -> Result<Doc, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# top comment
top = 1

[fabric]
link_gbps = 6.8      # inline comment
wqe_cache = 256
window = 7m
name = "connectx3"
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["top"], Value::Int(1));
        let f = &doc["fabric"];
        assert_eq!(f["link_gbps"].as_f64(), Some(6.8));
        assert_eq!(f["wqe_cache"].as_u64(), Some(256));
        assert_eq!(f["window"].as_u64(), Some(7 * 1024 * 1024));
        assert_eq!(f["name"].as_str(), Some("connectx3"));
        assert_eq!(f["enabled"].as_bool(), Some(true));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[oops").is_err());
        assert!(parse("keyonly").is_err());
        assert!(parse("x = @@").is_err());
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = parse("n = 1_000_000").unwrap();
        assert_eq!(doc[""]["n"].as_u64(), Some(1_000_000));
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc[""]["x"].as_f64(), Some(3.0));
    }
}
