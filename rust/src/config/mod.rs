//! Configuration system: fabric cost model, TOML-subset parser, presets.
//!
//! Precedence: built-in preset (`FabricConfig::connectx3_fdr`) → optional
//! `--config <file.toml>` `[fabric]` overrides → individual CLI flags.

pub mod fabric;
pub mod toml;

pub use fabric::FabricConfig;

use crate::cli::Args;

/// Resolve the fabric config from CLI args (`--config path` override file).
pub fn fabric_from_args(args: &Args) -> Result<FabricConfig, String> {
    let mut cfg = FabricConfig::connectx3_fdr();
    if let Some(path) = args.get("config") {
        let doc = toml::load(path)?;
        cfg.apply_overrides(&doc)?;
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_resolution_without_file() {
        let args = Args::default();
        let cfg = fabric_from_args(&args).unwrap();
        assert_eq!(cfg.nic_pus, 4);
    }

    #[test]
    fn missing_config_file_errors() {
        let mut args = Args::default();
        args.flags
            .insert("config".into(), "/nonexistent/x.toml".into());
        assert!(fabric_from_args(&args).is_err());
    }
}
