//! Fabric cost model configuration: every hardware parameter of the
//! simulated RDMA path (NIC, PCIe, link, host CPU, memory, disk).
//!
//! Defaults are calibrated to the paper's testbed — Mellanox ConnectX-3 FDR
//! (56 Gb/s) on CloudLab nodes with Xeon E5-2650v2 — not to reproduce
//! absolute numbers (our substrate is a simulator) but so that the *shapes*
//! the paper reports fall out: single-QP saturation around 4 FIO threads
//! (Fig 1), the ~928 KB user-space memcpy/registration crossover (Fig 4),
//! interrupt-vs-spin tradeoffs (Fig 5, 9, 10), and nbdX's block-size
//! amplification (Fig 12, 13).

use super::toml::{Doc, Value};

#[derive(Debug, Clone)]
pub struct FabricConfig {
    // ---- wire ----
    /// Link bandwidth in bytes/ns (6.8 GB/s ≈ FDR 56 Gb/s effective).
    pub link_bytes_per_ns: f64,
    /// One-way propagation + switch latency, ns.
    pub link_prop_ns: u64,

    // ---- PCIe ----
    /// PCIe gen3 x8 effective bandwidth, bytes/ns.
    pub pcie_bytes_per_ns: f64,
    /// CPU-side cost of one 64 B MMIO posted write (doorbell / WQE write).
    pub mmio_cpu_ns: u64,
    /// PCIe bus occupancy of one MMIO (MMIO wastes more bus than DMA).
    pub mmio_bus_bytes: u64,
    /// Latency of a NIC-initiated DMA read (descriptor or payload setup).
    pub dma_read_lat_ns: u64,

    // ---- NIC ----
    /// Number of NIC processing units; QPs hash onto PUs.
    pub nic_pus: usize,
    /// WQE cache entries (on-NIC). Overflow → extra DMA fetch per WQE.
    pub wqe_cache_entries: usize,
    /// Penalty for a WQE cache miss (re-fetch over PCIe), ns.
    pub wqe_miss_penalty_ns: u64,
    /// MPT (memory protection table) cache entries; miss → PCIe fetch.
    pub mpt_cache_entries: usize,
    pub mpt_miss_penalty_ns: u64,
    /// QP context cache entries; too many active QPs thrash it (Fig 11 K=8).
    pub qp_cache_entries: usize,
    pub qp_miss_penalty_ns: u64,
    /// Host CPU cost to post one WQE (verbs post_send + block-layer
    /// per-request path) — paid in the serialized submission section; the
    /// cost Batching-on-MR amortizes by merging N requests into one WQE.
    pub post_wqe_cpu_ns: u64,
    /// Base NIC processing time per WQE (scheduling, transport state), ns.
    pub wqe_proc_ns: u64,
    /// Per-PU payload streaming bandwidth, bytes/ns: a single QP cannot
    /// saturate the FDR link (the documented ConnectX per-QP limit that
    /// makes multi-QP worth +63.8% in §6.1).
    pub pu_stream_bytes_per_ns: f64,
    /// Extra per-SGE gather cost, ns.
    pub sge_proc_ns: u64,
    /// CQE DMA write to host memory, ns (suppressed when unsignaled).
    pub cqe_dma_ns: u64,
    /// Max SGEs per WQE (batching-on-MR merge limit per WR).
    pub max_sge: usize,
    /// Max WRs in one doorbell chain.
    pub max_doorbell_chain: usize,

    // ---- host CPU ----
    pub cores: usize,
    /// Interrupt delivery + handler entry, ns.
    pub interrupt_ns: u64,
    /// Context switch cost, ns.
    pub ctx_switch_ns: u64,
    /// One `ibv_poll_cq` call, ns (hit or miss).
    pub poll_call_ns: u64,
    /// CQ event re-arm (`ibv_req_notify_cq`), ns.
    pub cq_arm_ns: u64,
    /// memcpy bandwidth, bytes/ns (preMR staging copy).
    pub memcpy_bytes_per_ns: f64,
    /// Fixed memcpy call overhead, ns.
    pub memcpy_base_ns: u64,

    // ---- MR registration cost model (Fig 4) ----
    /// Kernel space registers by physical address: cheap, flat per page.
    pub kern_reg_base_ns: u64,
    pub kern_reg_per_page_ns: u64,
    /// User space pays PTE walk + NIC translation entry per page.
    pub user_reg_base_ns: u64,
    pub user_reg_per_page_ns: u64,
    /// Deregistration cost as a fraction of registration.
    pub dereg_factor: f64,
    /// MR-cache hit: looking up the lkey of an already-registered span
    /// (the pinning-free path's fast case — a hash probe plus a
    /// reference-bit write, no verbs call).
    pub mr_cache_hit_ns: u64,

    // ---- memory / paging ----
    pub page_size: u64,

    // ---- disk fallback (remote paging replication) ----
    pub disk_bytes_per_ns: f64,
    pub disk_seek_ns: u64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self {
            link_bytes_per_ns: 6.8,
            link_prop_ns: 1_300,
            pcie_bytes_per_ns: 7.9,
            mmio_cpu_ns: 300,
            mmio_bus_bytes: 256, // MMIO wastes ~4x its 64B payload on the bus
            dma_read_lat_ns: 500,
            nic_pus: 4,
            wqe_cache_entries: 16,
            wqe_miss_penalty_ns: 6_000,
            mpt_cache_entries: 2048,
            mpt_miss_penalty_ns: 450,
            qp_cache_entries: 16,
            qp_miss_penalty_ns: 700,
            post_wqe_cpu_ns: 1_200,
            wqe_proc_ns: 2_000,
            pu_stream_bytes_per_ns: 4.0,
            sge_proc_ns: 40,
            cqe_dma_ns: 250,
            max_sge: 16,
            max_doorbell_chain: 4,
            cores: 32,
            interrupt_ns: 4_000,
            ctx_switch_ns: 2_000,
            poll_call_ns: 120,
            cq_arm_ns: 150,
            memcpy_bytes_per_ns: 10.0,
            memcpy_base_ns: 300,
            kern_reg_base_ns: 400,
            kern_reg_per_page_ns: 20,
            user_reg_base_ns: 37_000,
            user_reg_per_page_ns: 250,
            dereg_factor: 0.5,
            mr_cache_hit_ns: 60,
            page_size: 4096,
            disk_bytes_per_ns: 0.12, // 120 MB/s
            disk_seek_ns: 6_000_000,
        }
    }
}

impl FabricConfig {
    /// Paper testbed preset (ConnectX-3 FDR + CloudLab host). Currently the
    /// defaults; kept as a named constructor so experiments read clearly.
    pub fn connectx3_fdr() -> Self {
        Self::default()
    }

    /// Cost of a memcpy of `bytes` into a pre-registered MR.
    #[inline]
    pub fn memcpy_ns(&self, bytes: u64) -> u64 {
        self.memcpy_base_ns + (bytes as f64 / self.memcpy_bytes_per_ns) as u64
    }

    /// Cost of dynamic MR registration of `bytes` (kernel or user space).
    #[inline]
    pub fn reg_ns(&self, bytes: u64, kernel: bool) -> u64 {
        let pages = bytes.div_ceil(self.page_size);
        if kernel {
            self.kern_reg_base_ns + pages * self.kern_reg_per_page_ns
        } else {
            self.user_reg_base_ns + pages * self.user_reg_per_page_ns
        }
    }

    #[inline]
    pub fn dereg_ns(&self, bytes: u64, kernel: bool) -> u64 {
        (self.reg_ns(bytes, kernel) as f64 * self.dereg_factor) as u64
    }

    /// Analytic user-space crossover size where dynMR beats preMR+memcpy
    /// (the paper measures ~928 KB). Used by Fig 4's harness assertion and
    /// by `MrStrategy::Threshold`.
    pub fn user_crossover_bytes(&self) -> u64 {
        let per_page_copy = self.page_size as f64 / self.memcpy_bytes_per_ns;
        let per_page_reg = self.user_reg_per_page_ns as f64;
        if per_page_copy <= per_page_reg {
            return u64::MAX; // registration never wins
        }
        let base_gap = self.user_reg_base_ns as f64 - self.memcpy_base_ns as f64;
        let pages = base_gap / (per_page_copy - per_page_reg);
        (pages.max(0.0) * self.page_size as f64) as u64
    }

    /// Wire transfer time of a payload, ns (bandwidth term only).
    #[inline]
    pub fn wire_ns(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.link_bytes_per_ns) as u64
    }

    /// PCIe transfer time of a payload, ns.
    #[inline]
    pub fn pcie_ns(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.pcie_bytes_per_ns) as u64
    }

    /// Disk write/read time for the replication fallback path.
    #[inline]
    pub fn disk_ns(&self, bytes: u64) -> u64 {
        self.disk_seek_ns + (bytes as f64 / self.disk_bytes_per_ns) as u64
    }

    /// Apply `[fabric]` overrides from a parsed TOML doc.
    pub fn apply_overrides(&mut self, doc: &Doc) -> Result<(), String> {
        let Some(sec) = doc.get("fabric") else {
            return Ok(());
        };
        for (k, v) in sec {
            self.set(k, v)
                .map_err(|e| format!("[fabric].{k}: {e}"))?;
        }
        Ok(())
    }

    fn set(&mut self, key: &str, v: &Value) -> Result<(), String> {
        macro_rules! f64field {
            ($f:ident) => {{
                self.$f = v.as_f64().ok_or("expected number")?;
            }};
        }
        macro_rules! u64field {
            ($f:ident) => {{
                self.$f = v.as_u64().ok_or("expected integer")?;
            }};
        }
        macro_rules! usizefield {
            ($f:ident) => {{
                self.$f = v.as_u64().ok_or("expected integer")? as usize;
            }};
        }
        match key {
            "link_bytes_per_ns" => f64field!(link_bytes_per_ns),
            "link_prop_ns" => u64field!(link_prop_ns),
            "pcie_bytes_per_ns" => f64field!(pcie_bytes_per_ns),
            "mmio_cpu_ns" => u64field!(mmio_cpu_ns),
            "mmio_bus_bytes" => u64field!(mmio_bus_bytes),
            "dma_read_lat_ns" => u64field!(dma_read_lat_ns),
            "nic_pus" => usizefield!(nic_pus),
            "wqe_cache_entries" => usizefield!(wqe_cache_entries),
            "wqe_miss_penalty_ns" => u64field!(wqe_miss_penalty_ns),
            "mpt_cache_entries" => usizefield!(mpt_cache_entries),
            "mpt_miss_penalty_ns" => u64field!(mpt_miss_penalty_ns),
            "qp_cache_entries" => usizefield!(qp_cache_entries),
            "qp_miss_penalty_ns" => u64field!(qp_miss_penalty_ns),
            "post_wqe_cpu_ns" => u64field!(post_wqe_cpu_ns),
            "wqe_proc_ns" => u64field!(wqe_proc_ns),
            "pu_stream_bytes_per_ns" => f64field!(pu_stream_bytes_per_ns),
            "sge_proc_ns" => u64field!(sge_proc_ns),
            "cqe_dma_ns" => u64field!(cqe_dma_ns),
            "max_sge" => usizefield!(max_sge),
            "max_doorbell_chain" => usizefield!(max_doorbell_chain),
            "cores" => usizefield!(cores),
            "interrupt_ns" => u64field!(interrupt_ns),
            "ctx_switch_ns" => u64field!(ctx_switch_ns),
            "poll_call_ns" => u64field!(poll_call_ns),
            "cq_arm_ns" => u64field!(cq_arm_ns),
            "memcpy_bytes_per_ns" => f64field!(memcpy_bytes_per_ns),
            "memcpy_base_ns" => u64field!(memcpy_base_ns),
            "kern_reg_base_ns" => u64field!(kern_reg_base_ns),
            "kern_reg_per_page_ns" => u64field!(kern_reg_per_page_ns),
            "user_reg_base_ns" => u64field!(user_reg_base_ns),
            "user_reg_per_page_ns" => u64field!(user_reg_per_page_ns),
            "dereg_factor" => f64field!(dereg_factor),
            "mr_cache_hit_ns" => u64field!(mr_cache_hit_ns),
            "page_size" => u64field!(page_size),
            "disk_bytes_per_ns" => f64field!(disk_bytes_per_ns),
            "disk_seek_ns" => u64field!(disk_seek_ns),
            other => return Err(format!("unknown fabric key `{other}`")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn user_crossover_near_paper_value() {
        let c = FabricConfig::default();
        let x = c.user_crossover_bytes();
        // the paper measures 928 KB; our calibration should land within ~15%
        let paper = 928 * 1024;
        let rel = (x as f64 - paper as f64).abs() / paper as f64;
        assert!(rel < 0.15, "crossover {} vs paper {} (rel {rel:.2})", x, paper);
    }

    #[test]
    fn kernel_registration_always_beats_memcpy() {
        let c = FabricConfig::default();
        for sz in [4096u64, 64 << 10, 256 << 10, 1 << 20, 4 << 20] {
            assert!(
                c.reg_ns(sz, true) < c.memcpy_ns(sz),
                "kernel dynMR must win at {sz}"
            );
        }
    }

    #[test]
    fn user_small_sizes_favor_memcpy() {
        let c = FabricConfig::default();
        for sz in [4096u64, 64 << 10, 256 << 10] {
            assert!(
                c.reg_ns(sz, false) > c.memcpy_ns(sz),
                "user preMR must win at {sz}"
            );
        }
        // and large sizes favor registration
        assert!(c.reg_ns(4 << 20, false) < c.memcpy_ns(4 << 20));
    }

    #[test]
    fn overrides_apply() {
        let doc = toml::parse("[fabric]\nnic_pus = 8\nlink_bytes_per_ns = 12.5\n").unwrap();
        let mut c = FabricConfig::default();
        c.apply_overrides(&doc).unwrap();
        assert_eq!(c.nic_pus, 8);
        assert_eq!(c.link_bytes_per_ns, 12.5);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = toml::parse("[fabric]\nbogus = 1\n").unwrap();
        let mut c = FabricConfig::default();
        assert!(c.apply_overrides(&doc).is_err());
    }

    #[test]
    fn wire_and_pcie_costs_scale_linearly() {
        let c = FabricConfig::default();
        assert_eq!(c.wire_ns(0), 0);
        let w1 = c.wire_ns(1 << 20);
        let w2 = c.wire_ns(2 << 20);
        assert!((w2 as f64 / w1 as f64 - 2.0).abs() < 0.01);
        assert!(c.pcie_ns(1 << 20) < w1); // PCIe faster than FDR link
    }

    #[test]
    fn dereg_is_half_of_reg() {
        let c = FabricConfig::default();
        let r = c.reg_ns(1 << 20, false);
        let d = c.dereg_ns(1 << 20, false);
        assert!((d as f64 / r as f64 - 0.5).abs() < 0.01);
    }
}
