//! CLI plumbing: argument parsing and table rendering for the experiment
//! harness binary (`rdmabox`).

pub mod args;
pub mod table;

pub use args::Args;
pub use table::Table;
