//! Hand-rolled argument parser (clap is not in the offline registry).
//!
//! Grammar: `rdmabox <subcommand> [positional...] [--flag] [--key value]
//! [--key=value]`. Unknown flags are an error so typos do not silently fall
//! back to defaults.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value is next token unless it looks like another flag
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn parse_env() -> Result<Self, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => parse_u64(v).map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Reject any flag not in `allowed` (catch typos).
    pub fn check_allowed(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; allowed: {}",
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

/// Parse u64 with size suffixes: 4k/4K=4096, 2m/2M, 1g/1G (binary units),
/// plain digits, and `_` separators.
pub fn parse_u64(s: &str) -> Result<u64, String> {
    let s = s.replace('_', "");
    let (num, mult) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 1024u64),
        Some('m') | Some('M') => (&s[..s.len() - 1], 1024 * 1024),
        Some('g') | Some('G') => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s.as_str(), 1),
    };
    num.parse::<u64>()
        .map(|v| v * mult)
        .map_err(|e| format!("bad number `{s}`: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_and_positionals() {
        let a = parse(&["fig", "6"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig"));
        assert_eq!(a.positional, vec!["6"]);
    }

    #[test]
    fn parses_eq_and_space_flags() {
        let a = parse(&["run", "--threads=8", "--seed", "42", "--verbose"]);
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["x", "--fast", "--threads", "4"]);
        assert!(a.get_bool("fast"));
        assert_eq!(a.get_u64("threads", 0).unwrap(), 4);
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_u64("4k").unwrap(), 4096);
        assert_eq!(parse_u64("128K").unwrap(), 128 * 1024);
        assert_eq!(parse_u64("7m").unwrap(), 7 * 1024 * 1024);
        assert_eq!(parse_u64("2G").unwrap(), 2 * 1024 * 1024 * 1024);
        assert_eq!(parse_u64("1_000").unwrap(), 1000);
        assert!(parse_u64("abc").is_err());
    }

    #[test]
    fn check_allowed_catches_typos() {
        let a = parse(&["x", "--thread", "4"]);
        assert!(a.check_allowed(&["threads"]).is_err());
        assert!(a.check_allowed(&["thread"]).is_ok());
    }

    #[test]
    fn get_defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_u64("n", 7).unwrap(), 7);
        assert_eq!(a.get_str("mode", "hybrid"), "hybrid");
        assert_eq!(a.get_f64("theta", 0.99).unwrap(), 0.99);
    }
}
