//! ASCII table printer for experiment harness output. Every `fig N` /
//! `table N` subcommand prints its rows through this so the output looks
//! like the paper's tables and is easy to diff across runs.

#[derive(Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn headers<S: AsRef<str>>(mut self, hs: &[S]) -> Self {
        self.headers = hs.iter().map(|h| h.as_ref().to_string()).collect();
        self
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> &mut Self {
        self.rows
            .push(cells.iter().map(|c| c.as_ref().to_string()).collect());
        self
    }

    pub fn note(&mut self, n: &str) -> &mut Self {
        self.notes.push(n.to_string());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .headers
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let c = cells.get(i).map(|x| x.as_str()).unwrap_or("");
                s.push_str(&format!(" {:<width$} |", c, width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.headers.is_empty() {
            out.push_str(&fmt_row(&self.headers));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a quick one-line series "name: x1=v1 x2=v2 ..." for figure curves.
pub fn series_line(name: &str, xs: &[String], ys: &[String]) -> String {
    let pts: Vec<String> = xs
        .iter()
        .zip(ys.iter())
        .map(|(x, y)| format!("{x}={y}"))
        .collect();
    format!("{name}: {}", pts.join(" "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("T").headers(&["a", "longer"]);
        t.row(&["1", "2"]);
        t.row(&["100", "x"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| a   | longer |"));
        assert!(s.contains("| 100 | x      |"));
        // all separator lines equal length
        let lens: Vec<usize> = s
            .lines()
            .filter(|l| l.starts_with('+'))
            .map(|l| l.len())
            .collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn handles_ragged_rows_and_notes() {
        let mut t = Table::new("").headers(&["a", "b", "c"]);
        t.row(&["1"]);
        t.note("hello");
        let s = t.render();
        assert!(s.contains("note: hello"));
        assert!(s.contains("| 1 |"));
    }

    #[test]
    fn series_line_format() {
        let s = series_line(
            "busy",
            &["1".into(), "2".into()],
            &["10".into(), "20".into()],
        );
        assert_eq!(s, "busy: 1=10 2=20");
    }
}
