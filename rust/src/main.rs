//! `rdmabox` — the experiment/driver CLI.
//!
//! ```text
//! rdmabox fig <N> [--full] [--config fabric.toml]   regenerate figure N
//! rdmabox table 1                                   regenerate Table 1
//! rdmabox all [--full]                              every figure + table
//! rdmabox ml-e2e [--steps N]                        live 3-layer training
//! rdmabox qos [--pages N] [--nodes N]               live hog-vs-victim QoS demo
//! rdmabox gossip-smoke --listen <addr>              two-process gossip peer (side A)
//! rdmabox gossip-smoke --connect <addr>             two-process gossip peer (side B)
//! rdmabox list                                      what can run
//! ```

use rdmabox::cli::Args;
use rdmabox::config;
use rdmabox::experiments::{run_by_id, ExpCtx, ALL_IDS};

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Result<ExpCtx, String> {
    let fabric = config::fabric_from_args(args)?;
    Ok(ExpCtx {
        fabric,
        quick: !args.get_bool("full"),
    })
}

fn dispatch(args: &Args) -> Result<(), String> {
    match args.subcommand.as_deref() {
        Some("fig") => {
            args.check_allowed(&["full", "config"])?;
            let id = args
                .positional
                .first()
                .ok_or("usage: rdmabox fig <1|4|5|6|7|8|9|10|11|12|13|14>")?;
            let ctx = ctx_from(args)?;
            let out = run_by_id(id, &ctx).ok_or_else(|| format!("unknown figure `{id}`"))?;
            print!("{out}");
            Ok(())
        }
        Some("table") => {
            args.check_allowed(&["full", "config"])?;
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("1");
            if id != "1" {
                return Err("only table 1 exists in the paper".into());
            }
            let ctx = ctx_from(args)?;
            print!("{}", run_by_id("table1", &ctx).unwrap());
            Ok(())
        }
        Some("all") => {
            args.check_allowed(&["full", "config"])?;
            let ctx = ctx_from(args)?;
            for id in ALL_IDS {
                let label = if id == "table1" {
                    "Table 1".to_string()
                } else {
                    format!("Figure {id}")
                };
                println!("###### {label} ######");
                let t0 = std::time::Instant::now();
                print!("{}", run_by_id(id, &ctx).unwrap());
                println!(
                    "  [{label} regenerated in {:.1}s]\n",
                    t0.elapsed().as_secs_f64()
                );
            }
            Ok(())
        }
        Some("ml-e2e") => {
            args.check_allowed(&["steps", "rows", "resident"])?;
            let steps = args.get_u64("steps", 300)? as usize;
            let rows = args.get_u64("rows", 2048)? as usize;
            let resident = args.get_f64("resident", 0.25)?;
            run_ml_e2e(steps, rows, resident)
        }
        Some("qos") => {
            args.check_allowed(&["pages", "nodes"])?;
            let pages = args.get_u64("pages", 512)?;
            let nodes = args.get_u64("nodes", 2)? as usize;
            run_qos_demo(nodes, pages)
        }
        Some("gossip-smoke") => {
            args.check_allowed(&["listen", "connect", "ios"])?;
            let ios = args.get_u64("ios", 8)?;
            run_gossip_smoke(args, ios)
        }
        Some("list") | None => {
            println!("figures: {}", ALL_IDS.join(", "));
            println!(
                "usage: rdmabox fig <N> [--full] | rdmabox table 1 | rdmabox all | rdmabox ml-e2e | rdmabox qos | rdmabox gossip-smoke"
            );
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand `{other}` (try `rdmabox list`)")),
    }
}

/// Live multi-tenant QoS demo on the loopback fabric: a hog tenant
/// floods `pages` writes while a weighted victim tenant issues a much
/// smaller working set through the same shared merge queues and
/// admission window; afterwards the victim's data is read back verified
/// and the per-tenant regulator/drain counters are printed.
fn run_qos_demo(nodes: usize, pages: u64) -> Result<(), String> {
    use rdmabox::cli::Table;
    use rdmabox::coordinator::EngineSpec;
    use rdmabox::fabric::loopback::{LiveBox, LoopbackFabric};

    if nodes == 0 {
        return Err("--nodes must be at least 1".into());
    }
    let cap_per_node = 64 << 20;
    let fabric = LoopbackFabric::start(nodes, cap_per_node);
    // tenant 0 = victim (weight 3), tenant 1 = hog (weight 1): the
    // victim gets the larger admission share and drain priority even
    // though the hog submits ~8x the bytes.
    let spec = EngineSpec::new(nodes)
        .window(Some(16 * 4096))
        .tenants(&[3, 1]);
    let lb = LiveBox::build(fabric, &spec);

    let hog_pages = pages.max(8);
    let victim_pages = hog_pages / 8;
    let t0 = std::time::Instant::now();
    let hog = {
        let lb = lb.clone();
        std::thread::spawn(move || {
            // hog region: the upper half of each node's donation
            let base = (cap_per_node as u64) / 2;
            for i in 0..hog_pages {
                let node = (i % nodes as u64) as usize;
                lb.write_t(1, node, base + (i / nodes as u64) * 4096, &[0xA5u8; 4096]);
            }
        })
    };
    let victim = {
        let lb = lb.clone();
        std::thread::spawn(move || {
            for i in 0..victim_pages {
                let node = (i % nodes as u64) as usize;
                let fill = (i % 251) as u8 + 1;
                lb.write_t(0, node, (i / nodes as u64) * 4096, &[fill; 4096]);
            }
        })
    };
    hog.join().map_err(|_| "hog thread panicked")?;
    victim.join().map_err(|_| "victim thread panicked")?;
    for i in 0..victim_pages {
        let node = (i % nodes as u64) as usize;
        let data = lb.read_t(0, node, (i / nodes as u64) * 4096, 4096);
        let fill = (i % 251) as u8 + 1;
        if data[0] != fill || data[4095] != fill {
            return Err(format!("victim page {i} corrupted under hog load"));
        }
    }
    let wall_ms = t0.elapsed().as_millis();

    let mut table = Table::new("Multi-tenant QoS — loopback live").headers(&[
        "tenant", "weight", "posted B", "retired B", "in-window B", "borrows", "drained B",
        "deficit B",
    ]);
    for ts in lb.tenant_stats() {
        table.row(&ts.row());
    }
    table.note(&format!(
        "{nodes} node(s), hog {hog_pages} pages vs victim {victim_pages} pages, \
         64 KiB admission window, {wall_ms} ms; victim data read back verified"
    ));
    table.note("tenant 0 = victim (weight 3), tenant 1 = hog (weight 1)");
    table.print();
    Ok(())
}

/// Two-process gossip smoke: `--listen <addr>` on one side, `--connect
/// <addr>` on the other (addresses with a `:` are TCP `host:port`;
/// anything else is a Unix-domain socket path). Each process builds one
/// member of a two-engine gossip cluster, forces divergence with
/// disjoint local writes (every placed write mints an election epoch
/// the peer has never seen), then runs the lockstep anti-entropy sync
/// over the real byte stream until both fingerprints agree — the
/// ISSUE's two-OS-process convergence acceptance, runnable by hand.
fn run_gossip_smoke(args: &Args, ios: u64) -> Result<(), String> {
    use rdmabox::fabric::socket::{listen_tcp, ReconnectPeer};
    use rdmabox::metrics::RecoveryStats;

    let (addr, listen) = match (args.get("listen"), args.get("connect")) {
        (Some(a), None) => (a, true),
        (None, Some(a)) => (a, false),
        _ => return Err("pass exactly one of --listen <addr> or --connect <addr>".into()),
    };
    // the listener is engine 0 of the cluster, the connector engine 1
    let engine_id = usize::from(!listen);
    if addr.contains(':') {
        if listen {
            let mut peer = listen_tcp(addr).map_err(|e| format!("{addr}: {e}"))?;
            let peer_id = peer
                .hello(engine_id as u32)
                .map_err(|e| format!("handshake: {e}"))?;
            gossip_smoke(&mut peer, engine_id, peer_id, ios, 1)
        } else {
            // the TCP connector rides a ReconnectPeer: if the listener
            // dies and comes back, the sync restarts over a fresh dial
            // and the repair count lands in the recovery stats
            let mut peer = ReconnectPeer::connect(addr, engine_id as u32)
                .map_err(|e| format!("{addr}: {e}"))?;
            let peer_id = peer.peer_id;
            gossip_smoke(&mut peer, engine_id, peer_id, ios, 8)?;
            let rec = RecoveryStats {
                reconnects: peer.reconnects,
                ..RecoveryStats::default()
            };
            println!(
                "GOSSIP-SMOKE transport: survived {} reconnect(s)",
                rec.reconnects
            );
            Ok(())
        }
    } else {
        gossip_smoke_uds(addr, listen, engine_id, ios)
    }
}

#[cfg(unix)]
fn gossip_smoke_uds(addr: &str, listen: bool, engine_id: usize, ios: u64) -> Result<(), String> {
    use rdmabox::fabric::socket::{connect_uds, listen_uds};
    let peer = if listen { listen_uds(addr) } else { connect_uds(addr) };
    let mut peer = peer.map_err(|e| format!("{addr}: {e}"))?;
    let peer_id = peer
        .hello(engine_id as u32)
        .map_err(|e| format!("handshake: {e}"))?;
    gossip_smoke(&mut peer, engine_id, peer_id, ios, 1)
}

#[cfg(not(unix))]
fn gossip_smoke_uds(
    _addr: &str,
    _listen: bool,
    _engine_id: usize,
    _ios: u64,
) -> Result<(), String> {
    Err("unix-domain sockets are unavailable on this platform; use a host:port address".into())
}

fn gossip_smoke<P: rdmabox::fabric::socket::FramedPeer>(
    peer: &mut P,
    engine_id: usize,
    peer_id: u32,
    ios: u64,
    sync_attempts: u32,
) -> Result<(), String> {
    use rdmabox::coordinator::engine::{DrainOut, IoEngine};
    use rdmabox::coordinator::EngineSpec;
    use rdmabox::fabric::socket::gossip_sync;
    use rdmabox::fabric::{AppIo, Dir, Wc, WcStatus};

    /// Submit one placed write and complete every leg successfully (the
    /// engine is its own fabric here — the socket carries gossip only).
    fn drive_write(e: &mut IoEngine, out: &mut DrainOut, id: u64, addr: u64) {
        e.submit(AppIo {
            id,
            dir: Dir::Write,
            node: 0,
            addr,
            len: 4096,
            thread: 0,
            t_submit: 0,
            tenant: 0,
        });
        loop {
            e.drain_all_into(0, out);
            if out.wrs.is_empty() {
                break;
            }
            for wr in &mut out.wrs {
                let wc = Wc {
                    wr_id: wr.wr_id,
                    qp: 0,
                    op: wr.op,
                    len: wr.len,
                    app_ios: std::mem::take(&mut wr.app_ios),
                    status: WcStatus::Success,
                    tenant: wr.tenant,
                };
                e.on_wc(&wc, 0);
            }
        }
    }

    if peer_id as usize == engine_id {
        return Err(format!("both peers claim engine id {engine_id}"));
    }
    let mut engine = IoEngine::build(
        &EngineSpec::new(2)
            .replicated(2)
            .resync(4 * 4096)
            .election()
            .gossip(engine_id, 2),
    );
    // forced divergence: each process writes a span of its own, so each
    // mints epochs the peer has not seen until the sync exchanges them
    let base = (engine_id as u64) << 21;
    let mut out = DrainOut::default();
    for i in 0..ios.max(1) {
        drive_write(&mut engine, &mut out, i, base + i * 4096);
    }
    let before = engine.gossip_fingerprint();
    // gossip deltas carry full state and absorbing is idempotent, so a
    // sync that dies with its transport is restarted from round zero (a
    // ReconnectPeer dials a fresh connection underneath)
    let mut converged = None;
    let mut last = String::from("gossip sync: no attempts made");
    for attempt in 0..sync_attempts.max(1) {
        match gossip_sync(peer, &mut engine, 32) {
            Ok(fp) => {
                converged = Some(fp);
                break;
            }
            Err(e) => {
                last = format!("gossip sync: {e}");
                if attempt + 1 < sync_attempts {
                    eprintln!("{last}; restarting the sync");
                }
            }
        }
    }
    let fp = converged.ok_or(last)?;
    let s = engine.gossip_stats().expect("gossip is enabled");
    println!(
        "GOSSIP-SMOKE OK engine {engine_id}: converged fingerprint {fp:#018x} \
         (local pre-sync {before:#018x}), {} rounds sent, {} absorbed, {} epoch raises",
        s.rounds_sent, s.rounds_absorbed, s.epoch_raises
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn run_ml_e2e(steps: usize, rows: usize, resident: f64) -> Result<(), String> {
    use rdmabox::ml::train_paged_logreg;
    use rdmabox::runtime::Runtime;
    if !rdmabox::runtime::artifacts_available() {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let mut rt = Runtime::from_artifacts().map_err(|e| e.to_string())?;
    println!(
        "PJRT platform: {} | training logreg on paged remote memory ({} rows, {:.0}% resident)",
        rt.platform(),
        rows,
        resident * 100.0
    );
    let r = train_paged_logreg(&mut rt, 3, rows, 256, 512, resident, steps, 0.5)
        .map_err(|e| e.to_string())?;
    for (i, l) in r.losses.iter().enumerate() {
        if i % 20 == 0 || i + 1 == r.losses.len() {
            println!("step {i:4}  loss {l:.4}");
        }
    }
    println!(
        "done: {} steps in {} ms | page faults {} hits {} | {} bytes read from remote | merged ios {}",
        r.steps, r.wall_ms, r.faults, r.hits, r.bytes_read, r.merged_ios
    );
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn run_ml_e2e(_steps: usize, _rows: usize, _resident: f64) -> Result<(), String> {
    Err("built without the `xla` feature — the PJRT runtime is gated; see README §PJRT runtime"
        .into())
}
