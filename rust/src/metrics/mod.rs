//! Report assembly shared by the experiment harnesses: a named series of
//! (x, y) points plus ratio checks against the paper's reported numbers.

use crate::util::stats::geomean;

/// One measured curve of a figure.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub xs: Vec<String>,
    pub ys: Vec<f64>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn push<X: ToString>(&mut self, x: X, y: f64) {
        self.xs.push(x.to_string());
        self.ys.push(y);
    }

    pub fn max(&self) -> f64 {
        self.ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn argmax(&self) -> Option<&str> {
        let i = self
            .ys
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())?
            .0;
        Some(&self.xs[i])
    }
}

/// A shape check: "our ratio should be within [lo, hi]× of the paper's".
#[derive(Debug, Clone)]
pub struct ShapeCheck {
    pub what: String,
    pub paper: f64,
    pub measured: f64,
    pub pass: bool,
}

impl ShapeCheck {
    /// Pass when the measured ratio is in the same *direction* as the
    /// paper's (>1 stays >1) and within a loose band (the testbed is a
    /// simulator — we claim shape, not absolute numbers).
    pub fn direction(what: &str, paper: f64, measured: f64) -> Self {
        let pass = (paper >= 1.0) == (measured >= 1.0);
        Self {
            what: what.into(),
            paper,
            measured,
            pass,
        }
    }

    pub fn within(what: &str, paper: f64, measured: f64, rel_band: f64) -> Self {
        let pass = measured >= paper * (1.0 - rel_band) && measured <= paper * (1.0 + rel_band);
        Self {
            what: what.into(),
            paper,
            measured,
            pass,
        }
    }

    pub fn row(&self) -> Vec<String> {
        vec![
            self.what.clone(),
            format!("{:.2}", self.paper),
            format!("{:.2}", self.measured),
            if self.pass { "OK".into() } else { "MISS".into() },
        ]
    }
}

/// Per-tenant QoS counters exported by `IoEngine::stats()`-adjacent
/// surfaces (`IoEngine::tenant_stats`) and printed by the CLI: one row
/// per registered tenant, aggregating the regulator's admission ledger
/// with the merge queues' weighted-drain lane counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantStats {
    /// Dense tenant index (`crate::fabric::TenantId`).
    pub tenant: usize,
    /// Configured drain/admission weight.
    pub weight: u64,
    /// Bytes posted to the fabric on this tenant's behalf.
    pub posted_bytes: u64,
    /// Bytes whose completion released the tenant's sub-window.
    pub retired_bytes: u64,
    /// Bytes currently occupying the tenant's sub-window.
    pub window_occupancy: u64,
    /// High-water mark of `window_occupancy`.
    pub peak_window_occupancy: u64,
    /// Posts admitted while the tenant was over its proportional share —
    /// quota borrowed work-conservingly from idle peers.
    pub borrow_events: u64,
    /// Bytes drained out of this tenant's merge-queue lanes (read +
    /// write) by the weighted-deficit-round-robin drain.
    pub drained_bytes: u64,
    /// Residual DRR deficit carried by the tenant's lanes (read + write)
    /// — nonzero when the tenant had queued work a closed window or a
    /// spent budget left behind.
    pub drain_deficit: u64,
}

impl TenantStats {
    /// Table row for the CLI (`id weight posted retired in-window
    /// borrows drained deficit`).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.tenant.to_string(),
            self.weight.to_string(),
            self.posted_bytes.to_string(),
            self.retired_bytes.to_string(),
            self.window_occupancy.to_string(),
            self.borrow_events.to_string(),
            self.drained_bytes.to_string(),
            self.drain_deficit.to_string(),
        ]
    }
}

/// Dynamic-MR-cache counters exported by `IoEngine::mr_cache_stats()`
/// when the pinning-free memory path is enabled
/// (`EngineSpec::mr_cache`): lazy-registration traffic over the clock
/// cache of registration spans, plus the deferred-deregistration batch
/// count. One snapshot per engine; all counters are cumulative.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MrCacheStats {
    /// WR touches that found every span already registered.
    pub mr_hits: u64,
    /// Span touches that lazily registered (first touch or re-fault
    /// after eviction).
    pub mr_misses: u64,
    /// Spans evicted under pinned-bytes pressure (queued for deferred
    /// deregistration).
    pub mr_evictions: u64,
    /// Deregistration batches flushed off the critical path.
    pub mr_dereg_batches: u64,
    /// Bytes currently pinned (registered spans resident in the cache).
    pub pinned_bytes: u64,
    /// Configured pinned-bytes cap.
    pub cap_bytes: u64,
}

impl MrCacheStats {
    /// Fraction of span touches served without a registration.
    pub fn hit_rate(&self) -> f64 {
        let t = self.mr_hits + self.mr_misses;
        if t == 0 {
            0.0
        } else {
            self.mr_hits as f64 / t as f64
        }
    }

    /// Table row for the CLI (`hits misses hit% evictions dereg-batches
    /// pinned/cap`).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.mr_hits.to_string(),
            self.mr_misses.to_string(),
            format!("{:.1}%", self.hit_rate() * 100.0),
            self.mr_evictions.to_string(),
            self.mr_dereg_batches.to_string(),
            format!("{}/{}", self.pinned_bytes, self.cap_bytes),
        ]
    }
}

/// Gossip-plane counters exported by `IoEngine::gossip_stats()` when the
/// multi-engine coordination plane is enabled
/// (`EngineSpec::gossip(engine_id, engines)`): anti-entropy rounds
/// exported/absorbed plus what each merge actually changed. One snapshot
/// per engine; all counters are cumulative.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GossipStats {
    /// Deltas this engine exported.
    pub rounds_sent: u64,
    /// Peer deltas merged (past the staleness filter).
    pub rounds_absorbed: u64,
    /// Peer deltas dropped as duplicates or reorders (round ≤ the
    /// highest already absorbed from that peer) — the alloc-free path.
    pub stale_rounds: u64,
    /// Epoch-vector entries (required or applied) a merge raised.
    pub epoch_raises: u64,
    /// Node-state transitions adopted from peers (LWW wins).
    pub state_adoptions: u64,
    /// Missed-write ranges learned from peers and fed to resync.
    pub missed_merged: u64,
    /// Disk-surrender log entries consumed from peers.
    pub disk_spans_absorbed: u64,
}

impl GossipStats {
    /// Table row for the CLI (`sent absorbed stale raises adoptions
    /// missed disk-spans`).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.rounds_sent.to_string(),
            self.rounds_absorbed.to_string(),
            self.stale_rounds.to_string(),
            self.epoch_raises.to_string(),
            self.state_adoptions.to_string(),
            self.missed_merged.to_string(),
            self.disk_spans_absorbed.to_string(),
        ]
    }
}

/// Completion-recovery counters exported by `IoEngine::recovery_stats()`
/// when deadlines are enabled (`EngineSpec::deadlines(timeout_ns,
/// max_retries)`): local timeout retirements, per-QP error/reset
/// transitions, and (on the socket fabric) connection repairs. One
/// snapshot per engine; all counters are cumulative.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// WRs retired locally by deadline expiry (a synthesized timeout-WC
    /// released the window and rerouted the request).
    pub timeouts: u64,
    /// Outstanding WRs flushed as timeout-WCs by a QP entering `Error`.
    pub flushes: u64,
    /// QP `Error → Resetting → Ok` recoveries completed after probation.
    pub resets: u64,
    /// Socket-fabric connections re-established after a peer death
    /// (counted by the reconnect path, folded in by the smoke driver).
    pub reconnects: u64,
}

impl RecoveryStats {
    /// Table row for the CLI (`timeouts flushes resets reconnects`).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.timeouts.to_string(),
            self.flushes.to_string(),
            self.resets.to_string(),
            self.reconnects.to_string(),
        ]
    }
}

/// Summary speedup across checks (geometric mean of measured ratios).
pub fn summary_speedup(checks: &[ShapeCheck]) -> f64 {
    geomean(
        &checks
            .iter()
            .map(|c| c.measured.max(1e-9))
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_tracks_max() {
        let mut s = Series::new("iops");
        s.push(1, 10.0);
        s.push(4, 42.0);
        s.push(8, 17.0);
        assert_eq!(s.max(), 42.0);
        assert_eq!(s.argmax(), Some("4"));
    }

    #[test]
    fn direction_check() {
        assert!(ShapeCheck::direction("x", 6.48, 3.2).pass);
        assert!(!ShapeCheck::direction("x", 6.48, 0.7).pass);
        assert!(ShapeCheck::direction("y", 0.5, 0.9).pass);
    }

    #[test]
    fn within_check() {
        assert!(ShapeCheck::within("x", 100.0, 90.0, 0.15).pass);
        assert!(!ShapeCheck::within("x", 100.0, 50.0, 0.15).pass);
    }

    #[test]
    fn mr_cache_stats_hit_rate_and_row() {
        // an untouched cache reports 0% rather than dividing by zero
        assert_eq!(MrCacheStats::default().hit_rate(), 0.0);
        let s = MrCacheStats {
            mr_hits: 3,
            mr_misses: 1,
            mr_evictions: 1,
            mr_dereg_batches: 1,
            pinned_bytes: 65536,
            cap_bytes: 131072,
        };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        let row = s.row();
        assert_eq!(row[2], "75.0%");
        assert_eq!(row[5], "65536/131072");
    }

    #[test]
    fn gossip_stats_row_orders_counters() {
        let s = GossipStats {
            rounds_sent: 4,
            rounds_absorbed: 3,
            stale_rounds: 1,
            epoch_raises: 12,
            state_adoptions: 2,
            missed_merged: 5,
            disk_spans_absorbed: 1,
        };
        assert_eq!(s.row(), vec!["4", "3", "1", "12", "2", "5", "1"]);
        assert_eq!(GossipStats::default().row(), vec!["0"; 7]);
    }

    #[test]
    fn recovery_stats_row_orders_counters() {
        let s = RecoveryStats {
            timeouts: 7,
            flushes: 4,
            resets: 2,
            reconnects: 1,
        };
        assert_eq!(s.row(), vec!["7", "4", "2", "1"]);
        assert_eq!(RecoveryStats::default().row(), vec!["0"; 4]);
    }

    #[test]
    fn speedup_summary() {
        let checks = vec![
            ShapeCheck::direction("a", 2.0, 2.0),
            ShapeCheck::direction("b", 8.0, 8.0),
        ];
        assert!((summary_speedup(&checks) - 4.0).abs() < 1e-9);
    }
}
