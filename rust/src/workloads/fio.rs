//! FIO-style raw block workload: N threads, each keeping `iodepth` random
//! block I/Os in flight against the remote block device (Fig 1, Fig 8).

use std::cell::RefCell;
use std::rc::Rc;

use crate::fabric::sim::{Driver, Sim};
use crate::fabric::{AppIo, Dir};
use crate::util::rng::Pcg32;

use super::DriverStats;

pub struct FioDriver {
    pub threads: usize,
    pub iodepth: usize,
    pub block: u64,
    /// 0..=100.
    pub read_pct: u64,
    /// Device span in bytes (addresses are sampled uniformly in it).
    pub span: u64,
    pub nodes: usize,
    pub target_ops: u64,
    pub warmup_ops: u64,
    rng: Pcg32,
    stats: Rc<RefCell<DriverStats>>,
    submitted: u64,
    done: u64,
}

impl FioDriver {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        threads: usize,
        iodepth: usize,
        block: u64,
        read_pct: u64,
        span: u64,
        nodes: usize,
        target_ops: u64,
        seed: u64,
        stats: Rc<RefCell<DriverStats>>,
    ) -> Self {
        Self {
            threads,
            iodepth,
            block,
            read_pct,
            span,
            nodes,
            target_ops,
            warmup_ops: target_ops / 10,
            rng: Pcg32::new(seed),
            stats,
            submitted: 0,
            done: 0,
        }
    }

    fn one(&mut self, sim: &mut Sim, thread: usize, at: u64) {
        let blocks = (self.span / self.block).max(1);
        let addr = self.rng.gen_below(blocks) * self.block;
        let dir = if self.rng.gen_below(100) < self.read_pct {
            Dir::Read
        } else {
            Dir::Write
        };
        let node = (addr / self.block) as usize % self.nodes;
        sim.submit_at(dir, node, addr, self.block, thread, at);
        self.submitted += 1;
    }
}

impl Driver for FioDriver {
    fn on_start(&mut self, sim: &mut Sim) {
        for t in 0..self.threads {
            for _ in 0..self.iodepth {
                self.one(sim, t, 0);
            }
        }
    }

    fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, lat: u64, done_at: u64) {
        self.done += 1;
        {
            let mut s = self.stats.borrow_mut();
            s.ops_done = self.done;
            s.end_ns = done_at;
            if self.done == self.warmup_ops {
                s.warm_start_ns = done_at;
            }
            if self.done > self.warmup_ops {
                s.warm_ops += 1;
                s.op_lat.record(lat);
            }
        }
        if self.done + (self.threads * self.iodepth) as u64 > self.target_ops
            && self.submitted >= self.target_ops
        {
            if self.done >= self.target_ops {
                sim.request_stop();
            }
            return; // drain without resubmitting
        }
        self.one(sim, io.thread, done_at);
    }

    fn on_timer(&mut self, _sim: &mut Sim, _t: usize, _tag: u64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;
    use crate::coordinator::StackConfig;
    use crate::fabric::sim::run_pipeline;

    fn run_fio(
        threads: usize,
        qps: usize,
        window: Option<u64>,
    ) -> (crate::fabric::sim::SimReport, Rc<RefCell<DriverStats>>) {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg)
            .with_qps(qps)
            .with_window(window);
        let stats = DriverStats::shared();
        let driver = Box::new(FioDriver::new(
            threads,
            2,
            4096,
            50,
            1 << 30,
            1,
            4000,
            7,
            stats.clone(),
        ));
        (run_pipeline(&cfg, &stack, 1, driver), stats)
    }

    #[test]
    fn completes_target() {
        let (r, stats) = run_fio(4, 1, None);
        assert!(r.completed_reads + r.completed_writes >= 4000);
        assert!(stats.borrow().throughput() > 0.0);
    }

    #[test]
    fn iops_rises_then_falls_with_threads_single_qp() {
        // the Fig 1a shape: saturation then decline under WQE-cache thrash
        let mut iops = Vec::new();
        for threads in [1usize, 2, 4, 8, 16] {
            let (r, _) = run_fio(threads, 1, None);
            iops.push(r.iops());
        }
        let peak = iops.iter().cloned().fold(0.0f64, f64::max);
        let peak_idx = iops.iter().position(|&x| x == peak).unwrap();
        assert!(peak_idx >= 1, "peak not at 1 thread: {iops:?}");
        assert!(
            *iops.last().unwrap() < peak * 0.98,
            "no decline after peak: {iops:?}"
        );
    }

    #[test]
    fn admission_control_tames_heavy_load() {
        // Fig 8: with a window, high-thread-count IOPS should not collapse
        let (without, _) = run_fio(16, 4, None);
        let (with, _) = run_fio(16, 4, Some(7 << 20));
        assert!(
            with.iops() >= without.iops() * 0.95,
            "with {} vs without {}",
            with.iops(),
            without.iops()
        );
        assert!(with.peak_inflight_bytes <= 7 << 20);
    }
}
