//! Fig 5 microbenchmark: one-to-one connection, synchronous 4 KB writes —
//! the next I/O is posted when the previous WC arrives. Measures bandwidth,
//! poller CPU, interrupts and context switches as MAX_RETRY varies.

use crate::fabric::sim::{Driver, Sim};
use crate::fabric::{AppIo, Dir};

pub struct SyncWriteDriver {
    pub ops: u64,
    pub len: u64,
    /// Pause between bursts (paper §5.2: real WC load is "intermittent and
    /// burst"; bursts of back-to-back writes separated by app think time).
    pub gap_every: u64,
    pub gap_ns: u64,
    done: u64,
    addr: u64,
}

impl SyncWriteDriver {
    pub fn new(ops: u64, len: u64) -> Self {
        Self {
            ops,
            len,
            gap_every: 16,
            gap_ns: 30_000,
            done: 0,
            addr: 0,
        }
    }

    fn next(&mut self, sim: &mut Sim, at: u64) {
        self.addr += self.len;
        if self.gap_every > 0 && self.done % self.gap_every == 0 {
            sim.set_timer(0, at + self.gap_ns, 1);
        } else {
            sim.submit_at(Dir::Write, 0, self.addr, self.len, 0, at);
        }
    }
}

impl Driver for SyncWriteDriver {
    fn on_start(&mut self, sim: &mut Sim) {
        sim.submit_at(Dir::Write, 0, self.addr, self.len, 0, 0);
    }

    fn on_io_done(&mut self, sim: &mut Sim, _io: &AppIo, _lat: u64, done_at: u64) {
        self.done += 1;
        if self.done >= self.ops {
            sim.request_stop();
            return;
        }
        self.next(sim, done_at);
    }

    fn on_timer(&mut self, sim: &mut Sim, _t: usize, tag: u64) {
        if tag == 1 {
            let now = sim.now();
            sim.submit_at(Dir::Write, 0, self.addr, self.len, 0, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;
    use crate::coordinator::polling::PollingMode;
    use crate::coordinator::StackConfig;
    use crate::fabric::sim::{run_pipeline, SimReport};

    fn run_sync(polling: PollingMode, ops: u64) -> SimReport {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg)
            .with_polling(polling)
            .with_qps(1)
            .with_window(None);
        run_pipeline(&cfg, &stack, 1, Box::new(SyncWriteDriver::new(ops, 4096)))
    }

    #[test]
    fn sync_ops_serialize() {
        let r = run_sync(PollingMode::Busy, 1000);
        assert_eq!(r.completed_writes, 1000);
        // strictly one WR at a time
        assert_eq!(r.peak_inflight_ops, 1);
    }

    #[test]
    fn fig5_shape_bandwidth_rises_with_max_retry() {
        // small MAX_RETRY behaves like event mode (slow, interrupts);
        // large MAX_RETRY approaches busy polling bandwidth at lower CPU.
        let busy = run_sync(PollingMode::Busy, 2000);
        let r0 = run_sync(
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 0,
            },
            2000,
        );
        let r120 = run_sync(
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 120,
            },
            2000,
        );
        let bw = |r: &SimReport| r.throughput_bytes_per_sec();
        assert!(
            bw(&r120) > bw(&r0),
            "bandwidth should rise with MAX_RETRY: {} vs {}",
            bw(&r120),
            bw(&r0)
        );
        assert!(
            bw(&r120) > 0.9 * bw(&busy),
            "MAX_RETRY=120 should approach busy: {} vs {}",
            bw(&r120),
            bw(&busy)
        );
        assert!(
            r120.poller_cpu_cores() < busy.poller_cpu_cores(),
            "adaptive CPU {} should stay below busy {}",
            r120.poller_cpu_cores(),
            busy.poller_cpu_cores()
        );
        assert!(
            r120.trace.interrupts < r0.trace.interrupts,
            "interrupts fall as MAX_RETRY grows"
        );
    }
}
