//! ML training memory traces (Fig 13): epoch-structured access over a
//! dataset larger than the container limit, plus model/state updates.
//!
//! Each workload is (dataset pages, sequential-batch sweep pattern,
//! compute per batch, update-write fraction). The paper's observation:
//! memory-hungry/low-compute jobs (TextRank) gain most from a faster
//! paging stack; compute-bound ones (K-means, GBoost) least.

use std::cell::RefCell;
use std::rc::Rc;

use crate::coordinator::node::NodeMap;
use crate::fabric::sim::{Driver, Sim};
use crate::fabric::{AppIo, Dir};
use crate::paging::{Pager, Target};
use crate::util::rng::Pcg32;

use super::DriverStats;

/// One ML workload's memory/compute profile.
#[derive(Debug, Clone, Copy)]
pub struct MlProfile {
    pub name: &'static str,
    /// Dataset pages swept per epoch.
    pub dataset_pages: u64,
    /// Pages per minibatch (sequential run).
    pub batch_pages: u64,
    /// Compute per minibatch, ns (inflated under CPU pressure).
    pub compute_per_batch_ns: u64,
    /// Fraction of batches that also write model/state pages.
    pub update_frac: f64,
    /// Model/state pages (hot, revisited every batch).
    pub state_pages: u64,
    pub epochs: u64,
}

/// Logistic regression: streaming sweeps, moderate compute, small model.
pub fn logreg() -> MlProfile {
    MlProfile {
        name: "LogisticRegression",
        dataset_pages: 24_000,
        batch_pages: 16,
        compute_per_batch_ns: 60_000,
        update_frac: 1.0,
        state_pages: 64,
        epochs: 3,
    }
}

/// Gradient-boost classification: compute-heavy histogram building.
pub fn gboost() -> MlProfile {
    MlProfile {
        name: "GradientBoost",
        dataset_pages: 20_000,
        batch_pages: 16,
        compute_per_batch_ns: 400_000,
        update_frac: 0.5,
        state_pages: 256,
        epochs: 3,
    }
}

/// K-means: compute-heavy distance evaluation, small state.
pub fn kmeans() -> MlProfile {
    MlProfile {
        name: "KMeans",
        dataset_pages: 24_000,
        batch_pages: 16,
        compute_per_batch_ns: 250_000,
        update_frac: 0.2,
        state_pages: 32,
        epochs: 3,
    }
}

/// TextRank: giant graph, very little compute per touched page —
/// the memory-hungriest of the four (paper: biggest RDMAbox win).
pub fn textrank() -> MlProfile {
    MlProfile {
        name: "TextRank",
        dataset_pages: 48_000,
        batch_pages: 8,
        compute_per_batch_ns: 15_000,
        update_frac: 0.9,
        state_pages: 2_000,
        epochs: 2,
    }
}

pub struct MlDriver {
    profile: MlProfile,
    resident_pages: usize,
    pager: Pager,
    rng: Pcg32,
    stats: Rc<RefCell<DriverStats>>,
    // progress
    epoch: u64,
    cursor: u64,
    /// Pages this batch still has to touch — touched *serially*, as a real
    /// single-threaded trainer faults (each fault blocks the thread; no
    /// artificial cross-fault coalescing).
    pending: std::collections::VecDeque<(u64, bool)>,
    waiting_io: Option<u64>,
    batch_start: u64,
    compute_ns: u64,
    disk_ns: u64,
    batches_done: u64,
}

const TAG_BATCH_DONE: u64 = 1;
const TAG_DISK_READ: u64 = 2;

impl MlDriver {
    pub fn new(
        profile: MlProfile,
        resident_frac: f64,
        nodes: usize,
        replicas: usize,
        disk_ns: u64,
        seed: u64,
        stats: Rc<RefCell<DriverStats>>,
    ) -> Self {
        let total = profile.dataset_pages + profile.state_pages;
        let resident = ((total as f64) * resident_frac).max(32.0) as usize;
        let mut pager = Pager::new(resident, NodeMap::new(nodes, replicas, 1 << 20), 4096)
            .with_reclaim_batch(32);
        // the dataset exists before training starts (loaded / mmapped)
        pager.prepopulate(total);
        Self {
            profile,
            resident_pages: resident,
            pager,
            rng: Pcg32::new(seed),
            stats,
            epoch: 0,
            cursor: 0,
            pending: std::collections::VecDeque::new(),
            waiting_io: None,
            batch_start: 0,
            compute_ns: 0,
            disk_ns,
            batches_done: 0,
        }
    }

    pub fn resident_pages(&self) -> usize {
        self.resident_pages
    }

    fn start_batch(&mut self, sim: &mut Sim, at: u64) {
        if self.epoch >= self.profile.epochs {
            sim.request_stop();
            let mut s = self.stats.borrow_mut();
            s.end_ns = at;
            return;
        }
        self.batch_start = at;
        let writes_model = self.rng.gen_bool(self.profile.update_frac);

        // dataset pages for this minibatch (sequential run within epoch)
        self.pending.clear();
        for i in 0..self.profile.batch_pages {
            self.pending
                .push_back(((self.cursor + i) % self.profile.dataset_pages, false));
        }
        // hot state pages (model params / cluster centers), a few per batch
        let state_base = self.profile.dataset_pages;
        for _ in 0..4u64.min(self.profile.state_pages) {
            let sp = state_base + self.rng.gen_below(self.profile.state_pages.max(1));
            self.pending.push_back((sp, writes_model));
        }

        self.cursor = (self.cursor + self.profile.batch_pages) % self.profile.dataset_pages;
        if self.cursor < self.profile.batch_pages {
            self.epoch += 1;
        }

        self.compute_ns = sim.inflate_cpu(self.profile.compute_per_batch_ns, 1);
        self.walk(sim, at);
    }

    /// Touch the batch's pages one at a time; a fault suspends the walk
    /// until its read completes (real page-fault semantics).
    fn walk(&mut self, sim: &mut Sim, at: u64) {
        while let Some((page, write)) = self.pending.pop_front() {
            let out = self.pager.touch_ra(page, write, 4);
            // write-backs and readahead never block the trainer
            for req in out.writebacks.iter().chain(out.readahead.iter()) {
                match req.target {
                    Target::Node(n) => {
                        sim.submit_at(req.dir, n, req.addr, req.len, 0, at);
                    }
                    Target::Disk => {
                        self.stats.borrow_mut().disk_ios += 1;
                    }
                }
            }
            if let Some(load) = out.load {
                match load.target {
                    Target::Node(n) => {
                        let id = sim.submit_at(load.dir, n, load.addr, load.len, 0, at);
                        self.waiting_io = Some(id);
                    }
                    Target::Disk => {
                        self.stats.borrow_mut().disk_ios += 1;
                        self.waiting_io = Some(u64::MAX); // disk marker
                        sim.set_timer(0, at + self.disk_ns, TAG_DISK_READ);
                    }
                }
                return; // suspended on the fault
            }
        }
        // all pages resident: run the compute
        sim.set_timer(0, at + self.compute_ns, TAG_BATCH_DONE);
    }

    fn finish_batch(&mut self, sim: &mut Sim, at: u64) {
        self.batches_done += 1;
        {
            let mut s = self.stats.borrow_mut();
            s.ops_done = self.batches_done;
            s.warm_ops = self.batches_done;
            s.end_ns = at;
            s.op_lat.record(at.saturating_sub(self.batch_start));
        }
        self.start_batch(sim, at);
    }

    fn io_arrived(&mut self, sim: &mut Sim, id: u64, at: u64) {
        if self.waiting_io == Some(id) {
            self.waiting_io = None;
            self.walk(sim, at);
        }
    }
}

impl Driver for MlDriver {
    fn on_start(&mut self, sim: &mut Sim) {
        self.start_batch(sim, 0);
    }

    fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, _lat: u64, done_at: u64) {
        if io.dir == Dir::Read {
            self.io_arrived(sim, io.id, done_at);
        }
    }

    fn on_timer(&mut self, sim: &mut Sim, _thread: usize, tag: u64) {
        let now = sim.now();
        match tag {
            TAG_BATCH_DONE => self.finish_batch(sim, now),
            TAG_DISK_READ => self.io_arrived(sim, u64::MAX, now),
            _ => {}
        }
    }
}

/// Run one ML workload to completion; returns wall-clock (virtual) time.
pub fn run_ml(
    fabric: &crate::config::FabricConfig,
    stack: &crate::coordinator::StackConfig,
    profile: MlProfile,
    resident_frac: f64,
    nodes: usize,
) -> (u64, crate::fabric::sim::SimReport) {
    let stats = DriverStats::shared();
    let disk_ns = fabric.disk_ns(4096);
    let driver = Box::new(MlDriver::new(
        profile,
        resident_frac,
        nodes,
        2,
        disk_ns,
        11,
        stats.clone(),
    ));
    let report = crate::fabric::sim::run_pipeline(fabric, stack, nodes, driver);
    let end = stats.borrow().end_ns;
    (end.max(report.elapsed_ns), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::FabricConfig;
    use crate::coordinator::StackConfig;

    fn small(p: MlProfile) -> MlProfile {
        MlProfile {
            dataset_pages: 2_000,
            state_pages: p.state_pages.min(128),
            epochs: 2,
            ..p
        }
    }

    #[test]
    fn trains_to_completion() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let (t, report) = run_ml(&cfg, &stack, small(logreg()), 0.25, 3);
        assert!(t > 0);
        assert!(report.completed_reads > 0, "paged in data");
    }

    #[test]
    fn rdmabox_faster_than_nbdx_on_memory_hungry_job() {
        let cfg = FabricConfig::default();
        let rbox = StackConfig::rdmabox(&cfg);
        let nbdx = baselines::nbdx(&cfg, 512 * 1024);
        let (t_box, _) = run_ml(&cfg, &rbox, small(textrank()), 0.25, 3);
        let (t_nbdx, _) = run_ml(&cfg, &nbdx, small(textrank()), 0.25, 3);
        assert!(
            t_nbdx > t_box,
            "nbdX {} should be slower than RDMAbox {}",
            t_nbdx,
            t_box
        );
    }

    #[test]
    fn compute_bound_job_less_sensitive_than_memory_bound() {
        let cfg = FabricConfig::default();
        let rbox = StackConfig::rdmabox(&cfg);
        let nbdx = baselines::nbdx(&cfg, 512 * 1024);
        let ratio = |p: MlProfile| {
            let (a, _) = run_ml(&cfg, &rbox, small(p), 0.25, 3);
            let (b, _) = run_ml(&cfg, &nbdx, small(p), 0.25, 3);
            b as f64 / a as f64
        };
        let r_text = ratio(textrank());
        let r_kmeans = ratio(kmeans());
        assert!(
            r_text > r_kmeans,
            "TextRank gap {} should exceed K-means gap {}",
            r_text,
            r_kmeans
        );
    }
}
