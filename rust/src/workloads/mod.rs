//! Workload generators and application models driving the fabric:
//!
//! * [`fio`] — FIO-style raw block I/O (threads × iodepth × block size),
//!   used by Fig 1 and Fig 8.
//! * [`micro`] — the synchronous 4 KB-write microbenchmark of Fig 5.
//! * [`kv`] — the memory-intensive application model: YCSB Zipfian ETC/SYS
//!   over VoltDB/MongoDB/Redis profiles with a container memory limit that
//!   forces paging (Fig 6, 7, 9–12, Table 1).
//! * [`mltrace`] — ML training memory traces (epoch sweeps + model
//!   updates) for Fig 13.

pub mod fio;
pub mod kv;
pub mod micro;
pub mod mltrace;

use crate::util::hist::Hist;
use std::cell::RefCell;
use std::rc::Rc;

/// Application-level statistics, shared between a driver (which lives
/// inside the sim) and the experiment harness (which reads it afterwards).
#[derive(Debug, Default)]
pub struct DriverStats {
    pub ops_done: u64,
    /// Ops completed after warmup (throughput window).
    pub warm_ops: u64,
    pub warm_start_ns: u64,
    pub end_ns: u64,
    /// Per-op application latency (post-warmup).
    pub op_lat: Hist,
    pub disk_ios: u64,
}

impl DriverStats {
    pub fn shared() -> Rc<RefCell<DriverStats>> {
        Rc::new(RefCell::new(DriverStats::default()))
    }

    /// Ops/sec over the post-warmup window.
    pub fn throughput(&self) -> f64 {
        let dt = self.end_ns.saturating_sub(self.warm_start_ns);
        if dt == 0 {
            0.0
        } else {
            self.warm_ops as f64 * 1e9 / dt as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_from_window() {
        let s = DriverStats {
            warm_ops: 1000,
            warm_start_ns: 1_000_000,
            end_ns: 2_000_000,
            ..Default::default()
        };
        // 1000 ops over 1 ms = 1M ops/s
        assert!((s.throughput() - 1e6).abs() < 1.0);
    }

    #[test]
    fn zero_window_is_zero() {
        let s = DriverStats::default();
        assert_eq!(s.throughput(), 0.0);
    }
}
