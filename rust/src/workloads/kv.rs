//! Memory-intensive application model: an in-memory store (VoltDB /
//! MongoDB / Redis profile) driven by YCSB Zipfian workloads (Facebook
//! ETC = 95/5 read/write, SYS = 75/25) under a container memory limit —
//! the paper's §6/§7.1 methodology. Misses page against the remote paging
//! system; dirty evictions replicate to 2 remote nodes.

use std::cell::RefCell;
use crate::util::fxhash::FxHashMap;
use std::rc::Rc;

use crate::coordinator::node::NodeMap;
use crate::fabric::sim::{Driver, Sim};
use crate::fabric::{AppIo, Dir};
use crate::paging::{Pager, Target};
use crate::util::rng::Pcg32;
use crate::util::zipf::ScrambledZipfian;

use super::DriverStats;

/// Application profile: how much CPU and how many page touches one
/// app-level operation costs.
#[derive(Debug, Clone, Copy)]
pub struct AppProfile {
    pub name: &'static str,
    pub record_bytes: u64,
    /// App compute per op (query parsing, index walk, txn bookkeeping).
    pub cpu_per_op_ns: u64,
    /// Probability an op touches a second data page (large documents /
    /// overflow chains).
    pub second_page_prob: f64,
    /// Probability an op touches a uniformly-random page of the heap —
    /// index interior nodes, allocator metadata, undo/txn buffers. This is
    /// what makes the apps *memory-intensive*: the uniform component defeats
    /// the page cache once the container limit bites (paper §6: "indexing
    /// strategies ... require more memory for indices as well as dataset").
    pub uniform_touch_prob: f64,
}

/// VoltDB: ACID in-memory SQL — CPU-heavy per op, 1 KB tuples, big index
/// and txn-undo footprint.
pub fn voltdb() -> AppProfile {
    AppProfile {
        name: "VoltDB",
        record_bytes: 1024,
        cpu_per_op_ns: 6_000,
        second_page_prob: 0.15,
        uniform_touch_prob: 0.6,
    }
}

/// MongoDB: document store, ~2 KB documents, BSON parsing overhead,
/// B-tree indexes over the whole collection.
pub fn mongodb() -> AppProfile {
    AppProfile {
        name: "MongoDB",
        record_bytes: 2048,
        cpu_per_op_ns: 9_000,
        second_page_prob: 0.35,
        uniform_touch_prob: 0.7,
    }
}

/// Redis: thin KV interface, small values, cheapest CPU path, dict +
/// allocator metadata spread over the heap.
pub fn redis() -> AppProfile {
    AppProfile {
        name: "Redis",
        record_bytes: 512,
        cpu_per_op_ns: 2_500,
        second_page_prob: 0.05,
        uniform_touch_prob: 0.45,
    }
}

/// YCSB workload mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// Facebook ETC: 95% read / 5% write.
    Etc,
    /// Facebook SYS: 75% read / 25% write.
    Sys,
}

impl Mix {
    pub fn read_pct(self) -> u64 {
        match self {
            Mix::Etc => 95,
            Mix::Sys => 75,
        }
    }
    pub fn label(self) -> &'static str {
        match self {
            Mix::Etc => "ETC",
            Mix::Sys => "SYS",
        }
    }
}

/// Build configuration for the KV model.
#[derive(Debug, Clone)]
pub struct KvConfig {
    pub profile: AppProfile,
    pub mix: Mix,
    pub records: u64,
    pub zipf_theta: f64,
    /// Fraction of the working set that fits in memory (container limit).
    pub resident_frac: f64,
    pub threads: usize,
    pub ops: u64,
    pub warmup_frac: f64,
    pub nodes: usize,
    pub replicas: usize,
    pub page_size: u64,
    pub seed: u64,
}

impl KvConfig {
    pub fn small(profile: AppProfile, mix: Mix) -> Self {
        Self {
            profile,
            mix,
            records: 200_000,
            zipf_theta: 0.99,
            resident_frac: 0.25,
            threads: 8,
            ops: 60_000,
            warmup_frac: 0.25,
            nodes: 3,
            replicas: 2,
            page_size: 4096,
            seed: 0x5EED,
        }
    }

    pub fn total_pages(&self) -> u64 {
        (self.records * self.profile.record_bytes).div_ceil(self.page_size)
    }
}

const TAG_NEXT_OP: u64 = 1;

struct ThreadState {
    /// Reads this op is still blocked on.
    waiting: u32,
    op_start: u64,
    /// CPU to charge once reads complete.
    cpu_ns: u64,
}

pub struct KvDriver {
    cfg: KvConfig,
    zipf: ScrambledZipfian,
    rng: Pcg32,
    pager: Pager,
    threads: Vec<ThreadState>,
    /// io id -> thread blocked on it (reads only).
    waiting_reads: FxHashMap<u64, usize>,
    stats: Rc<RefCell<DriverStats>>,
    ops_issued: u64,
    ops_done: u64,
    warmup_ops: u64,
    stopping: bool,
    disk_ns: u64,
}

impl KvDriver {
    pub fn new(cfg: KvConfig, disk_ns: u64, stats: Rc<RefCell<DriverStats>>) -> Self {
        let resident_pages = ((cfg.total_pages() as f64) * cfg.resident_frac).max(16.0) as usize;
        let map = NodeMap::new(cfg.nodes, cfg.replicas, 1 << 20);
        let mut pager =
            Pager::new(resident_pages, map, cfg.page_size).with_reclaim_batch(32);
        // YCSB load phase: the store is fully populated before measurement;
        // everything beyond the container limit already lives remote
        pager.prepopulate(cfg.total_pages());
        let zipf = ScrambledZipfian::new(cfg.records, cfg.zipf_theta);
        let warmup_ops = (cfg.ops as f64 * cfg.warmup_frac) as u64;
        let threads = (0..cfg.threads)
            .map(|_| ThreadState {
                waiting: 0,
                op_start: 0,
                cpu_ns: 0,
            })
            .collect();
        Self {
            rng: Pcg32::new(cfg.seed),
            zipf,
            pager,
            threads,
            waiting_reads: FxHashMap::default(),
            stats,
            ops_issued: 0,
            ops_done: 0,
            warmup_ops,
            stopping: false,
            disk_ns,
            cfg,
        }
    }

    pub fn pager(&self) -> &Pager {
        &self.pager
    }

    fn submit_req(
        &mut self,
        sim: &mut Sim,
        req: crate::paging::IoReq,
        thread: usize,
        at: u64,
        block_on_it: bool,
    ) {
        match req.target {
            Target::Node(n) => {
                let id = sim.submit_at(req.dir, n, req.addr, req.len, thread, at);
                if block_on_it {
                    self.waiting_reads.insert(id, thread);
                    self.threads[thread].waiting += 1;
                }
            }
            Target::Disk => {
                self.stats.borrow_mut().disk_ios += 1;
                if block_on_it {
                    // disk read: thread resumes after the disk latency
                    self.threads[thread].waiting += 1;
                    // tag encodes "disk read done" via the NEXT_OP path:
                    // we reuse a timer with a special resume handled in
                    // on_timer (tag = 2 | thread handled there)
                    sim.set_timer(thread, at + self.disk_ns, 2);
                }
                // disk writes are fire-and-forget
            }
        }
    }

    fn start_op(&mut self, sim: &mut Sim, thread: usize, at: u64) {
        if self.stopping || self.ops_issued >= self.cfg.ops {
            self.maybe_stop(sim);
            return;
        }
        self.ops_issued += 1;
        let key = self.zipf.sample(&mut self.rng);
        let is_read = self.rng.gen_below(100) < self.cfg.mix.read_pct();
        let first_page = key * self.cfg.profile.record_bytes / self.cfg.page_size;
        let mut pages = vec![first_page];
        if self.rng.gen_bool(self.cfg.profile.second_page_prob) {
            pages.push(first_page + 1);
        }
        if self.rng.gen_bool(self.cfg.profile.uniform_touch_prob) {
            // index/metadata touch: uniform over the whole heap — the
            // memory-pressure component the page cache cannot absorb
            pages.push(self.rng.gen_below(self.cfg.total_pages().max(1)));
        }

        let cpu = sim.inflate_cpu(self.cfg.profile.cpu_per_op_ns, self.cfg.threads);
        self.threads[thread].op_start = at;
        self.threads[thread].cpu_ns = cpu;
        self.threads[thread].waiting = 0;

        let mut reqs = Vec::new();
        for page in pages {
            // swap readahead (page-cluster) gives swap-ins their adjacency
            let out = self.pager.touch_ra(page, !is_read, 4);
            for wb in out.writebacks {
                reqs.push((wb, false));
            }
            if let Some(load) = out.load {
                reqs.push((load, true));
            }
            for ra in out.readahead {
                reqs.push((ra, false)); // readahead does not block the op
            }
        }
        for (req, block) in reqs {
            self.submit_req(sim, req, thread, at, block);
        }

        if self.threads[thread].waiting == 0 {
            // pure in-memory op: finishes after its CPU time
            sim.set_timer(thread, at + cpu, TAG_NEXT_OP);
        }
        // else: resumes when the blocked read(s) complete
    }

    fn finish_op(&mut self, sim: &mut Sim, thread: usize, at: u64) {
        self.ops_done += 1;
        let lat = at.saturating_sub(self.threads[thread].op_start);
        {
            let mut s = self.stats.borrow_mut();
            s.ops_done = self.ops_done;
            s.end_ns = at;
            if self.ops_done == self.warmup_ops {
                s.warm_start_ns = at;
            }
            if self.ops_done > self.warmup_ops {
                s.warm_ops += 1;
                s.op_lat.record(lat);
            }
        }
        if self.ops_done >= self.cfg.ops {
            self.stopping = true;
            self.maybe_stop(sim);
            return;
        }
        self.start_op(sim, thread, at);
    }

    fn maybe_stop(&mut self, sim: &mut Sim) {
        if self.stopping && self.ops_done >= self.cfg.ops {
            sim.request_stop();
        }
    }

    fn read_done(&mut self, sim: &mut Sim, thread: usize, at: u64) {
        let ts = &mut self.threads[thread];
        ts.waiting = ts.waiting.saturating_sub(1);
        if ts.waiting == 0 {
            let cpu = ts.cpu_ns;
            let t_done = at + cpu;
            // op completes after the remaining compute
            self.finish_op(sim, thread, t_done);
        }
    }
}

impl Driver for KvDriver {
    fn on_start(&mut self, sim: &mut Sim) {
        for t in 0..self.cfg.threads {
            self.start_op(sim, t, 0);
        }
    }

    fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, _lat: u64, done_at: u64) {
        if io.dir == Dir::Read {
            if let Some(thread) = self.waiting_reads.remove(&io.id) {
                self.read_done(sim, thread, done_at);
            }
        }
        // writeback completions need no app action
    }

    fn on_timer(&mut self, sim: &mut Sim, thread: usize, tag: u64) {
        let now = sim.now();
        match tag {
            TAG_NEXT_OP => self.finish_op(sim, thread, now),
            2 => self.read_done(sim, thread, now), // disk read complete
            _ => {}
        }
    }
}

/// Convenience: run a KV scenario against a stack; returns (SimReport,
/// DriverStats).
pub fn run_kv(
    fabric: &crate::config::FabricConfig,
    stack: &crate::coordinator::StackConfig,
    kv: KvConfig,
) -> (crate::fabric::sim::SimReport, DriverStats) {
    let stats = DriverStats::shared();
    let disk_ns = fabric.disk_ns(kv.page_size);
    let nodes = kv.nodes;
    let driver = Box::new(KvDriver::new(kv, disk_ns, stats.clone()));
    let report = crate::fabric::sim::run_pipeline(fabric, stack, nodes, driver);
    let s = std::rc::Rc::try_unwrap(stats)
        .map(|c| c.into_inner())
        .unwrap_or_else(|rc| {
            let b = rc.borrow();
            DriverStats {
                ops_done: b.ops_done,
                warm_ops: b.warm_ops,
                warm_start_ns: b.warm_start_ns,
                end_ns: b.end_ns,
                op_lat: b.op_lat.clone(),
                disk_ios: b.disk_ios,
            }
        });
    (report, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;
    use crate::coordinator::batching::BatchMode;
    use crate::coordinator::StackConfig;

    fn quick_cfg(mix: Mix) -> KvConfig {
        KvConfig {
            records: 50_000,
            ops: 12_000,
            threads: 8,
            ..KvConfig::small(voltdb(), mix)
        }
    }

    #[test]
    fn completes_and_measures() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let (report, stats) = run_kv(&cfg, &stack, quick_cfg(Mix::Etc));
        assert_eq!(stats.ops_done, 12_000);
        assert!(stats.throughput() > 0.0);
        assert!(stats.op_lat.count() > 0);
        // paging happened: reads and writes hit the fabric
        assert!(report.completed_reads > 0, "swap-ins occurred");
        assert!(report.completed_writes > 0, "swap-outs occurred");
    }

    #[test]
    fn sys_mix_writes_more_than_etc() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let (r_etc, _) = run_kv(&cfg, &stack, quick_cfg(Mix::Etc));
        let (r_sys, _) = run_kv(&cfg, &stack, quick_cfg(Mix::Sys));
        // more dirty pages -> more write-backs per op
        assert!(
            r_sys.completed_writes > r_etc.completed_writes,
            "SYS {} vs ETC {}",
            r_sys.completed_writes,
            r_etc.completed_writes
        );
    }

    #[test]
    fn smaller_resident_set_pages_more() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let mut kv25 = quick_cfg(Mix::Etc);
        kv25.resident_frac = 0.25;
        let mut kv50 = quick_cfg(Mix::Etc);
        kv50.resident_frac = 0.50;
        let (r25, s25) = run_kv(&cfg, &stack, kv25);
        let (r50, s50) = run_kv(&cfg, &stack, kv50);
        assert!(
            r25.completed_reads > r50.completed_reads,
            "25% resident faults more: {} vs {}",
            r25.completed_reads,
            r50.completed_reads
        );
        assert!(
            s50.throughput() > s25.throughput(),
            "more memory -> more throughput: {} vs {}",
            s50.throughput(),
            s25.throughput()
        );
    }

    #[test]
    fn hybrid_batching_beats_single_on_this_workload() {
        // the core Fig 6 comparison, small scale
        let cfg = FabricConfig::default();
        let hybrid = StackConfig::rdmabox(&cfg);
        let single = StackConfig::rdmabox(&cfg).with_batch(BatchMode::Single);
        let (rh, sh) = run_kv(&cfg, &hybrid, quick_cfg(Mix::Sys));
        let (rs, ss) = run_kv(&cfg, &single, quick_cfg(Mix::Sys));
        assert!(
            rh.trace.wqes_total() < rs.trace.wqes_total(),
            "hybrid reduces RDMA I/O: {} vs {}",
            rh.trace.wqes_total(),
            rs.trace.wqes_total()
        );
        assert!(
            sh.throughput() >= ss.throughput() * 0.95,
            "hybrid at least on par: {} vs {}",
            sh.throughput(),
            ss.throughput()
        );
    }

    #[test]
    fn deterministic() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let (a, sa) = run_kv(&cfg, &stack, quick_cfg(Mix::Etc));
        let (b, sb) = run_kv(&cfg, &stack, quick_cfg(Mix::Etc));
        assert_eq!(a.elapsed_ns, b.elapsed_ns);
        assert_eq!(sa.warm_ops, sb.warm_ops);
    }

    #[test]
    fn profiles_differ() {
        assert!(mongodb().cpu_per_op_ns > voltdb().cpu_per_op_ns);
        assert!(redis().cpu_per_op_ns < voltdb().cpu_per_op_ns);
        assert_eq!(Mix::Etc.read_pct(), 95);
        assert_eq!(Mix::Sys.read_pct(), 75);
    }
}
