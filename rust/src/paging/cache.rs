//! Page cache with CLOCK (second-chance) replacement — the host-side page
//! cache whose capacity is the container memory limit in the paper's
//! experiments (25% / 50% in-memory working set).
//!
//! CLOCK matters beyond fidelity: its hand sweeps frames in fault order, so
//! eviction bursts produce *runs* of victims that were faulted together —
//! which, combined with the sequential swap-slot allocator, is what gives
//! swap-out traffic the contiguity that Batching-on-MR exploits.

use crate::util::fxhash::FxHashMap;

#[derive(Debug, Clone, Copy)]
struct Frame {
    page: u64,
    referenced: bool,
    dirty: bool,
    occupied: bool,
}

/// Outcome of touching a page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Page was not resident. `evicted` is the victim (page, was_dirty) if
    /// the cache was full; the caller must write it back if dirty.
    Miss { evicted: Option<(u64, bool)> },
}

#[derive(Debug)]
pub struct ClockCache {
    frames: Vec<Frame>,
    map: FxHashMap<u64, usize>,
    hand: usize,
    capacity: usize,
    /// Frames emptied by batch reclaim, reusable without eviction.
    free_slots: Vec<usize>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub dirty_evictions: u64,
}

impl ClockCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            frames: Vec::with_capacity(capacity.min(1 << 20)),
            map: FxHashMap::with_capacity_and_hasher(capacity.min(1 << 20), Default::default()),
            hand: 0,
            capacity,
            free_slots: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
            dirty_evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.frames.len() - self.free_slots.len()
    }

    pub fn is_empty(&self) -> bool {
        // `frames` keeps slots freed by reclaim/invalidate, so the vector
        // being non-empty says nothing about residency — count like `len()`.
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, page: u64) -> bool {
        self.map.contains_key(&page)
    }

    pub fn is_dirty(&self, page: u64) -> bool {
        self.map
            .get(&page)
            .map_or(false, |&i| self.frames[i].dirty)
    }

    /// Touch `page`; `write` marks it dirty.
    pub fn access(&mut self, page: u64, write: bool) -> Access {
        if let Some(&i) = self.map.get(&page) {
            self.hits += 1;
            self.frames[i].referenced = true;
            self.frames[i].dirty |= write;
            return Access::Hit;
        }
        self.misses += 1;
        // frames emptied by batch reclaim are reused first
        if let Some(slot) = self.free_slots.pop() {
            self.frames[slot] = Frame {
                page,
                referenced: true,
                dirty: write,
                occupied: true,
            };
            self.map.insert(page, slot);
            return Access::Miss { evicted: None };
        }
        if self.frames.len() < self.capacity {
            self.map.insert(page, self.frames.len());
            self.frames.push(Frame {
                page,
                referenced: true,
                dirty: write,
                occupied: true,
            });
            return Access::Miss { evicted: None };
        }
        let (victim_page, victim_dirty, slot) = self.sweep_one();
        self.frames[slot] = Frame {
            page,
            referenced: true,
            dirty: write,
            occupied: true,
        };
        self.map.insert(page, slot);
        Access::Miss {
            evicted: Some((victim_page, victim_dirty)),
        }
    }

    /// One CLOCK sweep: returns (victim page, was dirty, freed slot).
    fn sweep_one(&mut self) -> (u64, bool, usize) {
        loop {
            let f = &mut self.frames[self.hand];
            if !f.occupied {
                self.hand = (self.hand + 1) % self.frames.len();
                continue;
            }
            if f.referenced {
                f.referenced = false;
                self.hand = (self.hand + 1) % self.frames.len();
            } else {
                let victim = (f.page, f.dirty);
                let slot = self.hand;
                self.map.remove(&f.page);
                f.occupied = false;
                self.hand = (self.hand + 1) % self.frames.len();
                self.evictions += 1;
                if victim.1 {
                    self.dirty_evictions += 1;
                }
                return (victim.0, victim.1, slot);
            }
        }
    }

    /// Batch reclaim (kswapd-style): evict up to `n` victims at once,
    /// leaving their frames free for upcoming faults. Victims come from
    /// consecutive CLOCK-hand positions — pages faulted together leave
    /// together, which (with sequential swap slots) makes the write-back
    /// burst contiguous on the swap device.
    pub fn reclaim(&mut self, n: usize) -> Vec<(u64, bool)> {
        let n = n.min(self.len().saturating_sub(1));
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let (p, d, slot) = self.sweep_one();
            self.free_slots.push(slot);
            out.push((p, d));
        }
        out
    }

    /// Frames currently free for faults without eviction.
    pub fn free_frames(&self) -> usize {
        self.free_slots.len() + (self.capacity - self.frames.len())
    }

    /// Drop a page (e.g. after a failed replica set forces a disk copy).
    pub fn invalidate(&mut self, page: u64) {
        if let Some(i) = self.map.remove(&page) {
            self.frames[i].occupied = false;
            self.free_slots.push(i);
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};

    #[test]
    fn fills_then_evicts() {
        let mut c = ClockCache::new(3);
        assert_eq!(c.access(1, false), Access::Miss { evicted: None });
        assert_eq!(c.access(2, false), Access::Miss { evicted: None });
        assert_eq!(c.access(3, true), Access::Miss { evicted: None });
        assert_eq!(c.access(1, false), Access::Hit);
        // full; referenced bits all set -> hand clears 1,2,3 then evicts 1
        match c.access(4, false) {
            Access::Miss {
                evicted: Some((p, dirty)),
            } => {
                assert_eq!(p, 1);
                assert!(!dirty);
            }
            other => panic!("{other:?}"),
        }
        assert!(!c.contains(1));
        assert!(c.contains(4));
    }

    #[test]
    fn dirty_bit_travels_to_eviction() {
        // cap 2, both frames referenced: the sweep for 12 clears the ref
        // bits on 10 and 11, wraps, and must evict 10 — the dirty page —
        // deterministically. No wildcard arms: any other outcome fails.
        let mut c = ClockCache::new(2);
        c.access(10, true); // dirty
        c.access(11, false);
        assert_eq!(
            c.access(12, false),
            Access::Miss {
                evicted: Some((10, true))
            },
            "the dirty page must be the victim and carry its dirty bit"
        );
        assert_eq!(c.dirty_evictions, 1);
        assert_eq!(c.evictions, 1);
        assert!(!c.contains(10));
        // the survivor 11 was swept clean, so the next fault evicts it —
        // and it must report clean (dirty never leaks between victims)
        assert_eq!(
            c.access(13, false),
            Access::Miss {
                evicted: Some((11, false))
            }
        );
        assert_eq!(c.dirty_evictions, 1, "clean eviction must not count");
    }

    /// Regression: `is_empty()` used to consult `frames.is_empty()`, which
    /// stays false forever once a frame existed — disagreeing with `len()`
    /// after reclaim/invalidate freed every frame.
    #[test]
    fn is_empty_agrees_with_len_after_invalidate_all() {
        let mut c = ClockCache::new(4);
        assert!(c.is_empty());
        for p in 0..3 {
            c.access(p, false);
        }
        assert!(!c.is_empty());
        for p in 0..3 {
            c.invalidate(p);
        }
        assert_eq!(c.len(), 0);
        assert!(c.is_empty(), "all residents invalidated");
        // batch reclaim path: refill, then reclaim down to one resident
        for p in 10..13 {
            c.access(p, false);
        }
        c.reclaim(2);
        c.invalidate(c.frames.iter().find(|f| f.occupied).unwrap().page);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty(), "reclaim + invalidate leaves it empty");
    }

    #[test]
    fn second_chance_protects_referenced() {
        let mut c = ClockCache::new(2);
        c.access(1, false);
        c.access(2, false);
        c.access(1, false); // re-reference 1
        c.access(3, false); // sweep: 1 gets second chance… eventually 2 out
        assert!(c.contains(1) || c.contains(3));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut c = ClockCache::new(4);
        for p in 0..4 {
            c.access(p, false);
        }
        for _ in 0..16 {
            for p in 0..4 {
                assert_eq!(c.access(p, false), Access::Hit);
            }
        }
        assert!(c.hit_rate() > 0.9);
    }

    #[test]
    fn invalidate_removes() {
        let mut c = ClockCache::new(3);
        c.access(1, true);
        c.access(2, false);
        c.invalidate(1);
        assert!(!c.contains(1));
        assert_eq!(c.len(), 1);
        assert!(c.contains(2));
        // re-access after invalidate is a miss
        assert!(matches!(c.access(1, false), Access::Miss { .. }));
    }

    /// Property: map and frames stay consistent; resident set never exceeds
    /// capacity; a hit never reports an eviction.
    #[test]
    fn prop_clock_invariants() {
        prop::forall(cfg(0xC70C4), |rng, size| {
            let cap = 1 + rng.gen_below(16) as usize;
            let mut c = ClockCache::new(cap);
            for _ in 0..size * 8 {
                let p = rng.gen_below(32);
                let was_resident = c.contains(p);
                match c.access(p, rng.gen_bool(0.3)) {
                    Access::Hit => {
                        if !was_resident {
                            return Err("hit on non-resident".into());
                        }
                    }
                    Access::Miss { evicted } => {
                        if was_resident {
                            return Err("miss on resident".into());
                        }
                        if let Some((v, _)) = evicted {
                            if c.contains(v) {
                                return Err("evicted page still resident".into());
                            }
                        }
                    }
                }
                if c.len() > cap {
                    return Err(format!("over capacity: {} > {}", c.len(), cap));
                }
                if rng.gen_bool(0.05) {
                    c.invalidate(rng.gen_below(32));
                }
            }
            Ok(())
        });
    }
}
