//! Swap-slot allocator: assigns remote device addresses to evicted pages.
//!
//! Mirrors the Linux swap allocator's behaviour that matters here: slots
//! are handed out *sequentially* (with freed-slot reuse), so a burst of
//! evictions — which CLOCK produces in runs — lands on contiguous device
//! addresses. That contiguity is precisely what Load-aware Batching's
//! adjacent-merge finds in swap-out traffic (paper Table 1: writes merge
//! well, zipf-random swap-ins much less).

#[derive(Debug, Default)]
pub struct SwapAllocator {
    next: u64,
    /// Freed slots, reused LIFO (cheap and preserves some locality).
    free: Vec<u64>,
    pub allocated: u64,
    pub reused: u64,
}

impl SwapAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a slot index (device address = slot * page_size).
    pub fn alloc(&mut self) -> u64 {
        self.allocated += 1;
        if let Some(s) = self.free.pop() {
            self.reused += 1;
            s
        } else {
            let s = self.next;
            self.next += 1;
            s
        }
    }

    /// Allocate `n` slots, preferring a fresh contiguous run (the batch
    /// path used when several victims are written back together).
    pub fn alloc_run(&mut self, n: usize) -> Vec<u64> {
        // a contiguous run beats freelist reuse for merge-ability
        if self.free.len() < n {
            let start = self.next;
            self.next += n as u64;
            self.allocated += n as u64;
            (start..start + n as u64).collect()
        } else {
            (0..n).map(|_| self.alloc()).collect()
        }
    }

    pub fn release(&mut self, slot: u64) {
        debug_assert!(slot < self.next, "releasing never-allocated slot");
        self.free.push(slot);
    }

    /// High-water mark of the swap device in slots.
    pub fn high_water(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_allocation() {
        let mut a = SwapAllocator::new();
        assert_eq!(a.alloc(), 0);
        assert_eq!(a.alloc(), 1);
        assert_eq!(a.alloc(), 2);
    }

    #[test]
    fn freed_slots_reused() {
        let mut a = SwapAllocator::new();
        let s0 = a.alloc();
        let _s1 = a.alloc();
        a.release(s0);
        assert_eq!(a.alloc(), s0);
        assert_eq!(a.reused, 1);
    }

    #[test]
    fn alloc_run_is_contiguous_when_freelist_small() {
        let mut a = SwapAllocator::new();
        a.alloc();
        let run = a.alloc_run(8);
        for w in run.windows(2) {
            assert_eq!(w[1], w[0] + 1);
        }
    }

    #[test]
    fn alloc_run_drains_freelist_when_large() {
        let mut a = SwapAllocator::new();
        let slots: Vec<u64> = (0..8).map(|_| a.alloc()).collect();
        for &s in &slots {
            a.release(s);
        }
        let run = a.alloc_run(4);
        assert_eq!(run.len(), 4);
        // reused from freelist, all below high water
        assert!(run.iter().all(|&s| s < 8));
    }

    #[test]
    fn high_water_tracks_fresh_allocations() {
        let mut a = SwapAllocator::new();
        a.alloc_run(16);
        assert_eq!(a.high_water(), 16);
        a.release(3);
        a.alloc(); // reuses 3
        assert_eq!(a.high_water(), 16);
    }
}
