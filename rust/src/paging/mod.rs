//! Remote paging system (paper §6, §7.1): a virtual swap device backed by
//! remote memory through the RDMAbox node abstraction.
//!
//! [`Pager`] combines the host page cache ([`cache::ClockCache`], sized to
//! the container memory limit), the swap-slot allocator ([`swap`]) and the
//! replication placement ([`NodeMap`]): touching a non-resident page emits
//! the block I/Os that must hit the fabric — a read from the first alive
//! replica for the fault, replicated writes for the dirty victim, or a
//! disk fallback when every replica is down.

pub mod cache;
pub mod swap;

use crate::coordinator::node::{EpochMap, NodeMap, ReadRoute};
use crate::fabric::Dir;
use cache::{Access, ClockCache};
use crate::util::fxhash::FxHashMap;
use swap::SwapAllocator;

/// The paging layer's **per-block disk bit** (paper §7.1: every block has
/// a local-disk replica; reads go to disk only while no remote copy is
/// authoritative), ordered by write stamp so concurrent writes cannot
/// race the ownership flag.
///
/// A span is disk-owned iff the newest write that sent it to the disk
/// path — an all-replicas-dead submit, a write whose every leg failed in
/// flight, or an election *surrender*
/// ([`crate::coordinator::engine::IoEngine::take_disk_surrenders`]) — is
/// newer than every write that landed remotely over it. Stamping both
/// sides with monotone write ids makes the tracking race-free: an older
/// write retiring late can never clear a newer write's disk mark.
///
/// This is the structure the live client (`fabric::loopback::LiveBox`)
/// consults before every placed read, and that [`Pager::surrender`] feeds
/// from the engine's disk-surrender signal — the client-side disk-span
/// shortcut of earlier revisions now lives here, in the paging layer.
#[derive(Debug, Default)]
pub struct DiskSpans {
    marked: EpochMap,
    healed: EpochMap,
}

impl DiskSpans {
    /// Record that write `stamp` sent `[addr, addr + len)` to the disk
    /// path: the local disk copy is now the newest data there.
    pub fn mark(&mut self, addr: u64, len: u64, stamp: u64) {
        self.marked.raise(addr, len, stamp);
    }

    /// Record that write `stamp` landed remotely over `[addr, addr+len)`:
    /// remote replicas own the span again unless a *newer* write marked
    /// it disk.
    pub fn heal(&mut self, addr: u64, len: u64, stamp: u64) {
        self.healed.raise(addr, len, stamp);
    }

    /// Does the local disk own any byte of `[addr, addr + len)`?
    pub fn disk_owned(&self, addr: u64, len: u64) -> bool {
        self.marked
            .segments(addr, len)
            .into_iter()
            .any(|(sa, sl, m)| m > 0 && self.healed.min_over(sa, sl) < m)
    }

    /// No byte is currently (or was ever) disk-marked.
    pub fn is_empty(&self) -> bool {
        self.marked.is_empty()
    }
}

/// Where a paging I/O must go.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    Node(usize),
    /// All replicas failed — local disk fallback (paper: "disk access
    /// occurs only when all replication is failed").
    Disk,
}

/// One block I/O the paging layer needs executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoReq {
    pub dir: Dir,
    pub target: Target,
    pub addr: u64,
    pub len: u64,
}

/// Result of touching a page.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TouchOutcome {
    /// Read the app thread must block on (None = cold first touch or hit).
    pub load: Option<IoReq>,
    /// Asynchronous write-backs (dirty victim × replicas).
    pub writebacks: Vec<IoReq>,
    /// Additional swap-readahead loads (adjacent swapped pages).
    pub readahead: Vec<IoReq>,
    pub hit: bool,
}

#[derive(Debug)]
pub struct Pager {
    cache: ClockCache,
    slots: SwapAllocator,
    map: NodeMap,
    page_size: u64,
    /// page -> swap slot, for pages currently swapped out.
    swapped: FxHashMap<u64, u64>,
    /// Pages whose only copy is on disk (replicas failed at writeback).
    on_disk: FxHashMap<u64, u64>,
    /// kswapd-style batch reclaim size: victims evicted per reclaim round.
    reclaim_batch: usize,
    pub faults: u64,
    pub cold_faults: u64,
    pub disk_reads: u64,
    pub disk_writes: u64,
}

impl Pager {
    pub fn new(resident_pages: usize, map: NodeMap, page_size: u64) -> Self {
        Self {
            cache: ClockCache::new(resident_pages.max(1)),
            slots: SwapAllocator::new(),
            map,
            page_size,
            swapped: FxHashMap::default(),
            on_disk: FxHashMap::default(),
            reclaim_batch: 1,
            faults: 0,
            cold_faults: 0,
            disk_reads: 0,
            disk_writes: 0,
        }
    }

    pub fn cache(&self) -> &ClockCache {
        &self.cache
    }

    /// Reclaim victims in batches of `n` (Linux kswapd behaviour). Batch
    /// reclaim is what creates the write-back *bursts* that stack up in
    /// the merge queue — and, with CLOCK runs + sequential slots, their
    /// device-address contiguity.
    pub fn with_reclaim_batch(mut self, n: usize) -> Self {
        self.reclaim_batch = n.max(1);
        self
    }

    pub fn node_map_mut(&mut self) -> &mut NodeMap {
        &mut self.map
    }

    pub fn page_size(&self) -> u64 {
        self.page_size
    }

    /// Touch `page` with swap readahead: on a fault, also fault in up to
    /// `ra` following pages that are currently swapped out (Linux
    /// `page-cluster` behaviour). Readahead is what gives swap-in traffic
    /// its adjacency — consecutive pages sit on consecutive swap slots, so
    /// the resulting reads are contiguous on the remote node and
    /// Batching-on-MR can merge them (paper Table 1).
    pub fn touch_ra(&mut self, page: u64, write: bool, ra: usize) -> TouchOutcome {
        let mut out = self.touch(page, write);
        if out.hit || out.load.is_none() || ra == 0 {
            return out;
        }
        let mut extra_loads = Vec::new();
        for i in 1..=ra as u64 {
            let p = page + i;
            if !self.swapped.contains_key(&p) || self.cache.contains(p) {
                break; // readahead stops at the first non-swapped page
            }
            let o = self.touch(p, false);
            out.writebacks.extend(o.writebacks);
            if let Some(l) = o.load {
                extra_loads.push(l);
            }
        }
        out.readahead = extra_loads;
        out
    }

    /// Touch `page`; returns the I/Os this access requires.
    pub fn touch(&mut self, page: u64, write: bool) -> TouchOutcome {
        let first_evict = match self.cache.access(page, write) {
            Access::Hit => {
                return TouchOutcome {
                    load: None,
                    writebacks: Vec::new(),
                    readahead: Vec::new(),
                    hit: true,
                }
            }
            Access::Miss { evicted } => evicted,
        };
        self.faults += 1;
        let mut writebacks = Vec::new();
        // the single eviction `access` may have performed
        if let Some((v, d)) = first_evict {
            self.writeback_victim(v, d, &mut writebacks);
        }
        // kswapd-style batch reclaim: once the cache runs out of free
        // frames, evict a whole batch so the next faults find room — this
        // is what makes write-backs bursty (and, via CLOCK runs +
        // sequential slots, contiguous)
        if self.cache.free_frames() == 0 && self.reclaim_batch > 1 {
            let victims = self.cache.reclaim(self.reclaim_batch);
            for (victim, dirty) in victims {
                self.writeback_victim(victim, dirty, &mut writebacks);
            }
        }
        let load = self.load_for(page);
        TouchOutcome {
            load,
            writebacks,
            readahead: Vec::new(),
            hit: false,
        }
    }

    /// Emit the write-backs for an evicted victim (replicated, or disk if
    /// every replica is dead). Anonymous-memory semantics: a page with no
    /// valid swap/disk copy must be written even if clean.
    fn writeback_victim(&mut self, victim: u64, dirty: bool, out: &mut Vec<IoReq>) {
        let has_copy =
            self.swapped.contains_key(&victim) || self.on_disk.contains_key(&victim);
        if !dirty && has_copy {
            return; // remote copy still current
        }
        let slot = match self.swapped.get(&victim) {
            Some(&s) => s, // rewrite in place
            None => {
                let s = self.slots.alloc();
                self.swapped.insert(victim, s);
                s
            }
        };
        let addr = slot * self.page_size;
        let route = self.map.route_write(addr);
        if route.disk_fallback {
            // the node abstraction's explicit all-replicas-dead signal
            self.disk_writes += 1;
            self.on_disk.insert(victim, slot);
            self.swapped.remove(&victim);
            out.push(IoReq {
                dir: Dir::Write,
                target: Target::Disk,
                addr,
                len: self.page_size,
            });
        } else {
            for n in route.targets {
                out.push(IoReq {
                    dir: Dir::Write,
                    target: Target::Node(n),
                    addr,
                    len: self.page_size,
                });
            }
        }
    }

    /// The read required to fault `page` in (None = cold first touch).
    fn load_for(&mut self, page: u64) -> Option<IoReq> {
        if let Some(&slot) = self.swapped.get(&page) {
            let addr = slot * self.page_size;
            match self.map.route_read(addr) {
                ReadRoute::Node(n) => Some(IoReq {
                    dir: Dir::Read,
                    target: Target::Node(n),
                    addr,
                    len: self.page_size,
                }),
                ReadRoute::DiskFallback => {
                    self.disk_reads += 1;
                    Some(IoReq {
                        dir: Dir::Read,
                        target: Target::Disk,
                        addr,
                        len: self.page_size,
                    })
                }
            }
        } else if let Some(&slot) = self.on_disk.get(&page) {
            self.disk_reads += 1;
            Some(IoReq {
                dir: Dir::Read,
                target: Target::Disk,
                addr: slot * self.page_size,
                len: self.page_size,
            })
        } else {
            self.cold_faults += 1;
            None
        }
    }

    /// Consume one engine disk-surrender range (the
    /// `IoEngine::take_disk_surrenders` signal): every page whose swap
    /// slot falls inside the surrendered device span `[addr, addr+len)`
    /// loses its remote copy — no live replica holds the required
    /// version — and flips to the per-block disk bit, so subsequent
    /// faults route to the local-disk replica instead of reading stale
    /// remote bytes. Returns how many pages flipped.
    pub fn surrender(&mut self, addr: u64, len: u64) -> usize {
        let end = addr + len;
        // overlap, not containment: surrender ranges arrive at write
        // (byte) granularity, so a span starting mid-page must still
        // flip the page whose slot it cuts into
        let flipped: Vec<(u64, u64)> = self
            .swapped
            .iter()
            .filter(|&(_, &slot)| {
                let a = slot * self.page_size;
                a < end && a + self.page_size > addr
            })
            .map(|(&page, &slot)| (page, slot))
            .collect();
        for &(page, slot) in &flipped {
            self.swapped.remove(&page);
            self.on_disk.insert(page, slot);
        }
        flipped.len()
    }

    /// Is `page` currently owned by the disk path (its per-block disk
    /// bit set)?
    pub fn disk_backed(&self, page: u64) -> bool {
        self.on_disk.contains_key(&page)
    }

    /// Number of pages currently swapped out to remote memory.
    pub fn swapped_out(&self) -> usize {
        self.swapped.len()
    }

    /// Mark pages `0..n` as existing and swapped out (sequential slots) —
    /// the state after a YCSB load phase populates the store under the
    /// container limit: everything beyond the resident set lives remote.
    /// First touches then fault *in* instead of being free cold faults.
    pub fn prepopulate(&mut self, n: u64) {
        for page in 0..n {
            let slot = self.slots.alloc();
            self.swapped.insert(page, slot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pager(resident: usize, nodes: usize, replicas: usize) -> Pager {
        Pager::new(
            resident,
            NodeMap::new(nodes, replicas, 1 << 20),
            4096,
        )
    }

    #[test]
    fn hits_require_no_io() {
        let mut p = pager(4, 3, 2);
        p.touch(1, false);
        let o = p.touch(1, false);
        assert!(o.hit);
        assert!(o.load.is_none());
        assert!(o.writebacks.is_empty());
    }

    #[test]
    fn cold_fault_needs_no_read() {
        let mut p = pager(2, 3, 2);
        let o = p.touch(1, true);
        assert!(!o.hit);
        assert!(o.load.is_none(), "first touch has nothing to load");
        assert_eq!(p.cold_faults, 1);
    }

    #[test]
    fn dirty_eviction_replicates_writeback() {
        let mut p = pager(1, 3, 2);
        p.touch(1, true); // resident, dirty
        let o = p.touch(2, false); // evicts 1
        assert_eq!(o.writebacks.len(), 2, "2 replicas");
        assert!(o
            .writebacks
            .iter()
            .all(|w| w.dir == Dir::Write && matches!(w.target, Target::Node(_))));
        // both replicas carry the same device address
        assert_eq!(o.writebacks[0].addr, o.writebacks[1].addr);
        assert_eq!(p.swapped_out(), 1);
    }

    #[test]
    fn refault_reads_from_primary_replica() {
        let mut p = pager(1, 3, 2);
        p.touch(1, true);
        let o = p.touch(2, false); // 1 swapped out
        let slot_addr = o.writebacks[0].addr;
        let o2 = p.touch(1, false); // refault 1, evicts 2 (clean)
        let load = o2.load.expect("needs read");
        assert_eq!(load.dir, Dir::Read);
        assert_eq!(load.addr, slot_addr);
        assert!(matches!(load.target, Target::Node(_)));
    }

    #[test]
    fn eviction_burst_gets_contiguous_slots() {
        let mut p = pager(4, 3, 2);
        for pg in 0..4 {
            p.touch(pg, true);
        }
        // fault in 4 new pages -> 4 dirty evictions
        let mut addrs = Vec::new();
        for pg in 4..8 {
            let o = p.touch(pg, true);
            for w in &o.writebacks {
                if matches!(w.target, Target::Node(_)) {
                    addrs.push(w.addr);
                }
            }
        }
        addrs.sort_unstable();
        addrs.dedup();
        // sequential slot allocation -> contiguous device addresses
        for w in addrs.windows(2) {
            assert_eq!(w[1], w[0] + 4096, "contiguous swap slots: {addrs:?}");
        }
    }

    #[test]
    fn all_replicas_dead_falls_back_to_disk() {
        let mut p = pager(1, 2, 2);
        p.node_map_mut().set_alive(0, false);
        p.node_map_mut().set_alive(1, false);
        p.touch(1, true);
        let o = p.touch(2, false); // dirty evict -> disk
        assert_eq!(o.writebacks.len(), 1);
        assert_eq!(o.writebacks[0].target, Target::Disk);
        assert_eq!(p.disk_writes, 1);
        // refault reads from disk
        let o2 = p.touch(1, false);
        assert_eq!(o2.load.unwrap().target, Target::Disk);
        assert_eq!(p.disk_reads, 1);
    }

    /// The per-block disk bit is write-stamp ordered: an older write
    /// retiring late cannot clear a newer write's disk mark, and only a
    /// strictly newer remote landing flips ownership back.
    #[test]
    fn disk_spans_are_write_stamp_ordered() {
        let mut d = DiskSpans::default();
        assert!(d.is_empty());
        assert!(!d.disk_owned(0, 4096));
        // write 5 went to disk over [0, 8K)
        d.mark(0, 8192, 5);
        assert!(d.disk_owned(0, 4096));
        assert!(d.disk_owned(4096, 8192), "partial overlap counts");
        assert!(!d.disk_owned(8192, 4096));
        // an OLDER write (3) landing remotely must not clear the mark
        d.heal(0, 8192, 3);
        assert!(d.disk_owned(0, 8192), "older heal loses to newer mark");
        // a NEWER write (9) landing remotely flips the span back
        d.heal(0, 4096, 9);
        assert!(!d.disk_owned(0, 4096));
        assert!(d.disk_owned(4096, 4096), "unhealed tail stays disk");
        // and a yet-newer disk mark wins again
        d.mark(0, 4096, 11);
        assert!(d.disk_owned(0, 4096));
    }

    /// ISSUE 5 satellite: the engine's disk-surrender signal flips the
    /// surrendered swap slots to the per-block disk bit, so faults of
    /// those pages route to the local-disk replica.
    #[test]
    fn surrender_flips_swapped_pages_to_disk() {
        let mut p = pager(1, 2, 2);
        p.prepopulate(8); // pages 0..8 on slots 0..8
        assert_eq!(p.swapped_out(), 8);
        // the engine surrendered device span [2*4096, 5*4096)
        let flipped = p.surrender(2 * 4096, 3 * 4096);
        assert_eq!(flipped, 3);
        assert_eq!(p.swapped_out(), 5);
        for page in 2..5u64 {
            assert!(p.disk_backed(page));
            let o = p.touch(page, false);
            assert_eq!(o.load.expect("load").target, Target::Disk);
        }
        // untouched pages still read from a replica
        let o = p.touch(6, false);
        assert!(matches!(o.load.expect("load").target, Target::Node(_)));
        assert!(!p.disk_backed(6));
        // an empty or non-overlapping surrender flips nothing
        assert_eq!(p.surrender(100 << 20, 4096), 0);
        // a surrender cutting into the middle of a page still flips it
        // (write-granular ranges vs page-granular slots)
        assert_eq!(p.surrender(5 * 4096 + 2048, 1024), 1);
        assert!(p.disk_backed(5));
    }

    #[test]
    fn rewrite_in_place_reuses_slot() {
        let mut p = pager(1, 3, 2);
        p.touch(1, true);
        let o = p.touch(2, true); // evict 1 -> slot A
        let a = o.writebacks[0].addr;
        let _ = p.touch(1, true); // refault 1 (dirty), evict 2 -> slot B
        let o3 = p.touch(3, false); // evict 1 again -> must reuse slot A
        let again: Vec<_> = o3
            .writebacks
            .iter()
            .filter(|w| w.addr == a)
            .collect();
        assert!(!again.is_empty(), "slot reused in place");
    }
}
