//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them on the XLA CPU client. This
//! is the only bridge between the Rust coordinator and the L2/L1 compute —
//! Python never runs on the request path.
//!
//! The PJRT/XLA bindings need an external native toolchain, so everything
//! touching them is gated behind the off-by-default `xla` cargo feature:
//! the default build (and CI) has **zero** external dependencies. Enabling
//! `--features xla` additionally requires uncommenting the `xla`
//! dependency in `rust/Cargo.toml` (see README §PJRT runtime).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → HloModuleProto
//! → XlaComputation → compile → execute (outputs are tuples because
//! aot.py lowers with `return_tuple=True`).

use std::path::PathBuf;

/// Known artifact names (kept in sync with python/compile/model.py).
pub const LOGREG_STEP: &str = "logreg_step";
pub const KMEANS_STEP: &str = "kmeans_step";
pub const PAGERANK_STEP: &str = "pagerank_step";

/// Runtime error (local, dependency-free replacement for `anyhow`).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Error(msg.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

pub fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Locate the artifacts directory: $RDMABOX_ARTIFACTS, ./artifacts, or
/// nearby relative paths.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("RDMABOX_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// True when `make artifacts` has been run (tests skip gracefully if not).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::{err, Result, LOGREG_STEP};

    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        execs: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// CPU PJRT client over the given artifacts directory.
        pub fn cpu<P: AsRef<Path>>(dir: P) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| err(format!("PJRT cpu client: {e:?}")))?;
            Ok(Self {
                client,
                dir: dir.as_ref().to_path_buf(),
                execs: HashMap::new(),
            })
        }

        /// Default runtime over [`super::artifacts_dir`].
        pub fn from_artifacts() -> Result<Self> {
            Self::cpu(super::artifacts_dir())
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (once) and cache the executable for `name`.
        pub fn load(&mut self, name: &str) -> Result<()> {
            if self.execs.contains_key(name) {
                return Ok(());
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| err(format!("parse {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err(format!("compile {name}: {e:?}")))?;
            self.execs.insert(name.to_string(), exe);
            Ok(())
        }

        pub fn loaded(&self) -> Vec<&str> {
            self.execs.keys().map(|s| s.as_str()).collect()
        }

        /// Execute `name` with the given literals; returns the tuple
        /// elements of the result.
        pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            self.load(name)?;
            let exe = self.execs.get(name).unwrap();
            let result = exe
                .execute::<xla::Literal>(args)
                .map_err(|e| err(format!("execute {name}: {e:?}")))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("fetch result {name}: {e:?}")))?;
            lit.to_tuple().map_err(|e| err(format!("untuple {name}: {e:?}")))
        }
    }

    /// f32 literal helpers (the xla crate's Literal API is low-level).
    pub mod lit {
        use super::super::{err, Result};

        pub fn f32_vec(v: &[f32]) -> xla::Literal {
            xla::Literal::vec1(v)
        }

        pub fn f32_mat(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
            assert_eq!(v.len(), rows * cols);
            xla::Literal::vec1(v)
                .reshape(&[rows as i64, cols as i64])
                .map_err(|e| err(format!("reshape: {e:?}")))
        }

        pub fn f32_scalar(x: f32) -> Result<xla::Literal> {
            xla::Literal::vec1(&[x])
                .reshape(&[])
                .map_err(|e| err(format!("scalar reshape: {e:?}")))
        }

        pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| err(format!("to_vec: {e:?}")))
        }
    }

    /// Run `steps` of the logistic-regression training loop on the PJRT
    /// CPU client; returns the loss curve. Used by the e2e example and the
    /// fig13 live validation.
    pub fn train_logreg(
        rt: &mut Runtime,
        x: &[f32],
        y: &[f32],
        batch: usize,
        features: usize,
        steps: usize,
        lr: f32,
    ) -> Result<Vec<f32>> {
        assert_eq!(x.len(), batch * features);
        assert_eq!(y.len(), batch);
        let mut w = vec![0f32; features];
        let xs = lit::f32_mat(x, batch, features)?;
        let ys = lit::f32_vec(y);
        let lrl = lit::f32_scalar(lr)?;
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let wl = lit::f32_vec(&w);
            let out = rt.execute(LOGREG_STEP, &[wl, xs.clone(), ys.clone(), lrl.clone()])?;
            w = lit::to_f32(&out[0])?;
            let loss = lit::to_f32(&out[1])?[0];
            losses.push(loss);
        }
        Ok(losses)
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{lit, train_logreg, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_displays_message() {
        let e = err("boom");
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn error_converts_from_strings() {
        let e: Error = String::from("owned").into();
        assert_eq!(e.to_string(), "owned");
        let e: Error = "borrowed".into();
        assert_eq!(e.to_string(), "borrowed");
    }

    #[test]
    fn artifacts_dir_has_a_default() {
        // without the env var and without a manifest nearby, the default
        // relative path comes back
        let d = artifacts_dir();
        assert!(!d.as_os_str().is_empty());
    }

    #[cfg(feature = "xla")]
    mod xla_backed {
        use super::super::*;

        fn need_artifacts() -> bool {
            if !artifacts_available() {
                eprintln!("skipping: run `make artifacts` first");
                return false;
            }
            true
        }

        #[test]
        fn client_comes_up() {
            let rt = Runtime::cpu("artifacts").expect("client");
            let p = rt.platform().to_lowercase();
            assert!(p.contains("cpu") || p.contains("host"), "platform {p}");
        }

        #[test]
        fn loads_and_runs_logreg_step() {
            if !need_artifacts() {
                return;
            }
            let mut rt = Runtime::from_artifacts().unwrap();
            let b = 256usize;
            let f = 512usize;
            // linearly separable data
            let mut x = vec![0f32; b * f];
            let mut y = vec![0f32; b];
            for i in 0..b {
                let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
                x[i * f] = sign;
                y[i] = if sign > 0.0 { 1.0 } else { 0.0 };
            }
            let losses = train_logreg(&mut rt, &x, &y, b, f, 20, 1.0).unwrap();
            assert_eq!(losses.len(), 20);
            assert!(
                losses[19] < losses[0] * 0.5,
                "loss should drop: {:?} -> {:?}",
                losses[0],
                losses[19]
            );
        }

        #[test]
        fn kmeans_step_runs_and_reduces_inertia() {
            if !need_artifacts() {
                return;
            }
            let mut rt = Runtime::from_artifacts().unwrap();
            let n = 1024usize;
            let d = 32usize;
            let k = 16usize;
            // two blobs
            let mut pts = vec![0f32; n * d];
            for i in 0..n {
                let off = if i < n / 2 { 4.0 } else { -4.0 };
                for j in 0..d {
                    pts[i * d + j] = off + ((i * 31 + j * 17) % 13) as f32 * 0.01;
                }
            }
            let mut c = vec![0f32; k * d];
            for (i, v) in c.iter_mut().enumerate() {
                *v = ((i * 7) % 11) as f32 * 0.2 - 1.0;
            }
            let pl = lit::f32_mat(&pts, n, d).unwrap();
            let mut cl = lit::f32_mat(&c, k, d).unwrap();
            let mut inertias = Vec::new();
            for _ in 0..5 {
                let out = rt.execute(KMEANS_STEP, &[cl, pl.clone()]).unwrap();
                let flat = lit::to_f32(&out[0]).unwrap();
                inertias.push(lit::to_f32(&out[1]).unwrap()[0]);
                cl = lit::f32_mat(&flat, k, d).unwrap();
            }
            assert!(inertias[4] <= inertias[0], "Lloyd monotone: {inertias:?}");
        }

        #[test]
        fn pagerank_step_preserves_mass() {
            if !need_artifacts() {
                return;
            }
            let mut rt = Runtime::from_artifacts().unwrap();
            let n = 512usize;
            // column-stochastic ring + shortcut
            let mut m = vec![0f32; n * n];
            for j in 0..n {
                m[((j + 1) % n) * n + j] = 0.7;
                m[((j + 7) % n) * n + j] += 0.3;
            }
            let r = vec![1.0f32 / n as f32; n];
            let ml = lit::f32_mat(&m, n, n).unwrap();
            let rl = lit::f32_vec(&r);
            let out = rt.execute(PAGERANK_STEP, &[rl, ml]).unwrap();
            let r2 = lit::to_f32(&out[0]).unwrap();
            let sum: f32 = r2.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "mass {sum}");
        }

        #[test]
        fn missing_artifact_is_an_error() {
            let mut rt = Runtime::cpu("artifacts").unwrap();
            assert!(rt.execute("nonexistent_model", &[]).is_err());
        }
    }
}
