//! Remote File System (paper §7.2): a userspace network file system over
//! the RDMAbox node abstraction, FUSE-style — files striped across remote
//! server nodes, POSIX-ish open/read/write/close, raw-I/O focused (the
//! paper excludes metadata management from the comparison).
//!
//! * [`Vfs`] — inode table, directory map, open-handle table.
//! * [`Layout`] — stripes file extents over server nodes.
//! * [`FsClient`] — turns `pwrite`/`pread` into fabric block I/Os.
//! * [`IozoneDriver`] — the IOzone-like record-size sweep used by Fig 14.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::fabric::sim::{Driver, Sim};
use crate::fabric::{AppIo, Dir};
use crate::workloads::DriverStats;

/// Stripe placement: file space → (server node, remote address).
#[derive(Debug, Clone)]
pub struct Layout {
    nodes: usize,
    stripe_bytes: u64,
    /// Bytes already allocated per node (per-node linear allocators).
    alloc: Vec<u64>,
}

impl Layout {
    pub fn new(nodes: usize, stripe_bytes: u64) -> Self {
        assert!(nodes > 0 && stripe_bytes > 0);
        Self {
            nodes,
            stripe_bytes,
            alloc: vec![0; nodes],
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Reserve remote space for a file of `len` bytes; returns the base
    /// remote offset used on every node (round-robin stripes).
    fn reserve(&mut self, len: u64) -> u64 {
        let stripes = len.div_ceil(self.stripe_bytes);
        let per_node = stripes.div_ceil(self.nodes as u64) * self.stripe_bytes;
        let base = *self.alloc.iter().max().unwrap();
        for a in self.alloc.iter_mut() {
            *a = base + per_node;
        }
        base
    }

    /// Map a file-relative extent to per-node block I/Os, splitting at
    /// stripe boundaries.
    pub fn map(&self, file_base: u64, offset: u64, len: u64) -> Vec<(usize, u64, u64)> {
        let mut out = Vec::new();
        let mut off = offset;
        let end = offset + len;
        while off < end {
            let stripe = off / self.stripe_bytes;
            let within = off % self.stripe_bytes;
            let chunk = (self.stripe_bytes - within).min(end - off);
            let node = (stripe % self.nodes as u64) as usize;
            let node_stripe = stripe / self.nodes as u64;
            let addr = file_base + node_stripe * self.stripe_bytes + within;
            out.push((node, addr, chunk));
            off += chunk;
        }
        out
    }
}

#[derive(Debug, Clone)]
pub struct Inode {
    pub ino: u64,
    pub size: u64,
    pub base: u64,
    /// Reserved remote capacity.
    pub capacity: u64,
}

/// Minimal VFS: path → inode, open handles.
#[derive(Debug, Default)]
pub struct Vfs {
    by_path: HashMap<String, u64>,
    inodes: HashMap<u64, Inode>,
    handles: HashMap<u64, u64>, // fd -> ino
    next_ino: u64,
    next_fd: u64,
}

impl Vfs {
    pub fn new() -> Self {
        Self {
            next_ino: 1,
            next_fd: 3, // after stdio, for flavor
            ..Default::default()
        }
    }

    pub fn create(&mut self, path: &str, base: u64, capacity: u64) -> u64 {
        let ino = self.next_ino;
        self.next_ino += 1;
        self.by_path.insert(path.to_string(), ino);
        self.inodes.insert(
            ino,
            Inode {
                ino,
                size: 0,
                base,
                capacity,
            },
        );
        ino
    }

    pub fn lookup(&self, path: &str) -> Option<&Inode> {
        self.by_path.get(path).and_then(|i| self.inodes.get(i))
    }

    pub fn open(&mut self, path: &str) -> Option<u64> {
        let ino = *self.by_path.get(path)?;
        let fd = self.next_fd;
        self.next_fd += 1;
        self.handles.insert(fd, ino);
        Some(fd)
    }

    pub fn close(&mut self, fd: u64) -> bool {
        self.handles.remove(&fd).is_some()
    }

    pub fn inode_of_fd(&self, fd: u64) -> Option<&Inode> {
        self.handles.get(&fd).and_then(|i| self.inodes.get(i))
    }

    pub fn grow(&mut self, fd: u64, new_size: u64) {
        if let Some(&ino) = self.handles.get(&fd) {
            if let Some(inode) = self.inodes.get_mut(&ino) {
                inode.size = inode.size.max(new_size);
            }
        }
    }

    pub fn unlink(&mut self, path: &str) -> bool {
        if let Some(ino) = self.by_path.remove(path) {
            self.inodes.remove(&ino);
            true
        } else {
            false
        }
    }
}

/// The FS client: POSIX-ish calls → fabric block I/Os.
#[derive(Debug)]
pub struct FsClient {
    pub vfs: Vfs,
    pub layout: Layout,
}

impl FsClient {
    pub fn new(nodes: usize, stripe_bytes: u64) -> Self {
        Self {
            vfs: Vfs::new(),
            layout: Layout::new(nodes, stripe_bytes),
        }
    }

    /// Create a file with reserved capacity; returns an open fd.
    pub fn create(&mut self, path: &str, capacity: u64) -> u64 {
        let base = self.layout.reserve(capacity);
        self.vfs.create(path, base, capacity);
        self.vfs.open(path).unwrap()
    }

    /// Translate a pwrite/pread into (node, remote_addr, len) I/Os.
    pub fn io_plan(
        &mut self,
        fd: u64,
        offset: u64,
        len: u64,
        write: bool,
    ) -> Vec<(usize, u64, u64)> {
        let inode = self.vfs.inode_of_fd(fd).expect("open fd");
        assert!(
            offset + len <= inode.capacity,
            "I/O beyond reserved capacity"
        );
        let base = inode.base;
        let plan = self.layout.map(base, offset, len);
        if write {
            self.vfs.grow(fd, offset + len);
        }
        plan
    }
}

// ---------------------------------------------------------------------
// IOzone-like driver (Fig 14)
// ---------------------------------------------------------------------

/// FUSE caps request payloads (the paper sets MAX_WRITE=128KB), and its
/// writeback cache / readahead keep a window of requests in flight.
pub const FUSE_MAX_REQ: u64 = 128 * 1024;
/// Default request window for async engines (RDMAbox node abstraction,
/// Accelio messaging): FUSE writeback/readahead depth.
pub const FUSE_PIPELINE: u32 = 16;
/// Synchronous-RPC file systems (Octopus, GlusterFS translate each FUSE
/// request into a blocking RPC — one outstanding request per stream).
pub const SYNC_RPC_PIPELINE: u32 = 1;

/// Pipeline depth a stack's FS client design sustains.
pub fn pipeline_of(stack: &crate::coordinator::StackConfig) -> u32 {
    use crate::coordinator::batching::BatchMode;
    if stack.batch == BatchMode::Single {
        SYNC_RPC_PIPELINE
    } else {
        FUSE_PIPELINE
    }
}

/// Sequential write phase then sequential read phase over one big file.
/// IOzone issues record-sized calls; the FUSE layer splits them into
/// ≤128 KB requests and keeps up to [`FUSE_PIPELINE`] in flight (writeback
/// cache on the write path, readahead on the read path). Those concurrent,
/// *adjacent* requests are exactly what Load-aware Batching merges.
pub struct IozoneDriver {
    fs: FsClient,
    fd: u64,
    pub record: u64,
    pub file_bytes: u64,
    /// FUSE message-loop overhead per request (user↔kernel crossing).
    fuse_overhead_ns: u64,
    /// Per-request MR staging done by the daemon thread (serialized):
    /// memcpy into preMR or buffer registration for dynMR.
    staging_write_ns: u64,
    staging_read_ns: u64,
    chunk: u64,
    pipeline: u32,
    /// The FUSE daemon dispatches requests serially; this is its timeline.
    dispatch_free: u64,
    phase_write: bool,
    /// Next file offset to issue.
    offset: u64,
    inflight: u32,
    /// Bytes completed in this phase.
    done_bytes: u64,
    stats: Rc<RefCell<DriverStats>>,
    pub write_done_ns: u64,
    pub read_done_ns: u64,
    t_phase_start: u64,
}

impl IozoneDriver {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        nodes: usize,
        stripe_bytes: u64,
        record: u64,
        file_bytes: u64,
        fuse_overhead_ns: u64,
        staging_write_ns: u64,
        staging_read_ns: u64,
        pipeline: u32,
        stats: Rc<RefCell<DriverStats>>,
    ) -> Self {
        let mut fs = FsClient::new(nodes, stripe_bytes);
        let fd = fs.create("/testfile", file_bytes);
        Self {
            fs,
            fd,
            record,
            file_bytes,
            fuse_overhead_ns,
            staging_write_ns,
            staging_read_ns,
            chunk: record.min(FUSE_MAX_REQ),
            pipeline: pipeline.max(1),
            dispatch_free: 0,
            phase_write: true,
            offset: 0,
            inflight: 0,
            done_bytes: 0,
            stats,
            write_done_ns: 0,
            read_done_ns: 0,
            t_phase_start: 0,
        }
    }

    /// Keep the FUSE request window full. Dispatch is serialized through
    /// the daemon (one user↔kernel crossing per request).
    fn pump(&mut self, sim: &mut Sim, at: u64) {
        while self.inflight < self.pipeline && self.offset < self.file_bytes {
            let len = self.chunk.min(self.file_bytes - self.offset);
            let staging = if self.phase_write {
                self.staging_write_ns
            } else {
                self.staging_read_ns
            };
            self.dispatch_free = self.dispatch_free.max(at) + self.fuse_overhead_ns + staging;
            let at = self.dispatch_free;
            let write = self.phase_write;
            let plan = self.fs.io_plan(self.fd, self.offset, len, write);
            self.offset += len;
            for (node, addr, l) in plan {
                let dir = if write { Dir::Write } else { Dir::Read };
                sim.submit_at(dir, node, addr, l, 0, at);
                self.inflight += 1;
            }
        }
    }

    fn phase_finished(&mut self, sim: &mut Sim, now: u64) {
        if self.phase_write {
            self.write_done_ns = now.saturating_sub(self.t_phase_start);
            self.phase_write = false;
            self.offset = 0;
            self.done_bytes = 0;
            self.t_phase_start = now;
            self.pump(sim, now);
        } else {
            self.read_done_ns = now.saturating_sub(self.t_phase_start);
            self.stats.borrow_mut().end_ns = now;
            sim.request_stop();
        }
    }
}

impl Driver for IozoneDriver {
    fn on_start(&mut self, sim: &mut Sim) {
        self.t_phase_start = 0;
        self.pump(sim, 0);
    }

    fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, lat: u64, done_at: u64) {
        self.inflight = self.inflight.saturating_sub(1);
        self.done_bytes += io.len;
        {
            let mut s = self.stats.borrow_mut();
            s.ops_done += 1;
            s.warm_ops += 1;
            s.op_lat.record(lat);
        }
        if self.done_bytes >= self.file_bytes && self.inflight == 0 {
            self.phase_finished(sim, done_at);
        } else {
            self.pump(sim, done_at);
        }
    }

    fn on_timer(&mut self, _sim: &mut Sim, _t: usize, _tag: u64) {}
}

/// Fig 14 runner: returns (write GB/s, read GB/s) for a stack at a record
/// size.
pub fn run_iozone(
    fabric: &crate::config::FabricConfig,
    stack: &crate::coordinator::StackConfig,
    nodes: usize,
    record: u64,
    file_bytes: u64,
) -> (f64, f64) {
    let (w, r, _) = run_iozone_with_stats(fabric, stack, nodes, record, file_bytes);
    (w, r)
}

/// [`run_iozone`] returning the per-request [`DriverStats`] as well —
/// the macro bench trajectory gates the FUSE request p99 from it.
pub fn run_iozone_with_stats(
    fabric: &crate::config::FabricConfig,
    stack: &crate::coordinator::StackConfig,
    nodes: usize,
    record: u64,
    file_bytes: u64,
) -> (f64, f64, DriverStats) {
    let stats = DriverStats::shared();
    // FUSE crossing ≈ 6 µs per request (same client for every system —
    // the paper compares FUSE-based systems against each other only);
    // pipeline depth reflects the system's client design (async engine vs
    // synchronous per-request RPC).
    let depth = pipeline_of(stack);
    // the FUSE daemon stages each request (copy or registration) before
    // posting — serialized in its dispatch thread
    let chunk = record.min(FUSE_MAX_REQ);
    let stage_w =
        crate::coordinator::mr_strategy::post_cost_ns(fabric, stack.mr, stack.space, chunk, true);
    let stage_r =
        crate::coordinator::mr_strategy::post_cost_ns(fabric, stack.mr, stack.space, chunk, false);
    let drv = IozoneDriver::new(
        nodes,
        1 << 20,
        record,
        file_bytes,
        6_000,
        stage_w,
        stage_r,
        depth,
        stats.clone(),
    );
    let cell = Rc::new(RefCell::new((0u64, 0u64)));
    // wrap to capture phase times
    struct Wrap {
        inner: IozoneDriver,
        out: Rc<RefCell<(u64, u64)>>,
    }
    impl Driver for Wrap {
        fn on_start(&mut self, sim: &mut Sim) {
            self.inner.on_start(sim)
        }
        fn on_io_done(&mut self, sim: &mut Sim, io: &AppIo, l: u64, a: u64) {
            self.inner.on_io_done(sim, io, l, a);
            *self.out.borrow_mut() = (self.inner.write_done_ns, self.inner.read_done_ns);
        }
        fn on_timer(&mut self, sim: &mut Sim, t: usize, g: u64) {
            self.inner.on_timer(sim, t, g)
        }
    }
    let _ = crate::fabric::sim::run_pipeline(
        fabric,
        stack,
        nodes,
        Box::new(Wrap {
            inner: drv,
            out: cell.clone(),
        }),
    );
    let (w_ns, r_ns) = *cell.borrow();
    let gbs = |ns: u64| {
        if ns == 0 {
            0.0
        } else {
            file_bytes as f64 / ns as f64 // bytes/ns == GB/s
        }
    };
    let taken = std::mem::take(&mut *stats.borrow_mut());
    (gbs(w_ns), gbs(r_ns), taken)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::FabricConfig;
    use crate::coordinator::StackConfig;

    #[test]
    fn layout_splits_at_stripe_boundaries() {
        let l = Layout::new(3, 1024);
        let plan = l.map(0, 512, 1536);
        // 512..1024 on stripe0(node0), 1024..2048 on stripe1(node1)
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0], (0, 512, 512));
        assert_eq!(plan[1], (1, 0 + 0 * 1024 + 0, 1024));
    }

    #[test]
    fn layout_round_robins_nodes() {
        let l = Layout::new(4, 1 << 20);
        let plan = l.map(0, 0, 4 << 20);
        let nodes: Vec<usize> = plan.iter().map(|p| p.0).collect();
        assert_eq!(nodes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn layout_conserves_bytes() {
        let l = Layout::new(3, 4096);
        for (off, len) in [(0u64, 10_000u64), (5000, 123), (4095, 2)] {
            let total: u64 = l.map(0, off, len).iter().map(|p| p.2).sum();
            assert_eq!(total, len);
        }
    }

    #[test]
    fn vfs_lifecycle() {
        let mut v = Vfs::new();
        v.create("/a", 0, 1 << 20);
        let fd = v.open("/a").unwrap();
        assert!(v.inode_of_fd(fd).is_some());
        v.grow(fd, 4096);
        assert_eq!(v.lookup("/a").unwrap().size, 4096);
        assert!(v.close(fd));
        assert!(!v.close(fd));
        assert!(v.unlink("/a"));
        assert!(v.lookup("/a").is_none());
    }

    #[test]
    fn fs_client_plans_within_capacity() {
        let mut fs = FsClient::new(2, 4096);
        let fd = fs.create("/f", 1 << 20);
        let plan = fs.io_plan(fd, 0, 8192, true);
        assert_eq!(plan.len(), 2);
        assert_eq!(fs.vfs.inode_of_fd(fd).unwrap().size, 8192);
    }

    #[test]
    #[should_panic(expected = "beyond reserved capacity")]
    fn fs_client_rejects_overflow() {
        let mut fs = FsClient::new(2, 4096);
        let fd = fs.create("/f", 4096);
        let _ = fs.io_plan(fd, 0, 8192, true);
    }

    #[test]
    fn two_files_do_not_overlap() {
        let mut fs = FsClient::new(2, 4096);
        let f1 = fs.create("/a", 64 << 10);
        let f2 = fs.create("/b", 64 << 10);
        let p1 = fs.io_plan(f1, 0, 64 << 10, true);
        let p2 = fs.io_plan(f2, 0, 64 << 10, true);
        // same node extents must not intersect
        for (n1, a1, l1) in &p1 {
            for (n2, a2, l2) in &p2 {
                if n1 == n2 {
                    let no_overlap = a1 + l1 <= *a2 || a2 + l2 <= *a1;
                    assert!(no_overlap, "overlap: {p1:?} vs {p2:?}");
                }
            }
        }
    }

    #[test]
    fn iozone_runs_and_rdmabox_beats_glusterfs() {
        let cfg = FabricConfig::default();
        let rbox = StackConfig::rdmabox_user(&cfg);
        let gluster = baselines::glusterfs(&cfg);
        let (w_box, r_box) = run_iozone(&cfg, &rbox, 4, 128 << 10, 16 << 20);
        let (w_glu, r_glu) = run_iozone(&cfg, &gluster, 4, 128 << 10, 16 << 20);
        assert!(w_box > 0.0 && r_box > 0.0);
        assert!(
            w_box > w_glu && r_box > r_glu,
            "RDMAbox w={w_box:.2}/r={r_box:.2} vs Gluster w={w_glu:.2}/r={r_glu:.2}"
        );
    }
}
