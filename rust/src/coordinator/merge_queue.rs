//! The I/O merge queue — the central data structure of Load-aware Batching
//! (paper §5.1).
//!
//! One queue per direction (read / write). Every data-request thread
//! *enqueues first, then immediately merge-checks*: the earliest-arriving
//! thread drains whatever has stacked up and builds a batch plan; threads
//! whose requests were taken by someone else's merge-check simply return.
//! Under light load a thread finds only its own request and posts a single
//! I/O immediately — batching never adds latency when there is nothing to
//! batch. Under heavy load (or while the admission-control window is
//! closed) requests accumulate, and the *wait itself* creates merge
//! opportunities.

use crate::fabric::{AppIo, Dir};

/// Outcome of one enqueue + merge-check round for a thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MergeCheck {
    /// This thread drained the queue; it must now plan and post the batch.
    Drained(Vec<AppIo>),
    /// Another thread already took this thread's request (it will be posted
    /// as part of that thread's batch) — nothing to do.
    TakenByPeer,
    /// The admission window is closed; requests stay queued.
    Blocked,
}

/// Allocation-free outcome of [`MergeQueue::merge_check_into`]: the
/// drained requests land in the caller's scratch buffer instead of a
/// fresh `Vec` (the engine's hot drain path reuses one buffer per drain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeOutcome {
    /// The scratch buffer now holds the drained requests.
    Drained,
    /// Queue empty — another thread's merge-check took everything.
    TakenByPeer,
    /// The admission window is closed; requests stay queued.
    Blocked,
}

/// A single-direction merge queue. Deliberately a plain FIFO + counters:
/// the paper's point is that a *single* queue with opportunistic draining
/// beats per-CPU queues with enforced cross-CPU merging.
#[derive(Debug, Default)]
pub struct MergeQueue {
    q: Vec<AppIo>,
    /// Total bytes currently queued.
    queued_bytes: u64,
    /// Statistics.
    pub enqueued: u64,
    pub drains: u64,
    pub empty_checks: u64,
    pub max_depth: usize,
}

impl MergeQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn queued_bytes(&self) -> u64 {
        self.queued_bytes
    }

    /// Enqueue a request (step 1 of the protocol).
    pub fn push(&mut self, io: AppIo) {
        self.queued_bytes += io.len;
        self.q.push(io);
        self.enqueued += 1;
        self.max_depth = self.max_depth.max(self.q.len());
    }

    /// Merge-check (step 2): drain up to `window_bytes` worth of requests.
    /// `u64::MAX` means no admission limit. Returns what this thread should
    /// post. Drains in FIFO order so a closed window cannot starve old
    /// requests (fairness of the single-queue design, paper §5.1).
    ///
    /// Allocating convenience wrapper around
    /// [`MergeQueue::merge_check_into`]; the engine's hot path uses the
    /// `_into` form with a reused scratch buffer.
    pub fn merge_check(&mut self, window_bytes: u64) -> MergeCheck {
        let mut out = Vec::new();
        match self.merge_check_into(window_bytes, &mut out) {
            MergeOutcome::Drained => MergeCheck::Drained(out),
            MergeOutcome::TakenByPeer => MergeCheck::TakenByPeer,
            MergeOutcome::Blocked => MergeCheck::Blocked,
        }
    }

    /// Zero-allocation merge-check: the drained requests are written into
    /// `out` (cleared first), which the caller reuses across drains — a
    /// swap-buffer when the whole queue drains (the common case, stealing
    /// the queue's backing storage and leaving it `out`'s old capacity),
    /// a memcpy of the admitted prefix when the window truncates.
    pub fn merge_check_into(&mut self, window_bytes: u64, out: &mut Vec<AppIo>) -> MergeOutcome {
        out.clear();
        if self.q.is_empty() {
            self.empty_checks += 1;
            return MergeOutcome::TakenByPeer;
        }
        if window_bytes == 0 || self.q[0].len > window_bytes {
            return MergeOutcome::Blocked;
        }
        let mut budget = window_bytes;
        let mut n = 0;
        let mut bytes = 0u64;
        for io in &self.q {
            if io.len > budget {
                break;
            }
            budget -= io.len;
            bytes += io.len;
            n += 1;
        }
        if n == self.q.len() {
            // full drain: swap buffers, no element moves at all
            std::mem::swap(&mut self.q, out);
        } else {
            out.extend(self.q.drain(..n));
        }
        self.queued_bytes -= bytes;
        self.drains += 1;
        MergeOutcome::Drained
    }

    /// Peek the queued requests (tests, introspection).
    pub fn peek(&self) -> &[AppIo] {
        &self.q
    }
}

/// The pair of queues the node abstraction owns (paper: "a single merge
/// queue for each write and read").
#[derive(Debug, Default)]
pub struct MergeQueues {
    pub read: MergeQueue,
    pub write: MergeQueue,
}

impl MergeQueues {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn of(&mut self, dir: Dir) -> &mut MergeQueue {
        match dir {
            Dir::Read => &mut self.read,
            Dir::Write => &mut self.write,
        }
    }

    pub fn total_queued_bytes(&self) -> u64 {
        self.read.queued_bytes() + self.write.queued_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};
    use crate::util::rng::Pcg32;

    fn io(id: u64, addr: u64, len: u64) -> AppIo {
        AppIo {
            id,
            dir: Dir::Write,
            node: 0,
            addr,
            len,
            thread: 0,
            t_submit: 0,
        }
    }

    #[test]
    fn single_request_drains_immediately() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 4096));
        match q.merge_check(u64::MAX) {
            MergeCheck::Drained(v) => assert_eq!(v.len(), 1),
            other => panic!("{other:?}"),
        }
        assert!(q.is_empty());
    }

    #[test]
    fn peer_sees_empty_after_drain() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 4096));
        q.push(io(2, 4096, 4096));
        // thread A drains both…
        assert!(matches!(q.merge_check(u64::MAX), MergeCheck::Drained(v) if v.len() == 2));
        // …thread B (which pushed id=2) finds nothing: taken by peer.
        assert_eq!(q.merge_check(u64::MAX), MergeCheck::TakenByPeer);
    }

    #[test]
    fn window_blocks_and_partially_admits() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 4096));
        q.push(io(2, 4096, 4096));
        q.push(io(3, 8192, 4096));
        // window admits only two pages
        match q.merge_check(8192) {
            MergeCheck::Drained(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[0].id, 1);
                assert_eq!(v[1].id, 2);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(q.len(), 1);
        // zero window blocks
        assert_eq!(q.merge_check(0), MergeCheck::Blocked);
        // window smaller than head blocks (no starvation bypass)
        assert_eq!(q.merge_check(100), MergeCheck::Blocked);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = MergeQueue::new();
        for i in 0..10 {
            q.push(io(i, i * 4096, 4096));
        }
        match q.merge_check(u64::MAX) {
            MergeCheck::Drained(v) => {
                let ids: Vec<u64> = v.iter().map(|x| x.id).collect();
                assert_eq!(ids, (0..10).collect::<Vec<_>>());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 100));
        q.push(io(2, 100, 200));
        assert_eq!(q.queued_bytes(), 300);
        let _ = q.merge_check(150);
        assert_eq!(q.queued_bytes(), 200);
    }

    #[test]
    fn queues_pair_routes_by_dir() {
        let mut qs = MergeQueues::new();
        qs.of(Dir::Read).push(AppIo {
            dir: Dir::Read,
            ..io(1, 0, 4096)
        });
        qs.of(Dir::Write).push(io(2, 0, 4096));
        assert_eq!(qs.read.len(), 1);
        assert_eq!(qs.write.len(), 1);
        assert_eq!(qs.total_queued_bytes(), 8192);
    }

    /// The zero-allocation drain path: scratch reuse, swap-buffer full
    /// drains, exact agreement with the allocating wrapper.
    #[test]
    fn merge_check_into_reuses_scratch_and_matches_wrapper() {
        let mut q = MergeQueue::new();
        let mut scratch = Vec::new();
        for i in 0..8 {
            q.push(io(i, i * 4096, 4096));
        }
        assert_eq!(q.merge_check_into(u64::MAX, &mut scratch), MergeOutcome::Drained);
        let ids: Vec<u64> = scratch.iter().map(|x| x.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert!(q.is_empty());
        // empty queue: taken by peer, scratch cleared
        assert_eq!(q.merge_check_into(u64::MAX, &mut scratch), MergeOutcome::TakenByPeer);
        assert!(scratch.is_empty());
        // window truncation drains the admitted prefix only
        for i in 0..4 {
            q.push(io(100 + i, i * 4096, 4096));
        }
        assert_eq!(q.merge_check_into(2 * 4096, &mut scratch), MergeOutcome::Drained);
        assert_eq!(scratch.len(), 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.merge_check_into(0, &mut scratch), MergeOutcome::Blocked);
        // steady state: capacities circulate between queue and scratch,
        // so the buffers stop growing
        let _ = q.merge_check_into(u64::MAX, &mut scratch);
        let cap = scratch.capacity();
        for _ in 0..100 {
            for i in 0..8 {
                q.push(io(i, i * 4096, 4096));
            }
            assert_eq!(q.merge_check_into(u64::MAX, &mut scratch), MergeOutcome::Drained);
            assert_eq!(scratch.len(), 8);
        }
        assert!(scratch.capacity() <= cap.max(8), "scratch kept its capacity");
    }

    /// Property: for any sequence of pushes and window-limited drains, no
    /// request is lost or duplicated, FIFO order holds, and byte accounting
    /// stays consistent.
    #[test]
    fn prop_conservation_and_fifo() {
        prop::forall(cfg(0x4D45_5247), |rng, size| prop_body(rng, size));
        fn prop_body(rng: &mut Pcg32, size: usize) -> Result<(), String> {
            let mut q = MergeQueue::new();
            let mut pushed: Vec<u64> = Vec::new();
            let mut drained: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..size * 4 {
                if rng.gen_bool(0.6) {
                    let len = (1 + rng.gen_below(64)) * 512;
                    q.push(io(next_id, next_id * 4096, len));
                    pushed.push(next_id);
                    next_id += 1;
                } else {
                    let window = rng.gen_below(1 << 18);
                    if let MergeCheck::Drained(v) = q.merge_check(window) {
                        drained.extend(v.iter().map(|x| x.id));
                    }
                }
                let total: u64 = q.peek().iter().map(|x| x.len).sum();
                if total != q.queued_bytes() {
                    return Err(format!(
                        "byte accounting drift: {} vs {}",
                        total,
                        q.queued_bytes()
                    ));
                }
            }
            if let MergeCheck::Drained(v) = q.merge_check(u64::MAX) {
                drained.extend(v.iter().map(|x| x.id));
            }
            if drained != pushed {
                return Err(format!("lost/reordered: {drained:?} vs {pushed:?}"));
            }
            Ok(())
        }
    }
}
