//! The I/O merge queue — the central data structure of Load-aware Batching
//! (paper §5.1), extended with a weighted-deficit-round-robin (DRR) drain
//! across tenants for multi-tenant QoS.
//!
//! One queue per direction (read / write). Every data-request thread
//! *enqueues first, then immediately merge-checks*: the earliest-arriving
//! thread drains whatever has stacked up and builds a batch plan; threads
//! whose requests were taken by someone else's merge-check simply return.
//! Under light load a thread finds only its own request and posts a single
//! I/O immediately — batching never adds latency when there is nothing to
//! batch. Under heavy load (or while the admission-control window is
//! closed) requests accumulate, and the *wait itself* creates merge
//! opportunities.
//!
//! **Single-tenant queues drain in plain FIFO order, byte-identically to
//! the pre-QoS behavior.** When more than one tenant is configured
//! ([`MergeQueue::set_tenants`]), the drain becomes a two-phase DRR over
//! per-tenant lanes:
//!
//! 1. **Entitled phase** — lanes are served round-robin, each visit adding
//!    `weight × 4 KiB` of deficit, but no lane may exceed the per-tenant
//!    entitlement the caller passes in (the regulator's sub-window slack).
//! 2. **Borrow phase** — whatever global budget entitled demand left
//!    unclaimed is distributed by the same weighted round-robin with the
//!    entitlement caps lifted (work-conserving borrowing of unused quota).
//!
//! Within a lane, FIFO order is preserved; across lanes, a hog tenant's
//! burst can no longer occupy the whole admission window while another
//! tenant's requests age behind it.

use crate::fabric::{AppIo, Dir, TenantId};

/// DRR deficit added per weight unit each time the round-robin visits a
/// lane with queued work (one page: fine-grained interleaving even inside
/// a small admission window).
const DRR_QUANTUM: u64 = 4096;

/// Outcome of one enqueue + merge-check round for a thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MergeCheck {
    /// This thread drained the queue; it must now plan and post the batch.
    Drained(Vec<AppIo>),
    /// Another thread already took this thread's request (it will be posted
    /// as part of that thread's batch) — nothing to do.
    TakenByPeer,
    /// The admission window is closed; requests stay queued.
    Blocked,
}

/// Allocation-free outcome of [`MergeQueue::merge_check_into`]: the
/// drained requests land in the caller's scratch buffer instead of a
/// fresh `Vec` (the engine's hot drain path reuses one buffer per drain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeOutcome {
    /// The scratch buffer now holds the drained requests.
    Drained,
    /// Queue empty — another thread's merge-check took everything.
    TakenByPeer,
    /// The admission window is closed; requests stay queued.
    Blocked,
}

/// A single-direction merge queue. A plain FIFO + counters in the
/// single-tenant case (the paper's point is that a *single* queue with
/// opportunistic draining beats per-CPU queues with enforced cross-CPU
/// merging); per-tenant DRR lanes over the same flat FIFO storage when
/// tenants are configured.
#[derive(Debug, Default)]
pub struct MergeQueue {
    q: Vec<AppIo>,
    /// Total bytes currently queued.
    queued_bytes: u64,
    /// Per-tenant DRR weights; empty = single-tenant FIFO drain.
    weights: Vec<u64>,
    /// Per-lane deficit carry-over between drains (bytes).
    deficits: Vec<u64>,
    /// Cumulative bytes drained per lane (QoS stats).
    lane_drained: Vec<u64>,
    /// Rotating round-robin start lane.
    cursor: usize,
    // Reusable drain scratch (no steady-state allocation):
    lane_idx: Vec<Vec<u32>>,
    lane_pos: Vec<usize>,
    ent_rem: Vec<u64>,
    admit: Vec<bool>,
    /// Statistics.
    pub enqueued: u64,
    pub drains: u64,
    pub empty_checks: u64,
    pub max_depth: usize,
}

impl MergeQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Configure per-tenant DRR lanes. One weight per tenant; a single
    /// weight (or never calling this) keeps the exact FIFO drain. Must be
    /// called before any traffic is queued.
    pub fn set_tenants(&mut self, weights: &[u64]) {
        assert!(!weights.is_empty(), "at least one tenant");
        assert!(
            weights.iter().all(|&w| (1..=1 << 20).contains(&w)),
            "tenant weights must be in 1..=2^20"
        );
        assert!(self.q.is_empty(), "set_tenants on a non-empty queue");
        let n = weights.len();
        self.weights = weights.to_vec();
        self.deficits = vec![0; n];
        self.lane_drained = vec![0; n];
        self.lane_idx = (0..n).map(|_| Vec::new()).collect();
        self.lane_pos = vec![0; n];
        self.ent_rem = Vec::with_capacity(n);
        self.cursor = 0;
    }

    /// Configured tenant lanes (1 when unconfigured: single-tenant FIFO).
    pub fn lanes(&self) -> usize {
        self.weights.len().max(1)
    }

    /// Cumulative bytes drained for `tenant` (0 for unconfigured lanes).
    pub fn lane_drained(&self, tenant: TenantId) -> u64 {
        self.lane_drained.get(tenant).copied().unwrap_or(0)
    }

    /// Current DRR deficit carry-over for `tenant`.
    pub fn lane_deficit(&self, tenant: TenantId) -> u64 {
        self.deficits.get(tenant).copied().unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn queued_bytes(&self) -> u64 {
        self.queued_bytes
    }

    /// Enqueue a request (step 1 of the protocol).
    pub fn push(&mut self, io: AppIo) {
        self.queued_bytes += io.len;
        self.q.push(io);
        self.enqueued += 1;
        self.max_depth = self.max_depth.max(self.q.len());
    }

    /// Merge-check (step 2): drain up to `window_bytes` worth of requests.
    /// `u64::MAX` means no admission limit. Returns what this thread should
    /// post. Single-tenant queues drain in FIFO order so a closed window
    /// cannot starve old requests (fairness of the single-queue design,
    /// paper §5.1); multi-tenant queues drain by weighted DRR with no
    /// per-tenant entitlement caps.
    ///
    /// Allocating convenience wrapper around
    /// [`MergeQueue::merge_check_into`]; the engine's hot path uses the
    /// `_into` form with a reused scratch buffer.
    pub fn merge_check(&mut self, window_bytes: u64) -> MergeCheck {
        let mut out = Vec::new();
        match self.merge_check_into(window_bytes, &mut out) {
            MergeOutcome::Drained => MergeCheck::Drained(out),
            MergeOutcome::TakenByPeer => MergeCheck::TakenByPeer,
            MergeOutcome::Blocked => MergeCheck::Blocked,
        }
    }

    /// Zero-allocation merge-check: the drained requests are written into
    /// `out` (cleared first), which the caller reuses across drains — a
    /// swap-buffer when the whole queue drains (the common case, stealing
    /// the queue's backing storage and leaving it `out`'s old capacity),
    /// a memcpy of the admitted subset when the window truncates.
    pub fn merge_check_into(&mut self, window_bytes: u64, out: &mut Vec<AppIo>) -> MergeOutcome {
        if self.weights.len() > 1 {
            return self.drr_drain(window_bytes, None, out);
        }
        out.clear();
        if self.q.is_empty() {
            self.empty_checks += 1;
            return MergeOutcome::TakenByPeer;
        }
        if window_bytes == 0 || self.q[0].len > window_bytes {
            return MergeOutcome::Blocked;
        }
        let mut budget = window_bytes;
        let mut n = 0;
        let mut bytes = 0u64;
        for io in &self.q {
            if io.len > budget {
                break;
            }
            budget -= io.len;
            bytes += io.len;
            n += 1;
        }
        if n == self.q.len() {
            // full drain: swap buffers, no element moves at all
            std::mem::swap(&mut self.q, out);
        } else {
            out.extend(self.q.drain(..n));
        }
        if let Some(d) = self.lane_drained.first_mut() {
            *d += bytes;
        }
        self.queued_bytes -= bytes;
        self.drains += 1;
        MergeOutcome::Drained
    }

    /// Multi-tenant merge-check: drain up to `window_bytes` total, with
    /// per-tenant entitlements (`ents[t]` = bytes tenant `t` may still
    /// admit inside its regulator sub-window) honored in the first DRR
    /// phase and borrowed past in the work-conserving second phase.
    /// Requires [`MergeQueue::set_tenants`] with `ents.len()` weights.
    pub fn merge_check_tenants_into(
        &mut self,
        window_bytes: u64,
        ents: &[u64],
        out: &mut Vec<AppIo>,
    ) -> MergeOutcome {
        assert_eq!(ents.len(), self.weights.len(), "one entitlement per tenant");
        self.drr_drain(window_bytes, Some(ents), out)
    }

    /// The two-phase weighted-deficit-round-robin drain (see module docs).
    fn drr_drain(
        &mut self,
        window_bytes: u64,
        ents: Option<&[u64]>,
        out: &mut Vec<AppIo>,
    ) -> MergeOutcome {
        out.clear();
        if self.q.is_empty() {
            self.empty_checks += 1;
            return MergeOutcome::TakenByPeer;
        }
        if window_bytes == 0 {
            return MergeOutcome::Blocked;
        }
        let lanes = self.weights.len();
        // bucket FIFO positions by lane (per-lane order = FIFO order)
        for v in &mut self.lane_idx {
            v.clear();
        }
        for (i, io) in self.q.iter().enumerate() {
            debug_assert!(io.tenant < lanes, "tenant {} out of range", io.tenant);
            self.lane_idx[io.tenant.min(lanes - 1)].push(i as u32);
        }
        self.lane_pos.iter_mut().for_each(|p| *p = 0);
        self.admit.clear();
        self.admit.resize(self.q.len(), false);
        self.ent_rem.clear();
        match ents {
            Some(e) => self.ent_rem.extend_from_slice(e),
            None => self.ent_rem.resize(lanes, u64::MAX),
        }

        let mut budget = window_bytes;
        let mut admitted = 0usize;
        // phase 0 honors entitlements; phase 1 is the work-conserving
        // borrow pass over whatever budget entitled demand left unclaimed
        for phase in 0..2u32 {
            loop {
                let mut any_active = false;
                for k in 0..lanes {
                    let t = (self.cursor + k) % lanes;
                    let Some(&i0) = self.lane_idx[t].get(self.lane_pos[t]) else {
                        continue;
                    };
                    let head_len = self.q[i0 as usize].len;
                    if head_len > budget {
                        continue; // lane head cannot be served this drain
                    }
                    if phase == 0 && head_len > self.ent_rem[t] {
                        continue; // beyond the sub-window: wait for phase 1
                    }
                    any_active = true;
                    // each visit to an active lane tops up its deficit
                    self.deficits[t] += self.weights[t] * DRR_QUANTUM;
                    while let Some(&i) = self.lane_idx[t].get(self.lane_pos[t]) {
                        let len = self.q[i as usize].len;
                        if len > budget || len > self.deficits[t] {
                            break;
                        }
                        if phase == 0 && len > self.ent_rem[t] {
                            break;
                        }
                        self.admit[i as usize] = true;
                        self.lane_pos[t] += 1;
                        budget -= len;
                        self.deficits[t] -= len;
                        if phase == 0 {
                            self.ent_rem[t] -= len;
                        }
                        self.lane_drained[t] += len;
                        admitted += 1;
                    }
                }
                // a cycle with active lanes but no admissions still tops
                // up deficits, so the largest active head eventually fits
                // and the loop terminates
                if budget == 0 || !any_active {
                    break;
                }
            }
            if budget == 0 {
                break;
            }
        }
        // liveness escape, mirroring the FIFO rule "a head that fits the
        // window always drains": if deficits alone blocked everything,
        // admit exactly the oldest queued request
        if admitted == 0 && self.q[0].len <= budget {
            let head = self.q[0];
            let t = head.tenant.min(lanes - 1);
            self.admit[0] = true;
            self.lane_pos[t] = self.lane_pos[t].max(1);
            self.deficits[t] = self.deficits[t].saturating_sub(head.len);
            self.lane_drained[t] += head.len;
            admitted = 1;
        }
        if admitted == 0 {
            return MergeOutcome::Blocked;
        }

        // compact the kept suffixes back in FIFO order; admitted requests
        // leave in FIFO order too (the planner re-sorts by address)
        let mut kept = 0usize;
        let mut bytes = 0u64;
        for i in 0..self.q.len() {
            let io = self.q[i];
            if self.admit[i] {
                bytes += io.len;
                out.push(io);
            } else {
                self.q[kept] = io;
                kept += 1;
            }
        }
        self.q.truncate(kept);
        self.queued_bytes -= bytes;
        self.drains += 1;
        for t in 0..lanes {
            if self.lane_pos[t] >= self.lane_idx[t].len() {
                // classic DRR: an emptied lane forfeits its carry-over
                self.deficits[t] = 0;
            } else {
                // bounded carry-over keeps a long-starved lane's burst fair
                self.deficits[t] = self.deficits[t].min(self.weights[t] * DRR_QUANTUM);
            }
        }
        self.cursor = (self.cursor + 1) % lanes;
        MergeOutcome::Drained
    }

    /// Peek the queued requests (tests, introspection).
    pub fn peek(&self) -> &[AppIo] {
        &self.q
    }
}

/// The pair of queues the node abstraction owns (paper: "a single merge
/// queue for each write and read").
#[derive(Debug, Default)]
pub struct MergeQueues {
    pub read: MergeQueue,
    pub write: MergeQueue,
}

impl MergeQueues {
    pub fn new() -> Self {
        Self::default()
    }

    /// Configure DRR lanes on both directions (see
    /// [`MergeQueue::set_tenants`]).
    pub fn set_tenants(&mut self, weights: &[u64]) {
        self.read.set_tenants(weights);
        self.write.set_tenants(weights);
    }

    pub fn of(&mut self, dir: Dir) -> &mut MergeQueue {
        match dir {
            Dir::Read => &mut self.read,
            Dir::Write => &mut self.write,
        }
    }

    pub fn total_queued_bytes(&self) -> u64 {
        self.read.queued_bytes() + self.write.queued_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};
    use crate::util::rng::Pcg32;

    fn io(id: u64, addr: u64, len: u64) -> AppIo {
        AppIo {
            id,
            dir: Dir::Write,
            node: 0,
            addr,
            len,
            thread: 0,
            t_submit: 0,
            tenant: 0,
        }
    }

    fn tio(id: u64, len: u64, tenant: usize) -> AppIo {
        AppIo {
            tenant,
            ..io(id, id * 4096, len)
        }
    }

    #[test]
    fn single_request_drains_immediately() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 4096));
        match q.merge_check(u64::MAX) {
            MergeCheck::Drained(v) => assert_eq!(v.len(), 1),
            other => panic!("{other:?}"),
        }
        assert!(q.is_empty());
    }

    #[test]
    fn peer_sees_empty_after_drain() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 4096));
        q.push(io(2, 4096, 4096));
        // thread A drains both…
        assert!(matches!(q.merge_check(u64::MAX), MergeCheck::Drained(v) if v.len() == 2));
        // …thread B (which pushed id=2) finds nothing: taken by peer.
        assert_eq!(q.merge_check(u64::MAX), MergeCheck::TakenByPeer);
    }

    #[test]
    fn window_blocks_and_partially_admits() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 4096));
        q.push(io(2, 4096, 4096));
        q.push(io(3, 8192, 4096));
        // window admits only two pages
        match q.merge_check(8192) {
            MergeCheck::Drained(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[0].id, 1);
                assert_eq!(v[1].id, 2);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(q.len(), 1);
        // zero window blocks
        assert_eq!(q.merge_check(0), MergeCheck::Blocked);
        // window smaller than head blocks (no starvation bypass)
        assert_eq!(q.merge_check(100), MergeCheck::Blocked);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = MergeQueue::new();
        for i in 0..10 {
            q.push(io(i, i * 4096, 4096));
        }
        match q.merge_check(u64::MAX) {
            MergeCheck::Drained(v) => {
                let ids: Vec<u64> = v.iter().map(|x| x.id).collect();
                assert_eq!(ids, (0..10).collect::<Vec<_>>());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut q = MergeQueue::new();
        q.push(io(1, 0, 100));
        q.push(io(2, 100, 200));
        assert_eq!(q.queued_bytes(), 300);
        let _ = q.merge_check(150);
        assert_eq!(q.queued_bytes(), 200);
    }

    #[test]
    fn queues_pair_routes_by_dir() {
        let mut qs = MergeQueues::new();
        qs.of(Dir::Read).push(AppIo {
            dir: Dir::Read,
            ..io(1, 0, 4096)
        });
        qs.of(Dir::Write).push(io(2, 0, 4096));
        assert_eq!(qs.read.len(), 1);
        assert_eq!(qs.write.len(), 1);
        assert_eq!(qs.total_queued_bytes(), 8192);
    }

    /// The zero-allocation drain path: scratch reuse, swap-buffer full
    /// drains, exact agreement with the allocating wrapper.
    #[test]
    fn merge_check_into_reuses_scratch_and_matches_wrapper() {
        let mut q = MergeQueue::new();
        let mut scratch = Vec::new();
        for i in 0..8 {
            q.push(io(i, i * 4096, 4096));
        }
        assert_eq!(q.merge_check_into(u64::MAX, &mut scratch), MergeOutcome::Drained);
        let ids: Vec<u64> = scratch.iter().map(|x| x.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert!(q.is_empty());
        // empty queue: taken by peer, scratch cleared
        assert_eq!(q.merge_check_into(u64::MAX, &mut scratch), MergeOutcome::TakenByPeer);
        assert!(scratch.is_empty());
        // window truncation drains the admitted prefix only
        for i in 0..4 {
            q.push(io(100 + i, i * 4096, 4096));
        }
        assert_eq!(q.merge_check_into(2 * 4096, &mut scratch), MergeOutcome::Drained);
        assert_eq!(scratch.len(), 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.merge_check_into(0, &mut scratch), MergeOutcome::Blocked);
        // steady state: capacities circulate between queue and scratch,
        // so the buffers stop growing
        let _ = q.merge_check_into(u64::MAX, &mut scratch);
        let cap = scratch.capacity();
        for _ in 0..100 {
            for i in 0..8 {
                q.push(io(i, i * 4096, 4096));
            }
            assert_eq!(q.merge_check_into(u64::MAX, &mut scratch), MergeOutcome::Drained);
            assert_eq!(scratch.len(), 8);
        }
        assert!(scratch.capacity() <= cap.max(8), "scratch kept its capacity");
    }

    // ---------------- DRR drain-order suite ----------------

    /// A 2-lane queue carrying only tenant-0 traffic admits exactly the
    /// same sets as a plain FIFO queue across random push/drain schedules.
    #[test]
    fn drr_single_active_lane_matches_fifo() {
        prop::forall(cfg(0xD2_0001), |rng, size| {
            let mut fifo = MergeQueue::new();
            let mut drr = MergeQueue::new();
            drr.set_tenants(&[1, 1]);
            let mut next = 0u64;
            for _ in 0..size * 2 {
                if rng.gen_bool(0.6) {
                    let len = (1 + rng.gen_below(8)) * 4096;
                    fifo.push(io(next, next * 4096, len));
                    drr.push(io(next, next * 4096, len));
                    next += 1;
                } else {
                    let w = rng.gen_below(1 << 16);
                    let a = fifo.merge_check(w);
                    let b = drr.merge_check(w);
                    let ids = |c: &MergeCheck| match c {
                        MergeCheck::Drained(v) => Some(v.iter().map(|x| x.id).collect::<Vec<_>>()),
                        _ => None,
                    };
                    match (ids(&a), ids(&b)) {
                        (Some(x), Some(y)) => {
                            let mut y = y;
                            y.sort_unstable();
                            let mut x = x;
                            x.sort_unstable();
                            if x != y {
                                return Err(format!("admitted sets differ: {x:?} vs {y:?}"));
                            }
                        }
                        (None, None) => {}
                        (x, y) => return Err(format!("outcomes differ: {x:?} vs {y:?}")),
                    }
                    if fifo.queued_bytes() != drr.queued_bytes() {
                        return Err("queued bytes diverged".into());
                    }
                }
            }
            Ok(())
        });
    }

    /// Equal weights split a tight window evenly even when the hog queued
    /// its whole burst first — the FIFO drain would hand it the entire
    /// window.
    #[test]
    fn drr_splits_a_tight_window_between_tenants() {
        let mut q = MergeQueue::new();
        q.set_tenants(&[1, 1]);
        for i in 0..8 {
            q.push(tio(i, 4096, 0)); // hog burst, queued first
        }
        for i in 8..12 {
            q.push(tio(i, 4096, 1)); // victim, queued behind it
        }
        let mut out = Vec::new();
        let ents = [u64::MAX, u64::MAX];
        assert_eq!(
            q.merge_check_tenants_into(4 * 4096, &ents, &mut out),
            MergeOutcome::Drained
        );
        let victim = out.iter().filter(|x| x.tenant == 1).count();
        let hog = out.iter().filter(|x| x.tenant == 0).count();
        assert_eq!((hog, victim), (2, 2), "equal weights, equal service: {out:?}");
        // per-lane FIFO order held
        let vids: Vec<u64> = out.iter().filter(|x| x.tenant == 1).map(|x| x.id).collect();
        assert_eq!(vids, vec![8, 9]);
    }

    /// A 3:1 weight ratio shows up in the admitted byte split.
    #[test]
    fn drr_weights_bias_the_split() {
        let mut q = MergeQueue::new();
        q.set_tenants(&[3, 1]);
        for i in 0..8 {
            q.push(tio(i, 4096, 0));
        }
        for i in 8..16 {
            q.push(tio(i, 4096, 1));
        }
        let mut out = Vec::new();
        let ents = [u64::MAX, u64::MAX];
        assert_eq!(
            q.merge_check_tenants_into(4 * 4096, &ents, &mut out),
            MergeOutcome::Drained
        );
        let hog = out.iter().filter(|x| x.tenant == 0).count();
        let victim = out.iter().filter(|x| x.tenant == 1).count();
        assert_eq!((hog, victim), (3, 1), "{out:?}");
    }

    /// Entitlements bind in phase 0; phase 1 borrows the leftover budget
    /// (work-conserving: an idle peer's quota is not wasted).
    #[test]
    fn drr_entitlement_then_borrow() {
        let mut q = MergeQueue::new();
        q.set_tenants(&[1, 1]);
        for i in 0..4 {
            q.push(tio(i, 4096, 0));
        }
        let mut out = Vec::new();
        // tenant 0 entitled to one page only, tenant 1 idle: the other
        // three pages are borrowed, not stranded
        assert_eq!(
            q.merge_check_tenants_into(4 * 4096, &[4096, u64::MAX], &mut out),
            MergeOutcome::Drained
        );
        assert_eq!(out.len(), 4, "borrow phase drained the rest: {out:?}");
        assert!(q.is_empty());
    }

    /// With competing entitled demand, the entitled tenant is served
    /// before the hog may borrow.
    #[test]
    fn drr_entitled_demand_preempts_borrowing() {
        let mut q = MergeQueue::new();
        q.set_tenants(&[1, 1]);
        for i in 0..4 {
            q.push(tio(i, 4096, 0)); // hog, almost no entitlement left
        }
        for i in 4..8 {
            q.push(tio(i, 4096, 1)); // victim, fully entitled
        }
        let mut out = Vec::new();
        assert_eq!(
            q.merge_check_tenants_into(4 * 4096, &[4096, 4 * 4096], &mut out),
            MergeOutcome::Drained
        );
        let hog = out.iter().filter(|x| x.tenant == 0).count();
        let victim = out.iter().filter(|x| x.tenant == 1).count();
        assert_eq!((hog, victim), (1, 3), "entitled victim beats the borrower: {out:?}");
    }

    /// An oversized head (bigger than any one round's deficit) still
    /// drains once the window fits it — the FIFO liveness rule.
    #[test]
    fn drr_oversized_head_still_drains() {
        let mut q = MergeQueue::new();
        q.set_tenants(&[1, 1]);
        q.push(tio(1, 64 * 4096, 0));
        let mut out = Vec::new();
        assert_eq!(
            q.merge_check_tenants_into(64 * 4096, &[u64::MAX, u64::MAX], &mut out),
            MergeOutcome::Drained
        );
        assert_eq!(out.len(), 1);
        // and blocks when the window cannot fit it
        q.push(tio(2, 64 * 4096, 0));
        assert_eq!(
            q.merge_check_tenants_into(4096, &[u64::MAX, u64::MAX], &mut out),
            MergeOutcome::Blocked
        );
    }

    /// Multi-tenant conservation: nothing lost or duplicated, per-lane
    /// FIFO order held, byte accounting exact — under random pushes,
    /// windows, and entitlements.
    #[test]
    fn prop_drr_conservation_and_lane_fifo() {
        prop::forall(cfg(0xD2_0002), |rng, size| {
            let lanes = 2 + rng.gen_below(3) as usize;
            let weights: Vec<u64> = (0..lanes).map(|_| 1 + rng.gen_below(4)).collect();
            let mut q = MergeQueue::new();
            q.set_tenants(&weights);
            let mut pushed: Vec<Vec<u64>> = vec![Vec::new(); lanes];
            let mut drained: Vec<Vec<u64>> = vec![Vec::new(); lanes];
            let mut out = Vec::new();
            let mut next = 0u64;
            for _ in 0..size * 4 {
                if rng.gen_bool(0.6) {
                    let t = rng.gen_below(lanes as u64) as usize;
                    let len = (1 + rng.gen_below(8)) * 512;
                    q.push(tio(next, len, t));
                    pushed[t].push(next);
                    next += 1;
                } else {
                    let w = rng.gen_below(1 << 16);
                    let ents: Vec<u64> =
                        (0..lanes).map(|_| rng.gen_below(1 << 16)).collect();
                    if q.merge_check_tenants_into(w, &ents, &mut out) == MergeOutcome::Drained {
                        for x in &out {
                            drained[x.tenant].push(x.id);
                        }
                    }
                }
                let total: u64 = q.peek().iter().map(|x| x.len).sum();
                if total != q.queued_bytes() {
                    return Err(format!(
                        "byte accounting drift: {total} vs {}",
                        q.queued_bytes()
                    ));
                }
            }
            let ents: Vec<u64> = vec![u64::MAX; lanes];
            while q.merge_check_tenants_into(u64::MAX, &ents, &mut out) == MergeOutcome::Drained {
                for x in &out {
                    drained[x.tenant].push(x.id);
                }
            }
            if drained != pushed {
                return Err(format!("lost/reordered per lane: {drained:?} vs {pushed:?}"));
            }
            Ok(())
        });
    }

    /// Property: for any sequence of pushes and window-limited drains, no
    /// request is lost or duplicated, FIFO order holds, and byte accounting
    /// stays consistent.
    #[test]
    fn prop_conservation_and_fifo() {
        prop::forall(cfg(0x4D45_5247), |rng, size| prop_body(rng, size));
        fn prop_body(rng: &mut Pcg32, size: usize) -> Result<(), String> {
            let mut q = MergeQueue::new();
            let mut pushed: Vec<u64> = Vec::new();
            let mut drained: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..size * 4 {
                if rng.gen_bool(0.6) {
                    let len = (1 + rng.gen_below(64)) * 512;
                    q.push(io(next_id, next_id * 4096, len));
                    pushed.push(next_id);
                    next_id += 1;
                } else {
                    let window = rng.gen_below(1 << 18);
                    if let MergeCheck::Drained(v) = q.merge_check(window) {
                        drained.extend(v.iter().map(|x| x.id));
                    }
                }
                let total: u64 = q.peek().iter().map(|x| x.len).sum();
                if total != q.queued_bytes() {
                    return Err(format!(
                        "byte accounting drift: {} vs {}",
                        total,
                        q.queued_bytes()
                    ));
                }
            }
            if let MergeCheck::Drained(v) = q.merge_check(u64::MAX) {
                drained.extend(v.iter().map(|x| x.id));
            }
            if drained != pushed {
                return Err(format!("lost/reordered: {drained:?} vs {pushed:?}"));
            }
            Ok(())
        }
    }
}
