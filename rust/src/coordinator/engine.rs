//! [`IoEngine`] — the unified submission pipeline of the I/O stack:
//! **merge → batch → admit → poll-retire**, as one object.
//!
//! Before this module existed the policy pieces ([`merge_queue`],
//! [`batching`], [`regulator`], [`channel`], [`node`]) were assembled by
//! hand at every call site (sim engine, loopback client, each experiment
//! harness). `IoEngine` owns the whole pipeline:
//!
//! * **Sharded merge queues** — one read/write queue pair per QP
//!   (`qps_per_node` channels per remote node, paper §6.1). Submissions are
//!   routed to a shard by an address-affine hash over 1 MiB regions, so
//!   adjacent requests land in the same shard and Batching-on-MR still
//!   finds its merge candidates, while independent regions engage
//!   independent QPs (and therefore independent NIC processing units).
//! * **Batch planning** — each shard drain runs through the
//!   [`batching::plan_into`] planner (Single / BatchOnMr / Doorbell /
//!   Hybrid).
//! * **Admission control** — drains are bounded by the [`Regulator`]
//!   window; a closed window leaves requests queued where later arrivals
//!   keep merging with them (paper §5.1).
//! * **Replicated placement** — in placed mode the engine routes by
//!   [`NodeMap`]: writes fan out to every alive replica, reads go to the
//!   first alive replica and *fail over* to the next on completion error;
//!   an application I/O retires exactly once, and only when its
//!   replication policy is satisfied. All replicas dead surfaces the
//!   paper's disk-fallback signal instead of an I/O.
//!
//! The same object is driven by the discrete-event fabric
//! ([`crate::fabric::sim`], via `StackEngine`) and by the live loopback
//! fabric ([`crate::fabric::loopback`], via `LiveBox`): the backends only
//! move bytes and deliver completions; every policy decision is here.
//!
//! [`merge_queue`]: crate::coordinator::merge_queue
//! [`batching`]: crate::coordinator::batching
//! [`regulator`]: crate::coordinator::regulator
//! [`channel`]: crate::coordinator::channel
//! [`node`]: crate::coordinator::node

use crate::config::FabricConfig;
use crate::coordinator::batching::{plan_into, BatchLimits, BatchMode, ChainSpan, PlanArena};
use crate::coordinator::channel::ChannelMap;
use crate::coordinator::merge_queue::{MergeOutcome, MergeQueues};
use crate::coordinator::mr_cache::MrCache;
use crate::coordinator::node::{EpochMap, NodeMap, NodeState, ReadRoute};
use crate::coordinator::regulator::{AdmissionPolicy, Regulator, StaticWindow, Unlimited};
use crate::coordinator::spec::EngineSpec;
use crate::coordinator::StackConfig;
use crate::fabric::{AppIo, Dir, IdList, NodeId, OpKind, QpId, TenantId, Wc, WcStatus, WorkRequest};
use crate::metrics::{RecoveryStats, TenantStats};
use crate::coordinator::gossip::{state_code, state_from_code, GossipDelta, GossipState};
use crate::util::eventq::EventQueue;
use crate::util::slab::Slab;

/// Shard affinity region size (re-exported from the channel layer, which
/// owns the routing function). Because merging only happens within one
/// shard's drain, a multi-SGE WR never spans a region boundary when
/// `qps_per_node > 1`.
pub use crate::coordinator::channel::SHARD_REGION_SHIFT;

/// CPU costs the engine charges on the (serialized) drain path. The sim
/// backend fills these from the calibrated fabric model; the live backend
/// runs with [`EngineCosts::free`] (real time is measured, not modeled).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCosts {
    /// Per-WQE posting cost (verbs post_send + block layer).
    pub post_wqe_cpu_ns: u64,
    /// Per-chain MMIO doorbell cost.
    pub mmio_cpu_ns: u64,
    /// Fixed cost of one merge-check (lock + scan setup).
    pub merge_check_base_ns: u64,
    /// Per-request merge-scan cost.
    pub merge_check_per_io_ns: u64,
    /// MR-cache hit: lkey lookup of an already-registered span.
    pub mr_hit_ns: u64,
    /// MR-cache miss: lazy registration of one span
    /// ([`crate::coordinator::mr_cache::MR_SPAN_BYTES`] bytes, kernel
    /// path — physical addresses, no PTE walk).
    pub mr_miss_ns: u64,
    /// Deregistration of one evicted span, charged when a deferred batch
    /// flushes (off the per-post critical path).
    pub mr_dereg_ns: u64,
}

impl EngineCosts {
    pub fn from_fabric(cfg: &FabricConfig) -> Self {
        use crate::coordinator::mr_cache::MR_SPAN_BYTES;
        Self {
            post_wqe_cpu_ns: cfg.post_wqe_cpu_ns,
            mmio_cpu_ns: cfg.mmio_cpu_ns,
            merge_check_base_ns: 120,
            merge_check_per_io_ns: 25,
            mr_hit_ns: cfg.mr_cache_hit_ns,
            mr_miss_ns: cfg.reg_ns(MR_SPAN_BYTES, true),
            mr_dereg_ns: cfg.dereg_ns(MR_SPAN_BYTES, true),
        }
    }

    /// Zero-cost model (live backends measure wall time instead).
    pub fn free() -> Self {
        Self::default()
    }
}

/// How submissions are routed to remote nodes.
#[derive(Debug)]
enum Routing {
    /// The caller names the destination node in `AppIo::node`.
    Direct,
    /// The engine places by address: replica fan-out, read failover, disk
    /// fallback (paper §6/§7.1).
    Placed(NodeMap),
}

/// Result of submitting one application I/O.
#[derive(Debug, Clone)]
pub struct Submitted {
    /// The queued fabric-level sub-I/O ids (one per replica per
    /// stripe-local leg for placed writes; `[io.id]` in direct mode).
    /// Work requests carry these ids. Inline up to 16 ids, so the common
    /// submit does not allocate.
    pub sub_ids: IdList,
    /// Every leg of the request found every replica dead: nothing was
    /// queued, the caller owns the disk path for the whole span.
    pub disk_fallback: bool,
    /// Stripe-local legs that took the disk path at submit time (their
    /// replicas were all dead) while other legs were queued. Empty unless
    /// the engine-level splitter produced a partial-disk request; the
    /// caller owns the disk path for exactly these sub-spans.
    pub disk_legs: Vec<(u64, u64)>,
    /// Tenant the request was billed to (admission sub-window + drain
    /// lane) — copied from [`AppIo::tenant`].
    pub tenant: TenantId,
}

/// One planned post: a doorbell chain bound to a concrete QP. The chain's
/// work requests are `wrs[start..end]` of the owning [`DrainOut`]'s flat
/// buffer — a span, not an owned `Vec`, so a reused `DrainOut` keeps one
/// contiguous WR arena alive across drains instead of allocating a `Vec`
/// per chain.
#[derive(Debug, Clone, Copy)]
pub struct PostChain {
    pub qp: QpId,
    pub node: NodeId,
    /// Index of the chain's first WR in [`DrainOut::wrs`].
    pub start: usize,
    /// One past the chain's last WR in [`DrainOut::wrs`].
    pub end: usize,
    /// Serialized CPU consumed on the drain path up to (and including)
    /// this chain's post — backends posting with a cost model schedule the
    /// chain at `drain_start + cpu_offset_ns`.
    pub cpu_offset_ns: u64,
}

/// Result of draining the sharded queues: a flat arena of posted WRs plus
/// the chain spans that partition it (in post order). Reuse one instance
/// across drains via [`IoEngine::drain_all_into`] — `clear` keeps the
/// buffers' capacity, making the steady-state drain allocation-free.
#[derive(Debug, Default)]
pub struct DrainOut {
    /// Every WR of this drain, flat, in post order.
    pub wrs: Vec<WorkRequest>,
    pub chains: Vec<PostChain>,
    /// Total serialized CPU of this drain (merge scans + posting).
    pub cpu_ns: u64,
    pub merged_ios: u64,
    /// Times the admission window blocked or truncated a shard drain.
    pub admission_blocked: u64,
}

impl DrainOut {
    /// Reset for reuse, keeping the WR/chain buffer capacity.
    pub fn clear(&mut self) {
        self.wrs.clear();
        self.chains.clear();
        self.cpu_ns = 0;
        self.merged_ios = 0;
        self.admission_blocked = 0;
    }

    /// The work requests of one chain.
    pub fn chain_wrs(&self, c: &PostChain) -> &[WorkRequest] {
        &self.wrs[c.start..c.end]
    }

    /// Consume the drain, yielding every chain with its owned WRs, in
    /// post order. This is the one place that relies on the invariant
    /// that the chain spans exactly tile `wrs` in order — backends that
    /// need owned WRs (to move them into their queues) carve through
    /// here instead of re-implementing the walk.
    pub fn into_chains(self) -> impl Iterator<Item = (PostChain, Vec<WorkRequest>)> {
        let DrainOut { wrs, chains, .. } = self;
        let mut wrs = wrs.into_iter();
        chains.into_iter().map(move |c| {
            let chain_wrs: Vec<WorkRequest> = wrs.by_ref().take(c.end - c.start).collect();
            (c, chain_wrs)
        })
    }
}

/// An application I/O whose replication policy is satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetiredIo {
    pub id: u64,
    /// No replica could serve it (reads: every attempt failed; writes:
    /// every replica write failed) — the caller owns the disk path.
    pub disk_fallback: bool,
    /// At least one read attempt failed over to a secondary replica.
    pub failed_over: bool,
}

/// Sentinel parent id of engine-internal resync sub-I/Os: they never
/// retire an application I/O, and backends see it in `completed_subs` /
/// `failed_subs` only for per-sub resource cleanup.
pub const RESYNC_PARENT: u64 = u64::MAX;

/// One resync copy advancing from its read stage to its write stage: the
/// source read `read_sub` completed, and the engine enqueued repair write
/// `write_sub` to the recovering node. The backend must attach whatever
/// payload it returned for `read_sub` to `write_sub` before the next
/// drain posts it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResyncCopy {
    pub read_sub: u64,
    pub write_sub: u64,
    /// The recovering node the repair write targets.
    pub target: NodeId,
    pub addr: u64,
    pub len: u64,
}

/// Result of handling one work completion. Reuse one instance across
/// completions via [`IoEngine::on_wc_into`] — `clear` keeps the buffers'
/// capacity, so steady-state retirement performs no heap allocation.
#[derive(Debug, Default)]
pub struct WcOut {
    pub retired: Vec<RetiredIo>,
    /// `(sub_id, parent_id)` for every sub-I/O that completed successfully
    /// in this WC — backends use it to hand read payloads back to the
    /// right application I/O.
    pub completed_subs: Vec<(u64, u64)>,
    /// `(sub_id, parent_id)` for every sub-I/O that failed *terminally*
    /// (no failover left) — backends use it to release per-sub resources.
    pub failed_subs: Vec<(u64, u64)>,
    /// Resync copies whose read stage completed in this WC (see
    /// [`ResyncCopy`]). The caller should drain again to post the writes.
    pub resync_copies: Vec<ResyncCopy>,
    /// Read sub-I/Os re-queued onto the next alive replica (failover).
    /// The caller should drain again to post them.
    pub requeued: u32,
}

impl WcOut {
    /// Reset for reuse, keeping the buffers' capacity.
    pub fn clear(&mut self) {
        self.retired.clear();
        self.completed_subs.clear();
        self.failed_subs.clear();
        self.resync_copies.clear();
        self.requeued = 0;
    }
}

/// Cumulative pipeline statistics.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub submitted: u64,
    pub retired: u64,
    pub requeued: u64,
    pub disk_fallbacks: u64,
    pub admission_blocks: u64,
    pub merged_ios: u64,
    pub wqes: u64,
    pub posts: u64,
    /// Completions for a wr_id that was not outstanding (duplicates, or
    /// late deliveries after the WR already retired) — ignored, counted.
    pub duplicate_wcs: u64,
    /// Missed-write ranges recorded against a non-alive (or diverged)
    /// replica for later resync.
    pub missed_ranges: u64,
    /// Alive replicas demoted to `Resyncing` because a replicated write
    /// to them failed terminally (they diverged from their peers).
    pub resync_demotions: u64,
    /// Resync rounds started (one round = one pass over a node's
    /// missed-range backlog).
    pub resync_rounds: u64,
    /// Resync copies spawned (read-from-peer → write-to-target pairs).
    pub resync_copies: u64,
    /// Resync copy stages that failed (no alive source, source read
    /// exhausted failover, or repair write error) — the range returns to
    /// the missed backlog.
    pub resync_copy_failures: u64,
    /// Nodes promoted back to `Alive` after draining their backlog.
    pub resyncs_completed: u64,
    /// Multi-stripe application I/Os split into stripe-local legs at
    /// submission (the engine-level request splitter).
    pub split_requests: u64,
    /// Stripe-local legs produced by the splitter (counts only legs of
    /// split requests; a request inside one stripe produces none).
    pub split_legs: u64,
    /// Repair copies whose donor was chosen by the epoch-vector election
    /// (the conservative source rule had no candidate).
    pub resync_elections: u64,
    /// Missed ranges dropped because the recovering node's own applied
    /// epoch already covers the required epoch — a spurious missed record
    /// from a concurrent-divergence race, healed in place.
    pub resync_self_heals: u64,
    /// Missed ranges surrendered to the disk path because no live replica
    /// holds the required epoch (e.g. every peer of the stripe is dead).
    /// Surfaced to the backend via [`IoEngine::take_disk_surrenders`].
    pub resync_disk_surrenders: u64,
    /// MR-cache span hits on the post path (mirrors the cache's own
    /// counters; zero when the cache is disabled).
    pub mr_hits: u64,
    /// MR-cache span misses — lazy registrations charged on the post path.
    pub mr_misses: u64,
    /// Spans evicted by the MR cache under pinned-bytes pressure.
    pub mr_evictions: u64,
    /// Deferred deregistration batches flushed off the critical path.
    pub mr_dereg_batches: u64,
    /// Admission-ledger violations the regulator observed (double post,
    /// mismatched release, unmatched release) — mirrored from
    /// [`Regulator::window_leaks`] so the chaos quiescence gates can
    /// hold it at zero in release builds too (debug builds panic at the
    /// violation site instead).
    pub window_leaks: u64,
}

/// What a placed sub-I/O is doing in the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SubKind {
    /// Ordinary replica leg of an application I/O.
    App,
    /// Resync stage 1: read a missed range from an alive peer, destined
    /// for the recovering `target`.
    ResyncRead { target: NodeId },
    /// Resync stage 2: repair write of the fetched range to `target`.
    ResyncWrite { target: NodeId },
}

/// A queued fabric-level sub-I/O (placed mode).
#[derive(Debug, Clone, Copy)]
struct SubIo {
    /// Slab key of the [`Pending`] leg this sub belongs to, or
    /// [`RESYNC_PARENT`] for engine-internal resync sub-I/Os (slab keys
    /// never reach `u64::MAX`, so the sentinel cannot collide).
    parent: u64,
    addr: u64,
    len: u64,
    dir: Dir,
    thread: usize,
    t_submit: u64,
    /// Bitmask of replica nodes already attempted (failover skips them).
    attempted: u64,
    /// Node this sub-I/O currently targets.
    node: NodeId,
    kind: SubKind,
    /// Election epoch riding on this sub: the write's minted epoch for
    /// app writes, the donor's applied epoch for resync copies (applied
    /// to the target's vector when the repair write lands). 0 when the
    /// donor election is disabled.
    epoch: u64,
    /// Owning tenant: inherited from the application I/O for app legs,
    /// [`crate::fabric::DEFAULT_TENANT`] for engine-internal resync
    /// traffic (repair copies bill to the system lane, not a victim's).
    tenant: TenantId,
    /// Next sub in its posted WR's intrusive chain (`u64::MAX` ends the
    /// chain). Rebuilt at every post; walked only to rebuild the sub
    /// list of a synthesized timeout-WC, so the deadline path needs no
    /// side allocation.
    next_in_wr: u64,
    /// Deadline expiries this sub has been re-queued through. Capped by
    /// the spec's `max_retries`; the next expiry resolves terminally
    /// like any other completion error.
    timeouts: u32,
}

/// Coalescing set of byte ranges (the per-node missed-write backlog; also
/// reused by backends, e.g. the loopback client's disk-backed span
/// tracker). Stored as `start → end` (end exclusive); overlapping and
/// adjacent inserts merge, so replaying the set touches each byte once.
#[derive(Debug, Default, Clone)]
pub struct RangeSet {
    ranges: std::collections::BTreeMap<u64, u64>,
}

impl RangeSet {
    /// Add `[addr, addr + len)`, merging overlapping/adjacent ranges.
    pub fn insert(&mut self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        let mut start = addr;
        let mut end = addr + len;
        if let Some((&s, &e)) = self.ranges.range(..=start).next_back() {
            if e >= start {
                start = s;
                end = end.max(e);
                self.ranges.remove(&s);
            }
        }
        while let Some((&s, &e)) = self.ranges.range(start..=end).next() {
            end = end.max(e);
            self.ranges.remove(&s);
        }
        self.ranges.insert(start, end);
    }

    /// `true` when no byte is covered.
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Number of stored (coalesced) ranges.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// Does any recorded range intersect `[addr, addr + len)`?
    pub fn overlaps(&self, addr: u64, len: u64) -> bool {
        if len == 0 {
            return false;
        }
        match self.ranges.range(..addr + len).next_back() {
            Some((_, &end)) => end > addr,
            None => false,
        }
    }

    /// Erase `[addr, addr + len)`, splitting entries that straddle it.
    pub fn remove(&mut self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        let end = addr + len;
        let overlapping: Vec<(u64, u64)> = self
            .ranges
            .range(..end)
            .filter(|&(_, &e)| e > addr)
            .map(|(&s, &e)| (s, e))
            .collect();
        for (s, e) in overlapping {
            self.ranges.remove(&s);
            if s < addr {
                self.ranges.insert(s, addr);
            }
            if e > end {
                self.ranges.insert(end, e);
            }
        }
    }

    /// Take every `(addr, len)` range, leaving the set empty.
    pub fn drain(&mut self) -> Vec<(u64, u64)> {
        let out = self.ranges.iter().map(|(&s, &e)| (s, e - s)).collect();
        self.ranges.clear();
        out
    }

    /// Visit every `(addr, len)` range without consuming the set.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.ranges.iter().map(|(&s, &e)| (s, e - s))
    }
}

/// Per-node resync bookkeeping (the §6 node abstraction's recovery side).
#[derive(Debug)]
struct ResyncState {
    enabled: bool,
    /// Epoch-vector donor election (ISSUE 4): when the conservative
    /// source rule has no candidate, elect the freshest live replica by
    /// comparing applied epoch vectors against the required floor —
    /// including among mutually-overlapping resyncing peers — and
    /// surrender ranges with no live copy at all to the disk path
    /// instead of parking the node.
    election: bool,
    /// Copies are chunked to this size so a resync transfer can never
    /// exceed the admission window of a windowed pipeline.
    max_copy_bytes: u64,
    /// Writes each non-alive replica missed, per node.
    missed: Vec<RangeSet>,
    /// Ranges whose repair copy is currently in flight, per recovering
    /// node. Spawning drains a range out of `missed`, so source
    /// selection must consult this set too — a peer whose overlapping
    /// repair has not landed yet still lacks the data.
    repairing: Vec<RangeSet>,
    /// Resync copies currently in flight, per recovering node.
    outstanding: Vec<u32>,
    /// A round found no spawnable work (no alive source for anything):
    /// don't retry until new information arrives (a missed-range record
    /// or a node coming up).
    dormant: Vec<bool>,
    /// A round deferred everything behind in-flight application writes:
    /// don't re-scan until one of them completes (cleared whenever an
    /// app write sub resolves), so steady write traffic doesn't pay an
    /// O(live subs) scan per event.
    deferred_wait: Vec<bool>,
    /// Monotone epoch counter: every placed application write mints one
    /// at submit time (election mode only).
    next_epoch: u64,
    /// Per-node **applied** epoch vector: the highest write epoch whose
    /// data the node's store holds, per range (raised when a write leg —
    /// app or repair — completes successfully on the node). This is the
    /// vector each replica "publishes"; it is maintained incrementally
    /// so it is already current at every demotion/revival transition.
    applied: Vec<EpochMap>,
    /// Cluster-wide **required** epoch vector: the highest epoch the
    /// client has issued per range, raised at submit time. A donor is
    /// valid for a range iff its applied vector dominates this floor
    /// over the whole range.
    required: EpochMap,
    /// Ranges surrendered to the disk path (no live copy held the
    /// required epoch), awaiting pickup by the backend.
    surrendered: Vec<(NodeId, u64, u64)>,
    /// Prune the epoch vectors when the required floor grows past this
    /// many stored ranges; doubled after each prune so the amortized
    /// cost stays O(1) per write (see `IoEngine::prune_epoch_floor`).
    prune_watermark: usize,
}

impl ResyncState {
    fn disabled(nodes: usize) -> Self {
        Self {
            enabled: false,
            election: false,
            max_copy_bytes: 0,
            missed: (0..nodes).map(|_| RangeSet::default()).collect(),
            repairing: (0..nodes).map(|_| RangeSet::default()).collect(),
            outstanding: vec![0; nodes],
            dormant: vec![false; nodes],
            deferred_wait: vec![false; nodes],
            next_epoch: 0,
            applied: (0..nodes).map(|_| EpochMap::default()).collect(),
            required: EpochMap::default(),
            surrendered: Vec::new(),
            prune_watermark: PRUNE_FLOOR_RANGES,
        }
    }
}

/// Initial (and minimum) prune watermark: below this many stored ranges
/// the required floor is not worth scanning.
const PRUNE_FLOOR_RANGES: usize = 64;

/// Caller-chosen application I/O ids must stay below this bit: everything
/// above it is reserved id space (historically the engine's leg ids; the
/// slab keys the engine mints today also stay below it by construction).
const LEG_BASE: u64 = 1 << 63;

/// Upper bound on replicas per stripe the submit path supports with
/// inline (allocation-free) target buffers. Enforced by
/// [`IoEngine::with_placement`]; every shipped topology uses ≤ 4.
const MAX_REPLICAS: usize = 8;

/// Aggregation state of one split application I/O: the request retires
/// when every stripe-local leg has retired, with the disk-fallback and
/// failed-over flags ORed across legs. Slab-resident; each leg's
/// [`Pending`] entry holds the slab key.
#[derive(Debug)]
struct LegAgg {
    remaining: u32,
    disk_any: bool,
    failed_over_any: bool,
    /// The application I/O id to retire when the last leg lands.
    app_id: u64,
}

/// Retirement state of one placed leg (slab-resident; sub-I/Os hold the
/// slab key in their `parent` field).
#[derive(Debug)]
struct Pending {
    remaining: u32,
    any_ok: bool,
    failed_over: bool,
    /// The application I/O id this leg resolves to — what backends see in
    /// `completed_subs` / `failed_subs`, and what retires for an unsplit
    /// request.
    app_id: u64,
    /// Slab key of the [`LegAgg`] for a split request; `None` when the
    /// request had a single stripe-local leg and retires directly.
    agg: Option<u64>,
    /// Write replicas whose leg failed terminally. Recorded as missed
    /// (and demoted) only at retirement, and only when the write
    /// retired `any_ok`: an all-legs-failed write takes the disk path —
    /// the paging layer's disk bit owns those reads, and recording a
    /// backlog no alive peer can source would park every replica of
    /// the stripe in `Resyncing` forever.
    failed_nodes: Vec<NodeId>,
}

/// A WR posted to the fabric and not yet completed. The slab keyed by
/// this is the engine's idempotency ledger: the WR's id *is* its slab key
/// (slot | generation), so the first completion for a wr_id frees the
/// slot — bumping its generation — and any later delivery of the same
/// wr_id fails the generation check and is dropped before it can touch
/// the window or the retirement state.
#[derive(Debug, Clone, Copy)]
struct PostedWr {
    bytes: u64,
    t_post: u64,
    /// Tenant the WR's bytes were billed to at post time. Authoritative
    /// for the completion-side release (the fabric's `Wc::tenant` is
    /// informational only — a forged or corrupted completion cannot
    /// shift bytes between tenant sub-windows).
    tenant: TenantId,
    /// QP the WR was posted on — drives the per-QP error/reset state
    /// machine when its deadline expires.
    qp: QpId,
    op: OpKind,
    /// Head of the WR's sub chain through the `subs` slab (linked via
    /// [`SubIo::next_in_wr`]); `u64::MAX` when deadlines are off. A
    /// synthesized timeout-WC rebuilds its `app_ios` by walking this
    /// chain, so the deadline ledger lives entirely in the slabs.
    first_sub: u64,
    /// Absolute engine-time deadline (`u64::MAX` = no deadline).
    deadline_at: u64,
    /// Intrusive deadline-list links (slab keys of the neighboring
    /// outstanding WRs, `u64::MAX` at the ends). Posts append at the
    /// tail (deadlines are minted monotonically), completions unlink in
    /// O(1), and expiry pops from the head — no allocation, no timer
    /// wheel entry per WR to cancel.
    dl_prev: u64,
    dl_next: u64,
}

/// Entries of the engine's recovery timer lane (an [`EventQueue`] in
/// engine time): everything that must fire later than the event that
/// scheduled it. WR deadlines are NOT in here — they live in the
/// intrusive list through the `outstanding` slab, which supports the
/// O(1) cancel-on-completion an event queue cannot.
#[derive(Debug, Clone, Copy)]
enum TimerEntry {
    /// Re-route a timed-out read sub once its jittered backoff elapses.
    BackoffRelease(u64),
    /// Advance a tripped QP one step along `Error → Resetting → Ok`.
    QpProbe(QpId),
}

/// Verbs-mirroring QP lifecycle: a QP in `Error` has flushed its
/// outstanding WRs and admits no new posts until probation re-admits it
/// through `Resetting` back to `Ok`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QpState {
    Ok,
    Error,
    Resetting,
}

/// Per-QP health tracked by the deadline recovery layer.
#[derive(Debug, Clone, Copy)]
struct QpHealth {
    state: QpState,
    /// Deadline expiries since the last successful completion; reaching
    /// [`QP_ERROR_TIMEOUTS`] flips the QP to `Error`.
    consecutive_timeouts: u32,
}

impl QpHealth {
    fn fresh() -> Self {
        Self {
            state: QpState::Ok,
            consecutive_timeouts: 0,
        }
    }
}

/// Consecutive deadline expiries that flip a QP from `Ok` to `Error`
/// (mirroring a verbs QP entering the error state after transport
/// retries are exhausted).
const QP_ERROR_TIMEOUTS: u32 = 3;

/// Probation an `Error` QP serves before its first recovery probe, in
/// deadline-timeout units; the `Resetting → Ok` step takes one more.
const QP_PROBATION_TIMEOUTS: u64 = 4;

/// Timed-out reads back off exponentially per expiry, capped at
/// `timeout_ns << BACKOFF_CAP_SHIFT`.
const BACKOFF_CAP_SHIFT: u32 = 3;

/// Deterministic per-(sub, attempt) jitter: a splitmix64 finalizer over
/// the pair, so replays are bit-identical while concurrent retries still
/// decorrelate instead of stampeding in lockstep.
fn backoff_jitter(sid: u64, attempt: u32) -> u64 {
    let mut z = sid ^ ((attempt as u64) << 56) ^ 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Backoff delay for a read sub's `attempt`-th expiry: doubles from
/// `timeout_ns`, capped, then jittered into `[delay/2, delay]` so the
/// schedule stays deterministic but unsynchronized.
fn backoff_delay(timeout_ns: u64, attempt: u32, sid: u64) -> u64 {
    let shift = attempt.min(BACKOFF_CAP_SHIFT);
    let delay = timeout_ns.saturating_mul(1u64 << shift);
    let half = delay / 2;
    half + backoff_jitter(sid, attempt) % (delay - half + 1)
}

/// The unified submit → merge → batch → admit → retire pipeline.
///
/// All four in-flight ledgers (`subs`, `pending`, `outstanding`, `aggs`)
/// are generational [`Slab`]s: the engine mints every id it later looks
/// up, so the ids encode their own storage slot and completion-time
/// lookup is an array index, not a hash probe. Together with the drain
/// scratch buffers (`drain_buf`, `span_buf`, `plan_arena`) and the
/// caller-owned [`DrainOut`]/[`WcOut`], the steady-state
/// submit → drain → retire cycle allocates nothing — a property the
/// `engine_pipeline_64ios_steady` bench gate enforces in CI.
#[derive(Debug)]
pub struct IoEngine {
    batch: BatchMode,
    limits: BatchLimits,
    channels: ChannelMap,
    /// One read/write merge-queue pair per QP (global QP id indexing).
    shards: Vec<MergeQueues>,
    regulator: Regulator,
    routing: Routing,
    costs: EngineCosts,
    /// Provisional WR ids handed to the planner; every planned WR is
    /// re-keyed to its `outstanding` slab key before it leaves the drain.
    next_wr_id: u64,
    /// Rotating start shard for drains: when the admission window closes
    /// mid-drain, the next drain starts one shard later, so low-numbered
    /// QPs cannot starve the rest under a tight window.
    drain_cursor: usize,
    /// Live sub-I/Os, keyed by the sub id (slab key) backends carry.
    subs: Slab<SubIo>,
    /// Per-leg retirement state, keyed by `SubIo::parent`.
    pending: Slab<Pending>,
    /// wr_id → posted bytes + post time (idempotency ledger + RTT).
    outstanding: Slab<PostedWr>,
    /// Split-request aggregation, keyed by `Pending::agg`.
    aggs: Slab<LegAgg>,
    /// Swap-buffer for shard drains (see `MergeQueue::merge_check_into`).
    drain_buf: Vec<AppIo>,
    /// Reused per-tenant entitlement scratch for multi-tenant drains
    /// (filled by `Regulator::entitlements_into` before each shard's
    /// weighted drain — part of the zero-allocation steady state).
    ent_buf: Vec<u64>,
    /// Chain spans of the shard currently being planned.
    span_buf: Vec<ChainSpan>,
    /// Reusable per-node grouping buffers for the batch planner.
    plan_arena: PlanArena,
    resync: ResyncState,
    /// The pinning-free memory path (`EngineSpec::mr_cache`): lazy
    /// registration + clock eviction over spans, probed per WR on the
    /// drain path. `None` = every buffer is considered pre-registered.
    mr_cache: Option<MrCache>,
    /// The multi-engine coordination plane (`EngineSpec::gossip`):
    /// interleaved epoch minting plus the anti-entropy bookkeeping
    /// exchanged with peer engines. `None` = single-engine cluster.
    gossip: Option<GossipState>,
    /// Completion-deadline recovery (`EngineSpec::deadlines`):
    /// `(timeout_ns, max_retries)`. `None` keeps the pre-deadline
    /// behaviour — a completion that never arrives hangs its request.
    deadlines: Option<(u64, u32)>,
    /// Head/tail of the intrusive deadline list through `outstanding`
    /// (`u64::MAX` = empty). Earliest deadline at the head.
    dl_head: u64,
    dl_tail: u64,
    /// Recovery timer lane: read-retry backoffs and QP probes, in
    /// engine time. Sim/chaos backends drive it off
    /// [`IoEngine::next_timer_at`]; live backends poll it with coarse
    /// monotonic ticks.
    timers: EventQueue<TimerEntry>,
    /// Per-QP error/reset state machine (global QP id indexing); all-Ok
    /// and untouched unless deadlines are enabled.
    qp_health: Vec<QpHealth>,
    /// Nodes this engine itself declared down because every QP wedged —
    /// the first QP recovering re-admits them via `on_node_up`.
    auto_downed: Vec<bool>,
    /// Reused scratch for QP-error flushes (wr_ids collected off the
    /// deadline list before synthesizing their timeout-WCs).
    flush_buf: Vec<u64>,
    /// Deadline-recovery counters ([`IoEngine::recovery_stats`]).
    recovery: RecoveryStats,
    pub stats: EngineStats,
}

impl IoEngine {
    /// Internal positional constructor. Everything outside the
    /// coordinator builds through [`IoEngine::build`] with an
    /// [`EngineSpec`] — the one construction path shared by the sim,
    /// loopback, and chaos backends.
    pub(crate) fn new(
        batch: BatchMode,
        limits: BatchLimits,
        nodes: usize,
        qps_per_node: usize,
        window_bytes: Option<u64>,
        costs: EngineCosts,
    ) -> Self {
        let channels = ChannelMap::new(nodes, qps_per_node);
        let total_qps = channels.total_qps();
        let shards = (0..total_qps).map(|_| MergeQueues::new()).collect();
        let regulator = match window_bytes {
            Some(w) => Regulator::static_window(w),
            None => Regulator::unlimited(),
        };
        Self {
            batch,
            limits,
            channels,
            shards,
            regulator,
            routing: Routing::Direct,
            costs,
            next_wr_id: 1,
            drain_cursor: 0,
            subs: Slab::new(),
            pending: Slab::new(),
            outstanding: Slab::new(),
            aggs: Slab::new(),
            drain_buf: Vec::new(),
            ent_buf: Vec::new(),
            span_buf: Vec::new(),
            plan_arena: PlanArena::default(),
            resync: ResyncState::disabled(nodes),
            mr_cache: None,
            gossip: None,
            deadlines: None,
            dl_head: u64::MAX,
            dl_tail: u64::MAX,
            timers: EventQueue::new(),
            qp_health: vec![QpHealth::fresh(); total_qps],
            auto_downed: vec![false; nodes],
            flush_buf: Vec::new(),
            recovery: RecoveryStats::default(),
            stats: EngineStats::default(),
        }
    }

    /// Build from an [`EngineSpec`] — the single construction path for
    /// every backend. Placement, resync, the donor election, and the
    /// multi-tenant QoS tables are all wired here, in dependency order,
    /// so a spec can never express the invalid chains the old
    /// constructor zoo allowed (e.g. election without resync).
    pub fn build(spec: &EngineSpec) -> Self {
        spec.validate();
        let mut e = Self::new(
            spec.batch,
            spec.limits,
            spec.nodes,
            spec.qps_per_node,
            spec.window_bytes,
            spec.costs,
        );
        if let Some(replicas) = spec.replicas {
            e = e.with_placement(NodeMap::new(spec.nodes, replicas, spec.stripe_bytes));
        }
        if let Some(chunk) = spec.resync_chunk {
            e.enable_resync(chunk);
        }
        if spec.election {
            e.enable_donor_election();
        }
        if spec.tenant_weights.len() > 1 {
            e.set_tenants(&spec.tenant_weights);
        }
        if let Some(cap) = spec.mr_cache_bytes {
            e.mr_cache = Some(MrCache::new(cap));
        }
        if let Some((id, n)) = spec.gossip {
            e.gossip = Some(GossipState::new(id, n, spec.nodes));
        }
        e.deadlines = spec.deadlines;
        e
    }

    /// Build from a full stack design point (how the sim backend does it):
    /// the [`StackConfig`] is lowered onto an [`EngineSpec`] and built
    /// through the unified path.
    pub fn from_stack(stack: &StackConfig, nodes: usize, costs: EngineCosts) -> Self {
        Self::build(&EngineSpec::from_stack(stack, nodes).costs(costs))
    }

    /// Install the multi-tenant QoS tables: one admission sub-window and
    /// one drain lane per tenant, weighted by `weights`. Must run before
    /// any traffic (ledgers and queues must be empty).
    pub(crate) fn set_tenants(&mut self, weights: &[u64]) {
        assert_eq!(
            self.stats.submitted, 0,
            "install tenants before submitting traffic"
        );
        self.regulator.set_tenants(weights);
        for shard in &mut self.shards {
            shard.set_tenants(weights);
        }
    }

    /// Enable placed routing: replica fan-out, read failover, disk signal.
    pub(crate) fn with_placement(mut self, map: NodeMap) -> Self {
        assert_eq!(
            map.nodes(),
            self.channels.nodes(),
            "NodeMap and channel topology disagree on cluster size"
        );
        assert!(map.nodes() <= 64, "failover bitmask supports up to 64 nodes");
        assert!(
            map.replicas() <= MAX_REPLICAS,
            "inline submit-path target buffers support up to {MAX_REPLICAS} replicas"
        );
        self.routing = Routing::Placed(map);
        self
    }

    /// Enable the epoch-based resync protocol (requires placement and at
    /// least 2 replicas to be meaningful): a node that comes back up
    /// enters `Resyncing`, is excluded from routing, and only returns to
    /// `Alive` once the writes it missed have been replayed from an alive
    /// peer — through this same merge → batch → admit pipeline, so repair
    /// traffic is admission-controlled like everything else. Copies are
    /// chunked to `max_copy_bytes` so a repair transfer can never exceed
    /// a windowed regulator's admission bound.
    pub(crate) fn with_resync(mut self, max_copy_bytes: u64) -> Self {
        self.enable_resync(max_copy_bytes);
        self
    }

    /// Non-consuming form of [`IoEngine::with_resync`].
    pub(crate) fn enable_resync(&mut self, max_copy_bytes: u64) {
        assert!(
            matches!(self.routing, Routing::Placed(_)),
            "resync requires placed routing (call with_placement first)"
        );
        assert!(max_copy_bytes > 0, "resync copy chunk must be non-zero");
        self.resync.enabled = true;
        self.resync.max_copy_bytes = max_copy_bytes;
    }

    pub fn resync_enabled(&self) -> bool {
        self.resync.enabled
    }

    /// Enable the **epoch-vector donor election** on top of the resync
    /// protocol (ISSUE 4; the ROADMAP's "epoch-vector exchange between
    /// donors"). Every placed application write mints a monotone epoch;
    /// the engine tracks, per node, the *applied* epoch vector (what the
    /// node's store holds) and, cluster-wide, the *required* floor (what
    /// the client has issued). When the conservative source rule finds no
    /// donor for a missed range, the election:
    ///
    /// * **elects the freshest live replica** whose applied vector
    ///   dominates the required floor over the range — including a
    ///   mutually-overlapping resyncing peer, the topology the
    ///   pre-election protocol parked forever;
    /// * **heals spurious records in place** when the recovering node's
    ///   own applied vector already covers the floor (a race between two
    ///   concurrent diverging writes can record a miss the node has
    ///   since outrun);
    /// * **surrenders ranges with no live copy at all** to the disk path
    ///   (the paper keeps a local-disk replica of every block) instead of
    ///   parking — surfaced via [`IoEngine::take_disk_surrenders`].
    ///
    /// Must be enabled before any traffic so every write carries an
    /// epoch; epoch vectors are compact (coalesced ranges), but they are
    /// retained for the engine's lifetime.
    pub(crate) fn with_donor_election(mut self) -> Self {
        self.enable_donor_election();
        self
    }

    /// Non-consuming form of [`IoEngine::with_donor_election`].
    pub(crate) fn enable_donor_election(&mut self) {
        assert!(
            self.resync.enabled,
            "donor election requires resync (call with_resync first)"
        );
        assert_eq!(
            self.stats.submitted, 0,
            "enable donor election before submitting traffic: every write \
             must carry an epoch for the vectors to be authoritative"
        );
        self.resync.election = true;
    }

    pub fn election_enabled(&self) -> bool {
        self.resync.election
    }

    /// Take the ranges the election surrendered to the disk path since
    /// the last call: `(recovering node, addr, len)` triples for which no
    /// live replica held the required epoch. The backend owns routing
    /// reads of these spans to its disk copy (the paging layer's
    /// per-block disk bit) until a later write makes the remote side
    /// authoritative again.
    pub fn take_disk_surrenders(&mut self) -> Vec<(NodeId, u64, u64)> {
        std::mem::take(&mut self.resync.surrendered)
    }

    /// `true` when the multi-engine coordination plane is attached
    /// (`EngineSpec::gossip`).
    pub fn gossip_enabled(&self) -> bool {
        self.gossip.is_some()
    }

    /// Gossip-plane counters; `None` when gossip is disabled.
    pub fn gossip_stats(&self) -> Option<crate::metrics::GossipStats> {
        self.gossip.as_ref().map(|g| g.stats)
    }

    /// Export this engine's full anti-entropy state into `delta`
    /// (cleared first; its vectors are reused round over round, so a
    /// steady-state exchange allocates nothing once they reach their
    /// working size). The delta carries the required floor, every
    /// per-node applied vector, versioned node states, the missed-write
    /// backlog and the cumulative disk-surrender log.
    pub fn export_gossip_into(&mut self, delta: &mut GossipDelta) {
        let g = self
            .gossip
            .as_mut()
            .expect("gossip is not enabled on this engine (EngineSpec::gossip)");
        delta.clear();
        g.round += 1;
        g.stats.rounds_sent += 1;
        delta.from = g.engine_id as u32;
        delta.round = g.round;
        delta.epoch_counter = g.counter;
        for (s, e, ep) in self.resync.required.entries() {
            delta.required.push((s, e, ep));
        }
        for (node, map) in self.resync.applied.iter().enumerate() {
            for (s, e, ep) in map.entries() {
                delta.applied.push((node as u32, s, e, ep));
            }
        }
        if let Routing::Placed(m) = &self.routing {
            for node in 0..m.nodes() {
                delta
                    .states
                    .push((node as u32, g.node_versions[node], state_code(m.state(node))));
            }
        }
        for (node, set) in self.resync.missed.iter().enumerate() {
            for (a, l) in set.iter() {
                delta.missed.push((node as u32, a, l));
            }
        }
        for &(node, a, l) in &g.disk_log {
            delta.surrendered.push((node as u32, a, l));
        }
    }

    /// Merge a peer's delta into this engine. Every step is a
    /// semilattice join — epoch max-merge, missed-range union,
    /// last-writer-wins node states — so absorbing a delta twice, out
    /// of order, or after a loss changes nothing beyond the first
    /// in-order merge. Duplicates and reorders die at the per-peer
    /// round filter without touching any ledger (the alloc-free path).
    pub fn absorb_gossip(&mut self, delta: &GossipDelta) {
        let g = self
            .gossip
            .as_mut()
            .expect("gossip is not enabled on this engine (EngineSpec::gossip)");
        let from = delta.from as usize;
        if from == g.engine_id || from >= g.seen_round.len() {
            return;
        }
        if delta.round <= g.seen_round[from] {
            g.stats.stale_rounds += 1;
            return;
        }
        g.seen_round[from] = delta.round;
        g.absorb_counter(delta.epoch_counter);
        g.stats.rounds_absorbed += 1;
        // dominate every epoch the peer could have minted so the
        // single-engine ledgers keep their monotone view
        let counter_bound = g.counter * g.engines as u64;
        self.resync.next_epoch = self.resync.next_epoch.max(counter_bound);

        let mut raises = 0u64;
        for &(s, e, ep) in &delta.required {
            if e <= s || ep == 0 {
                continue;
            }
            if self.resync.required.min_over(s, e - s) < ep {
                raises += 1;
            }
            self.resync.required.raise(s, e - s, ep);
        }
        for &(n, s, e, ep) in &delta.applied {
            let n = n as usize;
            if n >= self.resync.applied.len() || e <= s || ep == 0 {
                continue;
            }
            if self.resync.applied[n].min_over(s, e - s) < ep {
                raises += 1;
            }
            self.resync.applied[n].raise(s, e - s, ep);
        }

        let mut adoptions = 0u64;
        for &(n, ver, code) in &delta.states {
            let n = n as usize;
            let Some(state) = state_from_code(code) else {
                continue;
            };
            let local = match &self.routing {
                Routing::Placed(m) if n < m.nodes() => m.state(n),
                _ => continue,
            };
            let g = self.gossip.as_mut().expect("checked above");
            if n >= g.node_versions.len() {
                continue;
            }
            let local_ver = g.node_versions[n];
            // last writer wins; on a version tie the more severe state
            // does, so both sides of a tie resolve identically
            if ver < local_ver || (ver == local_ver && state_code(state) <= state_code(local)) {
                continue;
            }
            // divergence guard: never adopt a less-severe state while
            // this engine still owes the node repairs — our own promote
            // will version past the peer's claim once the backlog drains
            let backlog = n < self.resync.missed.len()
                && (!self.resync.missed[n].is_empty()
                    || !self.resync.repairing[n].is_empty()
                    || self.resync.outstanding[n] > 0);
            if backlog && state_code(state) < state_code(local) {
                continue;
            }
            let g = self.gossip.as_mut().expect("checked above");
            g.node_versions[n] = ver;
            if state != local {
                if let Routing::Placed(m) = &mut self.routing {
                    m.set_state(n, state);
                }
                adoptions += 1;
            }
        }

        let mut merged = 0u64;
        for &(n, a, l) in &delta.missed {
            let n = n as usize;
            if n >= self.resync.missed.len() || l == 0 {
                continue;
            }
            // self-heal pre-filter: after the epoch merges above, a
            // node whose applied vector already dominates the required
            // floor over the range holds the data — the peer's missed
            // record is stale and must not echo back into resync
            let req = self.resync.required.max_over(a, l);
            if req > 0 && self.resync.applied[n].min_over(a, l) >= req {
                continue;
            }
            let before = self.stats.missed_ranges;
            self.record_missed(n, a, l);
            if self.stats.missed_ranges > before {
                merged += 1;
            }
        }

        let start = self.gossip.as_ref().expect("checked above").seen_disk[from];
        for &(n, a, l) in delta.surrendered.get(start..).unwrap_or(&[]) {
            self.resync.surrendered.push((n as usize, a, l));
        }
        let absorbed = delta.surrendered.len().saturating_sub(start) as u64;

        let g = self.gossip.as_mut().expect("checked above");
        g.seen_disk[from] = g.seen_disk[from].max(delta.surrendered.len());
        g.stats.epoch_raises += raises;
        g.stats.state_adoptions += adoptions;
        g.stats.missed_merged += merged;
        g.stats.disk_spans_absorbed += absorbed;

        if self.resync.enabled {
            // anything learned is new information: wake dormant nodes
            // and let the resync state machine re-evaluate
            self.resync.dormant.fill(false);
            self.resync.deferred_wait.fill(false);
            self.kick_resync();
        }
    }

    /// Order-insensitive digest of the converged gossip state: the
    /// required floor, per-node applied vectors, versioned node states,
    /// the missed backlog and the mint counter (an FNV-1a fold). Two
    /// engines that have exchanged deltas in both directions and
    /// quiesced hold equal fingerprints; transient divergence (repairs
    /// in flight, unabsorbed rounds) shows up as inequality. Excludes
    /// purely local bookkeeping (per-peer cursors, stats, the
    /// disk-surrender log, in-flight repair state).
    pub fn gossip_fingerprint(&self) -> u64 {
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        fn fold(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(FNV_PRIME)
        }
        let g = self
            .gossip
            .as_ref()
            .expect("gossip is not enabled on this engine (EngineSpec::gossip)");
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h = fold(h, g.counter);
        for (s, e, ep) in self.resync.required.entries() {
            h = fold(fold(fold(h, s), e), ep);
        }
        for (node, map) in self.resync.applied.iter().enumerate() {
            h = fold(h, node as u64);
            for (s, e, ep) in map.entries() {
                h = fold(fold(fold(h, s), e), ep);
            }
            h = fold(h, g.node_versions[node]);
            if let Routing::Placed(m) = &self.routing {
                h = fold(h, state_code(m.state(node)) as u64);
            }
            for (a, l) in self.resync.missed[node].iter() {
                h = fold(fold(h, a), l);
            }
        }
        h
    }

    /// Swap the admission window at runtime (admission-policy churn): the
    /// in-flight byte accounting survives the swap, so bytes posted under
    /// the old window release under the new one and a shrink below the
    /// current in-flight level blocks new admissions without leaking.
    pub fn set_window(&mut self, window_bytes: Option<u64>) {
        let policy: Box<dyn AdmissionPolicy> = match window_bytes {
            Some(w) => Box::new(StaticWindow(w)),
            None => Box::new(Unlimited),
        };
        self.regulator.set_policy(policy);
    }

    /// Lifecycle state of a node (placed mode), `None` in direct mode.
    pub fn node_state(&self, node: NodeId) -> Option<NodeState> {
        self.node_map().map(|m| m.state(node))
    }

    /// Missed-write ranges currently recorded against `node`.
    pub fn resync_backlog(&self, node: NodeId) -> usize {
        self.resync.missed[node].len()
    }

    /// Every local node-state transition funnels through here so the
    /// gossip plane can version it for last-writer-wins exchange; peers
    /// that absorb the transition adopt the version as-is.
    fn set_node_state(&mut self, node: NodeId, state: NodeState) {
        if let Routing::Placed(m) = &mut self.routing {
            m.set_state(node, state);
        }
        if let Some(g) = &mut self.gossip {
            g.node_versions[node] += 1;
        }
    }

    /// A node went down: exclude it from routing. In-flight verbs to it
    /// are expected to complete in error (the fabric's job); writes it
    /// misses from here on are recorded for resync.
    pub fn on_node_down(&mut self, node: NodeId) {
        if matches!(self.routing, Routing::Placed(_)) {
            self.set_node_state(node, NodeState::Dead);
        }
    }

    /// A node came back up. Without resync (or with a clean backlog) it
    /// rejoins as `Alive` immediately; with resync and a missed-write
    /// backlog it enters `Resyncing` and repair copies are queued into
    /// the pipeline. The caller should drain afterwards to post them.
    pub fn on_node_up(&mut self, node: NodeId) {
        let clean = !self.resync.enabled
            || (self.resync.missed[node].is_empty() && self.resync.outstanding[node] == 0);
        let state = if clean {
            NodeState::Alive
        } else {
            NodeState::Resyncing
        };
        if matches!(self.routing, Routing::Placed(_)) {
            self.set_node_state(node, state);
        } else {
            return;
        }
        if self.resync.enabled {
            // any node coming up is a potential new copy source
            self.resync.dormant.fill(false);
            self.resync.deferred_wait.fill(false);
            self.kick_resync();
        }
    }

    /// Remote span `(addr, len, dir)` of a live (not yet completed)
    /// sub-I/O. Backends use this to slice per-sub payloads out of merged
    /// WRs — including engine-internal resync sub-I/Os they never saw at
    /// submit time.
    pub fn sub_span(&self, sub_id: u64) -> Option<(u64, u64, Dir)> {
        self.subs.get(sub_id).map(|s| (s.addr, s.len, s.dir))
    }

    pub fn regulator(&self) -> &Regulator {
        &self.regulator
    }

    /// Number of registered tenants (1 unless a multi-tenant spec
    /// installed weights).
    pub fn tenant_count(&self) -> usize {
        self.regulator.tenant_count()
    }

    /// Per-tenant QoS counters: the regulator's admission ledgers joined
    /// with the merge queues' weighted-drain lane counters, one row per
    /// tenant. Allocates (reporting surface, not a hot path).
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        (0..self.regulator.tenant_count())
            .map(|t| {
                let led = self.regulator.tenant(t);
                let mut drained = 0u64;
                let mut deficit = 0u64;
                for shard in &self.shards {
                    drained += shard.read.lane_drained(t) + shard.write.lane_drained(t);
                    deficit += shard.read.lane_deficit(t) + shard.write.lane_deficit(t);
                }
                TenantStats {
                    tenant: t,
                    weight: led.weight,
                    posted_bytes: led.posted_bytes,
                    retired_bytes: led.retired_bytes,
                    window_occupancy: led.in_flight,
                    peak_window_occupancy: led.peak_in_flight,
                    borrow_events: led.borrow_events,
                    drained_bytes: drained,
                    drain_deficit: deficit,
                }
            })
            .collect()
    }

    /// MR-cache counters plus the current pinned/cap occupancy; `None`
    /// when the pinning-free path is disabled (`EngineSpec::mr_cache`).
    pub fn mr_cache_stats(&self) -> Option<crate::metrics::MrCacheStats> {
        self.mr_cache.as_ref().map(|c| c.snapshot())
    }

    /// `true` when completion-deadline recovery is armed
    /// (`EngineSpec::deadlines`).
    pub fn deadlines_enabled(&self) -> bool {
        self.deadlines.is_some()
    }

    /// Deadline-recovery counters: local timeout retirements, QP-error
    /// flushes, completed QP resets. (`reconnects` is owned by the
    /// socket fabric; the engine's copy stays zero.)
    pub fn recovery_stats(&self) -> RecoveryStats {
        self.recovery
    }

    /// QPs currently *not* in the `Ok` state (in `Error` or probation).
    /// Zero whenever deadlines are disabled; the chaos quiescence gate
    /// holds it at zero after every recovery scenario drains.
    pub fn qps_not_ok(&self) -> usize {
        self.qp_health
            .iter()
            .filter(|h| h.state != QpState::Ok)
            .count()
    }

    /// Swap in a custom admission policy (the paper's §5.1 hook).
    pub fn set_regulator(&mut self, r: Regulator) {
        self.regulator = r;
    }

    pub fn channels(&self) -> &ChannelMap {
        &self.channels
    }

    pub fn node_map(&self) -> Option<&NodeMap> {
        match &self.routing {
            Routing::Placed(m) => Some(m),
            Routing::Direct => None,
        }
    }

    pub fn node_map_mut(&mut self) -> Option<&mut NodeMap> {
        match &mut self.routing {
            Routing::Placed(m) => Some(m),
            Routing::Direct => None,
        }
    }

    /// Address-affine shard (= QP) selection for a request to `node`.
    pub fn shard_of(&self, node: NodeId, addr: u64) -> QpId {
        self.channels.select_by_addr(node, addr)
    }

    /// Requests currently queued across every shard.
    pub fn queued_ios(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read.len() + s.write.len())
            .sum()
    }

    /// Requests currently queued in one direction.
    pub fn queued_ios_dir(&self, dir: Dir) -> usize {
        self.shards
            .iter()
            .map(|s| match dir {
                Dir::Read => s.read.len(),
                Dir::Write => s.write.len(),
            })
            .sum()
    }

    fn enqueue(&mut self, id: u64, node: NodeId, sub: &SubIo) {
        let qp = self.shard_of(node, sub.addr);
        self.shards[qp].of(sub.dir).push(AppIo {
            id,
            dir: sub.dir,
            node,
            addr: sub.addr,
            len: sub.len,
            thread: sub.thread,
            t_submit: sub.t_submit,
            tenant: sub.tenant,
        });
    }

    /// Submit one application I/O into the pipeline (step 1 of the §5.1
    /// protocol: enqueue; the caller then triggers a drain, which is the
    /// merge-check step).
    ///
    /// Placed routing splits the request into **stripe-local legs** at
    /// submission: each leg is placed — and replicated — by its own
    /// stripe, and the request retires once every leg's replication
    /// policy is satisfied (disk-fallback / failed-over flags ORed across
    /// legs). Callers no longer need to keep requests stripe-local; the
    /// old contract (route by the *first* byte's stripe, tail pages
    /// landing on the wrong replicas) is gone. Direct routing is
    /// unchanged: the caller names the node, no splitting.
    ///
    /// Application I/O ids must stay below `1 << 63` (the engine mints
    /// internal leg ids above that bit).
    pub fn submit(&mut self, io: AppIo) -> Submitted {
        self.stats.submitted += 1;
        debug_assert!(
            io.id < LEG_BASE,
            "application I/O ids >= 1<<63 are reserved for engine-internal legs"
        );
        debug_assert!(
            io.tenant < self.regulator.tenant_count(),
            "tenant {} not registered (engine has {} tenants)",
            io.tenant,
            self.regulator.tenant_count()
        );
        let submitted = match &self.routing {
            Routing::Direct => {
                let qp = self.shard_of(io.node, io.addr);
                self.shards[qp].of(io.dir).push(io);
                let mut sub_ids = IdList::new();
                sub_ids.push(io.id);
                Submitted {
                    sub_ids,
                    disk_fallback: false,
                    disk_legs: Vec::new(),
                    tenant: io.tenant,
                }
            }
            Routing::Placed(map) => {
                // every placed write mints a monotone election epoch and
                // raises the required floor over its span — even when the
                // write ends up on the disk path (disk then owns the
                // span, and no remote replica can satisfy the floor until
                // a later write lands remotely, which is exactly right)
                let epoch = if self.resync.election && io.dir == Dir::Write {
                    // In a multi-engine cluster the epoch comes from the
                    // gossip plane's interleaved stream, so two engines
                    // writing the same range under a partition can never
                    // mint the same epoch; `next_epoch` shadows it so the
                    // single-engine ledgers keep their monotone view.
                    let e = match &mut self.gossip {
                        Some(g) => g.mint_epoch(),
                        None => self.resync.next_epoch + 1,
                    };
                    self.resync.next_epoch = self.resync.next_epoch.max(e);
                    self.resync.required.raise(io.addr, io.len, e);
                    e
                } else {
                    0
                };
                let mut sub_ids = IdList::new();
                if map.stripe_local(io.addr, io.len) {
                    let disk =
                        self.submit_leg(io.id, None, &io, io.addr, io.len, epoch, &mut sub_ids);
                    let mut disk_legs = Vec::new();
                    if disk {
                        disk_legs.push((io.addr, io.len));
                    }
                    Submitted {
                        sub_ids,
                        disk_fallback: disk,
                        disk_legs,
                        tenant: io.tenant,
                    }
                } else {
                    let legs = map.split_stripe_local(io.addr, io.len);
                    self.stats.split_requests += 1;
                    self.stats.split_legs += legs.len() as u64;
                    let agg_key = self.aggs.insert(LegAgg {
                        remaining: 0,
                        disk_any: false,
                        failed_over_any: false,
                        app_id: io.id,
                    });
                    let mut disk_legs = Vec::new();
                    let mut live_legs = 0u32;
                    for (addr, len) in legs {
                        let disk = self.submit_leg(
                            io.id,
                            Some(agg_key),
                            &io,
                            addr,
                            len,
                            epoch,
                            &mut sub_ids,
                        );
                        if disk {
                            disk_legs.push((addr, len));
                        } else {
                            live_legs += 1;
                        }
                    }
                    if live_legs == 0 {
                        self.aggs.remove(agg_key).expect("fresh agg");
                        Submitted {
                            sub_ids,
                            disk_fallback: true,
                            disk_legs,
                            tenant: io.tenant,
                        }
                    } else {
                        let agg = self.aggs.get_mut(agg_key).expect("fresh agg");
                        agg.remaining = live_legs;
                        agg.disk_any = !disk_legs.is_empty();
                        Submitted {
                            sub_ids,
                            disk_fallback: false,
                            disk_legs,
                            tenant: io.tenant,
                        }
                    }
                }
            }
        };
        // kick only after this I/O's subs are registered: a resync round
        // spawned here must see them as in-flight and defer overlapping
        // ranges (copying around a write it cannot see would let a stale
        // copy win the race and promote a diverged node)
        self.kick_resync();
        submitted
    }

    /// Place, record, and enqueue one stripe-local leg of an application
    /// I/O, appending the queued sub-I/O ids to `sub_ids`. Returns
    /// whether the leg took the disk path at submit (every replica of
    /// its stripe dead). `agg` is the [`LegAgg`] slab key for a split
    /// request, `None` for a single-leg one.
    #[allow(clippy::too_many_arguments)]
    fn submit_leg(
        &mut self,
        app_id: u64,
        agg: Option<u64>,
        io: &AppIo,
        addr: u64,
        len: u64,
        epoch: u64,
        sub_ids: &mut IdList,
    ) -> bool {
        // Replica targets of one leg, held inline (replication is
        // bounded by MAX_REPLICAS — every shipped topology uses <= 4)
        // so the hot submit path does not allocate a target list; the
        // first `usize` entries of the array are valid.
        enum Route {
            Disk,
            Targets([NodeId; MAX_REPLICAS], usize),
        }
        let Routing::Placed(map) = &self.routing else {
            unreachable!("submit_leg is placed-mode only");
        };
        let mut missed_replicas = [0 as NodeId; MAX_REPLICAS];
        let mut n_missed = 0usize;
        let route = match io.dir {
            Dir::Write => {
                // replicas skipped because they are dead or resyncing
                // miss this write: record the range so resync replays it.
                // Skipped when resync is off (don't tax the hot submit
                // path), when no replica was actually skipped, and when
                // the write takes the disk path — the authoritative copy
                // is then on disk (the paging layer's per-block disk bit
                // owns those reads), and a backlog no alive peer can
                // source would only park every replica of the stripe in
                // `Resyncing` forever.
                let mut targets = [0 as NodeId; MAX_REPLICAS];
                let mut n_targets = 0usize;
                for n in map.replicas_of(addr) {
                    if map.is_alive(n) {
                        targets[n_targets] = n;
                        n_targets += 1;
                    } else if self.resync.enabled {
                        missed_replicas[n_missed] = n;
                        n_missed += 1;
                    }
                }
                if n_targets == 0 {
                    n_missed = 0; // disk owns the span: no missed records
                    Route::Disk
                } else {
                    Route::Targets(targets, n_targets)
                }
            }
            Dir::Read => {
                n_missed = 0;
                match map.route_read(addr) {
                    ReadRoute::Node(n) => {
                        let mut targets = [0 as NodeId; MAX_REPLICAS];
                        targets[0] = n;
                        Route::Targets(targets, 1)
                    }
                    ReadRoute::DiskFallback => Route::Disk,
                }
            }
        };
        for &node in &missed_replicas[..n_missed] {
            self.record_missed(node, addr, len);
        }
        match route {
            Route::Disk => {
                self.stats.disk_fallbacks += 1;
                true
            }
            Route::Targets(targets, n_targets) => {
                let parent = self.pending.insert(Pending {
                    remaining: n_targets as u32,
                    any_ok: false,
                    failed_over: false,
                    app_id,
                    agg,
                    failed_nodes: Vec::new(),
                });
                for &node in &targets[..n_targets] {
                    let sub = SubIo {
                        parent,
                        addr,
                        len,
                        dir: io.dir,
                        thread: io.thread,
                        t_submit: io.t_submit,
                        attempted: 1u64 << node,
                        node,
                        kind: SubKind::App,
                        epoch,
                        tenant: io.tenant,
                        next_in_wr: u64::MAX,
                        timeouts: 0,
                    };
                    let sid = self.subs.insert(sub);
                    self.enqueue(sid, node, &sub);
                    sub_ids.push(sid);
                }
                false
            }
        }
    }

    /// Drain one direction through every shard, bounded by the admission
    /// window. Registers each posted WR with the regulator; the returned
    /// chains are ready for the backend to move.
    ///
    /// Allocating convenience wrapper around
    /// [`IoEngine::drain_dir_into`]; hot paths reuse one [`DrainOut`].
    pub fn drain_dir(&mut self, dir: Dir, now: u64) -> DrainOut {
        let mut out = DrainOut::default();
        self.drain_dir_into(dir, now, &mut out);
        out
    }

    /// Zero-allocation drain of one direction: appends this pass's WRs
    /// and chain spans to `out` (callers reuse one buffer across drains;
    /// [`IoEngine::drain_all_into`] clears it first). Shard drains go
    /// through the merge queues' swap-buffer path and the planner's
    /// arena, and every planned WR is re-keyed to its slot in the
    /// `outstanding` slab — so at steady state the whole
    /// merge → plan → post cycle touches no allocator.
    pub fn drain_dir_into(&mut self, dir: Dir, now: u64, out: &mut DrainOut) {
        let cpu_base = out.cpu_ns;
        let mut cpu = 0u64;
        let mut merged = 0u64;
        let mut blocked = 0u64;
        let n_shards = self.shards.len();
        let start = self.drain_cursor % n_shards;
        self.drain_cursor = self.drain_cursor.wrapping_add(1);
        let multi_tenant = self.regulator.tenant_count() > 1;
        for i in 0..n_shards {
            let qp = (start + i) % n_shards;
            if self.shards[qp].of(dir).is_empty() {
                continue;
            }
            if self.deadlines.is_some() && self.qp_health[qp].state != QpState::Ok {
                // a tripped QP admits no posts until probation walks it
                // back to `Ok`; its queued requests wait (and keep
                // merging with later arrivals) instead of feeding a
                // wedged pipe
                continue;
            }
            let avail = self.regulator.available(now);
            if avail == 0 {
                blocked += 1;
                break;
            }
            let outcome = if multi_tenant {
                // weighted drain: each tenant's lane is capped by its
                // remaining sub-window entitlement in the entitled pass;
                // leftover budget is lent out work-conservingly by the
                // queue's borrow pass
                self.regulator.entitlements_into(&mut self.ent_buf);
                self.shards[qp]
                    .of(dir)
                    .merge_check_tenants_into(avail, &self.ent_buf, &mut self.drain_buf)
            } else {
                self.shards[qp].of(dir).merge_check_into(avail, &mut self.drain_buf)
            };
            match outcome {
                MergeOutcome::Drained => {}
                MergeOutcome::Blocked => {
                    // progress guarantee: a request larger than the window
                    // must not deadlock — once the pipe is fully drained,
                    // admit exactly the head request (a budget of its own
                    // length drains it and nothing behind it)
                    if self.regulator.in_flight() == 0 {
                        let head_len = self.shards[qp].of(dir).peek()[0].len;
                        match self.shards[qp]
                            .of(dir)
                            .merge_check_into(head_len, &mut self.drain_buf)
                        {
                            MergeOutcome::Drained => {}
                            _ => continue,
                        }
                    } else {
                        blocked += 1;
                        continue;
                    }
                }
                MergeOutcome::TakenByPeer => continue,
            }
            if !self.shards[qp].of(dir).is_empty() {
                // window closed mid-drain: the tail stays queued (and keeps
                // merging with later arrivals — the regulator's side benefit)
                blocked += 1;
            }
            cpu += self.costs.merge_check_base_ns
                + self.costs.merge_check_per_io_ns * self.drain_buf.len() as u64;
            let node = self.channels.node_of(qp);
            self.span_buf.clear();
            let pstats = plan_into(
                self.batch,
                &self.limits,
                &mut self.drain_buf,
                &mut self.next_wr_id,
                &mut out.wrs,
                &mut self.span_buf,
                &mut self.plan_arena,
            );
            merged += pstats.merged_ios;
            self.stats.wqes += pstats.wqes;
            self.stats.posts += pstats.posts;
            for &span in &self.span_buf {
                debug_assert_eq!(span.node, node, "shard {qp} planned a foreign node");
                for wr in &mut out.wrs[span.start..span.end] {
                    // lazy registration precedes the post: spans already
                    // in the MR cache cost an lkey lookup, the rest a
                    // registration (eviction deregs are deferred/batched)
                    if let Some(cache) = &mut self.mr_cache {
                        let t = cache.touch(wr.remote_addr, wr.len);
                        cpu += self.costs.mr_hit_ns * u64::from(t.hit_spans)
                            + self.costs.mr_miss_ns * u64::from(t.miss_spans);
                    }
                    // with deadlines on, thread the WR's subs into an
                    // intrusive chain through the sub ledger so an
                    // expiry can rebuild its app_ios without keeping a
                    // side allocation per WR
                    let (first_sub, deadline_at) = match self.deadlines {
                        Some((timeout_ns, _)) => {
                            let mut head = u64::MAX;
                            for &sid in &wr.app_ios {
                                if let Some(s) = self.subs.get_mut(sid) {
                                    s.next_in_wr = head;
                                    head = sid;
                                }
                            }
                            (head, now.saturating_add(timeout_ns))
                        }
                        None => (u64::MAX, u64::MAX),
                    };
                    // re-key the WR to its outstanding-ledger slot: the
                    // wr_id the backend sees *is* the slab key, so the
                    // completion lookup is an index, not a hash probe
                    let key = self.outstanding.insert(PostedWr {
                        bytes: wr.len,
                        t_post: now + cpu,
                        tenant: wr.tenant,
                        qp,
                        op: wr.op,
                        first_sub,
                        deadline_at,
                        dl_prev: u64::MAX,
                        dl_next: u64::MAX,
                    });
                    if self.deadlines.is_some() {
                        self.dl_push_back(key);
                    }
                    wr.wr_id = key;
                    self.regulator.on_post(key, wr.tenant, wr.len);
                    cpu += self.costs.post_wqe_cpu_ns;
                }
                cpu += self.costs.mmio_cpu_ns;
                out.chains.push(PostChain {
                    qp,
                    node,
                    start: span.start,
                    end: span.end,
                    cpu_offset_ns: cpu_base + cpu,
                });
            }
        }
        if let Some(cache) = &mut self.mr_cache {
            // deferred deregistration: flush a full batch *after* every
            // chain's cpu_offset is fixed, so evictions never delay a
            // post — only the drain's total serialized CPU grows
            if cache.pending_deregs() >= cache.dereg_batch() {
                cpu += self.costs.mr_dereg_ns * cache.flush_deregs() as u64;
            }
            self.stats.mr_hits = cache.stats.mr_hits;
            self.stats.mr_misses = cache.stats.mr_misses;
            self.stats.mr_evictions = cache.stats.mr_evictions;
            self.stats.mr_dereg_batches = cache.stats.mr_dereg_batches;
        }
        out.cpu_ns = cpu_base + cpu;
        out.merged_ios += merged;
        out.admission_blocked += blocked;
        self.stats.merged_ios += merged;
        self.stats.admission_blocks += blocked;
        self.stats.window_leaks = self.regulator.window_leaks;
    }

    /// Drain both directions (reads first: page-ins are synchronous).
    ///
    /// Allocating convenience wrapper around
    /// [`IoEngine::drain_all_into`], kept for the unit suites; every
    /// shipping pump reuses one [`DrainOut`] through the `_into` path.
    #[cfg(test)]
    pub fn drain_all(&mut self, now: u64) -> DrainOut {
        let mut out = DrainOut::default();
        self.drain_all_into(now, &mut out);
        out
    }

    /// Zero-allocation drain of both directions into a reused buffer
    /// (cleared first; capacity is retained across calls).
    pub fn drain_all_into(&mut self, now: u64, out: &mut DrainOut) {
        out.clear();
        self.drain_dir_into(Dir::Read, now, out);
        let read_cpu = out.cpu_ns;
        self.drain_dir_into(Dir::Write, now + read_cpu, out);
    }

    /// Handle one work completion: release the admission window, map the
    /// WR's sub-I/Os back to application I/Os, apply the replication
    /// policy, and fail reads over to the next alive replica on error.
    ///
    /// Idempotent and order-independent: retirement is keyed by wr_id —
    /// the WR's slot in the generational `outstanding` slab — so
    /// duplicate, late, and reordered completions (a chaotic CQ delivers
    /// all three) are tolerated: freeing the slot bumps its generation,
    /// and a stale wr_id can never resolve again, even after the slot is
    /// recycled for a new WR. A WR releases its window bytes and
    /// resolves its sub-I/Os exactly once, whatever the CQ does.
    ///
    /// Allocating convenience wrapper around [`IoEngine::on_wc_into`];
    /// hot paths reuse one [`WcOut`].
    pub fn on_wc(&mut self, wc: &Wc, now: u64) -> WcOut {
        let mut out = WcOut::default();
        self.on_wc_into(wc, now, &mut out);
        out
    }

    /// Zero-allocation completion handling into a reused output buffer
    /// (cleared first; capacity is retained across calls).
    pub fn on_wc_into(&mut self, wc: &Wc, now: u64, out: &mut WcOut) {
        out.clear();
        self.on_wc_inner(wc, now, false, out);
        self.kick_resync();
        self.maybe_prune_epochs();
        self.stats.window_leaks = self.regulator.window_leaks;
    }

    /// Completion handling shared by real WCs and synthesized
    /// timeout-WCs. Appends to `out` without clearing it so the timer
    /// service can fold many expiries into one output batch; callers
    /// run the resync kick and epoch prune once per batch.
    fn on_wc_inner(&mut self, wc: &Wc, now: u64, timeout: bool, out: &mut WcOut) {
        let Some(posted) = self.outstanding.remove(wc.wr_id) else {
            // duplicate or unknown wr_id: dropped before it can touch the
            // window accounting or retire anything twice — this is also
            // where a late real WC lands after its WR timed out locally
            self.stats.duplicate_wcs += 1;
            return;
        };
        self.dl_unlink(&posted, wc.wr_id);
        debug_assert_eq!(posted.bytes, wc.len, "WC length disagrees with its WR");
        let rtt = now.saturating_sub(posted.t_post);
        // release against the tenant recorded at post time: the engine's
        // posted-WR ledger, not the fabric-echoed `wc.tenant`, decides
        // whose sub-window the bytes come back to
        self.regulator.on_complete(wc.wr_id, posted.tenant, wc.len, rtt);
        let ok = wc.status == WcStatus::Success;

        if matches!(self.routing, Routing::Direct) {
            // direct mode: sub-I/Os *are* the application I/Os — retire
            // each exactly once, no replication policy to satisfy. An
            // error completion (direct mode has no failover) surfaces as
            // the disk-fallback signal so callers can tell it apart.
            for &id in &wc.app_ios {
                out.retired.push(RetiredIo {
                    id,
                    disk_fallback: !ok,
                    failed_over: false,
                });
                if ok {
                    out.completed_subs.push((id, id));
                } else {
                    self.stats.disk_fallbacks += 1;
                    out.failed_subs.push((id, id));
                }
            }
            self.stats.retired += wc.app_ios.len() as u64;
            return;
        }

        let max_retries = self.deadlines.map_or(0, |(_, r)| r);
        for &sid in &wc.app_ios {
            // stale (already-resolved) sub ids fail the slab's generation
            // check — the per-sub duplicate guard
            let Some(&sub) = self.subs.get(sid) else {
                continue;
            };
            match sub.kind {
                // a timed-out read with retries left parks for backoff
                // instead of failing over immediately: the timeout may
                // be congestion, not death, and hammering the next
                // replica right away spreads it
                SubKind::App if timeout && sub.dir == Dir::Read && sub.timeouts < max_retries => {
                    self.hold_for_backoff(sid, sub, now)
                }
                SubKind::App => self.on_app_sub(sid, sub, ok, out),
                SubKind::ResyncRead { target } => {
                    self.on_resync_read_sub(sid, sub, target, ok, out)
                }
                SubKind::ResyncWrite { target } => {
                    self.on_resync_write_sub(sid, sub, target, ok, out)
                }
            }
        }
        if timeout {
            self.note_qp_timeout(posted.qp, now, out);
        } else if ok {
            self.qp_health[posted.qp].consecutive_timeouts = 0;
        }
    }

    /// Append a freshly posted WR at the tail of the deadline list.
    /// Deadlines are minted from the drain's `now`, which callers move
    /// monotonically, so tail-append keeps the list earliest-first and
    /// both ends of it O(1) — no heap, no allocation, just two links
    /// threaded through the outstanding slab.
    fn dl_push_back(&mut self, key: u64) {
        let tail = self.dl_tail;
        if let Some(p) = self.outstanding.get_mut(key) {
            p.dl_prev = tail;
            p.dl_next = u64::MAX;
        }
        // `u64::MAX` fails the slab's generation check, so an empty
        // tail falls through to the head update
        match self.outstanding.get_mut(tail) {
            Some(t) => t.dl_next = key,
            None => self.dl_head = key,
        }
        self.dl_tail = key;
    }

    /// Unlink a retired WR from the deadline list in O(1) — the
    /// completion-path "cancel" of its timeout. No-op when deadlines
    /// are off (the links are never threaded).
    fn dl_unlink(&mut self, posted: &PostedWr, key: u64) {
        if self.deadlines.is_none() {
            return;
        }
        match self.outstanding.get_mut(posted.dl_prev) {
            Some(p) => p.dl_next = posted.dl_next,
            None => {
                if self.dl_head == key {
                    self.dl_head = posted.dl_next;
                }
            }
        }
        match self.outstanding.get_mut(posted.dl_next) {
            Some(n) => n.dl_prev = posted.dl_prev,
            None => {
                if self.dl_tail == key {
                    self.dl_tail = posted.dl_prev;
                }
            }
        }
    }

    /// Synthesize the local timeout-WC for an expired (or flushed) WR
    /// and run it through the ordinary completion path: the admission
    /// window releases exactly once, subs re-route through
    /// backoff/failover, and the late real WC — if the fabric ever
    /// delivers it — dies at the generation check as a counted
    /// duplicate.
    fn expire_wr(&mut self, wr_id: u64, now: u64, out: &mut WcOut) {
        let Some(posted) = self.outstanding.get(wr_id).copied() else {
            return;
        };
        let mut ids = IdList::new();
        let mut sid = posted.first_sub;
        while sid != u64::MAX {
            ids.push(sid);
            sid = self.subs.get(sid).map_or(u64::MAX, |s| s.next_in_wr);
        }
        let wc = Wc {
            wr_id,
            qp: posted.qp,
            op: posted.op,
            len: posted.bytes,
            status: WcStatus::Error,
            app_ios: ids,
            tenant: posted.tenant,
        };
        self.on_wc_inner(&wc, now, true, out);
    }

    /// Park a timed-out read sub for a capped, jittered backoff instead
    /// of re-queueing it immediately. The window bytes were already
    /// released by the timeout-WC, so the parked sub costs nothing; the
    /// release timer funnels it back through the ordinary
    /// failover-or-terminal path with the timed-out node excluded.
    fn hold_for_backoff(&mut self, sid: u64, sub: SubIo, now: u64) {
        let (timeout_ns, _) = self.deadlines.expect("timeout path requires deadlines");
        if let Some(s) = self.subs.get_mut(sid) {
            s.timeouts = sub.timeouts + 1;
            // the node that timed out is as failed as one that errored
            s.attempted |= 1 << sub.node;
            s.next_in_wr = u64::MAX;
        }
        let delay = backoff_delay(timeout_ns, sub.timeouts, sid);
        self.timers
            .push(now.saturating_add(delay), TimerEntry::BackoffRelease(sid));
    }

    /// Fire a backoff release: the parked sub re-enters the routing
    /// machinery as a failed read — next alive, untried replica or
    /// terminal disk fallback. The parked sub is exclusively owned by
    /// its timer (a late real WC died at the generation check; a QP
    /// flush only walks WR-attached subs), so a dead generation here
    /// means the id was already resolved and the release is a no-op.
    fn release_backoff(&mut self, sid: u64, out: &mut WcOut) {
        let Some(&sub) = self.subs.get(sid) else {
            return;
        };
        self.on_app_sub(sid, sub, false, out);
    }

    /// Count a deadline expiry against its QP. [`QP_ERROR_TIMEOUTS`]
    /// consecutive expiries (any success resets the streak) flip the QP
    /// to `Error`, which — like a verbs QP entering the error state —
    /// flushes every WR it still carries as an immediate timeout-WC and
    /// schedules the probation probe that will walk it back to `Ok`.
    /// When that wedges the node's last healthy QP, the node itself is
    /// reported down so placement routes around it.
    fn note_qp_timeout(&mut self, qp: QpId, now: u64, out: &mut WcOut) {
        self.recovery.timeouts += 1;
        let Some((timeout_ns, _)) = self.deadlines else {
            return;
        };
        let h = &mut self.qp_health[qp];
        if h.state != QpState::Ok {
            // flushes land here: their nested timeout-WCs must not
            // re-trip the QP that is already in `Error`
            return;
        }
        h.consecutive_timeouts += 1;
        if h.consecutive_timeouts < QP_ERROR_TIMEOUTS {
            return;
        }
        h.state = QpState::Error;
        h.consecutive_timeouts = 0;
        self.timers.push(
            now.saturating_add(QP_PROBATION_TIMEOUTS.saturating_mul(timeout_ns)),
            TimerEntry::QpProbe(qp),
        );
        // flush: walk the deadline list once, collecting this QP's
        // outstanding WRs, then expire each — reusing a persistent
        // buffer so the wedge path allocates only on its first trip
        let mut flush = std::mem::take(&mut self.flush_buf);
        flush.clear();
        let mut cur = self.dl_head;
        while cur != u64::MAX {
            let p = self
                .outstanding
                .get(cur)
                .expect("deadline list holds only live WRs");
            if p.qp == qp {
                flush.push(cur);
            }
            cur = p.dl_next;
        }
        for &wr_id in &flush {
            self.recovery.flushes += 1;
            self.expire_wr(wr_id, now, out);
        }
        flush.clear();
        self.flush_buf = flush;
        let node = self.channels.node_of(qp);
        let all_out = (0..self.channels.total_qps())
            .filter(|&q| self.channels.node_of(q) == node)
            .all(|q| self.qp_health[q].state != QpState::Ok);
        if all_out && !self.auto_downed[node] && matches!(self.routing, Routing::Placed(_)) {
            self.auto_downed[node] = true;
            self.on_node_down(node);
        }
    }

    /// One probation step of a tripped QP: `Error → Resetting` (one
    /// more probe scheduled a timeout later), then `Resetting → Ok` —
    /// re-admitting the QP for drains and, if the wedge had taken the
    /// whole node down, re-admitting the node through the ordinary
    /// rejoin path (which resyncs any writes it missed).
    fn probe_qp(&mut self, qp: QpId, now: u64) {
        let Some((timeout_ns, _)) = self.deadlines else {
            return;
        };
        match self.qp_health[qp].state {
            QpState::Error => {
                self.qp_health[qp].state = QpState::Resetting;
                self.timers
                    .push(now.saturating_add(timeout_ns), TimerEntry::QpProbe(qp));
            }
            QpState::Resetting => {
                self.qp_health[qp].state = QpState::Ok;
                self.qp_health[qp].consecutive_timeouts = 0;
                self.recovery.resets += 1;
                let node = self.channels.node_of(qp);
                if self.auto_downed[node] {
                    self.auto_downed[node] = false;
                    self.on_node_up(node);
                }
            }
            QpState::Ok => {}
        }
    }

    /// Earliest pending recovery event — WR deadline, backoff release,
    /// or QP probe — in engine time; `None` when nothing is armed.
    /// Backends schedule their next [`IoEngine::service_timers`] call
    /// here instead of polling.
    pub fn next_timer_at(&mut self) -> Option<u64> {
        let dl = self.outstanding.get(self.dl_head).map(|p| p.deadline_at);
        match (dl, self.timers.peek_at()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Fire every recovery event due at or before `now`, earliest
    /// first, appending the synthesized retirements to `out` (cleared
    /// first) exactly as a real completion batch would. No-op when
    /// deadlines are off or nothing is due. After a call the caller
    /// should drain again: expiries re-queue work and probes re-admit
    /// QPs.
    pub fn service_timers(&mut self, now: u64, out: &mut WcOut) {
        out.clear();
        if self.deadlines.is_none() {
            return;
        }
        loop {
            let dl = self.outstanding.get(self.dl_head).map(|p| p.deadline_at);
            let dl_due = dl.map_or(false, |t| t <= now);
            let tq = self.timers.peek_at();
            let tq_due = tq.map_or(false, |t| t <= now);
            if dl_due && (!tq_due || dl <= tq) {
                let head = self.dl_head;
                self.expire_wr(head, now, out);
                if self.dl_head == head {
                    debug_assert!(false, "expiry failed to pop the deadline head");
                    break;
                }
            } else if tq_due {
                let Some((_, entry)) = self.timers.pop() else {
                    break;
                };
                match entry {
                    TimerEntry::BackoffRelease(sid) => self.release_backoff(sid, out),
                    TimerEntry::QpProbe(qp) => self.probe_qp(qp, now),
                }
            } else {
                break;
            }
        }
        self.kick_resync();
        self.maybe_prune_epochs();
        self.stats.window_leaks = self.regulator.window_leaks;
    }

    /// Resolve one application replica leg (placed mode). The sub stays
    /// in the ledger (same id, so late duplicates still resolve to it
    /// harmlessly) only when a failed read is re-queued for failover;
    /// every other outcome frees its slot.
    fn on_app_sub(&mut self, sid: u64, sub: SubIo, ok: bool, out: &mut WcOut) {
        if self.resync.enabled && sub.dir == Dir::Write {
            // an app write leaving the pipeline may unblock resync
            // ranges deferred behind it; re-arm only nodes whose backlog
            // actually overlaps, and let the end-of-on_wc kick re-scan
            for n in 0..self.resync.deferred_wait.len() {
                if self.resync.deferred_wait[n]
                    && self.resync.missed[n].overlaps(sub.addr, sub.len)
                {
                    self.resync.deferred_wait[n] = false;
                }
            }
        }
        if !ok && sub.dir == Dir::Read {
            // failover: re-queue onto the next alive, untried replica —
            // in place, under the same sub id
            let next = match &self.routing {
                Routing::Placed(map) => match map.route_read_excluding(sub.addr, sub.attempted) {
                    ReadRoute::Node(n) => Some(n),
                    ReadRoute::DiskFallback => None,
                },
                Routing::Direct => unreachable!(),
            };
            if let Some(node) = next {
                let mut retry = sub;
                retry.attempted |= 1u64 << node;
                retry.node = node;
                if let Some(s) = self.subs.get_mut(sid) {
                    *s = retry;
                }
                if let Some(p) = self.pending.get_mut(sub.parent) {
                    p.failed_over = true;
                }
                self.enqueue(sid, node, &retry);
                out.requeued += 1;
                self.stats.requeued += 1;
                return;
            }
        }
        // terminal resolution: the sub leaves the ledger
        self.subs.remove(sid);
        let app_id = self.pending.get(sub.parent).map_or(sub.parent, |p| p.app_id);
        if ok {
            if sub.dir == Dir::Write && sub.epoch > 0 {
                // the node's store now holds this write: publish it in
                // the node's applied epoch vector (the donor election
                // reads these)
                self.resync.applied[sub.node].raise(sub.addr, sub.len, sub.epoch);
            }
            out.completed_subs.push((sid, app_id));
        } else {
            out.failed_subs.push((sid, app_id));
        }
        let Some(p) = self.pending.get_mut(sub.parent) else {
            return;
        };
        if ok {
            p.any_ok = true;
        } else if sub.dir == Dir::Write {
            // this replica diverged; judged at retirement (below)
            p.failed_nodes.push(sub.node);
        }
        p.remaining -= 1;
        if p.remaining > 0 {
            return;
        }
        let done = self.pending.remove(sub.parent).expect("pending parent");
        let disk_fallback = !done.any_ok;
        if disk_fallback {
            self.stats.disk_fallbacks += 1;
        } else {
            // the write is durable on at least one replica: every
            // replica whose leg failed must be repaired before it
            // serves reads for this range again (recording demotes
            // it). Within this same completion, so no later submit
            // can route a read to the diverged node.
            for &n in &done.failed_nodes {
                self.record_missed(n, sub.addr, sub.len);
            }
        }
        // a split request retires once every stripe-local leg has
        // (flags ORed across legs); an unsplit request retires here
        match done.agg {
            Some(agg_key) => {
                let agg = self.aggs.get_mut(agg_key).expect("leg aggregation");
                agg.remaining -= 1;
                agg.disk_any |= disk_fallback;
                agg.failed_over_any |= done.failed_over;
                if agg.remaining == 0 {
                    let agg = self.aggs.remove(agg_key).expect("agg present");
                    self.stats.retired += 1;
                    out.retired.push(RetiredIo {
                        id: agg.app_id,
                        disk_fallback: agg.disk_any,
                        failed_over: agg.failed_over_any,
                    });
                }
            }
            None => {
                self.stats.retired += 1;
                out.retired.push(RetiredIo {
                    id: done.app_id,
                    disk_fallback,
                    failed_over: done.failed_over,
                });
            }
        }
    }

    /// Resolve the read stage of a resync copy: on success, enqueue the
    /// repair write to the recovering node; on error, fail over to the
    /// next alive source, or return the range to the missed backlog.
    fn on_resync_read_sub(
        &mut self,
        sid: u64,
        sub: SubIo,
        target: NodeId,
        ok: bool,
        out: &mut WcOut,
    ) {
        if ok {
            self.subs.remove(sid);
            let mut wsub = sub;
            wsub.dir = Dir::Write;
            wsub.attempted = 1u64 << target;
            wsub.node = target;
            wsub.kind = SubKind::ResyncWrite { target };
            let wsid = self.subs.insert(wsub);
            self.enqueue(wsid, target, &wsub);
            out.completed_subs.push((sid, RESYNC_PARENT));
            out.resync_copies.push(ResyncCopy {
                read_sub: sid,
                write_sub: wsid,
                target,
                addr: sub.addr,
                len: sub.len,
            });
            return;
        }
        let next = self
            .resync_source(target, sub.addr, sub.len, sub.attempted)
            .or_else(|| {
                // conservative rule exhausted: the election may still
                // name a valid donor among the untried replicas
                if self.resync.election {
                    let e_req = self.resync.required.max_over(sub.addr, sub.len);
                    self.elect_donor(target, sub.addr, sub.len, e_req, sub.attempted)
                } else {
                    None
                }
            });
        if let Some(node) = next {
            let mut retry = sub;
            retry.attempted |= 1u64 << node;
            retry.node = node;
            // the copy's epoch is whatever the new donor holds for the
            // span (what the repair write will publish on the target)
            if self.resync.election {
                retry.epoch = self.resync.applied[node].min_over(sub.addr, sub.len);
            }
            if let Some(s) = self.subs.get_mut(sid) {
                *s = retry;
            }
            self.enqueue(sid, node, &retry);
            out.requeued += 1;
            self.stats.requeued += 1;
        } else {
            // every eligible source failed: the range stays missed until
            // a new source appears (another node coming up / finishing
            // its own resync clears the dormant latch)
            self.subs.remove(sid);
            self.stats.resync_copy_failures += 1;
            self.resync.missed[target].insert(sub.addr, sub.len);
            self.resync.repairing[target].remove(sub.addr, sub.len);
            self.resync.outstanding[target] = self.resync.outstanding[target].saturating_sub(1);
            out.failed_subs.push((sid, RESYNC_PARENT));
        }
    }

    /// Resolve the write stage of a resync copy. A failed repair write
    /// restarts the whole copy from the read stage (the payload is gone
    /// from the backend), by returning the range to the missed backlog.
    fn on_resync_write_sub(
        &mut self,
        sid: u64,
        sub: SubIo,
        target: NodeId,
        ok: bool,
        out: &mut WcOut,
    ) {
        self.subs.remove(sid);
        self.resync.outstanding[target] = self.resync.outstanding[target].saturating_sub(1);
        self.resync.repairing[target].remove(sub.addr, sub.len);
        if ok {
            if sub.epoch > 0 {
                // the repair landed: the target now holds the donor's
                // data at the donor's epoch for this span
                self.resync.applied[target].raise(sub.addr, sub.len, sub.epoch);
            }
            out.completed_subs.push((sid, RESYNC_PARENT));
        } else {
            self.stats.resync_copy_failures += 1;
            self.resync.missed[target].insert(sub.addr, sub.len);
            self.resync.dormant[target] = false;
            out.failed_subs.push((sid, RESYNC_PARENT));
        }
    }

    /// Stored ranges currently held by the cluster-wide required epoch
    /// floor (the boundedness measure the prune test watches).
    pub fn epoch_floor_ranges(&self) -> usize {
        self.resync.required.len()
    }

    /// Amortized epoch-vector pruning: scan only when the required floor
    /// has outgrown its watermark, then re-arm the watermark at twice the
    /// post-prune size. Every placed write stores one floor range (each
    /// has a distinct epoch, so neighbors never coalesce) — without this,
    /// a long-running engine's floor grows linearly with writes ever
    /// issued instead of with *live divergence*.
    fn maybe_prune_epochs(&mut self) {
        if !self.resync.election || self.resync.required.len() < self.resync.prune_watermark {
            return;
        }
        self.prune_epoch_floor();
        self.resync.prune_watermark = PRUNE_FLOOR_RANGES.max(self.resync.required.len() * 2);
    }

    /// Prune the epoch bookkeeping (ROADMAP PR 4 follow-on): drop every
    /// required-floor range that *every* replica of its stripe provably
    /// satisfies — non-dead, not missing or repairing any byte of the
    /// range, and holding an applied epoch at or above the floor. Such a
    /// range carries no recovery information: any replica is already a
    /// valid donor for it, and only a *future* write (which mints a
    /// fresh epoch and re-raises the floor) can create new divergence
    /// over it. The matching applied-vector spans are erased with it, so
    /// both sides of the election metadata stay O(live divergence)
    /// instead of O(writes ever issued). Returns the ranges pruned.
    ///
    /// A dead replica pins every range it might have missed: its applied
    /// vector is frozen below the floor, so nothing it could need on
    /// revival is ever forgotten — the stale-promotion hazard of pruning
    /// by live replicas alone.
    pub fn prune_epoch_floor(&mut self) -> usize {
        if !self.resync.election {
            return 0;
        }
        let Routing::Placed(map) = &self.routing else {
            return 0;
        };
        let stripe = map.stripe_bytes();
        // collect first: erasing mutates the map under iteration
        let candidates: Vec<(u64, u64, u64)> = self.resync.required.entries().collect();
        let mut prune: Vec<(u64, u64)> = Vec::new();
        for (s, e, ep) in candidates {
            // a stored range can span stripes (writes are split into
            // stripe-local legs, but adjacent stripes' floors abut);
            // judge each stripe-local piece against its own replica set
            let mut a = s;
            while a < e {
                let piece_end = ((a / stripe + 1) * stripe).min(e);
                let l = piece_end - a;
                let satisfied = map.replicas_of(a).all(|r| {
                    map.state(r) != NodeState::Dead
                        && !self.resync.missed[r].overlaps(a, l)
                        && !self.resync.repairing[r].overlaps(a, l)
                        && self.resync.applied[r].min_over(a, l) >= ep
                });
                if satisfied {
                    prune.push((a, l));
                }
                a = piece_end;
            }
        }
        let pruned = prune.len();
        for (a, l) in prune {
            self.resync.required.erase(a, l);
            for applied in &mut self.resync.applied {
                applied.erase(a, l);
            }
        }
        pruned
    }

    /// Record a write range a replica missed (it was dead/resyncing at
    /// submit time, or its replica write failed). An alive node acquiring
    /// a missed range is demoted to `Resyncing` — it diverged, and must
    /// not serve reads for data it does not hold.
    fn record_missed(&mut self, node: NodeId, addr: u64, len: u64) {
        if !self.resync.enabled {
            return;
        }
        match &self.routing {
            // with a single replica there is no peer to repair from:
            // the machinery would only blackhole the node
            Routing::Placed(m) if m.replicas() >= 2 => {}
            _ => return,
        }
        self.resync.missed[node].insert(addr, len);
        self.resync.dormant[node] = false;
        self.stats.missed_ranges += 1;
        let demote =
            matches!(&self.routing, Routing::Placed(m) if m.state(node) == NodeState::Alive);
        if demote {
            self.set_node_state(node, NodeState::Resyncing);
            self.stats.resync_demotions += 1;
        }
    }

    /// Pick a copy source for resyncing `[addr, addr+len)` onto `target`:
    /// the first replica of the range's stripe, excluding `target` and
    /// anything in `attempted`, that is either `Alive` or — crucially —
    /// `Resyncing` but *not missing any byte of this range itself*. A
    /// resyncing node's data is valid outside its own missed set (that
    /// is the protocol's core invariant), and allowing such sources is
    /// what lets two replicas that demoted each other on disjoint ranges
    /// repair each other instead of parking forever.
    fn resync_source(&self, target: NodeId, addr: u64, len: u64, attempted: u64) -> Option<NodeId> {
        let Routing::Placed(map) = &self.routing else {
            return None;
        };
        let tried = |n: NodeId| n < 64 && attempted & (1u64 << n) != 0;
        map.place(addr).replicas.into_iter().find(|&n| {
            n != target
                && !tried(n)
                && match map.state(n) {
                    NodeState::Alive => true,
                    // valid outside its own backlog — which includes
                    // ranges whose repair copy is still in flight
                    NodeState::Resyncing => {
                        !self.resync.missed[n].overlaps(addr, len)
                            && !self.resync.repairing[n].overlaps(addr, len)
                    }
                    NodeState::Dead => false,
                }
        })
    }

    /// Epoch-vector donor election for `[addr, addr + len)` onto
    /// `target`: the first replica of the range's stripe — excluding
    /// `target`, dead nodes, and anything in `attempted` — whose
    /// **applied** epoch vector covers the whole range at or above
    /// `e_req` (the required floor). Unlike [`IoEngine::resync_source`],
    /// this accepts a resyncing peer whose own missed backlog *overlaps*
    /// the range: the vectors decide freshness, not the backlog — which
    /// is what lets two mutually-diverged replicas elect the one that
    /// actually holds the data instead of parking forever. A donor whose
    /// own repair for the range is still in flight is naturally excluded:
    /// its applied vector only rises when the repair write lands.
    fn elect_donor(
        &self,
        target: NodeId,
        addr: u64,
        len: u64,
        e_req: u64,
        attempted: u64,
    ) -> Option<NodeId> {
        let Routing::Placed(map) = &self.routing else {
            return None;
        };
        let tried = |n: NodeId| n < 64 && attempted & (1u64 << n) != 0;
        map.place(addr).replicas.into_iter().find(|&n| {
            n != target
                && !tried(n)
                && map.state(n) != NodeState::Dead
                && self.resync.applied[n].min_over(addr, len) >= e_req
        })
    }

    /// Queue one chunked read-from-donor for a missed range of `node`
    /// (stage 1 of a repair copy). `src_epoch` is what the donor holds
    /// for the span — published on the target when the repair lands.
    fn spawn_copy(&mut self, node: NodeId, src: NodeId, addr: u64, len: u64, src_epoch: u64) {
        let sub = SubIo {
            parent: RESYNC_PARENT,
            addr,
            len,
            dir: Dir::Read,
            thread: 0,
            t_submit: 0,
            attempted: 1u64 << src,
            node: src,
            kind: SubKind::ResyncRead { target: node },
            epoch: src_epoch,
            tenant: crate::fabric::DEFAULT_TENANT,
            next_in_wr: u64::MAX,
            timeouts: 0,
        };
        let sid = self.subs.insert(sub);
        self.enqueue(sid, src, &sub);
        self.resync.repairing[node].insert(addr, len);
        self.resync.outstanding[node] += 1;
        self.stats.resync_copies += 1;
    }

    /// Does any *application write* still in the pipeline overlap this
    /// range? Resync must not copy a range with writes in flight: the
    /// source may not have applied them yet, and promoting on a stale
    /// copy would reintroduce exactly the hole resync exists to close.
    /// Deferred ranges are retried when those writes complete.
    fn range_has_inflight_app_writes(&self, addr: u64, len: u64) -> bool {
        self.subs.values().any(|s| {
            s.kind == SubKind::App
                && s.dir == Dir::Write
                && s.addr < addr + len
                && addr < s.addr + s.len
        })
    }

    /// Advance the resync state machine for every recovering node: start
    /// a new round when the previous one drained, or promote the node
    /// back to `Alive` once its backlog is empty. Called after every
    /// submit / completion, so progress is event-driven and deterministic.
    fn kick_resync(&mut self) {
        if !self.resync.enabled {
            return;
        }
        // run to fixpoint: a promotion clears dormant latches, and nodes
        // scanned *before* the promoted one must be revisited in the
        // same kick — on a quiescent pipeline no later event would
        // re-scan them, and they would park despite a source appearing
        loop {
            let mut promoted = false;
            for node in 0..self.channels.nodes() {
                let state = match &self.routing {
                    Routing::Placed(m) => m.state(node),
                    Routing::Direct => return,
                };
                if state != NodeState::Resyncing
                    || self.resync.outstanding[node] > 0
                    || self.resync.dormant[node]
                    || self.resync.deferred_wait[node]
                {
                    continue;
                }
                if self.resync.missed[node].is_empty() {
                    self.promote(node);
                    promoted = true;
                    continue;
                }
                let (spawned, deferred) = self.spawn_resync_round(node);
                if self.resync.missed[node].is_empty() && self.resync.outstanding[node] == 0 {
                    // the whole backlog resolved without a copy in flight
                    // (election self-heals and/or disk surrenders): the
                    // node is current — promote it in this same kick
                    self.promote(node);
                    promoted = true;
                } else if spawned == 0 {
                    if deferred > 0 {
                        // everything waits on in-flight app writes:
                        // re-scan when one completes, not on every event
                        self.resync.deferred_wait[node] = true;
                    } else {
                        // no source for anything: wait for new information
                        self.resync.dormant[node] = true;
                    }
                }
            }
            if !promoted {
                return;
            }
        }
    }

    /// Promote a node whose backlog drained back to `Alive`; it is a new
    /// copy source, so dormant peers get another chance.
    fn promote(&mut self, node: NodeId) {
        debug_assert!(
            self.resync.repairing[node].is_empty(),
            "promoting node {node} with repairs still in flight"
        );
        if matches!(self.routing, Routing::Placed(_)) {
            self.set_node_state(node, NodeState::Alive);
        }
        self.stats.resyncs_completed += 1;
        self.resync.dormant.fill(false);
    }

    /// One pass over a node's missed backlog: queue a chunked
    /// read-from-peer for every range that has no application writes in
    /// flight. Returns `(spawned, deferred)` copy counts. Without the
    /// election, ranges with no conservative source go back to the
    /// backlog; with it, every chunk resolves — a donor is elected by
    /// epoch vector, the range self-heals (the node already holds the
    /// required epoch), or it is surrendered to the disk path (no live
    /// copy at all).
    fn spawn_resync_round(&mut self, node: NodeId) -> (u32, u32) {
        let ranges = self.resync.missed[node].drain();
        // coalesced ranges can cross stripe boundaries (adjacent writes
        // in neighboring stripes): clamp every copy to its own stripe,
        // so its source — the stripe's first alive replica — is a node
        // that actually replicates the whole chunk
        let stripe = match &self.routing {
            Routing::Placed(m) => m.stripe_bytes(),
            Routing::Direct => u64::MAX,
        };
        let mut spawned = 0u32;
        let mut deferred = 0u32;
        for (addr, len) in ranges {
            if self.range_has_inflight_app_writes(addr, len) {
                self.resync.missed[node].insert(addr, len);
                deferred += 1;
                continue;
            }
            let chunk = self.resync.max_copy_bytes;
            let mut off = 0u64;
            while off < len {
                let caddr = addr + off;
                let stripe_left = stripe - (caddr % stripe);
                let clen = chunk.min(len - off).min(stripe_left);
                if let Some(src) = self.resync_source(node, caddr, clen, 0) {
                    let src_epoch = if self.resync.election {
                        self.resync.applied[src].min_over(caddr, clen)
                    } else {
                        0
                    };
                    self.spawn_copy(node, src, caddr, clen, src_epoch);
                    spawned += 1;
                    off += clen;
                    continue;
                }
                if !self.resync.election {
                    // no peer can source the rest of this range
                    self.stats.resync_copy_failures += 1;
                    self.resync.missed[node].insert(caddr, len - off);
                    break;
                }
                // epoch-vector election, per uniform required-epoch
                // segment of the chunk (a chunk can span writes of
                // different epochs; each segment elects independently so
                // a donor is never credited beyond what it holds)
                off += clen;
                for (sa, sl, e_req) in self.resync.required.segments(caddr, clen) {
                    if self.resync.applied[node].min_over(sa, sl) >= e_req {
                        // spurious missed record: the node has since
                        // received (or been repaired to) the required
                        // epoch — heal in place, nothing to copy
                        self.stats.resync_self_heals += 1;
                    } else if let Some(src) = self.elect_donor(node, sa, sl, e_req, 0) {
                        let src_epoch = self.resync.applied[src].min_over(sa, sl);
                        self.spawn_copy(node, src, sa, sl, src_epoch);
                        self.stats.resync_elections += 1;
                        spawned += 1;
                    } else {
                        // no live replica holds the required epoch: the
                        // only current copy is the paging layer's local
                        // disk replica — surrender the span to the disk
                        // path instead of parking the node forever
                        self.stats.resync_disk_surrenders += 1;
                        self.resync.surrendered.push((node, sa, sl));
                        if let Some(g) = &mut self.gossip {
                            g.disk_log.push((node, sa, sl));
                        }
                    }
                }
            }
        }
        if spawned > 0 {
            self.stats.resync_rounds += 1;
        }
        (spawned, deferred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::NodeMap;
    use crate::fabric::OpKind;

    fn engine(nodes: usize, qps: usize, window: Option<u64>) -> IoEngine {
        IoEngine::new(
            BatchMode::Hybrid,
            BatchLimits::default(),
            nodes,
            qps,
            window,
            EngineCosts::free(),
        )
    }

    fn io(id: u64, dir: Dir, node: usize, addr: u64) -> AppIo {
        AppIo {
            id,
            dir,
            node,
            addr,
            len: 4096,
            thread: 0,
            tenant: 0,
            t_submit: 0,
        }
    }

    fn wc_for(wr: &WorkRequest, status: WcStatus) -> Wc {
        Wc {
            wr_id: wr.wr_id,
            qp: 0,
            op: wr.op,
            len: wr.len,
            app_ios: wr.app_ios.clone(),
            tenant: wr.tenant,
            status,
        }
    }

    /// Drain, then deliver every posted WR as a successful completion.
    fn complete_all(e: &mut IoEngine) -> Vec<RetiredIo> {
        let mut retired = Vec::new();
        loop {
            let out = e.drain_all(0);
            if out.wrs.is_empty() {
                break;
            }
            for wr in out.wrs {
                let r = e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
                retired.extend(r.retired);
            }
        }
        retired
    }

    #[test]
    fn direct_submit_retires_through_pipeline() {
        let mut e = engine(2, 4, None);
        for i in 0..8 {
            let s = e.submit(io(i, Dir::Write, (i % 2) as usize, i * 4096));
            assert_eq!(s.sub_ids, vec![i]);
        }
        let retired = complete_all(&mut e);
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert_eq!(e.queued_ios(), 0);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn adjacent_submissions_share_a_shard_and_merge() {
        let mut e = engine(1, 4, None);
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096)); // same 1 MiB region
        }
        let out = e.drain_all(0);
        assert_eq!(out.chains.len(), 1, "one shard, one chain");
        assert_eq!(out.merged_ios, 8, "all adjacent pages merged");
        assert!(out.wrs[0].num_sge > 1);
    }

    #[test]
    fn distant_regions_spread_over_shards() {
        let mut e = engine(1, 4, None);
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i << SHARD_REGION_SHIFT));
        }
        let out = e.drain_all(0);
        let qps: std::collections::BTreeSet<_> = out.chains.iter().map(|c| c.qp).collect();
        assert_eq!(qps.len(), 4, "8 regions cover all 4 shards");
    }

    #[test]
    fn same_region_maps_to_stable_shard() {
        let e = engine(3, 4, None);
        let a = e.shard_of(1, 5 << SHARD_REGION_SHIFT);
        assert_eq!(a, e.shard_of(1, (5 << SHARD_REGION_SHIFT) + 4096));
        assert_eq!(e.channels().node_of(a), 1);
    }

    #[test]
    fn admission_window_bounds_posted_bytes() {
        let mut e = engine(1, 2, Some(8192));
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        let posted: u64 = out.wrs.iter().map(|w| w.len).sum();
        assert!(posted <= 8192, "posted {posted} > window");
        assert_eq!(e.regulator().in_flight(), posted);
        assert!(out.admission_blocked > 0);
        // completing releases the window and the rest drains
        let mut done = 0;
        for wr in out.wrs {
            done += e.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired.len();
        }
        done += complete_all(&mut e).len();
        assert_eq!(done, 8);
    }

    #[test]
    fn oversized_request_has_progress_guarantee() {
        let mut e = engine(1, 1, Some(4096));
        let mut big = io(1, Dir::Write, 0, 0);
        big.len = 1 << 20;
        e.submit(big);
        // backlog behind the oversized head must NOT ride along with it
        e.submit(io(2, Dir::Write, 0, 1 << 21));
        let first = e.drain_all(0);
        let posted: u64 = first.wrs.iter().map(|w| w.len).sum();
        assert_eq!(posted, 1 << 20, "exactly the oversized head admitted");
        assert_eq!(e.queued_ios(), 1, "the small request stays queued");
        let mut done = 0;
        for wr in first.wrs {
            done += e.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired.len();
        }
        done += complete_all(&mut e).len();
        assert_eq!(done, 2, "both writes complete");
    }

    #[test]
    fn placed_write_fans_out_and_retires_once() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, None).with_placement(map);
        let s = e.submit(io(42, Dir::Write, 0, 0));
        assert_eq!(s.sub_ids.len(), 2, "two replicas queued");
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert_eq!(wrs.len(), 2);
        // first replica completing does NOT retire the io
        let r1 = e.on_wc(&wc_for(&wrs[0], WcStatus::Success), 0);
        assert!(r1.retired.is_empty(), "replication not yet satisfied");
        let r2 = e.on_wc(&wc_for(&wrs[1], WcStatus::Success), 0);
        assert_eq!(r2.retired.len(), 1);
        assert_eq!(r2.retired[0].id, 42);
        assert!(!r2.retired[0].disk_fallback);
    }

    #[test]
    fn placed_read_fails_over_to_next_replica() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, None).with_placement(map);
        e.submit(io(7, Dir::Read, 0, 0)); // primary = node 0
        let out = e.drain_all(0);
        let wr = out.wrs.into_iter().next().unwrap();
        assert_eq!(wr.node, 0);
        // primary dies mid-flight: error completion triggers failover
        e.node_map_mut().unwrap().set_alive(0, false);
        let r = e.on_wc(&wc_for(&wr, WcStatus::Error), 0);
        assert!(r.retired.is_empty());
        assert_eq!(r.requeued, 1);
        // the retry is queued for the secondary replica (node 1)
        let out2 = e.drain_all(0);
        let wr2 = out2.wrs.into_iter().next().unwrap();
        assert_eq!(wr2.node, 1);
        let r2 = e.on_wc(&wc_for(&wr2, WcStatus::Success), 0);
        assert_eq!(r2.retired.len(), 1);
        assert!(r2.retired[0].failed_over);
        assert!(!r2.retired[0].disk_fallback);
    }

    #[test]
    fn placed_read_all_replicas_failed_signals_disk() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.submit(io(9, Dir::Read, 0, 0));
        let out = e.drain_all(0);
        let wr = out.wrs.into_iter().next().unwrap();
        e.node_map_mut().unwrap().set_alive(0, false);
        let r = e.on_wc(&wc_for(&wr, WcStatus::Error), 0);
        assert_eq!(r.requeued, 1, "fails over to node 1 first");
        let out2 = e.drain_all(0);
        let wr2 = out2.wrs.into_iter().next().unwrap();
        e.node_map_mut().unwrap().set_alive(1, false);
        let r2 = e.on_wc(&wc_for(&wr2, WcStatus::Error), 0);
        assert_eq!(r2.retired.len(), 1);
        assert!(r2.retired[0].disk_fallback, "all replicas dead -> disk");
    }

    #[test]
    fn placed_submit_with_dead_cluster_signals_disk_immediately() {
        let mut map = NodeMap::new(2, 2, 1 << 20);
        map.set_alive(0, false);
        map.set_alive(1, false);
        let mut e = engine(2, 1, None).with_placement(map);
        let s = e.submit(io(1, Dir::Write, 0, 0));
        assert!(s.disk_fallback && s.sub_ids.is_empty());
        let s = e.submit(io(2, Dir::Read, 0, 0));
        assert!(s.disk_fallback);
        assert_eq!(e.stats.disk_fallbacks, 2);
        assert_eq!(e.queued_ios(), 0);
    }

    #[test]
    fn placed_write_partial_replica_failure_still_retires_remote() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.submit(io(5, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert_eq!(wrs.len(), 2);
        let r1 = e.on_wc(&wc_for(&wrs[0], WcStatus::Error), 0);
        assert!(r1.retired.is_empty());
        let r2 = e.on_wc(&wc_for(&wrs[1], WcStatus::Success), 0);
        assert_eq!(r2.retired.len(), 1);
        assert!(!r2.retired[0].disk_fallback, "one replica survived");
    }

    #[test]
    fn duplicate_wc_retires_once_direct_mode() {
        let mut e = engine(1, 1, Some(16 * 4096));
        e.submit(io(1, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wr = out.wrs.into_iter().next().unwrap();
        let wc = wc_for(&wr, WcStatus::Success);
        let r1 = e.on_wc(&wc, 0);
        assert_eq!(r1.retired.len(), 1);
        // the CQ delivers the same completion again: dropped, counted
        let r2 = e.on_wc(&wc, 0);
        assert!(r2.retired.is_empty(), "duplicate WC must not retire");
        assert!(r2.completed_subs.is_empty());
        assert_eq!(e.stats.duplicate_wcs, 1);
        assert_eq!(e.stats.retired, 1);
        assert_eq!(e.regulator().in_flight(), 0, "window released once");
    }

    #[test]
    fn duplicate_and_reordered_wcs_placed_mode() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, Some(64 * 4096)).with_placement(map);
        for i in 0..4u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        // deliver in reverse order, each twice
        let mut retired = Vec::new();
        for wr in wrs.iter().rev() {
            let wc = wc_for(wr, WcStatus::Success);
            retired.extend(e.on_wc(&wc, 0).retired);
            let dup = e.on_wc(&wc, 0);
            assert!(dup.retired.is_empty() && dup.completed_subs.is_empty());
        }
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3], "each io retired exactly once");
        assert_eq!(e.stats.duplicate_wcs, wrs.len() as u64);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn error_completions_keep_window_balanced() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, Some(8 * 4096)).with_placement(map);
        for i in 0..4u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        for wr in out.wrs {
            // every completion errors; window must still drain to zero
            e.on_wc(&wc_for(&wr, WcStatus::Error), 0);
        }
        assert_eq!(e.regulator().in_flight(), 0, "error WCs release bytes");
        assert_eq!(e.stats.retired, 4, "failed writes still retire");
        assert_eq!(e.stats.disk_fallbacks, 4);
    }

    /// Property-style check: random mixed traffic through the full
    /// pipeline conserves every application I/O exactly once and never
    /// exceeds the admission window in flight.
    #[test]
    fn prop_pipeline_conserves_ios_under_window() {
        use crate::util::rng::Pcg32;
        let window = 16 * 4096;
        let map = NodeMap::new(4, 2, 1 << 20);
        let mut e = engine(4, 4, Some(window)).with_placement(map);
        let mut rng = Pcg32::new(0xE761E);
        let mut in_flight: Vec<WorkRequest> = Vec::new();
        let mut retired = std::collections::BTreeSet::new();
        let total = 400u64;
        let mut submitted = 0u64;
        while (retired.len() as u64) < total {
            if submitted < total && rng.gen_bool(0.5) {
                let dir = if rng.gen_bool(0.3) { Dir::Read } else { Dir::Write };
                let addr = rng.gen_below(1 << 26) / 4096 * 4096;
                e.submit(io(submitted, dir, 0, addr));
                submitted += 1;
            }
            let out = e.drain_all(0);
            in_flight.extend(out.wrs);
            assert!(
                e.regulator().in_flight() <= window,
                "window exceeded: {}",
                e.regulator().in_flight()
            );
            if !in_flight.is_empty() {
                let i = rng.gen_below(in_flight.len() as u64) as usize;
                let wr = in_flight.swap_remove(i);
                let r = e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
                for ret in r.retired {
                    assert!(retired.insert(ret.id), "double retire of {}", ret.id);
                }
            }
        }
        assert_eq!(retired.len() as u64, total);
        assert_eq!(e.queued_ios(), 0);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn drain_charges_serialized_cpu_with_cost_model() {
        let mut e = IoEngine::new(
            BatchMode::Single,
            BatchLimits::default(),
            1,
            1,
            None,
            EngineCosts {
                post_wqe_cpu_ns: 100,
                mmio_cpu_ns: 10,
                merge_check_base_ns: 5,
                merge_check_per_io_ns: 1,
                ..EngineCosts::free()
            },
        );
        for i in 0..3u64 {
            e.submit(io(i, Dir::Write, 0, i << SHARD_REGION_SHIFT));
        }
        let out = e.drain_all(0);
        // scan: 5 + 3*1; per WR: 100 + 10 MMIO each (Single mode)
        assert_eq!(out.cpu_ns, 8 + 3 * 110);
        assert!(out.chains.windows(2).all(|w| w[0].cpu_offset_ns < w[1].cpu_offset_ns));
        assert_eq!(out.chains.last().unwrap().cpu_offset_ns, out.cpu_ns);
    }

    #[test]
    fn range_set_coalesces_overlap_and_adjacency() {
        let mut rs = RangeSet::default();
        rs.insert(0, 4096);
        rs.insert(8192, 4096);
        assert_eq!(rs.len(), 2);
        rs.insert(4096, 4096); // bridges both
        assert_eq!(rs.len(), 1);
        assert_eq!(rs.drain(), vec![(0, 12288)]);
        assert!(rs.is_empty());
        rs.insert(100, 50);
        rs.insert(120, 10); // fully contained
        assert_eq!(rs.drain(), vec![(100, 50)]);
        rs.insert(0, 10);
        rs.insert(20, 10);
        rs.insert(40, 10);
        rs.insert(5, 40); // swallows all three
        assert_eq!(rs.drain(), vec![(0, 50)]);
    }

    /// Complete every WR currently drainable, returning the WRs in post
    /// order (resync tests need the WR stream, not just retirements).
    fn complete_all_wrs(e: &mut IoEngine) -> Vec<WorkRequest> {
        let mut all = Vec::new();
        loop {
            let out = e.drain_all(0);
            if out.wrs.is_empty() {
                break;
            }
            for wr in out.wrs {
                e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
                all.push(wr);
            }
        }
        all
    }

    #[test]
    fn revive_without_resync_rejoins_immediately() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.on_node_down(0);
        assert_eq!(e.node_state(0), Some(NodeState::Dead));
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        e.on_node_up(0);
        // legacy behavior: no resync protocol, straight back to Alive
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert_eq!(e.stats.missed_ranges, 0);
    }

    #[test]
    fn revived_replica_resyncs_through_the_pipeline_before_serving() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        e.on_node_down(0);
        // this write lands only on node 1 and is recorded against node 0
        e.submit(io(2, Dir::Write, 0, 0));
        complete_all(&mut e);
        assert_eq!(e.resync_backlog(0), 1);
        e.on_node_up(0);
        assert_eq!(
            e.node_state(0),
            Some(NodeState::Resyncing),
            "missed writes: node must not rejoin immediately"
        );
        assert_eq!(e.stats.resync_rounds, 1);
        // reads route around the resyncing replica
        e.submit(io(3, Dir::Read, 0, 0));
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert!(
            wrs.iter().all(|w| w.node == 1),
            "both the app read and the resync source read go to the peer"
        );
        // complete the source reads: the engine stages the repair write
        let mut copies = Vec::new();
        for wr in &wrs {
            let r = e.on_wc(&wc_for(wr, WcStatus::Success), 0);
            copies.extend(r.resync_copies);
        }
        assert_eq!(copies.len(), 1, "one missed range, one repair copy");
        assert_eq!(copies[0].target, 0);
        // the repair write drains to node 0 through the normal pipeline
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert_eq!(wrs.len(), 1);
        assert_eq!(wrs[0].node, 0);
        e.on_wc(&wc_for(&wrs[0], WcStatus::Success), 0);
        assert_eq!(e.node_state(0), Some(NodeState::Alive), "backlog drained");
        assert_eq!(e.stats.resyncs_completed, 1);
        assert_eq!(e.resync_backlog(0), 0);
        // reads prefer the repaired primary again
        e.submit(io(4, Dir::Read, 0, 0));
        let wrs = complete_all_wrs(&mut e);
        assert_eq!(wrs[0].node, 0);
    }

    #[test]
    fn failed_replica_write_demotes_and_repairs_the_diverged_node() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.submit(io(1, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert_eq!(wrs.len(), 2, "two replica legs");
        // node 0's leg fails terminally (e.g. a partial partition): the
        // write still retires via node 1, but node 0 has diverged
        let (fail, okay): (Vec<_>, Vec<_>) = wrs.iter().partition(|w| w.node == 0);
        e.on_wc(&wc_for(fail[0], WcStatus::Error), 0);
        // divergence is judged at retirement (the write could still end
        // up all-failed and take the disk path), so not demoted yet
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        let r = e.on_wc(&wc_for(okay[0], WcStatus::Success), 0);
        assert_eq!(r.retired.len(), 1);
        assert!(!r.retired[0].disk_fallback, "peer replica satisfied it");
        assert_eq!(e.node_state(0), Some(NodeState::Resyncing), "demoted");
        assert_eq!(e.stats.resync_demotions, 1);
        // repair flows: source read from node 1, repair write to node 0
        let wrs = complete_all_wrs(&mut e);
        assert!(!wrs.is_empty(), "repair traffic was queued");
        assert_eq!(e.node_state(0), Some(NodeState::Alive), "repaired");
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn all_replica_legs_failing_takes_disk_path_without_parking_nodes() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.submit(io(1, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert_eq!(wrs.len(), 2);
        // a fault burst kills both legs: the write is not durable on any
        // replica — it takes the disk path, and neither node may be
        // demoted or left with a backlog no alive peer can source
        let mut retired = Vec::new();
        for wr in &wrs {
            retired.extend(e.on_wc(&wc_for(wr, WcStatus::Error), 0).retired);
        }
        assert_eq!(retired.len(), 1);
        assert!(retired[0].disk_fallback, "disk owns the data now");
        assert_eq!(e.node_state(0), Some(NodeState::Alive), "not parked");
        assert_eq!(e.node_state(1), Some(NodeState::Alive), "not parked");
        assert_eq!(e.resync_backlog(0) + e.resync_backlog(1), 0);
        assert_eq!(e.stats.resync_demotions, 0);
        // the cluster still serves: a later write lands normally
        e.submit(io(2, Dir::Write, 0, 0));
        let retired = complete_all(&mut e);
        assert_eq!(retired.len(), 1);
        assert!(!retired[0].disk_fallback);
    }

    #[test]
    fn mutually_diverged_replicas_repair_each_other() {
        // Wa's node-1 leg and Wb's node-0 leg fail on *disjoint* ranges:
        // each node ends up Resyncing while holding exactly the data its
        // peer misses — they must repair each other, not park forever
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.submit(io(1, Dir::Write, 0, 0));
        let wa: Vec<WorkRequest> = e.drain_all(0).wrs;
        e.submit(io(2, Dir::Write, 0, 4096));
        let wb: Vec<WorkRequest> = e.drain_all(0).wrs;
        assert_eq!((wa.len(), wb.len()), (2, 2));
        for wr in &wa {
            let status = if wr.node == 1 {
                WcStatus::Error
            } else {
                WcStatus::Success
            };
            e.on_wc(&wc_for(wr, status), 0);
        }
        for wr in &wb {
            let status = if wr.node == 0 {
                WcStatus::Error
            } else {
                WcStatus::Success
            };
            e.on_wc(&wc_for(wr, status), 0);
        }
        assert_eq!(e.stats.resync_demotions, 2, "both replicas diverged");
        // each copy sources the resyncing peer (its miss is disjoint)
        let _ = complete_all_wrs(&mut e);
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert_eq!(e.node_state(1), Some(NodeState::Alive));
        assert_eq!(e.stats.resyncs_completed, 2);
        assert_eq!(e.resync_backlog(0) + e.resync_backlog(1), 0);
    }

    #[test]
    fn range_set_overlap_queries() {
        let mut rs = RangeSet::default();
        rs.insert(4096, 4096);
        assert!(rs.overlaps(4096, 4096));
        assert!(rs.overlaps(0, 4097), "one-byte intersection counts");
        assert!(rs.overlaps(8191, 4096));
        assert!(!rs.overlaps(0, 4096), "touching is not overlapping");
        assert!(!rs.overlaps(8192, 4096));
        assert!(!rs.overlaps(4096, 0));
    }

    #[test]
    fn range_set_remove_splits_straddled_entries() {
        let mut rs = RangeSet::default();
        rs.insert(0, 100);
        rs.remove(40, 20); // punch a hole
        assert_eq!(rs.drain(), vec![(0, 40), (60, 40)]);
        rs.insert(0, 100);
        rs.remove(0, 100); // exact erase
        assert!(rs.is_empty());
        rs.insert(10, 10);
        rs.insert(30, 10);
        rs.remove(0, 50); // swallows both
        assert!(rs.is_empty());
        rs.insert(10, 10);
        rs.remove(15, 100); // right truncation
        assert_eq!(rs.drain(), vec![(10, 5)]);
    }

    /// A peer whose own repair copy for a range is still in flight does
    /// not hold that range yet — it must not be chosen as the copy
    /// source for another recovering replica (3-replica scenario: both
    /// non-durable replicas must source the one that has the data).
    #[test]
    fn in_flight_repair_target_is_not_a_copy_source() {
        let map = NodeMap::new(3, 3, 1 << 20);
        let mut e = engine(3, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.submit(io(1, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.wrs;
        assert_eq!(wrs.len(), 3, "three replica legs");
        // legs to nodes 0 and 1 fail; only node 2's copy is durable
        for wr in wrs.iter().filter(|w| w.node != 2) {
            e.on_wc(&wc_for(wr, WcStatus::Error), 0);
        }
        let durable = wrs.iter().find(|w| w.node == 2).expect("leg to node 2");
        e.on_wc(&wc_for(durable, WcStatus::Success), 0);
        assert_eq!(e.node_state(0), Some(NodeState::Resyncing));
        assert_eq!(e.node_state(1), Some(NodeState::Resyncing));
        // both repair copies were spawned in the same kick; the second
        // must skip the first's still-in-flight target and also read
        // from node 2 — the only replica that actually holds the data
        let out = e.drain_all(0);
        let reads: Vec<WorkRequest> = out.wrs;
        assert!(!reads.is_empty());
        assert!(
            reads.iter().all(|w| w.node == 2),
            "every source read must hit the durable replica: {reads:?}"
        );
        for wr in &reads {
            e.on_wc(&wc_for(wr, WcStatus::Success), 0);
        }
        let _ = complete_all_wrs(&mut e);
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert_eq!(e.node_state(1), Some(NodeState::Alive));
        assert_eq!(e.stats.resyncs_completed, 2);
    }

    #[test]
    fn resync_defers_ranges_with_app_writes_in_flight() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.on_node_down(0);
        e.submit(io(1, Dir::Write, 0, 0));
        // the write's sub to node 1 is still queued/in flight: a resync
        // copy now could read pre-write data from the source
        e.on_node_up(0);
        assert_eq!(e.node_state(0), Some(NodeState::Resyncing));
        assert_eq!(
            e.stats.resync_copies, 0,
            "copy must wait for the in-flight write"
        );
        assert_eq!(e.resync_backlog(0), 1, "range stays in the backlog");
        // once the write completes, the copy is spawned and repairs
        let wrs = complete_all_wrs(&mut e);
        assert!(wrs.len() >= 3, "app write + source read + repair write");
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert!(e.stats.resync_copies >= 1);
    }

    #[test]
    fn resync_copies_are_chunked_to_the_admission_window() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let window = 4 * 4096u64;
        let mut e = engine(2, 1, Some(window))
            .with_placement(map)
            .with_resync(window);
        e.on_node_down(0);
        // a large missed range: 16 pages, window is 4
        let mut big = io(1, Dir::Write, 0, 0);
        big.len = 16 * 4096;
        e.submit(big);
        complete_all(&mut e);
        e.on_node_up(0);
        // drive to quiescence, asserting the window bound throughout
        loop {
            let out = e.drain_all(0);
            assert!(
                e.regulator().in_flight() <= window,
                "resync overshot the window"
            );
            if out.wrs.is_empty() {
                break;
            }
            for wr in out.wrs {
                assert!(wr.len <= window);
                e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
            }
        }
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert!(
            e.stats.resync_copies >= 4,
            "16-page range split into window-sized copies: {}",
            e.stats.resync_copies
        );
    }

    #[test]
    fn resync_with_no_alive_source_parks_the_node_without_livelock() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096);
        e.on_node_down(0);
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        e.on_node_down(1); // the only copy source dies
        e.on_node_up(0);
        assert_eq!(e.node_state(0), Some(NodeState::Resyncing));
        assert_eq!(e.queued_ios(), 0, "no copy could be spawned");
        assert!(e.resync_backlog(0) > 0, "backlog preserved");
        // the source coming back re-arms the protocol
        e.on_node_up(1);
        let _ = complete_all_wrs(&mut e);
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
    }

    /// Property: RangeSet agrees with a naive per-byte BTreeSet model
    /// under random insert/remove interleavings — coverage, overlap
    /// queries, and coalesced drain output.
    #[test]
    fn prop_range_set_matches_naive_model() {
        use std::collections::BTreeSet;
        crate::util::prop::forall(crate::util::prop::cfg(0x2A6E5), |rng, size| {
            const SPAN: u64 = 192;
            let mut rs = RangeSet::default();
            let mut model: BTreeSet<u64> = BTreeSet::new();
            for _ in 0..size {
                let addr = rng.gen_below(SPAN);
                let len = rng.gen_below(SPAN - addr + 1);
                if rng.gen_bool(0.6) {
                    rs.insert(addr, len);
                    model.extend(addr..addr + len);
                } else {
                    rs.remove(addr, len);
                    for b in addr..addr + len {
                        model.remove(&b);
                    }
                }
                let qa = rng.gen_below(SPAN);
                let ql = rng.gen_below(SPAN - qa + 1);
                let naive = (qa..qa + ql).any(|b| model.contains(&b));
                if rs.overlaps(qa, ql) != naive {
                    return Err(format!("overlaps({qa},{ql}) disagrees with model"));
                }
                if rs.is_empty() != model.is_empty() {
                    return Err("is_empty disagrees with model".into());
                }
            }
            // drain must yield exactly the model's bytes, as maximal
            // coalesced ranges (no empty, touching, or overlapping runs)
            let ranges = rs.clone().drain();
            let mut covered: BTreeSet<u64> = BTreeSet::new();
            for w in ranges.windows(2) {
                if w[0].0 + w[0].1 >= w[1].0 {
                    return Err(format!("ranges not coalesced: {ranges:?}"));
                }
            }
            for (a, l) in ranges {
                if l == 0 {
                    return Err("empty range in drain".into());
                }
                covered.extend(a..a + l);
            }
            if covered != model {
                return Err("drain coverage disagrees with model".into());
            }
            Ok(())
        });
    }

    #[test]
    fn split_submission_covers_stripes_and_retires_once() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, None).with_placement(map);
        // a write spanning three stripes (one page + a full stripe + one
        // page): 3 legs x 2 replicas = 6 subs
        let mut big = io(7, Dir::Write, 0, (1 << 20) - 4096);
        big.len = (1 << 20) + 8192;
        let s = e.submit(big);
        assert_eq!(s.sub_ids.len(), 6, "per-leg replica fan-out");
        assert!(!s.disk_fallback && s.disk_legs.is_empty());
        assert_eq!(e.stats.split_requests, 1);
        assert_eq!(e.stats.split_legs, 3);
        // every WR stays inside its own stripe and targets that stripe's
        // replicas
        let out = e.drain_all(0);
        let mut retired = Vec::new();
        let map = e.node_map().unwrap().clone();
        for wr in out.wrs {
            let stripe_of = |a: u64| a / map.stripe_bytes();
            assert_eq!(
                stripe_of(wr.remote_addr),
                stripe_of(wr.remote_addr + wr.len - 1),
                "WR crosses a stripe boundary"
            );
            assert!(
                map.place(wr.remote_addr).replicas.contains(&wr.node),
                "leg routed off its stripe's replica set"
            );
            retired.extend(e.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired);
        }
        retired.extend(complete_all(&mut e));
        assert_eq!(retired.len(), 1, "split request retires exactly once");
        assert_eq!(retired[0].id, 7);
        assert!(!retired[0].disk_fallback);
        assert_eq!(e.queued_ios(), 0);
    }

    #[test]
    fn split_write_with_one_dead_stripe_flags_partial_disk() {
        // 2 nodes, 1 replica: stripe 0 -> node 0, stripe 1 -> node 1
        let map = NodeMap::new(2, 1, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.on_node_down(1);
        let mut big = io(3, Dir::Write, 0, (1 << 20) - 4096);
        big.len = 2 * 4096;
        let s = e.submit(big);
        assert!(!s.disk_fallback, "one leg was queued");
        assert_eq!(s.disk_legs, vec![(1 << 20, 4096)], "dead stripe's leg");
        assert_eq!(s.sub_ids.len(), 1);
        let retired = complete_all(&mut e);
        assert_eq!(retired.len(), 1);
        assert!(
            retired[0].disk_fallback,
            "partial-disk request surfaces the disk signal at retirement"
        );
    }

    /// The formerly-parked topology: two replicas demote each other on
    /// the *same* range (two concurrent writes, one leg of each fails on
    /// opposite nodes). Without the election both park in `Resyncing`
    /// forever; with it, the epoch vectors elect the replica that holds
    /// the later write as donor and the other self-heals its spurious
    /// missed record.
    #[test]
    fn overlapping_divergence_parks_without_election_and_heals_with_it() {
        let drive = |election: bool| {
            let map = NodeMap::new(2, 2, 1 << 20);
            let mut e = engine(2, 1, None).with_placement(map).with_resync(4 * 4096);
            if election {
                e.enable_donor_election();
            }
            e.submit(io(1, Dir::Write, 0, 0));
            let out = e.drain_all(0);
            let wa: Vec<WorkRequest> = out.wrs;
            e.submit(io(2, Dir::Write, 0, 0));
            let out = e.drain_all(0);
            let wb: Vec<WorkRequest> = out.wrs;
            assert_eq!((wa.len(), wb.len()), (2, 2));
            // W1: node 1's leg fails; W2: node 0's leg fails — both
            // replicas miss an overlapping write of the same range
            for wr in &wa {
                let st = if wr.node == 1 {
                    WcStatus::Error
                } else {
                    WcStatus::Success
                };
                e.on_wc(&wc_for(wr, st), 0);
            }
            for wr in &wb {
                let st = if wr.node == 0 {
                    WcStatus::Error
                } else {
                    WcStatus::Success
                };
                e.on_wc(&wc_for(wr, st), 0);
            }
            assert_eq!(e.stats.resync_demotions, 2, "both replicas diverged");
            let _ = complete_all_wrs(&mut e);
            e
        };
        let parked = drive(false);
        assert_eq!(
            parked.node_state(0),
            Some(NodeState::Resyncing),
            "without election the overlap parks node 0"
        );
        assert_eq!(parked.node_state(1), Some(NodeState::Resyncing));
        assert!(parked.resync_backlog(0) + parked.resync_backlog(1) > 0);

        let healed = drive(true);
        assert_eq!(healed.node_state(0), Some(NodeState::Alive), "repaired");
        assert_eq!(healed.node_state(1), Some(NodeState::Alive), "self-healed");
        assert!(healed.stats.resync_self_heals >= 1, "{:?}", healed.stats);
        assert!(healed.stats.resync_elections >= 1, "{:?}", healed.stats);
        assert_eq!(healed.stats.resync_disk_surrenders, 0);
        assert_eq!(healed.resync_backlog(0) + healed.resync_backlog(1), 0);
    }

    /// All peers of a recovering node are dead: the election finds no
    /// live copy of the missed range and surrenders it to the disk path
    /// (the paging layer's local-disk replica) instead of parking.
    #[test]
    fn all_peers_down_surrenders_missed_ranges_to_disk() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096)
            .with_donor_election();
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        e.on_node_down(0);
        e.submit(io(2, Dir::Write, 0, 0)); // lands only on node 1
        complete_all(&mut e);
        e.on_node_down(1); // the only holder of the new version dies
        e.on_node_up(0);
        assert_eq!(
            e.node_state(0),
            Some(NodeState::Alive),
            "no live copy: the node surrenders the range and rejoins"
        );
        assert_eq!(e.stats.resync_disk_surrenders, 1, "{:?}", e.stats);
        let surrendered = e.take_disk_surrenders();
        assert_eq!(surrendered, vec![(0, 0, 4096)]);
        assert!(e.take_disk_surrenders().is_empty(), "drained once");
        assert_eq!(e.resync_backlog(0), 0);
    }

    /// With the election on, the conservative paths still win when they
    /// can: a revived node with an alive peer repairs through a normal
    /// copy, no self-heal, no surrender.
    #[test]
    fn election_defers_to_conservative_source_when_available() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096)
            .with_donor_election();
        e.on_node_down(0);
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        e.on_node_up(0);
        let _ = complete_all_wrs(&mut e);
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert!(e.stats.resync_copies >= 1);
        assert_eq!(e.stats.resync_elections, 0, "alive peer: no election");
        assert_eq!(e.stats.resync_disk_surrenders, 0);
        assert_eq!(e.stats.resync_self_heals, 0);
    }

    #[test]
    fn set_window_churn_keeps_accounting_balanced() {
        let mut e = engine(1, 1, Some(8 * 4096));
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        let in_flight = e.regulator().in_flight();
        assert!(in_flight > 0);
        // shrink the window below the in-flight level mid-run
        e.set_window(Some(4096));
        let blocked = e.drain_all(0);
        assert!(blocked.wrs.is_empty(), "shrunk window admits nothing");
        for wr in out.wrs {
            e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
        }
        // old-policy bytes released cleanly; the rest drains under the
        // new window one page at a time
        let retired = complete_all(&mut e);
        assert_eq!(e.stats.retired, 8);
        assert_eq!(e.regulator().in_flight(), 0, "no leaked capacity");
        assert!(retired.iter().all(|r| !r.disk_fallback));
    }

    #[test]
    #[should_panic(expected = "donor election requires resync")]
    fn election_without_resync_is_rejected() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let _ = engine(2, 1, None).with_placement(map).with_donor_election();
    }

    /// Tentpole invariant: slab-minted wr_ids are generational, so a
    /// stale wr_id from a late/duplicate WC can never resolve after its
    /// slot was recycled by a newer WR — it dies at the generation
    /// check, counted as a duplicate, releasing nothing.
    #[test]
    fn stale_wr_ids_never_resolve_recycled_slots() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.submit(io(1, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let stale: Vec<WorkRequest> = out.wrs.clone();
        for wr in out.wrs {
            e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
        }
        assert_eq!(e.stats.retired, 1);
        // new traffic recycles the freed ledger slots under a fresh
        // generation: same slot set, disjoint ids
        e.submit(io(2, Dir::Write, 0, 0));
        let out2 = e.drain_all(0);
        let old_slots: std::collections::BTreeSet<u32> =
            stale.iter().map(|w| w.wr_id as u32).collect();
        let new_slots: std::collections::BTreeSet<u32> =
            out2.wrs.iter().map(|w| w.wr_id as u32).collect();
        assert_eq!(old_slots, new_slots, "freed slots were recycled");
        assert!(
            stale.iter().all(|o| out2.wrs.iter().all(|n| n.wr_id != o.wr_id)),
            "recycled slots carry new generations"
        );
        // replaying the stale WCs against the recycled slots must not
        // retire, complete, or release anything
        for wr in &stale {
            let r = e.on_wc(&wc_for(wr, WcStatus::Success), 0);
            assert!(r.retired.is_empty() && r.completed_subs.is_empty());
        }
        assert_eq!(e.stats.duplicate_wcs, stale.len() as u64);
        // and the live WRs still retire their io exactly once
        let mut retired = Vec::new();
        for wr in out2.wrs {
            retired.extend(e.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired);
        }
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].id, 2);
        assert_eq!(e.stats.retired, 2);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    /// Same property one layer down: sub ids are generational too, so a
    /// WC carrying sub ids whose slots were freed and recycled resolves
    /// none of them — the recycled tenants are untouched.
    #[test]
    fn stale_sub_ids_are_dropped_by_the_generation_check() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        let s1 = e.submit(io(1, Dir::Write, 0, 0));
        let stale_subs = s1.sub_ids.to_vec();
        for wr in e.drain_all(0).wrs {
            e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
        }
        let s2 = e.submit(io(2, Dir::Write, 0, 0));
        assert!(
            stale_subs.iter().all(|s| !s2.sub_ids.contains(s)),
            "recycled sub slots carry new generations"
        );
        let out = e.drain_all(0);
        let mut forged = wc_for(&out.wrs[0], WcStatus::Success);
        forged.app_ios = stale_subs.into();
        let r = e.on_wc(&forged, 0);
        assert!(
            r.retired.is_empty() && r.completed_subs.is_empty() && r.failed_subs.is_empty(),
            "stale sub ids must resolve nothing"
        );
        // the forged WC legitimately consumed its wr_id's window bytes;
        // only the second replica's WR remains in flight
        assert_eq!(e.regulator().in_flight(), out.wrs[1].len);
    }

    /// The `_into` scratch-reuse API is behaviorally identical to the
    /// allocating wrappers: same WRs, same chains, same retirements,
    /// driving one engine through each against mixed traffic.
    #[test]
    fn scratch_reuse_api_matches_allocating_api() {
        let mk = || {
            let map = NodeMap::new(2, 2, 1 << 20);
            engine(2, 2, Some(8 * 4096)).with_placement(map)
        };
        let mut a = mk();
        let mut b = mk();
        let mut out = DrainOut::default();
        let mut wout = WcOut::default();
        let mut retired_a = Vec::new();
        let mut retired_b = Vec::new();
        for i in 0..60u64 {
            let dir = if i % 3 == 0 { Dir::Read } else { Dir::Write };
            let addr = (i % 8) * 4096;
            a.submit(io(i, dir, 0, addr));
            b.submit(io(i, dir, 0, addr));
            let oa = a.drain_all(0);
            b.drain_all_into(0, &mut out);
            assert_eq!(oa.wrs.len(), out.wrs.len());
            assert_eq!(oa.chains.len(), out.chains.len());
            assert_eq!(oa.cpu_ns, out.cpu_ns);
            for (wa, wb) in oa.wrs.iter().zip(out.wrs.iter()) {
                assert_eq!(wa.wr_id, wb.wr_id, "deterministic slab keys");
                assert_eq!(wa.len, wb.len);
                assert_eq!((wa.remote_addr, wa.num_sge), (wb.remote_addr, wb.num_sge));
                assert_eq!(wa.app_ios, wb.app_ios);
            }
            for wr in oa.wrs {
                retired_a.extend(a.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired);
            }
            for wr in &out.wrs {
                let wc = wc_for(wr, WcStatus::Success);
                b.on_wc_into(&wc, 0, &mut wout);
                retired_b.extend(wout.retired.iter().copied());
            }
        }
        assert_eq!(retired_a.len(), 60);
        assert_eq!(retired_a, retired_b);
        assert_eq!(a.regulator().in_flight(), 0);
        assert_eq!(b.regulator().in_flight(), 0);
    }

    /// Satellite (ROADMAP PR 4 follow-on): the cluster-wide required
    /// epoch floor stays O(live divergence) in a long-running engine.
    /// Every placed write mints a distinct epoch (so floor ranges never
    /// coalesce); without pruning, ~800 writes to fresh addresses would
    /// hold ~800 ranges. With the amortized prune, the floor hovers
    /// around the watermark through repeated kill / miss / revive /
    /// repair cycles, and a final explicit prune on a fully-synced
    /// cluster drains it to (near) nothing.
    #[test]
    fn epoch_floor_stays_bounded_over_many_write_generations() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096)
            .with_donor_election();
        let mut id = 0u64;
        let mut peak = 0usize;
        for _round in 0..20 {
            e.on_node_down(0);
            for _ in 0..8 {
                e.submit(io(id, Dir::Write, 0, id * 4096));
                id += 1;
                complete_all(&mut e);
            }
            e.on_node_up(0);
            let _ = complete_all_wrs(&mut e); // drains the repair copies
            assert_eq!(e.node_state(0), Some(NodeState::Alive));
            for _ in 0..32 {
                e.submit(io(id, Dir::Write, 0, id * 4096));
                id += 1;
                complete_all(&mut e);
            }
            peak = peak.max(e.epoch_floor_ranges());
        }
        assert_eq!(id, 800, "the run actually issued 800 epochs");
        assert!(
            peak <= 256,
            "required floor grew with writes issued, not divergence: {peak}"
        );
        e.prune_epoch_floor();
        assert!(
            e.epoch_floor_ranges() <= 8,
            "healthy cluster retains {} floor ranges",
            e.epoch_floor_ranges()
        );
    }

    /// Pruning must never forget what a *diverged* replica still needs:
    /// ranges overlapping a missed backlog (or held by a dead node) are
    /// pinned, and the node still repairs correctly afterwards.
    #[test]
    fn epoch_prune_pins_diverged_ranges() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None)
            .with_placement(map)
            .with_resync(4 * 4096)
            .with_donor_election();
        e.on_node_down(0);
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        // node 0 is dead and missed the write: an explicit prune must
        // keep the range (the dead replica pins it)
        let before = e.epoch_floor_ranges();
        assert_eq!(e.prune_epoch_floor(), 0, "nothing prunable while diverged");
        assert_eq!(e.epoch_floor_ranges(), before);
        // after revival + repair the range becomes prunable
        e.on_node_up(0);
        let _ = complete_all_wrs(&mut e);
        assert_eq!(e.node_state(0), Some(NodeState::Alive));
        assert!(e.prune_epoch_floor() > 0, "repaired range now prunable");
        assert_eq!(e.epoch_floor_ranges(), 0);
    }

    #[test]
    fn reads_and_writes_drain_independently() {
        let mut e = engine(1, 1, None);
        e.submit(io(1, Dir::Read, 0, 0));
        e.submit(io(2, Dir::Write, 0, 4096));
        let r = e.drain_dir(Dir::Read, 0);
        assert_eq!(r.chains.len(), 1);
        assert_eq!(r.wrs[0].op, OpKind::Read);
        let w = e.drain_dir(Dir::Write, 0);
        assert_eq!(w.chains.len(), 1);
        assert_eq!(w.wrs[0].op, OpKind::Write);
    }

    #[test]
    fn mr_cache_stats_are_none_when_disabled() {
        let mut e = engine(1, 1, None);
        assert!(e.mr_cache_stats().is_none());
        e.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut e);
        assert!(e.mr_cache_stats().is_none());
        assert_eq!(e.stats.mr_hits + e.stats.mr_misses, 0);
    }

    /// Lazy registration lands on the drain path: the first touch of a
    /// span is charged the miss (registration) cost, a re-touch only the
    /// lkey-lookup cost — visible in the drain's serialized CPU.
    #[test]
    fn mr_miss_then_hit_charges_the_drain_cpu() {
        use crate::coordinator::mr_cache::MR_SPAN_BYTES;
        let costs = EngineCosts {
            mr_hit_ns: 10,
            mr_miss_ns: 1_000,
            mr_dereg_ns: 100,
            ..EngineCosts::free()
        };
        let spec = EngineSpec::new(1).mr_cache(MR_SPAN_BYTES).costs(costs);
        let mut e = IoEngine::build(&spec);
        e.submit(io(1, Dir::Write, 0, 0));
        let first = e.drain_all(0);
        assert_eq!(e.stats.mr_misses, 1, "first touch registers lazily");
        assert_eq!(e.stats.mr_hits, 0);
        for wr in first.wrs.iter() {
            e.on_wc(&wc_for(wr, WcStatus::Success), 0);
        }
        e.submit(io(2, Dir::Write, 0, 0));
        let second = e.drain_all(0);
        assert_eq!(e.stats.mr_misses, 1, "span is resident: no re-registration");
        assert_eq!(e.stats.mr_hits, 1);
        assert!(
            first.cpu_ns > second.cpu_ns,
            "miss ({}) must cost more than hit ({})",
            first.cpu_ns,
            second.cpu_ns
        );
        let s = e.mr_cache_stats().expect("cache enabled");
        assert_eq!(s.pinned_bytes, MR_SPAN_BYTES);
    }

    /// A one-span cache under a spanning workload: every drain evicts,
    /// the deferred dereg queue fills, and the flush is counted (and
    /// charged) at the end of a drain — never per post.
    #[test]
    fn mr_eviction_pressure_flushes_dereg_batches() {
        use crate::coordinator::mr_cache::{MR_DEREG_BATCH, MR_SPAN_BYTES};
        let spec = EngineSpec::new(1).qps(2).mr_cache(MR_SPAN_BYTES);
        let mut e = IoEngine::build(&spec);
        let n = (MR_DEREG_BATCH as u64) + 8;
        for i in 0..n {
            e.submit(io(i, Dir::Write, 0, i * MR_SPAN_BYTES));
        }
        let retired = complete_all(&mut e);
        assert_eq!(retired.len() as u64, n);
        assert_eq!(e.stats.mr_misses, n, "every span was a first touch");
        assert_eq!(e.stats.mr_evictions, n - 1, "one frame, n-1 evictions");
        assert!(e.stats.mr_dereg_batches >= 1, "a deferred batch flushed");
        let s = e.mr_cache_stats().expect("cache enabled");
        assert_eq!(s.pinned_bytes, MR_SPAN_BYTES, "cap held throughout");
        assert_eq!(s.cap_bytes, MR_SPAN_BYTES);
    }

    /// A member of a two-engine gossip cluster: 2 replica nodes, resync
    /// with the donor election, interleaved epoch minting.
    fn gossip_engine(id: usize) -> IoEngine {
        IoEngine::build(
            &EngineSpec::new(2)
                .replicated(2)
                .resync(4 * 4096)
                .election()
                .gossip(id, 2),
        )
    }

    #[test]
    fn gossip_mint_interleaves_epochs_across_engines() {
        let mut a = gossip_engine(0);
        let mut b = gossip_engine(1);
        for i in 0..3u64 {
            a.submit(io(i, Dir::Write, 0, i * 4096));
            complete_all(&mut a);
            b.submit(io(i, Dir::Write, 0, i * 4096));
            complete_all(&mut b);
        }
        // engine 0 mints 1, 3, 5; engine 1 mints 2, 4, 6 — disjoint
        assert_eq!(a.resync.next_epoch, 5);
        assert_eq!(b.resync.next_epoch, 6);
        assert_eq!(a.resync.required.max_over(0, 3 * 4096), 5);
        assert_eq!(b.resync.required.max_over(0, 3 * 4096), 6);
    }

    #[test]
    fn gossip_exchange_converges_fingerprints() {
        let mut a = gossip_engine(0);
        let mut b = gossip_engine(1);
        // A does real work; B is idle — their states diverge
        for i in 0..4u64 {
            a.submit(io(i, Dir::Write, 0, i * 4096));
            complete_all(&mut a);
        }
        assert_ne!(a.gossip_fingerprint(), b.gossip_fingerprint());
        // one exchange in each direction converges them
        let mut d = GossipDelta::default();
        a.export_gossip_into(&mut d);
        b.absorb_gossip(&d);
        b.export_gossip_into(&mut d);
        a.absorb_gossip(&d);
        assert_eq!(a.gossip_fingerprint(), b.gossip_fingerprint());
        let sa = a.gossip_stats().unwrap();
        let sb = b.gossip_stats().unwrap();
        assert_eq!((sa.rounds_sent, sa.rounds_absorbed), (1, 1));
        assert_eq!((sb.rounds_sent, sb.rounds_absorbed), (1, 1));
        assert!(sb.epoch_raises > 0, "B learned A's epochs: {sb:?}");
        // post-merge mints on B dominate everything A minted
        b.submit(io(9, Dir::Write, 0, 0));
        assert!(b.resync.required.max_over(0, 4096) > a.resync.next_epoch);
    }

    #[test]
    fn gossip_absorb_is_idempotent_under_duplication_and_reorder() {
        let mut a = gossip_engine(0);
        let mut b = gossip_engine(1);
        a.submit(io(1, Dir::Write, 0, 0));
        complete_all(&mut a);
        let mut d1 = GossipDelta::default();
        a.export_gossip_into(&mut d1);
        a.submit(io(2, Dir::Write, 0, 4096));
        complete_all(&mut a);
        let mut d2 = GossipDelta::default();
        a.export_gossip_into(&mut d2);
        // in-order merge of both rounds
        b.absorb_gossip(&d1);
        b.absorb_gossip(&d2);
        let fp = b.gossip_fingerprint();
        // duplicate and reordered redeliveries die at the round filter
        b.absorb_gossip(&d2);
        b.absorb_gossip(&d1);
        assert_eq!(b.gossip_fingerprint(), fp, "stale rounds changed state");
        let s = b.gossip_stats().unwrap();
        assert_eq!(s.rounds_absorbed, 2);
        assert_eq!(s.stale_rounds, 2);
        // a delta claiming to be from B itself is ignored outright
        let mut own = d2.clone();
        own.from = 1;
        own.round = 99;
        b.absorb_gossip(&own);
        assert_eq!(b.gossip_fingerprint(), fp);
    }

    #[test]
    fn gossip_state_adoption_is_lww_with_divergence_guard() {
        let mut b = gossip_engine(1);
        // a peer's versioned Dead claim for node 1 is adopted (no local
        // backlog for it)
        let dead = GossipDelta {
            from: 0,
            round: 1,
            states: vec![(1, 3, state_code(NodeState::Dead))],
            ..GossipDelta::default()
        };
        b.absorb_gossip(&dead);
        assert_eq!(b.node_state(1), Some(NodeState::Dead));
        assert_eq!(b.gossip_stats().unwrap().state_adoptions, 1);
        // diverge node 0 locally: its replica leg fails while node 1 is
        // revived so the write retires remotely
        b.on_node_up(1);
        b.submit(io(1, Dir::Write, 0, 0));
        let wrs: Vec<WorkRequest> = b.drain_all(0).wrs;
        for wr in &wrs {
            let st = if wr.node == 0 {
                WcStatus::Error
            } else {
                WcStatus::Success
            };
            b.on_wc(&wc_for(wr, st), 0);
        }
        assert_eq!(b.node_state(0), Some(NodeState::Resyncing));
        let owed = b.resync_backlog(0) > 0
            || !b.resync.repairing[0].is_empty()
            || b.resync.outstanding[0] > 0;
        assert!(owed, "node 0 is owed repairs");
        // a peer claiming node 0 is Alive at a *higher* version must not
        // win while this engine still owes node 0 repairs
        let premature = GossipDelta {
            from: 0,
            round: 2,
            states: vec![(0, 50, state_code(NodeState::Alive))],
            ..GossipDelta::default()
        };
        b.absorb_gossip(&premature);
        assert_eq!(
            b.node_state(0),
            Some(NodeState::Resyncing),
            "divergence guard: backlog pins the local state"
        );
        // draining the backlog promotes locally as usual
        let _ = complete_all_wrs(&mut b);
        assert_eq!(b.node_state(0), Some(NodeState::Alive));
    }

    #[test]
    fn gossip_missed_merge_feeds_resync_with_self_heal_filter() {
        let mut b = gossip_engine(1);
        // the peer says node 0 missed [0, 4096) at epoch 5 — but also
        // shows node 0's applied vector already at 5: stale record,
        // filtered out (no demotion, no backlog)
        let stale = GossipDelta {
            from: 0,
            round: 1,
            required: vec![(0, 4096, 5)],
            applied: vec![(0, 0, 4096, 5)],
            missed: vec![(0, 0, 4096)],
            ..GossipDelta::default()
        };
        b.absorb_gossip(&stale);
        assert_eq!(b.node_state(0), Some(NodeState::Alive));
        assert_eq!(b.resync_backlog(0), 0);
        assert_eq!(b.gossip_stats().unwrap().missed_merged, 0);
        // now the floor moves past node 0's copy and node 1 holds it:
        // the missed range is real, resync repairs it through the
        // normal pipeline
        let real = GossipDelta {
            from: 0,
            round: 2,
            required: vec![(0, 4096, 7)],
            applied: vec![(1, 0, 4096, 7)],
            missed: vec![(0, 0, 4096)],
            ..GossipDelta::default()
        };
        b.absorb_gossip(&real);
        assert_eq!(b.node_state(0), Some(NodeState::Resyncing), "demoted");
        assert_eq!(b.gossip_stats().unwrap().missed_merged, 1);
        let wrs = complete_all_wrs(&mut b);
        assert!(!wrs.is_empty(), "repair traffic flowed");
        assert!(wrs.iter().any(|w| w.node == 1), "sourced from the holder");
        assert_eq!(b.node_state(0), Some(NodeState::Alive), "repaired");
        assert_eq!(b.stats.resync_disk_surrenders, 0);
    }

    #[test]
    fn gossip_disk_log_absorbs_exactly_once_per_entry() {
        let mut b = gossip_engine(1);
        let d1 = GossipDelta {
            from: 0,
            round: 1,
            surrendered: vec![(0, 0, 4096)],
            ..GossipDelta::default()
        };
        b.absorb_gossip(&d1);
        assert_eq!(b.take_disk_surrenders(), vec![(0, 0, 4096)]);
        // the peer's log is cumulative: a later delta repeats old
        // entries, and only the new tail is consumed
        let d2 = GossipDelta {
            from: 0,
            round: 2,
            surrendered: vec![(0, 0, 4096), (1, 8192, 4096)],
            ..GossipDelta::default()
        };
        b.absorb_gossip(&d2);
        assert_eq!(b.take_disk_surrenders(), vec![(1, 8192, 4096)]);
        assert_eq!(b.gossip_stats().unwrap().disk_spans_absorbed, 2);
    }

    #[test]
    fn timeout_wc_retires_once_and_late_real_wc_is_duplicate() {
        let mut e = IoEngine::build(&EngineSpec::new(2).replicated(2).deadlines(1_000, 0));
        e.submit(io(7, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        assert_eq!(out.wrs.len(), 2, "one leg per replica");
        assert!(e.regulator().in_flight() > 0);
        assert_eq!(e.next_timer_at(), Some(1_000));

        // nothing is delivered: both legs expire at the deadline and the
        // request retires terminally (writes do not back off)
        let mut wout = WcOut::default();
        e.service_timers(1_000, &mut wout);
        assert_eq!(wout.retired.len(), 1);
        assert!(wout.retired[0].disk_fallback, "no replica confirmed it");
        assert_eq!(e.recovery_stats().timeouts, 2);
        assert_eq!(e.regulator().in_flight(), 0);
        assert_eq!(e.next_timer_at(), None, "retirement cancelled the deadlines");

        // the fabric finally delivers the real completions: both die at
        // the generation check — no double retire, no double release
        for wr in &out.wrs {
            let r = e.on_wc(&wc_for(wr, WcStatus::Success), 2_000);
            assert!(r.retired.is_empty());
            assert_eq!(r.requeued, 0);
        }
        assert_eq!(e.stats.duplicate_wcs, 2);
        assert_eq!(e.regulator().in_flight(), 0);
        assert_eq!(e.stats.window_leaks, 0);
    }

    #[test]
    fn read_timeout_backs_off_then_fails_over() {
        let mut e = IoEngine::build(&EngineSpec::new(2).replicated(2).deadlines(1_000, 2));
        e.submit(io(1, Dir::Read, 0, 0));
        let out = e.drain_all(0);
        assert_eq!(out.wrs.len(), 1, "a read has one leg");
        let first = out.wrs[0].clone();

        // expiry parks the read for its jittered backoff: window
        // released, nothing retired, nothing requeued yet
        let mut wout = WcOut::default();
        e.service_timers(1_000, &mut wout);
        assert!(wout.retired.is_empty());
        assert_eq!(wout.requeued, 0);
        assert_eq!(e.recovery_stats().timeouts, 1);
        assert_eq!(e.regulator().in_flight(), 0);

        // the release fires within (timeout/2, timeout] of the expiry
        let release = e.next_timer_at().expect("backoff release armed");
        assert!(release > 1_000 && release <= 2_000, "got {release}");
        e.service_timers(release, &mut wout);
        assert_eq!(wout.requeued, 1, "backoff release re-queued the read");

        // the retry routes to the untried replica and completes
        let out2 = e.drain_all(release);
        assert_eq!(out2.wrs.len(), 1);
        assert_ne!(out2.wrs[0].node, first.node, "failed over to the peer");
        let r = e.on_wc(&wc_for(&out2.wrs[0], WcStatus::Success), release + 10);
        assert_eq!(r.retired.len(), 1);
        assert!(r.retired[0].failed_over);
        assert!(!r.retired[0].disk_fallback);
        assert_eq!(e.regulator().in_flight(), 0);
        assert_eq!(e.stats.window_leaks, 0);

        // the original leg's real completion is a counted duplicate
        let dup = e.on_wc(&wc_for(&first, WcStatus::Success), release + 20);
        assert!(dup.retired.is_empty());
        assert_eq!(e.stats.duplicate_wcs, 1);
    }

    #[test]
    fn wedged_qp_flushes_and_recovers() {
        let mut e = IoEngine::build(&EngineSpec::new(2).replicated(2).deadlines(1_000, 0));
        // five writes, drained one at a time so each leg gets its own
        // WR; node 1's legs complete, node 0's are never delivered
        let mut held = Vec::new();
        for i in 0..5u64 {
            e.submit(io(i, Dir::Write, 0, i * 8192));
            let out = e.drain_all(i * 100);
            for wr in out.wrs {
                if wr.node == 1 {
                    e.on_wc(&wc_for(&wr, WcStatus::Success), i * 100);
                } else {
                    held.push(wr);
                }
            }
        }
        assert_eq!(held.len(), 5);

        // deadlines land at 1000..=1400; the third consecutive expiry
        // trips qp 0 into `Error`, flushing the two WRs it still holds
        let mut wout = WcOut::default();
        e.service_timers(1_200, &mut wout);
        let rec = e.recovery_stats();
        assert_eq!(rec.timeouts, 5, "3 expiries + 2 flushed");
        assert_eq!(rec.flushes, 2);
        assert_eq!(e.qps_not_ok(), 1);
        // qp 0 was node 0's only QP: the node auto-downed with it
        assert_eq!(e.node_state(0), Some(NodeState::Dead));
        // every write still retired durably via its node-1 leg
        assert_eq!(wout.retired.len(), 5);
        assert!(wout.retired.iter().all(|r| !r.disk_fallback));
        assert_eq!(e.regulator().in_flight(), 0);
        assert_eq!(e.stats.window_leaks, 0);

        // probation: Error -> Resetting after 4 timeouts, -> Ok one later
        let probe1 = e.next_timer_at().expect("probe armed");
        assert_eq!(probe1, 1_200 + 4_000);
        e.service_timers(probe1, &mut wout);
        assert_eq!(e.qps_not_ok(), 1, "still resetting");
        let probe2 = e.next_timer_at().expect("second probe armed");
        assert_eq!(probe2, probe1 + 1_000);
        e.service_timers(probe2, &mut wout);
        assert_eq!(e.qps_not_ok(), 0);
        assert_eq!(e.recovery_stats().resets, 1);
        assert_eq!(e.node_state(0), Some(NodeState::Alive), "auto-revived");

        // the recovered QP serves traffic again
        e.submit(io(100, Dir::Write, 0, 0));
        let retired = complete_all(&mut e);
        assert_eq!(retired.len(), 1);
        assert_eq!(e.regulator().in_flight(), 0);
        assert_eq!(e.next_timer_at(), None);
    }

    #[test]
    fn deadlines_off_is_zero_cost_and_timerless() {
        let mut e = engine(2, 2, None);
        for i in 0..4 {
            e.submit(io(i, Dir::Write, (i % 2) as usize, i * 4096));
        }
        complete_all(&mut e);
        assert_eq!(e.next_timer_at(), None);
        let mut wout = WcOut::default();
        wout.retired.push(RetiredIo {
            id: 9,
            disk_fallback: false,
            failed_over: false,
        });
        // service_timers still clears the reused buffer, then no-ops
        e.service_timers(u64::MAX, &mut wout);
        assert!(wout.retired.is_empty());
        assert_eq!(e.recovery_stats(), RecoveryStats::default());
        assert_eq!(e.stats.window_leaks, 0);
    }
}
